//! The §3.2 deployment story: configure TurboAngle for a NEW model with
//! 3–5 evaluation runs and zero calibration data.
//!
//!     make artifacts && cargo run --release --example config_search -- [model]

use anyhow::Result;
use turboangle::eval::{search, PplHarness};
use turboangle::runtime::{Entry, Manifest, ModelExecutor, Runtime};

fn main() -> Result<()> {
    let model = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "olmo-sim".to_string());
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    let exec = ModelExecutor::load(&rt, &manifest, &model, Entry::Eval)?;
    let h = PplHarness::new(&manifest, exec)?;

    println!("§3.2 heuristic search on {model}:");
    println!("  1. probe E4 with (256,128) and (128,256) -> pick K-dom vs V-dom");
    println!("  2. grow n_early while dPPL improves\n");

    let res = search::heuristic_search(&h, 6)?;
    for (i, s) in res.steps.iter().enumerate() {
        println!("  eval {:>2}: {:32} dPPL {:+.4}", i + 1, s.tag, s.delta_ppl);
    }
    println!(
        "\nchosen config: {} ({:.2} angle bits/element, dPPL {:+.4}, {} evals)",
        res.best.tag(),
        res.best.angle_bits_per_element(),
        res.best_delta,
        res.evals_used
    );
    assert!(res.evals_used <= 6, "the §3.2 budget is 3-5 evals + probes");

    // compare against the exhaustive sweep's pick (what Table 2 reports)
    println!("\n(for reference, the exhaustive Table-2 sweep on this model:)");
    let full = turboangle::eval::sweep::early_boost_sweep(&h, &model)?;
    println!(
        "  best {} dPPL {:+.4} at {:.2} bits",
        full.best_cfg.tag(),
        full.best_delta,
        full.best_bits
    );
    Ok(())
}
