//! Quickstart: walk the TurboAngle pipeline stage by stage (paper Fig. 1).
//!
//! Uses only the native quantizer — no artifacts needed. Prints each
//! intermediate tensor for one KV vector, then summarizes rate/error on a
//! batch, reproducing the pipeline diagram as a narrated run.
//!
//!     cargo run --release --example quickstart

use turboangle::quant::{angle, fwht, norm, packing, NormMode, QuantConfig};

fn main() {
    let d = 16usize; // small so every stage fits on screen
    let sign = fwht::test_sign_diag(d, 2026);
    let n_bins = 64u32;

    // a "KV cache entry": correlated, outlier-ish — hostile to raw quant
    let x: Vec<f32> = (0..d)
        .map(|i| (i as f32 * 0.7).sin() * if i == 3 { 6.0 } else { 1.5 })
        .collect();
    println!("x (KV vector, d={d}):\n  {}", fmt(&x));

    // Stage 1: random ±1 diagonal rotation
    println!("\nD (shared ±1 diagonal, seeded once — paper §3.1):\n  {}", fmt(&sign));
    let mut y = x.clone();
    for (v, s) in y.iter_mut().zip(&sign) {
        *v *= s;
    }
    println!("D·x:\n  {}", fmt(&y));

    // Stage 2: normalized FWHT
    fwht::fwht(&mut y);
    println!("\ny = H·D·x (normalized FWHT, O(d log d) butterfly):\n  {}", fmt(&y));

    // Stage 3: polar decomposition of consecutive pairs
    let enc = angle::encode(&x, &sign, n_bins);
    println!("\npolar pairs (r_i, theta->k_i) with n={n_bins} uniform bins:");
    println!("  r: {}", fmt(&enc.r));
    println!("  k: {:?}", enc.k);

    // Stage 4: what actually lands in memory — bit-packed angles
    let width = packing::bits_for(n_bins);
    let packed = packing::pack(&enc.k, width);
    println!(
        "\nstorage: {} angle bits/pair ({} bits total for {} pairs = {:.2} bits/element)",
        width,
        packed.len_bits(),
        enc.k.len(),
        packed.len_bits() as f64 / d as f64
    );

    // Stage 5: norm quantization (§3.3)
    let q = norm::quantize(&enc.r, NormMode::LINEAR8);
    println!(
        "norms -> 8-bit codes {:?} with fp32 window [{:.3}, {:.3}]",
        q.codes, q.vmin, q.vmax
    );

    // Stage 6: reconstruction
    let r_hat = norm::dequantize(&q, NormMode::LINEAR8);
    let x_hat = angle::decode(&r_hat, &enc.k, &sign, n_bins, false);
    println!("\nx_hat = D·H·y_hat (trig lookup + inverse FWHT):\n  {}", fmt(&x_hat));
    let mse: f32 = x
        .iter()
        .zip(&x_hat)
        .map(|(a, b)| (a - b) * (a - b))
        .sum::<f32>()
        / d as f32;
    let sig: f32 = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    println!(
        "per-element MSE {mse:.5} (signal power {sig:.3}, SNR {:.1} dB)",
        10.0 * (sig / mse).log10()
    );

    // Rate accounting on a realistic config (Eq. 1 / Eq. 3)
    println!("\n== rate accounting (Mistral-7B-like: L=32, d=128) ==");
    for (name, cfg) in [
        ("uniform K128V64 + fp32 norms", QuantConfig::paper_uniform(32)),
        (
            "E4(256,128) + K8V4-log (paper's best)",
            QuantConfig::early_boost(32, 4, 256, 128).with_k8v4_log(),
        ),
    ] {
        println!(
            "  {name:40} {:.2} angle bits, {:.2} total bits/element",
            cfg.angle_bits_per_element(),
            cfg.total_bits_per_element(128)
        );
    }
    println!("\n(16.0 bits/element is the fp16 reference -> ~2.4x compression end-to-end)");

    assert!(mse < 0.02 * sig, "roundtrip error out of spec");
    println!("\nquickstart OK");
}

fn fmt(v: &[f32]) -> String {
    v.iter()
        .map(|x| format!("{x:+.3}"))
        .collect::<Vec<_>>()
        .join(" ")
}
