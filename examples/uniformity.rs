//! The §2 angle-uniformity evidence, as a standalone figure generator.
//!
//! Prints 32-bin angle histograms (rotated vs raw) for three input
//! families, plus chi²/max-deviation stats — the data behind the paper's
//! uniformity claim AND its finite-d caveats (see DESIGN.md §6 and
//! EXPERIMENTS.md §Uniformity for the adversarial case we found).
//!
//!     cargo run --release --example uniformity

use turboangle::quant::{angle, fwht};
use turboangle::workload::Rng;

const BINS: usize = 32;
const ROWS: usize = 8192;

fn gauss(r: &mut Rng) -> f32 {
    let u1 = r.uniform().max(1e-12);
    let u2 = r.uniform();
    ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
}

fn histogram(d: usize, make_row: &mut dyn FnMut(&mut Rng, &mut [f32]), rotate: bool) -> Vec<u64> {
    let mut rng = Rng::new(4242);
    let sign = fwht::test_sign_diag(d, 7);
    let mut hist = vec![0u64; BINS];
    let mut x = vec![0.0f32; d];
    for _ in 0..ROWS {
        make_row(&mut rng, &mut x);
        let mut y = x.clone();
        if rotate {
            fwht::rotate(&mut y, &sign);
        }
        for p in 0..d / 2 {
            let theta = y[2 * p + 1].atan2(y[2 * p]);
            let t = if theta < 0.0 { theta + angle::TWO_PI } else { theta };
            hist[((t / angle::TWO_PI * BINS as f32) as usize).min(BINS - 1)] += 1;
        }
    }
    hist
}

fn stats(hist: &[u64], d: usize) -> (f64, f64) {
    let expected = (ROWS * d / 2) as f64 / BINS as f64;
    let chi2 = hist
        .iter()
        .map(|&c| (c as f64 - expected).powi(2) / expected)
        .sum();
    let maxdev = hist
        .iter()
        .map(|&c| (c as f64 / expected - 1.0).abs())
        .fold(0.0, f64::max);
    (chi2, maxdev)
}

fn bar(hist: &[u64]) -> String {
    let max = *hist.iter().max().unwrap() as f64;
    hist.iter()
        .map(|&c| {
            let lvl = (c as f64 / max * 7.0) as usize;
            ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'][lvl]
        })
        .collect()
}

fn main() {
    for d in [64usize, 128] {
        println!("==== d = {d} ====");
        let cases: Vec<(&str, Box<dyn FnMut(&mut Rng, &mut [f32])>)> = vec![
            (
                "iid gaussian (exactly uniform in theory)",
                Box::new(|r: &mut Rng, x: &mut [f32]| {
                    for v in x.iter_mut() {
                        *v = gauss(r);
                    }
                }),
            ),
            (
                "heteroscedastic + correlated (KV-like)",
                Box::new({
                    let mut scales: Vec<f32> = Vec::new();
                    move |r: &mut Rng, x: &mut [f32]| {
                        if scales.len() != x.len() {
                            scales = (0..x.len()).map(|_| (0.6 * gauss(r)).exp()).collect();
                        }
                        let common = gauss(r);
                        for (v, s) in x.iter_mut().zip(&scales) {
                            *v = (gauss(r) + 0.3 * common) * s;
                        }
                    }
                }),
            ),
            (
                "ADVERSARIAL period-2 energy (survives H·D!)",
                Box::new(|r: &mut Rng, x: &mut [f32]| {
                    for (i, v) in x.iter_mut().enumerate() {
                        *v = gauss(r) * if i % 2 == 0 { 2.0 } else { 1.0 };
                    }
                }),
            ),
        ];
        for (name, mut make) in cases {
            let rot = histogram(d, &mut *make, true);
            let raw = histogram(d, &mut *make, false);
            let (c_rot, m_rot) = stats(&rot, d);
            let (c_raw, m_raw) = stats(&raw, d);
            println!("\n  {name}");
            let (pr, pm) = (bar(&rot), m_rot * 100.0);
            println!("    rotated {pr}  chi2 {c_rot:>9.1}  maxdev {pm:>5.1}%");
            let (pr, pm) = (bar(&raw), m_raw * 100.0);
            println!("    raw     {pr}  chi2 {c_raw:>9.1}  maxdev {pm:>5.1}%");
        }
        println!();
    }
    println!(
        "note: the adversarial case shows E[y_j y_k] = (1/d) Σ H_ji H_ki x_i²\n\
         does NOT vanish for period-2 channel-energy patterns (Hadamard columns\n\
         with j^k=1 align with exactly the consecutive pairs TurboAngle uses) —\n\
         the random diagonal D cannot remove energy-pattern correlations. Real\n\
         KV activations don't have this structure; see EXPERIMENTS.md."
    );
}
