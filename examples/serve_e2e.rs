//! END-TO-END driver (EXPERIMENTS.md §E2E): load a trained simulated model,
//! serve a batched synthetic workload through the full stack — rust
//! coordinator → compressed paged KV cache → AOT prefill/decode HLOs — and
//! report latency, throughput, cache memory, and the quality cost of the
//! compression config actually used for serving.
//!
//!     make artifacts && cargo run --release --example serve_e2e
//!
//! Proves all layers compose: L1 kernels are inside the prefill/decode
//! HLOs, L2 lowered them, L3 owns batching + the compressed cache, and
//! python is nowhere on the request path.

use anyhow::Result;
use turboangle::coordinator::{Engine, EngineConfig};
use turboangle::eval::{sweep, PplHarness};
use turboangle::quant::{Mode, NormMode, QuantConfig};
use turboangle::runtime::{Entry, Manifest, ModelExecutor, Runtime};
use turboangle::workload::{self, WorkloadSpec};

const MODEL: &str = "smollm2-sim";

fn run_engine(
    manifest: &Manifest,
    rt: &Runtime,
    quant: QuantConfig,
    label: &str,
) -> Result<()> {
    let exec = ModelExecutor::load(rt, manifest, MODEL, Entry::Serve)?;
    let mut engine = Engine::new(
        exec,
        EngineConfig {
            capacity_pages: 2048,
            ..EngineConfig::new(quant)
        },
    );
    let spec = WorkloadSpec {
        n_requests: 12,
        prompt_min: 16,
        prompt_max: 60,
        gen_min: 8,
        gen_max: 24,
        seed: 7,
        sessions: 0,
        ..Default::default()
    };
    let reqs = workload::generate(&spec);
    let total_gen: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    let t0 = std::time::Instant::now();
    // stagger arrivals (Poisson-ish) to exercise the dynamic batcher
    let mut rng = workload::Rng::new(99);
    for req in reqs {
        engine.submit(req);
        // a couple of engine ticks between arrivals
        for _ in 0..rng.range(0, 3) {
            engine.tick()?;
        }
    }
    engine.run_to_completion()?;
    let wall = t0.elapsed();
    let m = &engine.metrics;
    println!("\n== {label} ==");
    println!("{}", m.report());
    println!(
        "wall {wall:?} | decode throughput {:.1} tok/s | expected {} gen tokens",
        m.tokens_generated as f64 / wall.as_secs_f64(),
        total_gen
    );
    assert_eq!(m.requests_finished, 12, "all requests must finish");
    assert_eq!(engine.memory_stats().pages_allocated, 0, "all pages freed");
    Ok(())
}

fn main() -> Result<()> {
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    let l = manifest.profile(MODEL)?.n_layers;
    let d = manifest.profile(MODEL)?.d_head;

    // Peak-memory evidence: one long sequence's compressed cache vs fp16
    println!("model: {MODEL} (mirrors {})", manifest.profile(MODEL)?.mirrors);

    // 1) serving with the paper's deployable config (uniform + K8V4-log)
    let quant = QuantConfig::paper_uniform(l).with_k8v4_log();
    println!(
        "serving config: {} — {:.2} total bits/element (fp16 = 16.0, {:.2}x compression)",
        quant.tag(),
        quant.total_bits_per_element(d),
        16.0 / quant.total_bits_per_element(d)
    );
    run_engine(&manifest, &rt, quant.clone(), "quantized serving (K8V4-log)")?;

    // 2) fp reference serving for the latency/throughput comparison
    let mut fp = QuantConfig::none(l);
    fp = fp.with_norms(NormMode::FP32, NormMode::FP32);
    fp.mode = Mode::None;
    run_engine(&manifest, &rt, fp, "fp reference serving")?;

    // 3) the quality cost of the serving config, measured by the PPL harness
    println!("\n== quality of the serving config (PPL protocol, §4.1) ==");
    let eval_exec = ModelExecutor::load(&rt, &manifest, MODEL, Entry::Eval)?;
    let h = PplHarness::new(&manifest, eval_exec)?;
    let base = h.baseline_ppl()?;
    let dq = h.delta_ppl(&quant)?;
    println!("reference PPL {base:.4}; serving config dPPL {dq:+.4}");

    // 4) K vs V asymmetry sanity (the §4.5 probe on this model)
    let rows = sweep::kv_sensitivity(&h, 4)?;
    for r in &rows {
        println!("  {:24} dPPL {:+.4}", r.variant, r.delta_ppl);
    }

    println!("\nserve_e2e OK");
    Ok(())
}
