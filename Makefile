# TurboAngle build entry points. `make artifacts` is the one python step
# (AOT train + lower, needs JAX); everything else is pure cargo.

ARTIFACTS ?= artifacts

.PHONY: all artifacts test bench smoke bench-serving smoke-serving \
        bench-fused smoke-fused profile-fused bench-prefix smoke-prefix \
        bench-latency smoke-latency bench-quality smoke-quality \
        bench-obs smoke-obs docs fmt lint analyze loom miri tsan clean

all: test

# Train the simulated profiles and lower the eval/prefill/decode HLOs +
# golden vectors into $(ARTIFACTS)/. Skips with a clear message when JAX
# is unavailable — PJRT-dependent tests and benches self-skip in that case.
artifacts:
	@if python3 -c "import jax" >/dev/null 2>&1; then \
		cd python && python3 -m compile.aot --out ../$(ARTIFACTS); \
	else \
		echo "skip: JAX unavailable — $(ARTIFACTS)/ not built;"; \
		echo "      native-quantizer tests still run; artifact-backed"; \
		echo "      tests and benches will print SKIP and pass vacuously."; \
	fi

test:
	cargo build --release
	cargo test -q

# The hot-path bench also writes BENCH_quant_hot_path.json (perf trajectory).
bench:
	cargo bench --bench quant_hot_path

smoke:
	cargo bench --bench quant_hot_path -- --smoke

# Multi-replica serving sweep (sim backend, real TCP): replicas 1/2/4,
# writes BENCH_serving_throughput.json.
bench-serving:
	cargo bench --bench serving_throughput

smoke-serving:
	cargo bench --bench serving_throughput -- --smoke

# Fused dequant-attention read path vs dense reinflation (steady +
# post-swap regimes), writes BENCH_fused_attention.json.
bench-fused:
	cargo bench --bench fused_attention

smoke-fused:
	cargo bench --bench fused_attention -- --smoke

# Profile the fused read path: cargo-flamegraph if installed, else a raw
# `perf record` of the bench binary (report with `perf report`). See README
# "Profiling the fused read path" for reading the output.
profile-fused:
	@if cargo flamegraph --version >/dev/null 2>&1; then \
		cargo flamegraph --bench fused_attention -o flamegraph-fused.svg; \
		echo "wrote flamegraph-fused.svg"; \
	elif command -v perf >/dev/null 2>&1; then \
		cargo bench --bench fused_attention --no-run; \
		BIN=$$(ls -t target/release/deps/fused_attention-* 2>/dev/null \
		       | grep -v '\.d$$' | head -n1); \
		perf record -g -o perf-fused.data "$$BIN"; \
		echo "wrote perf-fused.data — inspect with: perf report -i perf-fused.data"; \
	else \
		echo "error: neither cargo-flamegraph nor perf is installed."; \
		echo "  install one of:  cargo install flamegraph   (preferred)"; \
		echo "                   apt-get install linux-perf  (fallback)"; \
		exit 1; \
	fi

# Prefix cache: cold vs warm prefill on a shared-prefix workload (asserts
# cold/warm token bit-identity and prefix_hit_speedup > 1), writes
# BENCH_prefix_caching.json.
bench-prefix:
	cargo bench --bench prefix_caching

smoke-prefix:
	cargo bench --bench prefix_caching -- --smoke

# Chunked vs monolithic prefill on a mixed long-prompt + chat workload
# (asserts chunked/monolithic token bit-identity and p99_itl_improvement
# > 1), writes BENCH_serving_latency.json. Field docs: docs/BENCH_GLOSSARY.md.
bench-latency:
	cargo bench --bench serving_latency

smoke-latency:
	cargo bench --bench serving_latency -- --smoke

# The paper's quality loop, artifact-free: layer-group sensitivity sweep on
# the sim harness -> boost the most-sensitive half -> serve that schedule,
# asserting the achieved MemoryStats bits/element matches Eq.3 within 1%.
# Writes BENCH_quality_sweep.json. Field docs: docs/BENCH_GLOSSARY.md.
bench-quality:
	cargo bench --bench quality_sweep

smoke-quality:
	cargo bench --bench quality_sweep -- --smoke

# Observability overhead: the same serving workload with tracing off /
# sampled (stride 32) / fully instrumented (stride 1); asserts token
# bit-identity across modes, writes BENCH_obs_overhead.json plus a
# Perfetto-loadable example trace (BENCH_obs_overhead_trace.json). CI's
# bench-smoke gate asserts the measured overheads stay under the bound
# fields published in the JSON. Field docs: docs/BENCH_GLOSSARY.md.
bench-obs:
	cargo bench --bench obs_overhead

smoke-obs:
	cargo bench --bench obs_overhead -- --smoke

# Documentation gate: rustdoc clean under -D warnings (missing_docs
# included for quant/ and coordinator/) and every doc-example compiles
# and runs. CI runs the same two commands in the `docs` job.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo test --doc

fmt:
	cargo fmt --all

lint:
	cargo fmt --all -- --check
	cargo clippy -- -D warnings

# Repo-specific static analysis + exhaustive concurrency models: the four
# xtask lints (hot-path allocs, serving panics, identity-path
# nondeterminism, release-checked bounds) and the server/store protocol
# models. Suppress individual findings with `// xtask-allow(<rule>): why`.
# Invariant-by-tool matrix: docs/ANALYSIS.md. CI runs this in `analyze`.
analyze:
	cargo test -p xtask -q
	cargo xtask analyze

# Just the concurrency models; --trace prints the pinned counterexample
# schedules of the buggy variants.
loom:
	cargo xtask loom --trace

# Pointer-level UB check of the quant core under the Miri interpreter
# (nightly + `rustup component add miri`). TURBOANGLE_PROP_CASES trims
# the seeded property suites to fit the interpreter's speed.
miri:
	TURBOANGLE_PROP_CASES=8 cargo +nightly miri test --lib -- quant::

# ThreadSanitizer over the threaded server integration suite (nightly +
# `rustup component add rust-src --toolchain nightly`).
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
	cargo +nightly test -Zbuild-std --target x86_64-unknown-linux-gnu \
		--test coordinator_integration

clean:
	cargo clean
	rm -f BENCH_quant_hot_path.json BENCH_serving_throughput.json \
	      BENCH_fused_attention.json BENCH_prefix_caching.json \
	      BENCH_serving_latency.json BENCH_quality_sweep.json \
	      BENCH_obs_overhead.json BENCH_obs_overhead_trace.json \
	      flamegraph-fused.svg perf-fused.data
