//! Integration over the PJRT runtime: real artifacts, real executions.
//! Requires `make artifacts`. Uses one shared CPU client per test binary.

use turboangle::eval::PplHarness;
use turboangle::quant::{angle, fwht, Mode, QuantConfig};
use turboangle::runtime::{pjrt, tensorfile, Entry, Manifest, ModelExecutor, Runtime};

/// Both helpers return None (the calling test SKIPS, passing vacuously)
/// when the prerequisite is unavailable: artifacts come from
/// `make artifacts` (JAX), execution needs a real xla binding in place of
/// the rust/xla stub.
fn manifest() -> Option<Manifest> {
    match Manifest::discover() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP: {e} (run `make artifacts` first)");
            None
        }
    }
}

fn runtime() -> Option<Runtime> {
    match Runtime::cpu() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            None
        }
    }
}

#[test]
fn manifest_contract_complete() {
    let Some(m) = manifest() else { return };
    assert_eq!(m.profiles.len(), 7, "all seven simulated models");
    for (name, p) in &m.profiles {
        assert_eq!(&p.name, name);
        assert!(p.d_head == 64 || p.d_head == 128);
        assert_eq!(p.eval_inputs.len(), 11 + 6);
        assert_eq!(p.decode_inputs.len(), 11 + 11);
        assert!(m.path(&p.eval_hlo).exists(), "{name} eval artifact");
        assert!(m.path(&p.prefill_hlo).exists());
        assert!(m.path(&p.decode_hlo).exists());
        assert!(m.path(&p.weights).exists());
    }
    // paper layer counts preserved exactly
    assert_eq!(m.profiles["tinyllama-sim"].n_layers, 22);
    assert_eq!(m.profiles["mistral-sim"].n_layers, 32);
    assert_eq!(m.profiles["mistral-sim"].d_head, 128);
    assert_eq!(m.profiles["starcoder2-sim"].n_layers, 40);
}

#[test]
fn hlo_kernel_artifacts_match_native() {
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    for d in [64usize, 128] {
        // sign from the model weights (the real shared diagonal)
        let prof = m
            .profiles
            .values()
            .find(|p| p.d_head == d)
            .unwrap();
        let w = tensorfile::read(m.path(&prof.weights)).unwrap();
        let sign = w["sign"].as_f32().unwrap();

        let rows = 1024usize;
        let mut g = turboangle::util::prop::Gen::new(5 + d as u64);
        let x = g.f32_vec(rows * d, -3.0, 3.0);

        // encode kernel
        let enc = rt.load(m.path(&format!("kernels.encode.d{d}.hlo.txt"))).unwrap();
        let args = [
            pjrt::lit_f32(&[rows, d], &x).unwrap(),
            pjrt::lit_f32(&[d], &sign).unwrap(),
            pjrt::lit_scalar_f32(128.0),
        ];
        let out = enc.run(&args.iter().collect::<Vec<_>>()).unwrap();
        let hr = pjrt::to_f32(&out[0]).unwrap();
        let hk = pjrt::to_f32(&out[1]).unwrap();
        let half = d / 2;
        let mut mismatch = 0;
        for row in 0..rows {
            let e = angle::encode(&x[row * d..(row + 1) * d], &sign, 128);
            for i in 0..half {
                assert!((e.r[i] - hr[row * half + i]).abs() < 1e-3);
                mismatch += (e.k[i] as f32 != hk[row * half + i]) as usize;
            }
        }
        assert!(mismatch <= rows * half / 500, "d={d}: {mismatch} bin mismatches");

        // decode kernel closes the loop
        let dec = rt.load(m.path(&format!("kernels.decode.d{d}.hlo.txt"))).unwrap();
        let args = [
            pjrt::lit_f32(&[rows, half], &hr).unwrap(),
            pjrt::lit_f32(&[rows, half], &hk).unwrap(),
            pjrt::lit_f32(&[d], &sign).unwrap(),
            pjrt::lit_scalar_f32(128.0),
        ];
        let out = dec.run(&args.iter().collect::<Vec<_>>()).unwrap();
        let xh = pjrt::to_f32(&out[0]).unwrap();
        for row in 0..rows.min(64) {
            let native = angle::decode(
                &hr[row * half..(row + 1) * half]
                    .iter()
                    .cloned()
                    .collect::<Vec<_>>(),
                &hk[row * half..(row + 1) * half]
                    .iter()
                    .map(|&v| v as u16)
                    .collect::<Vec<_>>(),
                &sign,
                128,
                false,
            );
            for (a, b) in native.iter().zip(&xh[row * d..(row + 1) * d]) {
                assert!((a - b).abs() < 1e-3);
            }
        }

        // fwht kernel is orthonormal on-device
        let fw = rt.load(m.path(&format!("kernels.fwht.d{d}.hlo.txt"))).unwrap();
        let args = [pjrt::lit_f32(&[rows, d], &x).unwrap()];
        let out = fw.run(&args.iter().collect::<Vec<_>>()).unwrap();
        let y = pjrt::to_f32(&out[0]).unwrap();
        for row in 0..rows.min(64) {
            let mut native = x[row * d..(row + 1) * d].to_vec();
            fwht::fwht(&mut native);
            for (a, b) in native.iter().zip(&y[row * d..(row + 1) * d]) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }
}

#[test]
fn eval_modes_ordering_sane() {
    // On a trained model: no-quant <= angle(high bins) <= angle(low bins)
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let exec = ModelExecutor::load(&rt, &m, "smollm2-sim", Entry::Eval).unwrap();
    let h = PplHarness::new(&m, exec).unwrap();
    let l = h.n_layers();
    let base = h.baseline_ppl().unwrap();
    assert!(base > 1.0 && base < 50.0, "trained model PPL sane: {base}");
    let hi = h.ppl(&QuantConfig::uniform(l, 512, 512)).unwrap();
    let lo = h.ppl(&QuantConfig::uniform(l, 8, 8)).unwrap();
    assert!(hi - base < 0.05, "512 bins nearly lossless: {hi} vs {base}");
    assert!(lo > hi + 0.05, "8 bins clearly worse: {lo} vs {hi}");
    // centered-bin ablation should not be catastrophically different
    let mut c = QuantConfig::paper_uniform(l);
    c.mode = Mode::AngleCentered;
    let cent = h.ppl(&c).unwrap();
    assert!((cent - base).abs() < 0.05);
}

#[test]
fn eval_scalar_baselines_execute() {
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let exec = ModelExecutor::load(&rt, &m, "smollm2-sim", Entry::Eval).unwrap();
    let h = PplHarness::new(&m, exec).unwrap();
    let l = h.n_layers();
    let base = h.baseline_ppl().unwrap();
    for mode in [Mode::TqSymG4, Mode::Kivi, Mode::KvQuant] {
        let d8 = h.ppl(&QuantConfig::scalar_baseline(l, mode, 8)).unwrap();
        let d3 = h.ppl(&QuantConfig::scalar_baseline(l, mode, 3)).unwrap();
        assert!(d8.is_finite() && d3.is_finite(), "{mode:?} finite");
        assert!(d8 - base < 0.2, "{mode:?} 8-bit near-lossless: {d8} vs {base}");
        assert!(d3 >= d8 - 0.01, "{mode:?} 3-bit not better than 8-bit");
    }
}

#[test]
fn prefill_then_decode_consistent_with_eval_forward() {
    // greedy continuation via serving path == teacher-forced argmax:
    // run prefill + one decode, then check the decode logits argmax matches
    // a second prefill over the extended prompt.
    let Some(m) = manifest() else { return };
    let Some(rt) = runtime() else { return };
    let exec = ModelExecutor::load(&rt, &m, "smollm2-sim", Entry::All).unwrap();
    let cfg = QuantConfig::paper_uniform(exec.profile.n_layers);
    let b = m.serve.batch;
    let tp = m.serve.prefill_len;
    let (l, _, h_n, tmax, half) = exec.cache_dims();
    let vocab = exec.profile.vocab;

    let prompt: Vec<i32> = "the wodu zatu vebo ki"
        .bytes()
        .map(|c| c as i32)
        .collect();
    let plen = prompt.len();
    let mut tokens = vec![258i32; b * tp];
    tokens[..plen].copy_from_slice(&prompt);
    let mut lengths = vec![1i32; b];
    lengths[0] = plen as i32;
    let out = exec.run_prefill(&tokens, &lengths, &cfg).unwrap();
    let t1 = argmax(&out.logits[..vocab]);

    // place prefill cache into dense buffers, decode one step
    let n = l * b * h_n * tmax * half;
    let (mut kr, mut ki, mut vr, mut vi) =
        (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
    for li in 0..l {
        for hh in 0..h_n {
            for t in 0..plen {
                let src = (((li * b) * h_n + hh) * tp + t) * half;
                let dst = (((li * b) * h_n + hh) * tmax + t) * half;
                kr[dst..dst + half].copy_from_slice(&out.kr[src..src + half]);
                ki[dst..dst + half].copy_from_slice(&out.ki[src..src + half]);
                vr[dst..dst + half].copy_from_slice(&out.vr[src..src + half]);
                vi[dst..dst + half].copy_from_slice(&out.vi[src..src + half]);
            }
        }
    }
    let mut tok = vec![0i32; b];
    tok[0] = t1;
    let mut pos = vec![0i32; b];
    pos[0] = plen as i32;
    let dec = exec.run_decode(&tok, &pos, &cfg, &kr, &ki, &vr, &vi).unwrap();
    let t2_decode = argmax(&dec.logits[..vocab]);

    // reference: prefill over prompt + t1
    let mut tokens2 = vec![258i32; b * tp];
    tokens2[..plen].copy_from_slice(&prompt);
    tokens2[plen] = t1;
    let mut lengths2 = vec![1i32; b];
    lengths2[0] = (plen + 1) as i32;
    let out2 = exec.run_prefill(&tokens2, &lengths2, &cfg).unwrap();
    let t2_prefill = argmax(&out2.logits[..vocab]);

    assert_eq!(
        t2_decode, t2_prefill,
        "decode-over-compressed-cache disagrees with prefill continuation"
    );
}

fn argmax(xs: &[f32]) -> i32 {
    let mut bi = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            bi = i;
        }
    }
    bi as i32
}
