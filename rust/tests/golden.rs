//! Golden-vector cross-validation: the native rust quantizer must match
//! the python oracle (compile.kernels.ref) on vectors emitted at
//! `make artifacts` time. Requires artifacts/.

use turboangle::quant::{angle, baseline, fwht, norm, NormMode};
use turboangle::runtime::tensorfile;

/// Golden vectors are emitted by `make artifacts` (requires JAX). When they
/// are absent the tests SKIP (pass vacuously) rather than fail: the native
/// quantizer is still covered by unit tests and proptests; only the
/// cross-validation against the python oracle needs the files.
fn golden(d: usize) -> Option<std::collections::BTreeMap<String, tensorfile::Tensor>> {
    let dir = std::env::var("TURBOANGLE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let path = format!("{dir}/golden/golden_d{d}.tang");
    match tensorfile::read(&path) {
        Ok(t) => Some(t),
        Err(e) => {
            eprintln!("SKIP golden d={d}: {path}: {e} (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn rotate_matches_oracle() {
    for d in [64usize, 128] {
        let Some(g) = golden(d) else { continue };
        let x = g["x"].as_f32().unwrap();
        let sign = g["sign"].as_f32().unwrap();
        let want = g["rotated"].as_f32().unwrap();
        let rows = g["x"].shape[0];
        for r in 0..rows {
            let mut y = x[r * d..(r + 1) * d].to_vec();
            fwht::rotate(&mut y, &sign);
            for (a, b) in y.iter().zip(&want[r * d..(r + 1) * d]) {
                assert!((a - b).abs() < 1e-4, "d={d} row={r}");
            }
        }
    }
}

#[test]
fn encode_decode_matches_oracle_all_bins() {
    for d in [64usize, 128] {
        let Some(g) = golden(d) else { continue };
        let x = g["x"].as_f32().unwrap();
        let sign = g["sign"].as_f32().unwrap();
        let rows = g["x"].shape[0];
        let half = d / 2;
        for n in [48u32, 64, 128, 256] {
            let want_r = g[&format!("r_n{n}")].as_f32().unwrap();
            let want_k = g[&format!("k_n{n}")].as_f32().unwrap();
            let want_dec = g[&format!("dec_n{n}")].as_f32().unwrap();
            let want_decc = g[&format!("decc_n{n}")].as_f32().unwrap();
            let mut mismatches = 0usize;
            for row in 0..rows {
                let e = angle::encode(&x[row * d..(row + 1) * d], &sign, n);
                for i in 0..half {
                    assert!((e.r[i] - want_r[row * half + i]).abs() < 1e-3);
                    mismatches += (e.k[i] as f32 != want_k[row * half + i]) as usize;
                }
                let dec = angle::decode(&e.r, &e.k, &sign, n, false);
                let decc = angle::decode(&e.r, &e.k, &sign, n, true);
                for i in 0..d {
                    assert!((dec[i] - want_dec[row * d + i]).abs() < 1e-2);
                    assert!((decc[i] - want_decc[row * d + i]).abs() < 1e-2);
                }
            }
            // f32 boundary ties may flip the rare bin; must be ~0
            assert!(mismatches <= rows * half / 100, "d={d} n={n}: {mismatches}");
        }
    }
}

#[test]
fn norm_quant_matches_oracle() {
    for d in [64usize, 128] {
        let Some(g) = golden(d) else { continue };
        let r = g["r_n64"].as_f32().unwrap();
        let rows = g["r_n64"].shape[0];
        let half = d / 2;
        for (name, mode) in [
            ("normq_b8_log0", NormMode::LINEAR8),
            ("normq_b4_log1", NormMode::LOG4),
            ("normq_b4_log0", NormMode { bits: 4, log_space: false }),
        ] {
            let want = g[name].as_f32().unwrap();
            for row in 0..rows {
                let rq = norm::quant_dequant(&r[row * half..(row + 1) * half], mode);
                for (a, b) in rq.iter().zip(&want[row * half..(row + 1) * half]) {
                    assert!(
                        (a - b).abs() / b.abs().max(1e-3) < 1e-2,
                        "d={d} {name} row={row}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn tq_baseline_matches_oracle() {
    for d in [64usize, 128] {
        let Some(g) = golden(d) else { continue };
        let x = g["x"].as_f32().unwrap();
        let sign = g["sign"].as_f32().unwrap();
        let rows = g["x"].shape[0];
        for (name, bits) in [("tq4", 4u32), ("tq3", 3)] {
            let want = g[name].as_f32().unwrap();
            for row in 0..rows {
                let got = baseline::tq_scalar_g(&x[row * d..(row + 1) * d], &sign, bits, 4);
                for (a, b) in got.iter().zip(&want[row * d..(row + 1) * d]) {
                    assert!((a - b).abs() < 1e-3, "d={d} {name} row={row}");
                }
            }
        }
    }
}

#[test]
fn tensorfile_rust_write_python_layout() {
    // round-trip through our writer matches the reader (same format the
    // python side writes; parse() is layout-compatible by construction)
    use std::collections::BTreeMap;
    let mut m = BTreeMap::new();
    m.insert(
        "w".to_string(),
        tensorfile::Tensor::from_f32(&[3, 2], &[1., 2., 3., 4., 5., 6.]),
    );
    let p = std::env::temp_dir().join("golden_rt.tang");
    tensorfile::write(&p, &m).unwrap();
    let back = tensorfile::read(&p).unwrap();
    assert_eq!(back["w"].shape, vec![3, 2]);
    assert_eq!(back["w"].as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
}
