//! Property-based tests (util::prop) over the quantizer, packing, rate
//! accounting, and coordinator policies — the invariants DESIGN.md §8 lists.

use turboangle::coordinator::batcher::{Admission, BatchPolicy, DynamicBatcher};
use turboangle::coordinator::kv_manager::{PageId, PagedKvCache, TileScratch};
use turboangle::coordinator::prefix_cache::PrefixCache;
use turboangle::coordinator::router::{prefix_fingerprint, RoutePolicy, Router};
use turboangle::coordinator::session::Request;
use turboangle::coordinator::Histogram;
use turboangle::quant::packing::{
    bits_for, pack, unpack, unpack_codes_range_into, unpack_f32_range_into, BitCursor, BitVec,
};
use turboangle::quant::{
    angle, baseline, batch, fwht, norm, KernelKind, Mode, NormMode, QuantConfig,
};
use turboangle::util::prop::{run_cases, Gen};

const DIMS: [usize; 5] = [4, 16, 32, 64, 128];
const BIN_SET: [u32; 8] = [3, 8, 31, 48, 56, 64, 128, 512];

#[test]
fn prop_fwht_self_inverse_and_isometric() {
    run_cases(200, |g| {
        let d = *g.choice(&DIMS);
        let x = g.f32_vec(d, -5.0, 5.0);
        let mut y = x.clone();
        fwht::fwht(&mut y);
        let n0: f32 = x.iter().map(|v| v * v).sum();
        let n1: f32 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() <= 1e-3 * n0.max(1.0), "norm not preserved");
        fwht::fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-4, "not self-inverse");
        }
    });
}

#[test]
fn prop_encode_decode_error_bound() {
    // ||x - x̂|| <= ||x|| * 2π/n for left-edge decode, any input, any n
    run_cases(150, |g| {
        let d = *g.choice(&DIMS);
        let n = *g.choice(&BIN_SET);
        let sign = fwht::test_sign_diag(d, g.u64());
        let x = g.f32_vec(d, -8.0, 8.0);
        let xq = angle::quant_dequant(&x, &sign, n, false);
        let err: f32 = x.iter().zip(&xq).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt();
        let nrm: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(
            err <= nrm * angle::TWO_PI / n as f32 + 1e-3,
            "d={d} n={n} err={err} bound={}",
            nrm * angle::TWO_PI / n as f32
        );
    });
}

#[test]
fn prop_encode_preserves_pair_norms() {
    run_cases(100, |g| {
        let d = *g.choice(&DIMS);
        let n = *g.choice(&BIN_SET);
        let sign = fwht::test_sign_diag(d, g.u64());
        let x = g.f32_vec(d, -4.0, 4.0);
        let e0 = angle::encode(&x, &sign, n);
        let xq = angle::decode(&e0.r, &e0.k, &sign, n, g.bool());
        let e1 = angle::encode(&xq, &sign, n);
        for (a, b) in e0.r.iter().zip(&e1.r) {
            assert!((a - b).abs() < 1e-3, "pair norm changed");
        }
    });
}

#[test]
fn prop_packing_roundtrip_any_width() {
    run_cases(300, |g| {
        let n = *g.choice(&BIN_SET);
        let width = bits_for(n);
        let len = g.usize_in(0, 600);
        let codes: Vec<u16> = (0..len).map(|_| (g.u64() % n as u64) as u16).collect();
        let bv = pack(&codes, width);
        assert_eq!(unpack(&bv, len, width), codes);
        // bit-tightness: stored bits == len * width, rounded to u64 words
        assert_eq!(bv.len_bits(), len * width as usize);
        assert!(bv.storage_bytes() <= (len * width as usize).div_ceil(64) * 8);
    });
}

#[test]
fn prop_bitvec_roundtrip_all_widths_with_cursor() {
    // every width 1..=16, random streams with forced max-value codes (all
    // bits set) and lengths that cross u64 word boundaries; the sequential
    // BitCursor must agree with random-access get from any start
    run_cases(250, |g| {
        let width = g.u32_in(1, 16);
        let len = g.usize_in(0, 500);
        let max = ((1u64 << width) - 1) as u16;
        let mut codes: Vec<u16> = (0..len).map(|_| (g.u64() & max as u64) as u16).collect();
        if len > 0 {
            let i = g.usize_in(0, len - 1);
            codes[i] = max;
            codes[len - 1] = max;
        }
        let bv = pack(&codes, width);
        assert_eq!(unpack(&bv, len, width), codes, "w={width} len={len}");
        assert_eq!(bv.len_bits(), len * width as usize);
        if len > 0 {
            let start = g.usize_in(0, len - 1);
            let mut cur = BitCursor::new(&bv, start, width);
            for (idx, &want) in codes.iter().enumerate().skip(start) {
                assert_eq!(cur.next(width), want as u32, "w={width} idx={idx}");
            }
        }
    });
}

#[test]
fn prop_bulk_unpack_matches_sequential_cursor() {
    // the bulk word-window unpacker behind the Simd kernel must yield
    // exactly what sequential BitCursor reads yield — every width 1..=16,
    // random sub-ranges (mid-word starts, word-straddling codes, forced
    // all-ones values), and both the u16 and f32 sinks
    run_cases(250, |g| {
        let width = g.u32_in(1, 16);
        let len = g.usize_in(1, 600);
        let max = ((1u64 << width) - 1) as u16;
        let mut codes: Vec<u16> = (0..len).map(|_| (g.u64() & max as u64) as u16).collect();
        codes[g.usize_in(0, len - 1)] = max;
        codes[len - 1] = max;
        let bv = pack(&codes, width);
        let start = g.usize_in(0, len - 1);
        let n = g.usize_in(0, len - start);
        let mut cur = BitCursor::new(&bv, start, width);
        let want: Vec<u16> = (0..n).map(|_| cur.next(width) as u16).collect();
        let mut got = vec![0u16; n];
        unpack_codes_range_into(&bv, start, width, &mut got);
        assert_eq!(got, want, "w={width} start={start} n={n}");
        let mut got_f = vec![0.0f32; n];
        unpack_f32_range_into(&bv, start, width, &mut got_f);
        for (f, w) in got_f.iter().zip(&want) {
            assert_eq!(*f, *w as f32, "w={width} start={start} n={n}");
        }
    });
}

#[test]
fn prop_oversized_codes_truncate_without_smearing() {
    // regression for the release-mode push() bug: stray high bits must be
    // masked off, never ORed into neighboring codes
    run_cases(200, |g| {
        let width = g.u32_in(1, 15);
        let len = g.usize_in(1, 200);
        let mask = ((1u64 << width) - 1) as u32;
        let raw: Vec<u32> = (0..len)
            .map(|_| {
                let c = (g.u64() as u32) & mask;
                if g.bool() {
                    c | ((g.u64() as u32) << width) // garbage above the width
                } else {
                    c
                }
            })
            .collect();
        let mut bv = BitVec::with_capacity(len, width);
        for &c in &raw {
            bv.push(c, width);
        }
        for (i, &c) in raw.iter().enumerate() {
            assert_eq!(bv.get(i, width), c & mask, "w={width} idx={i}");
        }
    });
}

#[test]
fn prop_norm_quant_monotone_and_bounded() {
    run_cases(200, |g| {
        let len = g.usize_in(2, 128);
        let bits = g.u32_in(2, 8) as u8;
        let log = g.bool();
        let mode = NormMode { bits, log_space: log };
        let r = g.f32_vec(len, 0.01, 20.0);
        let q = norm::quantize(&r, mode);
        let deq = norm::dequantize(&q, mode);
        let lo = r.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for v in &deq {
            assert!(*v >= lo - 1e-3 && *v <= hi * 1.001 + 1e-3, "out of window");
        }
        // monotone: sorting inputs sorts the codes
        let mut idx: Vec<usize> = (0..len).collect();
        idx.sort_by(|&a, &b| r[a].partial_cmp(&r[b]).unwrap());
        for w in idx.windows(2) {
            assert!(q.codes[w[0]] <= q.codes[w[1]], "codes not monotone");
        }
    });
}

#[test]
fn prop_encode_batch_bit_identical_to_rowwise() {
    // the batched slab API must be indistinguishable from row-by-row
    // encode_into for ANY shape — bins, sign diagonal, and bit patterns
    // included (golden equivalence is inherited through this identity)
    run_cases(60, |g| {
        let d = *g.choice(&DIMS);
        let n = *g.choice(&BIN_SET);
        let rows = g.usize_in(1, 200);
        let half = d / 2;
        let sign = fwht::test_sign_diag(d, g.u64());
        let x = g.f32_vec(rows * d, -6.0, 6.0);
        let (mut rb, mut kb) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
        batch::encode_batch(&x, &sign, n, &mut rb, &mut kb);
        let mut scratch = vec![0.0f32; d];
        let (mut r1, mut k1) = (vec![0.0f32; half], vec![0u16; half]);
        for row in 0..rows {
            let xr = &x[row * d..(row + 1) * d];
            angle::encode_into(xr, &sign, n, &mut scratch, &mut r1, &mut k1);
            assert_eq!(&rb[row * half..(row + 1) * half], &r1[..], "r row {row}");
            assert_eq!(&kb[row * half..(row + 1) * half], &k1[..], "k row {row}");
        }
    });
}

#[test]
fn prop_decode_batch_bit_identical_to_rowwise() {
    run_cases(60, |g| {
        let d = *g.choice(&DIMS);
        let n = *g.choice(&BIN_SET);
        let rows = g.usize_in(1, 200);
        let centered = g.bool();
        let half = d / 2;
        let sign = fwht::test_sign_diag(d, g.u64());
        let x = g.f32_vec(rows * d, -6.0, 6.0);
        let (mut rb, mut kb) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
        batch::encode_batch_serial(&x, &sign, n, &mut rb, &mut kb);
        let mut out = vec![0.0f32; rows * d];
        batch::decode_batch(&rb, &kb, &sign, n, centered, &mut out);
        let mut want = vec![0.0f32; d];
        for row in 0..rows {
            angle::decode_into(
                &rb[row * half..(row + 1) * half],
                &kb[row * half..(row + 1) * half],
                &sign,
                n,
                centered,
                &mut want,
            );
            assert_eq!(&out[row * d..(row + 1) * d], &want[..], "row {row}");
        }
    });
}

#[test]
fn prop_batch_parallel_equals_serial() {
    // the rayon fan-out and the single-thread loop must agree to the bit
    // regardless of row count (crossing the dispatch threshold or not)
    run_cases(40, |g| {
        let d = *g.choice(&DIMS);
        let n = *g.choice(&BIN_SET);
        let rows = g.usize_in(1, 400);
        let half = d / 2;
        let sign = fwht::test_sign_diag(d, g.u64());
        let x = g.f32_vec(rows * d, -6.0, 6.0);
        let (mut rs, mut ks) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
        let (mut rp, mut kp) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
        batch::encode_batch_serial(&x, &sign, n, &mut rs, &mut ks);
        batch::encode_batch_parallel(&x, &sign, n, &mut rp, &mut kp);
        assert_eq!(rs, rp, "encode norms diverged");
        assert_eq!(ks, kp, "encode bins diverged");
        let lut = angle::TrigLut::new(n, g.bool());
        let (mut os, mut op) = (vec![0.0f32; rows * d], vec![0.0f32; rows * d]);
        batch::decode_batch_serial(&rs, &ks, &sign, &lut, &mut os);
        batch::decode_batch_parallel(&rp, &kp, &sign, &lut, &mut op);
        assert_eq!(os, op, "decode diverged");
    });
}

#[test]
fn prop_tq_more_bits_never_worse() {
    run_cases(60, |g| {
        let d = *g.choice(&[16usize, 64, 128]);
        let sign = fwht::test_sign_diag(d, g.u64());
        let x = g.f32_vec(d, -3.0, 3.0);
        let mse = |b: u32| -> f32 {
            baseline::tq_scalar_g(&x, &sign, b, 4)
                .iter()
                .zip(&x)
                .map(|(a, b)| (a - b).powi(2))
                .sum::<f32>()
        };
        assert!(mse(8) <= mse(4) + 1e-4);
        assert!(mse(4) <= mse(2) + 1e-4);
    });
}

#[test]
fn prop_rate_accounting_identities() {
    run_cases(200, |g| {
        let l = g.usize_in(1, 48);
        let n_early = g.usize_in(0, l);
        let cfg = QuantConfig::early_boost(l, n_early, 256, 128);
        // Eq.1 via explicit sum
        let expect: f64 = cfg
            .layers
            .iter()
            .map(|b| ((b.n_k as f64).log2() + (b.n_v as f64).log2()) / 4.0)
            .sum::<f64>()
            / l as f64;
        assert!((cfg.angle_bits_per_element() - expect).abs() < 1e-12);
        // boost never decreases the rate; uniform is the floor
        let uni = QuantConfig::paper_uniform(l);
        assert!(cfg.angle_bits_per_element() >= uni.angle_bits_per_element() - 1e-12);
        // Eq.3 dominates Eq.1 (norm bits are non-negative)
        for d in [64usize, 128] {
            assert!(
                cfg.clone().with_k8v4_log().total_bits_per_element(d)
                    > cfg.angle_bits_per_element()
            );
        }
        // physical storage within 1 byte/token of the idealized Eq.3 rate
        let cfgq = cfg.with_k8v4_log();
        for d in [64usize, 128] {
            let ideal_bits = cfgq.total_bits_per_element(0usize.max(d)) * d as f64 * 2.0;
            let phys_bits = (cfgq.stored_bytes_per_token_layer(0, d, 1) * 8) as f64;
            // stored uses ceil(log2 n) not log2 n and per-layer-0 bins;
            // allow the packing slack
            assert!(
                phys_bits <= ideal_bits + d as f64,
                "physical {phys_bits} vs ideal {ideal_bits}"
            );
        }
    });
}

#[test]
fn prop_batcher_never_exceeds_slots_and_preserves_fifo() {
    run_cases(200, |g| {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        let n = g.usize_in(0, 20);
        for i in 0..n {
            b.submit(Request::new(i as u64, vec![1], 4));
        }
        let free = g.usize_in(0, 8);
        let batch = b.take_batch(free, |_| Admission::Admit);
        assert!(batch.admitted.len() <= free);
        assert!(batch.admitted.len() <= n);
        assert!(batch.rejected.is_empty());
        for (i, r) in batch.admitted.iter().enumerate() {
            assert_eq!(r.id, i as u64, "FIFO violated");
        }
        assert_eq!(b.pending(), n - batch.admitted.len());
    });
}

#[test]
fn prop_batcher_rejects_never_block_admissible_tail() {
    // capacity-impossible requests are popped and returned, so whatever
    // fits behind them is still admitted in the same pass (no starvation)
    run_cases(200, |g| {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        let n = g.usize_in(1, 20);
        // mark a random subset as impossible via max_new_tokens == 999
        let mut impossible = 0;
        for i in 0..n {
            let doomed = g.bool();
            impossible += doomed as usize;
            b.submit(Request::new(i as u64, vec![1], if doomed { 999 } else { 4 }));
        }
        let batch = b.take_batch(n, |r| {
            if r.max_new_tokens == 999 {
                Admission::Reject
            } else {
                Admission::Admit
            }
        });
        assert_eq!(batch.rejected.len(), impossible);
        assert_eq!(batch.admitted.len(), n - impossible);
        assert_eq!(b.pending(), 0);
        // relative FIFO order survives within each class
        for w in batch.admitted.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        for w in batch.rejected.windows(2) {
            assert!(w[0].id < w[1].id);
        }
    });
}

#[test]
fn prop_router_load_conservation() {
    run_cases(100, |g| {
        let replicas = g.usize_in(1, 8);
        let policy = *g.choice(&[
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastLoaded,
            RoutePolicy::SessionAffinity,
        ]);
        let mut r = Router::new(replicas, policy);
        let mut outstanding = Vec::new();
        let mut completed_any = false;
        for _ in 0..g.usize_in(0, 100) {
            if g.bool() || outstanding.is_empty() {
                let key = if g.bool() { Some(g.u64() % 10) } else { None };
                outstanding.push(r.route(key));
            } else {
                let i = g.usize_in(0, outstanding.len() - 1);
                r.complete(outstanding.swap_remove(i));
                completed_any = true;
            }
        }
        let total: usize = r.loads().iter().sum();
        assert_eq!(total, outstanding.len(), "load accounting drifted");
        // least-loaded balance bound — only guaranteed when no completion
        // skewed the loads mid-stream (completions can empty one replica)
        if policy == RoutePolicy::LeastLoaded && !outstanding.is_empty() && !completed_any {
            let max = *r.loads().iter().max().unwrap();
            let min = *r.loads().iter().min().unwrap();
            assert!(max - min <= 1, "pure least-loaded fills evenly");
        }
    });
}

#[test]
fn prop_session_affinity_stable_under_load_churn() {
    // a session key's replica never changes, no matter how routing and
    // completion churn the load vector around it
    run_cases(150, |g| {
        let replicas = g.usize_in(1, 8);
        let mut r = Router::new(replicas, RoutePolicy::SessionAffinity);
        let mut first: std::collections::HashMap<u64, usize> = std::collections::HashMap::new();
        let mut outstanding = Vec::new();
        for _ in 0..g.usize_in(1, 200) {
            if g.bool() || outstanding.is_empty() {
                let key = g.u64() % 12;
                let picked = r.route(Some(key));
                let expect = *first.entry(key).or_insert(picked);
                assert_eq!(picked, expect, "affinity broke for key {key}");
                outstanding.push(picked);
            } else {
                let i = g.usize_in(0, outstanding.len() - 1);
                r.complete(outstanding.swap_remove(i));
            }
        }
    });
}

#[test]
fn prop_least_loaded_never_picks_strictly_more_loaded() {
    run_cases(150, |g| {
        let replicas = g.usize_in(1, 8);
        let mut r = Router::new(replicas, RoutePolicy::LeastLoaded);
        let mut outstanding = Vec::new();
        for _ in 0..g.usize_in(1, 200) {
            if g.bool() || outstanding.is_empty() {
                let min_before = *r.loads().iter().min().unwrap();
                let picked = r.route(None);
                // load of `picked` *before* routing is its load now minus 1
                assert_eq!(
                    r.loads()[picked] - 1,
                    min_before,
                    "least-loaded picked a strictly more-loaded replica"
                );
                outstanding.push(picked);
            } else {
                let i = g.usize_in(0, outstanding.len() - 1);
                r.complete(outstanding.swap_remove(i));
            }
        }
    });
}

#[test]
fn prop_prefix_ring_deterministic_per_fingerprint() {
    // the fingerprint→replica map is a pure function of (tokens, fleet
    // size): two routers of equal size agree, repeated lookups agree, and
    // an idle fleet routes exactly to the ring target (no spurious spill)
    run_cases(120, |g| {
        let replicas = g.usize_in(1, 8);
        let bound = g.usize_in(0, 4);
        let mut r1 = Router::new(replicas, RoutePolicy::Prefix { imbalance_bound: bound });
        let r2 = Router::new(replicas, RoutePolicy::Prefix { imbalance_bound: bound });
        let page = g.usize_in(1, 16);
        for _ in 0..20 {
            let len = page + g.usize_in(0, 8);
            let tokens: Vec<i32> = (0..len).map(|_| (g.u64() % 512) as i32).collect();
            let fp = prefix_fingerprint(&tokens, page).expect("window is full");
            assert_eq!(
                prefix_fingerprint(&tokens, page),
                Some(fp),
                "fingerprint must be deterministic"
            );
            assert_eq!(r1.target_of(fp), r2.target_of(fp), "equal rings diverged");
            assert_eq!(r1.target_of(fp), r1.target_of(fp), "lookup not stable");
            // idle fleet (all loads 0): min + bound is never exceeded, so
            // the route IS the ring target
            let picked = r1.route(Some(fp));
            assert_eq!(picked, r1.target_of(fp), "idle fleet must not spill");
            r1.complete(picked);
        }
    });
}

#[test]
fn prop_prefix_ring_rebalance_is_bounded_and_directional() {
    // growing the fleet from n to n+1 replicas only ADDS ring points, so
    // a key either keeps its target or moves onto the NEW replica — and
    // only about 1/(n+1) of keys may move at all
    run_cases(60, |g| {
        let n = g.usize_in(1, 7);
        let old = Router::new(n, RoutePolicy::Prefix { imbalance_bound: 0 });
        let new = Router::new(n + 1, RoutePolicy::Prefix { imbalance_bound: 0 });
        let k = 256usize;
        let mut moved = 0usize;
        for _ in 0..k {
            let fp = g.u64();
            let (a, b) = (old.target_of(fp), new.target_of(fp));
            if a != b {
                assert_eq!(
                    b, n,
                    "a moved key must land on the new replica, not shuffle among the old ones"
                );
                moved += 1;
            }
        }
        // expected moved share is 1/(n+1); 16 virtual nodes keep the
        // realized share near it — generous slack covers vnode placement
        // and key-sampling noise
        let bound = k as f64 * (2.5 / (n as f64 + 1.0) + 0.10);
        assert!(
            (moved as f64) <= bound,
            "moved {moved}/{k} keys growing {n}->{} (bound {bound:.0})",
            n + 1
        );
    });
}

#[test]
fn prop_prefix_spill_never_exceeds_imbalance_bound() {
    // whatever the churn, a prefix route lands on a replica whose
    // pre-route load sits within `imbalance_bound` of the fleet minimum:
    // the home replica when allowed, the least-loaded one otherwise
    run_cases(150, |g| {
        let replicas = g.usize_in(1, 6);
        let bound = g.usize_in(0, 5);
        let mut r = Router::new(replicas, RoutePolicy::Prefix { imbalance_bound: bound });
        let mut outstanding = Vec::new();
        for _ in 0..g.usize_in(1, 200) {
            if g.bool() || outstanding.is_empty() {
                // few hot fingerprints, so home replicas actually overload
                let fp = (g.u64() % 6).wrapping_mul(0x9E3779B97F4A7C15);
                let min_before = *r.loads().iter().min().unwrap();
                let picked = r.route(Some(fp));
                assert!(
                    r.loads()[picked] - 1 <= min_before + bound,
                    "routed to pre-route load {} with min {min_before}, bound {bound}",
                    r.loads()[picked] - 1
                );
                outstanding.push(picked);
            } else {
                let i = g.usize_in(0, outstanding.len() - 1);
                r.complete(outstanding.swap_remove(i));
            }
        }
        let total: usize = r.loads().iter().sum();
        assert_eq!(total, outstanding.len(), "load accounting drifted");
    });
}

#[test]
fn prop_swap_roundtrip_restores_dense_reinflation_bit_identically() {
    run_cases(60, |g| {
        let l_n = g.usize_in(1, 3);
        let h_n = g.usize_in(1, 2);
        let d = *g.choice(&[8usize, 16]);
        let half = d / 2;
        let tokens = g.usize_in(1, 10);
        let tmax = 16;
        let norms = *g.choice(&[
            (NormMode::FP32, NormMode::FP32),
            (NormMode::LINEAR8, NormMode::LOG4),
        ]);
        let cfg = QuantConfig::paper_uniform(l_n).with_norms(norms.0, norms.1);
        let mut c = PagedKvCache::new(cfg, l_n, h_n, d, tmax, 64, 4);
        c.new_seq(1, tokens).unwrap();
        for _ in 0..tokens {
            for l in 0..l_n {
                for h in 0..h_n {
                    let kr = g.f32_vec(half, 0.05, 4.0);
                    let ki: Vec<f32> = (0..half).map(|_| (g.u64() % 128) as f32).collect();
                    let vr = g.f32_vec(half, 0.05, 4.0);
                    let vi: Vec<f32> = (0..half).map(|_| (g.u64() % 64) as f32).collect();
                    c.append_token_lh(1, l, h, &kr, &ki, &vr, &vi).unwrap();
                }
            }
            c.commit_token(1).unwrap();
        }
        let n = l_n * h_n * tmax * half;
        let mut a = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(1, 0, 1, &mut a.0, &mut a.1, &mut a.2, &mut a.3).unwrap();
        c.swap_out(1).unwrap();
        assert_eq!(c.memory_stats().pages_allocated, 0);
        assert!(c.swap_in(1, tokens).unwrap());
        let mut b = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(1, 0, 1, &mut b.0, &mut b.1, &mut b.2, &mut b.3).unwrap();
        assert_eq!(a, b, "swap-out → swap-in must reinflate bit-identically");
    });
}

#[test]
fn prop_fused_tiles_match_fill_dense_and_decode_batch() {
    // the fused read path's tiles must be bit-identical to the dense
    // reinflation — and running the x-space batch decoder (TrigLut trig +
    // inverse FWHT) over those tiles must match decode_batch over the
    // dense rows, for random geometry, page sizes, and norm modes
    run_cases(40, |g| {
        let l_n = g.usize_in(1, 3);
        let h_n = g.usize_in(1, 2);
        let d = *g.choice(&[8usize, 16]);
        let half = d / 2;
        let tokens = g.usize_in(1, 12);
        let tmax = 16;
        let page_tokens = g.usize_in(2, 5);
        let norms = *g.choice(&[
            (NormMode::FP32, NormMode::FP32),
            (NormMode::LINEAR8, NormMode::LOG4),
        ]);
        let cfg = QuantConfig::paper_uniform(l_n).with_norms(norms.0, norms.1);
        let mut c = PagedKvCache::new(cfg, l_n, h_n, d, tmax, 64, page_tokens);
        c.new_seq(1, tokens).unwrap();
        for _ in 0..tokens {
            for l in 0..l_n {
                for h in 0..h_n {
                    let kr = g.f32_vec(half, 0.05, 4.0);
                    let ki: Vec<f32> = (0..half).map(|_| (g.u64() % 128) as f32).collect();
                    let vr = g.f32_vec(half, 0.05, 4.0);
                    let vi: Vec<f32> = (0..half).map(|_| (g.u64() % 64) as f32).collect();
                    c.append_token_lh(1, l, h, &kr, &ki, &vr, &vi).unwrap();
                }
            }
            c.commit_token(1).unwrap();
        }
        let n = l_n * h_n * tmax * half;
        let mut dense = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(1, 0, 1, &mut dense.0, &mut dense.1, &mut dense.2, &mut dense.3)
            .unwrap();
        let sign = fwht::test_sign_diag(d, g.u64());
        let mut scratch = TileScratch::new();
        let upto = g.usize_in(0, tokens);
        for l in 0..l_n {
            // stitch the visited tiles back into per-head contiguous slabs
            let mut skr: Vec<Vec<f32>> = vec![Vec::new(); h_n];
            let mut ski: Vec<Vec<f32>> = vec![Vec::new(); h_n];
            let mut svr: Vec<Vec<f32>> = vec![Vec::new(); h_n];
            let mut svi: Vec<Vec<f32>> = vec![Vec::new(); h_n];
            c.visit_seq_tiles(1, l, upto, &mut scratch, &mut |t| {
                assert!(t.tokens <= page_tokens, "tile larger than a page");
                assert_eq!(skr[t.head].len(), t.t0 * half, "tiles out of order");
                skr[t.head].extend_from_slice(t.kr);
                ski[t.head].extend_from_slice(t.ki);
                svr[t.head].extend_from_slice(t.vr);
                svi[t.head].extend_from_slice(t.vi);
            })
            .unwrap();
            for h in 0..h_n {
                let base = (l * h_n + h) * tmax * half;
                let span = upto * half;
                assert_eq!(&skr[h][..], &dense.0[base..base + span], "kr l={l} h={h}");
                assert_eq!(&ski[h][..], &dense.1[base..base + span], "ki l={l} h={h}");
                assert_eq!(&svr[h][..], &dense.2[base..base + span], "vr l={l} h={h}");
                assert_eq!(&svi[h][..], &dense.3[base..base + span], "vi l={l} h={h}");
                if upto == 0 {
                    continue;
                }
                // x-space: decode_batch over fused tiles vs over dense rows
                let ku: Vec<u16> = ski[h].iter().map(|&k| k as u16).collect();
                let mut from_tiles = vec![0.0f32; upto * d];
                batch::decode_batch(&skr[h], &ku, &sign, 128, false, &mut from_tiles);
                let dku: Vec<u16> =
                    dense.1[base..base + span].iter().map(|&k| k as u16).collect();
                let mut from_dense = vec![0.0f32; upto * d];
                batch::decode_batch(
                    &dense.0[base..base + span],
                    &dku,
                    &sign,
                    128,
                    false,
                    &mut from_dense,
                );
                assert_eq!(from_tiles, from_dense, "x-space decode diverged l={l} h={h}");
            }
        }
    });
}

/// Base-31 positional encoding of a token prefix: injective for our tiny
/// alphabets, so it models the kv store's chain content addressing (same
/// prefix ⇒ same page id, different prefix ⇒ different id) exactly.
fn model_pid(prefix: &[i32]) -> u64 {
    let mut h = 0x9E37u64;
    for &t in prefix {
        h = h.wrapping_mul(31).wrapping_add(t as u64 + 2);
    }
    h
}

#[test]
fn prop_prefix_tree_invariants_under_insert_match_evict() {
    // random insert / match / pin / evict interleavings against a flat
    // model map; pins: longest-prefix match correctness, evicted pages
    // always had refcount 0, and tree token count == pages * page_tokens
    run_cases(120, |g| {
        let pt = g.usize_in(1, 3);
        let mut tree = PrefixCache::new(pt);
        // model: live full-page prefix -> its page id (prefix-closed:
        // inserts add ancestors, eviction removes leaves first)
        let mut live: std::collections::HashMap<Vec<i32>, u64> = Default::default();
        let mut known_pids: Vec<u64> = Vec::new();
        let mut refs: std::collections::HashMap<u64, usize> = Default::default();
        for _ in 0..g.usize_in(1, 60) {
            let toks: Vec<i32> = (0..g.usize_in(0, 9)).map(|_| (g.u64() % 3) as i32).collect();
            match g.usize_in(0, 3) {
                0 => {
                    let pages: Vec<u64> =
                        (1..=toks.len() / pt).map(|k| model_pid(&toks[..k * pt])).collect();
                    tree.insert(&toks, &pages);
                    for (k, &pid) in pages.iter().enumerate() {
                        if live.insert(toks[..(k + 1) * pt].to_vec(), pid).is_none() {
                            known_pids.push(pid);
                        }
                    }
                }
                1 => {
                    let got = tree.match_prefix(&toks);
                    let mut want = Vec::new();
                    for k in 1..=toks.len() / pt {
                        match live.get(&toks[..k * pt]) {
                            Some(&pid) => want.push(pid),
                            None => break,
                        }
                    }
                    assert_eq!(got, want, "longest-prefix match vs model for {toks:?}");
                }
                2 => {
                    // flip a random known page between pinned and free
                    if !known_pids.is_empty() {
                        let pid = known_pids[g.usize_in(0, known_pids.len() - 1)];
                        if refs.remove(&pid).is_none() {
                            refs.insert(pid, g.usize_in(1, 3));
                        }
                    }
                }
                _ => {
                    let want = g.usize_in(1, 4);
                    let r = refs.clone();
                    let evicted = tree.evict_lru(want, &|p| r.get(&p).copied().unwrap_or(0));
                    assert!(evicted.len() <= want);
                    for pid in &evicted {
                        assert_eq!(
                            r.get(pid).copied().unwrap_or(0),
                            0,
                            "evicted page {pid} had live references"
                        );
                        live.retain(|_, v| v != pid);
                    }
                }
            }
            assert_eq!(
                tree.cached_tokens(),
                tree.pages() * pt,
                "tree token count drifted from its live pages"
            );
            assert_eq!(tree.pages(), live.len(), "tree pages vs model");
        }
    });
}

/// Deterministic compressed entry for (token-prefix, layer, element):
/// same logical prefix ⇒ same bits, the property real prefill has and the
/// one content-addressed page dedup relies on.
fn model_entry(tokens: &[i32], t: usize, l: usize, i: usize, bins: u32) -> (f32, f32) {
    let mut h = model_pid(&tokens[..=t]);
    h = h
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(((l * 64 + i) as u64) << 7);
    let r = 0.05 + (h % 997) as f32 / 300.0;
    let k = (h >> 32) % bins as u64;
    (r, k as f32)
}

fn append_model_suffix(kv: &mut PagedKvCache, id: u64, tokens: &[i32], from: usize) {
    let half = kv.d_head / 2;
    for t in from..tokens.len() {
        for l in 0..kv.n_layers {
            let bins = kv.cfg.layers[l];
            let mut kr = Vec::with_capacity(half);
            let mut ki = Vec::with_capacity(half);
            let mut vr = Vec::with_capacity(half);
            let mut vi = Vec::with_capacity(half);
            for i in 0..half {
                let (r, k) = model_entry(tokens, t, l, i, bins.n_k);
                kr.push(r);
                ki.push(k);
                let (r, k) = model_entry(tokens, t, l, i + half, bins.n_v);
                vr.push(r);
                vi.push(k);
            }
            kv.append_token_lh(id, l, 0, &kr, &ki, &vr, &vi).unwrap();
        }
        kv.commit_token(id).unwrap();
    }
}

/// The safety acceptance criterion: across random admit (with prefix
/// adoption) / finish-and-share / preempt / resume / evict interleavings,
/// pool accounting holds (allocated ≤ reserved ≤ capacity), shared-page
/// refcounts exactly track the live+swapped sequences that adopted them,
/// eviction never frees a referenced page — and adopted prefixes reinflate
/// the exact content the sequence would have written itself.
#[test]
fn prop_shared_pool_accounting_and_eviction_safety() {
    run_cases(40, |g| {
        let pt = g.usize_in(2, 4);
        let l_n = g.usize_in(1, 2);
        let (d, tmax) = (8usize, 32usize);
        let half = d / 2;
        let capacity = g.usize_in(6, 14);
        let cfg = QuantConfig::paper_uniform(l_n).with_norms(NormMode::LINEAR8, NormMode::LOG4);
        let mut kv = PagedKvCache::new(cfg, l_n, 1, d, tmax, capacity, pt);
        let mut tree = PrefixCache::new(pt);
        let mut next_id = 1u64;
        // (id, token stream, adopted shared pages)
        let mut live: Vec<(u64, Vec<i32>, Vec<PageId>)> = Vec::new();
        let mut swapped: Vec<(u64, Vec<i32>, Vec<PageId>)> = Vec::new();
        for _ in 0..g.usize_in(4, 30) {
            match g.usize_in(0, 4) {
                0 => {
                    // admit: adopt the longest cached prefix, append the rest
                    let tlen = g.usize_in(0, 10);
                    let tokens: Vec<i32> =
                        (0..tlen).map(|_| (g.u64() % 3) as i32).collect();
                    let matched = tree.match_prefix(&tokens);
                    let id = next_id;
                    // Ok(None) = pool pressure (no sequence created): skip,
                    // exactly like the pre-node-store Err on reserve failure
                    if let Ok(Some(_)) = kv.new_seq_with_prefix(id, tlen, &matched) {
                        next_id += 1;
                        append_model_suffix(&mut kv, id, &tokens, matched.len() * pt);
                        live.push((id, tokens, matched));
                    }
                }
                1 => {
                    // finish: seal full pages, index them in the tree
                    if !live.is_empty() {
                        let (id, tokens, _) =
                            live.swap_remove(g.usize_in(0, live.len() - 1));
                        let chain = kv.finish_seq_share(id, &tokens).unwrap();
                        assert_eq!(chain.len(), tokens.len() / pt);
                        tree.insert(&tokens, &chain);
                    }
                }
                2 => {
                    // preempt: private pages out, shared refs stay pinned
                    if !live.is_empty() {
                        let e = live.swap_remove(g.usize_in(0, live.len() - 1));
                        kv.swap_out(e.0).unwrap();
                        swapped.push(e);
                    }
                }
                3 => {
                    // resume (may legitimately fail under pool pressure)
                    if !swapped.is_empty() {
                        let i = g.usize_in(0, swapped.len() - 1);
                        let (id, ref tokens, _) = swapped[i];
                        let expected = tokens.len();
                        if kv.swap_in(id, expected).unwrap() {
                            let e = swapped.swap_remove(i);
                            live.push(e);
                        }
                    }
                }
                _ => {
                    // cache eviction under (simulated) pressure
                    let evicted = tree.evict_lru(g.usize_in(1, 3), &|pid| {
                        kv.shared_page_refs(pid).unwrap_or(0)
                    });
                    for pid in &evicted {
                        assert_eq!(
                            kv.shared_page_refs(*pid),
                            Some(0),
                            "evicted page {pid} still referenced"
                        );
                        kv.free_shared_page(*pid).unwrap();
                    }
                }
            }
            // pool accounting invariants, after EVERY operation
            let st = kv.memory_stats();
            assert!(
                st.pages_allocated <= st.pages_reserved,
                "allocated {} > reserved {}",
                st.pages_allocated,
                st.pages_reserved
            );
            assert!(
                st.pages_reserved <= st.pages_capacity,
                "reserved {} > capacity {}",
                st.pages_reserved,
                st.pages_capacity
            );
            // refcounts exactly track adoption by live + swapped sequences
            let mut want_refs: std::collections::HashMap<PageId, usize> = Default::default();
            for (_, _, adopted) in live.iter().chain(swapped.iter()) {
                for &pid in adopted {
                    *want_refs.entry(pid).or_insert(0) += 1;
                }
            }
            for (&pid, &n) in &want_refs {
                assert_eq!(kv.shared_page_refs(pid), Some(n), "refcount drift on {pid}");
                assert!(
                    kv.free_shared_page(pid).is_err(),
                    "a referenced page must refuse to free"
                );
            }
            assert_eq!(st.shared_refs, want_refs.values().sum::<usize>());
        }
        // read-back: a surviving sequence's cache — adopted shared pages
        // AND its own suffix — reinflates the exact angle codes the
        // content rule defines (codes are stored exactly; norms are lossy)
        if let Some((id, tokens, _)) = live.first() {
            let n = l_n * tmax * half;
            let mut kr = vec![0.0f32; n];
            let mut ki = vec![0.0f32; n];
            let mut vr = vec![0.0f32; n];
            let mut vi = vec![0.0f32; n];
            let len = kv.fill_dense(*id, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
            assert_eq!(len, tokens.len());
            for t in 0..tokens.len() {
                for l in 0..l_n {
                    let bins = kv.cfg.layers[l];
                    for i in 0..half {
                        let base = (l * tmax + t) * half + i;
                        let (_, k) = model_entry(tokens, t, l, i, bins.n_k);
                        assert_eq!(ki[base], k, "K angle code drift at t={t} l={l} i={i}");
                        let (_, k) = model_entry(tokens, t, l, i + half, bins.n_v);
                        assert_eq!(vi[base], k, "V angle code drift at t={t} l={l} i={i}");
                    }
                }
            }
        }
    });
}

/// Both dequant kernels must emit identical bits from the same compressed
/// pages — across mixed-width boost schedules (6-bit 48/64-bin layers next
/// to 8-bit 256-bin ones), norm modes (fp32 / linear / log), random page
/// sizes, and BOTH read paths (dense reinflation and fused tiles).
#[test]
fn prop_scalar_and_simd_kernels_decode_pages_identically() {
    run_cases(40, |g| {
        let pt = g.usize_in(2, 5);
        let l_n = g.usize_in(2, 3);
        let d = *g.choice(&[8usize, 16]);
        let half = d / 2;
        let tmax = 32usize;
        let tokens = g.usize_in(1, 12);
        let boosted: Vec<usize> = (0..l_n).filter(|_| g.bool()).collect();
        let cfg = match g.usize_in(0, 2) {
            0 => QuantConfig::uniform(l_n, 48, 64).with_k8v4_log(),
            1 => QuantConfig::selective_boost(l_n, &boosted, 256, 128).with_k8v4_log(),
            _ => QuantConfig::selective_boost(l_n, &boosted, 256, 128)
                .with_norms(NormMode::FP32, NormMode::LINEAR8),
        };
        let mut kv = PagedKvCache::new(cfg, l_n, 1, d, tmax, 64, pt);
        kv.new_seq(1, tokens).unwrap();
        let toks: Vec<i32> = (0..tokens).map(|_| (g.u64() % 3) as i32).collect();
        append_model_suffix(&mut kv, 1, &toks, 0);
        let n = l_n * tmax * half;
        let read_all = |kv: &mut PagedKvCache, kind: KernelKind| {
            kv.set_kernel(kind);
            let mut dense = (vec![0f32; n], vec![0f32; n], vec![0f32; n], vec![0f32; n]);
            kv.fill_dense(1, 0, 1, &mut dense.0, &mut dense.1, &mut dense.2, &mut dense.3)
                .unwrap();
            let mut tiles = Vec::new();
            let mut scratch = TileScratch::new();
            for l in 0..l_n {
                kv.visit_seq_tiles(1, l, tokens, &mut scratch, &mut |t| {
                    tiles.extend_from_slice(t.kr);
                    tiles.extend_from_slice(t.ki);
                    tiles.extend_from_slice(t.vr);
                    tiles.extend_from_slice(t.vi);
                })
                .unwrap();
            }
            (dense, tiles)
        };
        let scalar = read_all(&mut kv, KernelKind::Scalar);
        let simd = read_all(&mut kv, KernelKind::Simd);
        assert_eq!(scalar, simd, "kernels diverged (pt={pt} l_n={l_n} d={d})");
    });
}

/// The config is part of the shared-page hash chain: identical token
/// streams under configs that differ ONLY in per-layer codebook sizes
/// (48 vs 64 — the same 6-bit packed width, so the byte stream alone
/// can collide) or ONLY in norm modes must chain to pairwise-distinct
/// shared-page hashes. Mixed-precision pages never dedup across
/// schedules; the same config twice must dedup (determinism).
#[test]
fn prop_shared_hash_diverges_on_bins_and_norms_only() {
    run_cases(30, |g| {
        let pt = g.usize_in(2, 4);
        let l_n = g.usize_in(1, 3);
        let (d, tmax) = (8usize, 32usize);
        let n_pages = g.usize_in(1, 3);
        let tokens: Vec<i32> = (0..n_pages * pt).map(|_| (g.u64() % 3) as i32).collect();
        let chain_of = |cfg: QuantConfig| -> Vec<u64> {
            let mut kv = PagedKvCache::new(cfg, l_n, 1, d, tmax, 16, pt);
            kv.new_seq(1, tokens.len()).unwrap();
            append_model_suffix(&mut kv, 1, &tokens, 0);
            let chain = kv.finish_seq_share(1, &tokens).unwrap();
            chain.iter().map(|&p| kv.shared_page_hash(p).unwrap()).collect()
        };
        let base = QuantConfig::uniform(l_n, 64, 64).with_k8v4_log();
        let h0 = chain_of(base.clone());
        assert_eq!(h0.len(), n_pages);
        assert_eq!(h0, chain_of(base), "hash chain must be deterministic");
        let variants = [
            // bins only, identical packed width (bits_for(48) == bits_for(64))
            QuantConfig::uniform(l_n, 48, 64).with_k8v4_log(),
            // norm modes only — compressed angle codes are bit-identical
            QuantConfig::uniform(l_n, 64, 64).with_norms(NormMode::FP32, NormMode::FP32),
            // one boosted layer — per-layer mixed precision
            QuantConfig::selective_boost(l_n, &[0], 256, 128).with_k8v4_log(),
        ];
        for v in variants {
            let tag = v.tag();
            let hv = chain_of(v);
            assert_eq!(hv.len(), h0.len());
            for (i, (a, b)) in h0.iter().zip(&hv).enumerate() {
                assert_ne!(a, b, "page {i} hash collided across configs ({tag})");
            }
        }
    });
}

#[test]
fn prop_mode_values_match_manifest_contract() {
    // the lax.switch order in python/compile/model.py
    assert_eq!(Mode::None as i32, 0);
    assert_eq!(Mode::Angle as i32, 1);
    assert_eq!(Mode::AngleCentered as i32, 2);
    assert_eq!(Mode::TqSymG4 as i32, 3);
    assert_eq!(Mode::Kivi as i32, 4);
    assert_eq!(Mode::KvQuant as i32, 5);
}

#[test]
fn prop_histogram_merge_equals_concatenation() {
    // The contract docs/OBSERVABILITY.md leans on for fleet stats: merging
    // per-replica histograms is indistinguishable from one histogram that
    // saw every sample. Exact for counts/sums/max; exact for quantiles too
    // because the bucket layout is shared by construction.
    run_cases(200, |g| {
        let na = g.usize_in(0, 40);
        let nb = g.usize_in(0, 40);
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut c = Histogram::default();
        for i in 0..na + nb {
            let us = 1u64 << g.usize_in(0, 26); // spans past the last bucket
            let us = us + g.u64() % us.max(2); // off the power-of-two edges
            let d = std::time::Duration::from_micros(us);
            if i < na {
                a.record(d);
            } else {
                b.record(d);
            }
            c.record(d);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.sum_us(), c.sum_us());
        assert_eq!(a.max_us(), c.max_us());
        for q in [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0] {
            assert_eq!(
                a.quantile(q),
                c.quantile(q),
                "q={q} na={na} nb={nb}: merged and concatenated disagree"
            );
        }
    });
}
