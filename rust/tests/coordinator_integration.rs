//! Coordinator integration, two tiers:
//!
//! * sim-backend tests (always run): the full multi-replica serving stack —
//!   engine tick loop, preemption/swap restore, TCP front-end with routing —
//!   against the deterministic `SimExecutor`, no artifacts needed;
//! * artifact-backed tests: the same engine against real AOT HLOs; SKIP
//!   (passing vacuously) without `make artifacts` + a real xla binding.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;
use std::sync::Arc;
use turboangle::coordinator::server::serve_on;
use turboangle::coordinator::{
    BatchPolicy, Engine, EngineConfig, EngineCore, FinishReason, ReadPath, Request, RoutePolicy,
    SharedPageStore,
};
use turboangle::obs::{export, EventKind, TraceEvent};
use turboangle::quant::{KernelKind, Mode, NormMode, QuantConfig};
use turboangle::runtime::{Entry, Manifest, ModelExecutor, Runtime, SimExecutor};
use turboangle::util::json::Json;
use turboangle::workload::{self, WorkloadSpec};

/// Sim-backed engine: 2 layers, 2 heads, d=8, batch 4 — eager batching so
/// single requests prefill immediately (deterministic tick sequences).
/// Auto resolves to the fused read path (the sim supports it), so every
/// existing test here also exercises tile decode.
fn sim_engine(seed: u64, capacity_pages: usize, page_tokens: usize) -> Engine<SimExecutor> {
    sim_engine_path(seed, capacity_pages, page_tokens, ReadPath::Auto)
}

fn sim_engine_path(
    seed: u64,
    capacity_pages: usize,
    page_tokens: usize,
    read_path: ReadPath,
) -> Engine<SimExecutor> {
    sim_engine_prefix(seed, capacity_pages, page_tokens, read_path, false)
}

fn sim_engine_prefix(
    seed: u64,
    capacity_pages: usize,
    page_tokens: usize,
    read_path: ReadPath,
    prefix_cache: bool,
) -> Engine<SimExecutor> {
    Engine::new(
        SimExecutor::new(seed),
        EngineConfig {
            batch_policy: BatchPolicy {
                min_batch: 1,
                max_wait: Duration::ZERO,
            },
            capacity_pages,
            page_tokens,
            read_path,
            prefix_cache,
            ..EngineConfig::new(QuantConfig::paper_uniform(2).with_k8v4_log())
        },
    )
}

/// Prefix-caching engine whose shared store is chosen by the caller:
/// `None` = the usual replica-private store, `Some(store)` = a node-level
/// store shared with other engines (clone the `Arc` into each replica).
fn sim_engine_store(
    seed: u64,
    capacity_pages: usize,
    page_tokens: usize,
    read_path: ReadPath,
    store: Option<Arc<SharedPageStore>>,
) -> Engine<SimExecutor> {
    Engine::new(
        SimExecutor::new(seed),
        EngineConfig {
            batch_policy: BatchPolicy {
                min_batch: 1,
                max_wait: Duration::ZERO,
            },
            capacity_pages,
            page_tokens,
            read_path,
            prefix_cache: true,
            shared_store: store,
            ..EngineConfig::new(QuantConfig::paper_uniform(2).with_k8v4_log())
        },
    )
}

/// Chunked-prefill engine: same geometry as [`sim_engine_prefix`] but with
/// the token-budget tick planner on at (`chunk_tokens`, `tick_budget`).
fn sim_engine_chunked(
    seed: u64,
    capacity_pages: usize,
    page_tokens: usize,
    read_path: ReadPath,
    prefix_cache: bool,
    chunk_tokens: usize,
    tick_budget: usize,
) -> Engine<SimExecutor> {
    Engine::new(
        SimExecutor::new(seed),
        EngineConfig {
            batch_policy: BatchPolicy {
                min_batch: 1,
                max_wait: Duration::ZERO,
            },
            capacity_pages,
            page_tokens,
            read_path,
            prefix_cache,
            chunked_prefill: true,
            chunk_tokens,
            tick_token_budget: tick_budget,
            ..EngineConfig::new(QuantConfig::paper_uniform(2).with_k8v4_log())
        },
    )
}

/// Fully instrumented engine: same geometry as `sim_engine(seed, 256, 8)`
/// but with the trace ring on and gauges/stage timers sampled every tick —
/// the worst-case observability load for the identity tests below.
fn sim_engine_traced(seed: u64) -> Engine<SimExecutor> {
    Engine::new(
        SimExecutor::new(seed),
        EngineConfig {
            batch_policy: BatchPolicy {
                min_batch: 1,
                max_wait: Duration::ZERO,
            },
            capacity_pages: 256,
            page_tokens: 8,
            trace: true,
            sample_every: 1,
            ..EngineConfig::new(QuantConfig::paper_uniform(2).with_k8v4_log())
        },
    )
}

#[test]
fn sim_engine_serves_deterministically() {
    let run = || {
        let mut e = sim_engine(7, 64, 8);
        for req in workload::generate(&WorkloadSpec {
            n_requests: 6,
            prompt_min: 4,
            prompt_max: 20,
            gen_min: 2,
            gen_max: 8,
            seed: 5,
            sessions: 0,
            ..Default::default()
        }) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 6);
        let mem = e.memory_stats();
        assert_eq!(mem.sequences, 0);
        assert_eq!(mem.pages_allocated, 0);
        assert_eq!(mem.pages_reserved, 0, "reservations must drain too");
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    assert_eq!(run(), run(), "sim serving must be deterministic");
}

/// The acceptance-criteria test: a session preempted to the swap pool and
/// restored later generates EXACTLY the tokens of an uninterrupted run —
/// the compressed stream round-trips bit-identically and the sim backend's
/// cache-checksum decode would expose any corruption. Counters prove the
/// preemption actually happened.
#[test]
fn preempted_session_resumes_bit_identically() {
    let prompt_a: Vec<i32> = vec![10, 20, 30, 40, 50, 60, 70, 80];
    let prompt_b: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3, 2];
    // 4 pages of 4 tokens: either sequence (8 prompt + 8 gen = 16 tokens =
    // 4 pages) fills the whole pool — they can never be resident together
    let solo = |prompt: &[i32]| {
        let mut e = sim_engine(7, 4, 4);
        e.submit(Request::new(1, prompt.to_vec(), 8));
        e.run_to_completion().unwrap();
        let s = e.take_finished().pop().unwrap();
        assert_eq!(e.metrics.preemptions, 0);
        s.generated
    };
    let baseline_a = solo(&prompt_a);
    let baseline_b = solo(&prompt_b);

    let mut e = sim_engine(7, 4, 4);
    e.submit(Request::new(1, prompt_a.clone(), 8));
    // tick until A is seated (prefill ran, first token emitted)
    for _ in 0..100 {
        if e.tick().unwrap() == turboangle::coordinator::scheduler::Action::Prefill {
            break;
        }
    }
    // B arrives: admitting it requires evicting A's compressed cache
    e.submit(Request::new(2, prompt_b.clone(), 8));
    e.run_to_completion().unwrap();

    assert!(e.metrics.preemptions >= 1, "A must have been swapped out");
    assert!(e.metrics.swap_ins >= 1, "A must have been restored");
    let finished = e.take_finished();
    assert_eq!(finished.len(), 2);
    let a = finished.iter().find(|s| s.request.id == 1).unwrap();
    let b = finished.iter().find(|s| s.request.id == 2).unwrap();
    assert!(a.preemptions >= 1, "session records its preemption");
    assert_eq!(
        a.generated, baseline_a,
        "preempted-then-restored session must match the uninterrupted run"
    );
    assert_eq!(b.generated, baseline_b, "the preemptor must be unaffected");
    let mem = e.memory_stats();
    assert_eq!(mem.pages_allocated, 0);
    assert_eq!(mem.swapped_sequences, 0);
}

/// The same guarantee THROUGH a preemption: run the swap-out/swap-in
/// scenario on both read paths and demand identical token streams — the
/// fused tile decode must read a restored compressed cache exactly as the
/// dense reinflation would, and both must match the uninterrupted run.
#[test]
fn fused_preemption_matches_reinflate_bit_identically() {
    let prompt_a: Vec<i32> = vec![10, 20, 30, 40, 50, 60, 70, 80];
    let prompt_b: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3, 2];
    let run = |path: ReadPath| {
        // 4 pages of 4 tokens: A and B can never be resident together, so
        // admitting B forces A through the swap pool
        let mut e = sim_engine_path(7, 4, 4, path);
        e.submit(Request::new(1, prompt_a.clone(), 8));
        for _ in 0..100 {
            if e.tick().unwrap() == turboangle::coordinator::scheduler::Action::Prefill {
                break;
            }
        }
        e.submit(Request::new(2, prompt_b.clone(), 8));
        e.run_to_completion().unwrap();
        assert!(e.metrics.preemptions >= 1, "A must have been swapped out");
        assert!(e.metrics.swap_ins >= 1, "A must have been restored");
        let mut finished = e.take_finished();
        finished.sort_by_key(|s| s.request.id);
        assert_eq!(finished.len(), 2);
        (finished[0].generated.clone(), finished[1].generated.clone())
    };
    let fused = run(ReadPath::Fused);
    let reinflate = run(ReadPath::Reinflate);
    assert_eq!(
        fused, reinflate,
        "post-preemption generation must be bit-identical across read paths"
    );
}

/// Acceptance criterion of the fused read path: with everything else
/// identical, an engine that decodes straight from compressed page tiles
/// emits EXACTLY the tokens of the dense-reinflate engine, for a whole
/// mixed workload. The sim folds a checksum + streaming-softmax of every
/// cache element into each token, so even a 1-ulp divergence between the
/// two dequant paths would change the streams.
#[test]
fn fused_read_path_emits_bit_identical_tokens() {
    let run = |path: ReadPath| {
        let mut e = sim_engine_path(7, 64, 8, path);
        assert_eq!(e.is_fused(), path != ReadPath::Reinflate);
        for req in workload::generate(&WorkloadSpec {
            n_requests: 8,
            prompt_min: 3,
            prompt_max: 24,
            gen_min: 2,
            gen_max: 10,
            seed: 13,
            sessions: 0,
            ..Default::default()
        }) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 8);
        if path == ReadPath::Reinflate {
            assert!(e.dense_buffer_bytes() > 0);
        } else {
            // fused: no dense tensors, scratch bounded to one page of
            // four d/2 slabs (page_tokens=8, d/2=4, 4 slabs, f32)
            assert_eq!(e.dense_buffer_bytes(), 0, "fused path must not hold dense buffers");
            assert!(e.tile_scratch_bytes() <= 8 * 4 * 4 * 4, "scratch beyond one page");
        }
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    let fused = run(ReadPath::Fused);
    let reinflate = run(ReadPath::Reinflate);
    assert_eq!(
        fused, reinflate,
        "fused and reinflate read paths must generate identical tokens"
    );
    assert_eq!(run(ReadPath::Auto), fused, "sim Auto must resolve to fused");
}

/// The mixed-precision read-path criterion: a per-layer `selective_boost`
/// schedule (layers 0 and 2 of 4 at 256/128 bins, layers 1 and 3 at the
/// uniform base) must emit bit-identical token streams on the fused and
/// reinflate read paths — tile decode must honor each layer's own codebook
/// width exactly as dense reinflation does. This is the serving-side twin
/// of the `eval --boost-layers` sweep: what the sensitivity loop picks is
/// exactly what the engine serves.
#[test]
fn selective_boost_schedule_bit_identical_across_read_paths() {
    let cfg = QuantConfig::selective_boost(4, &[0, 2], 256, 128).with_k8v4_log();
    let run = |path: ReadPath| {
        let mut e = Engine::new(
            SimExecutor::with_dims(7, 4, 2, 8, 4, 32, 64),
            EngineConfig {
                batch_policy: BatchPolicy {
                    min_batch: 1,
                    max_wait: Duration::ZERO,
                },
                capacity_pages: 64,
                page_tokens: 8,
                read_path: path,
                ..EngineConfig::new(cfg.clone())
            },
        );
        for req in workload::generate(&WorkloadSpec {
            n_requests: 8,
            prompt_min: 3,
            prompt_max: 24,
            gen_min: 2,
            gen_max: 10,
            seed: 19,
            ..Default::default()
        }) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 8);
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    assert_eq!(
        run(ReadPath::Fused),
        run(ReadPath::Reinflate),
        "selective_boost schedule must decode identically on both read paths"
    );
}

/// The kernel-dispatch acceptance criterion: the vectorized (`Simd`) and
/// reference (`Scalar`) microkernels must emit bit-identical token streams
/// end to end — dequant on both read paths AND the attention scoring slab —
/// under a mixed-width boost schedule (6-bit and 8-bit layers in one
/// model). The sim folds a checksum + streaming softmax of every decoded
/// element into each token, so a single reassociated float anywhere in the
/// batched pipeline would change the streams.
#[test]
fn simd_and_scalar_kernels_emit_bit_identical_tokens() {
    let cfg = QuantConfig::selective_boost(4, &[0, 2], 256, 128).with_k8v4_log();
    let run = |path: ReadPath, kernel: KernelKind| {
        let mut e = Engine::new(
            SimExecutor::with_dims(7, 4, 2, 8, 4, 32, 64),
            EngineConfig {
                batch_policy: BatchPolicy {
                    min_batch: 1,
                    max_wait: Duration::ZERO,
                },
                capacity_pages: 64,
                page_tokens: 8,
                read_path: path,
                ..EngineConfig::new(cfg.clone())
            },
        );
        e.kv.set_kernel(kernel);
        e.exec.set_kernel(kernel);
        for req in workload::generate(&WorkloadSpec {
            n_requests: 8,
            prompt_min: 3,
            prompt_max: 24,
            gen_min: 2,
            gen_max: 10,
            seed: 23,
            ..Default::default()
        }) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 8);
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    let want = run(ReadPath::Fused, KernelKind::Simd);
    for (path, kernel) in [
        (ReadPath::Fused, KernelKind::Scalar),
        (ReadPath::Reinflate, KernelKind::Simd),
        (ReadPath::Reinflate, KernelKind::Scalar),
    ] {
        assert_eq!(
            run(path, kernel),
            want,
            "kernel {kernel:?} on {path:?} diverged from the simd fused stream"
        );
    }
}

/// The prefix-cache acceptance criterion: for a whole shared-prefix
/// workload, generated token streams with the cache ON equal the streams
/// with it OFF, on BOTH read paths — adoption only skips recomputing KV
/// bits deterministic prefill would reproduce, so the sim's cache-checksum
/// decode would expose any divergence. The ON runs must actually hit.
#[test]
fn prefix_cache_on_emits_bit_identical_tokens_and_hits() {
    let spec = WorkloadSpec {
        n_requests: 16,
        prompt_min: 2,
        prompt_max: 6,
        gen_min: 2,
        gen_max: 6,
        seed: 21,
        n_prefixes: 2,
        prefix_len: 12, // 3 full pages of 4 — matchable after one finish
        ..Default::default()
    };
    let run = |path: ReadPath, prefix: bool| {
        let mut e = sim_engine_prefix(7, 256, 4, path, prefix);
        assert_eq!(e.prefix_cache_enabled(), prefix);
        for req in workload::generate(&spec) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 16);
        let mem = e.memory_stats();
        assert_eq!(mem.sequences, 0);
        assert_eq!(mem.shared_refs, 0, "all refs dropped after drain");
        if prefix {
            assert!(e.metrics.prefix_hits >= 1, "warm requests must hit");
            assert!(e.metrics.prefix_tokens_reused >= 12);
            assert!(mem.shared_pages > 0, "finished prefixes stay cached");
            // after drain, ONLY the cache holds pool pages
            assert_eq!(mem.pages_allocated, mem.shared_pages);
            assert_eq!(mem.pages_reserved, mem.shared_pages);
            assert_eq!(mem.pages_private(), 0);
        } else {
            assert_eq!(e.metrics.prefix_hits + e.metrics.prefix_misses, 0);
            assert_eq!(mem.pages_allocated, 0);
            assert_eq!(mem.shared_pages, 0);
        }
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    let baseline = run(ReadPath::Reinflate, false);
    for (path, prefix) in [
        (ReadPath::Reinflate, true),
        (ReadPath::Fused, false),
        (ReadPath::Fused, true),
    ] {
        assert_eq!(
            run(path, prefix),
            baseline,
            "prefix cache and read path must not change tokens ({path:?}, prefix={prefix})"
        );
    }
}

/// Bit-identity THROUGH preemption with sharing: B adopts A's cached
/// prefix pages, gets swapped out while holding them (the refs pin the
/// pages), and resumes to generate exactly what the cache-off run does —
/// on both read paths.
#[test]
fn prefix_cache_preemption_matches_off_bit_identically() {
    let prompt_ab: Vec<i32> = vec![10, 20, 30, 40, 50, 60, 70, 80];
    let prompt_c: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3, 2];
    let run = |path: ReadPath, prefix: bool| {
        // pool of 6 pages × 4 tokens: with B resident (and, when caching,
        // A's pages cached) C's 4-page footprint forces a preemption
        let mut e = sim_engine_prefix(7, 6, 4, path, prefix);
        e.submit(Request::new(1, prompt_ab.clone(), 8));
        e.run_to_completion().unwrap();
        // B repeats A's prompt: with caching on it adopts A's pages
        e.submit(Request::new(2, prompt_ab.clone(), 8));
        for _ in 0..100 {
            if e.tick().unwrap() == turboangle::coordinator::scheduler::Action::Prefill {
                break;
            }
        }
        e.tick().unwrap(); // at least one decode so B is evictable
        e.submit(Request::new(3, prompt_c.clone(), 8));
        e.run_to_completion().unwrap();
        assert!(e.metrics.preemptions >= 1, "B must have been swapped out");
        assert!(e.metrics.swap_ins >= 1, "B must have been restored");
        if prefix {
            assert!(e.metrics.prefix_hits >= 1, "B must adopt A's pages");
        }
        let mut finished = e.take_finished();
        finished.sort_by_key(|s| s.request.id);
        assert_eq!(finished.len(), 3);
        finished
            .into_iter()
            .map(|s| s.generated)
            .collect::<Vec<_>>()
    };
    let baseline = run(ReadPath::Reinflate, false);
    assert_eq!(
        baseline[0], baseline[1],
        "same prompt, same deterministic stream"
    );
    for (path, prefix) in [
        (ReadPath::Reinflate, true),
        (ReadPath::Fused, false),
        (ReadPath::Fused, true),
    ] {
        assert_eq!(
            run(path, prefix),
            baseline,
            "preempted shared-prefix run diverged ({path:?}, prefix={prefix})"
        );
    }
}

/// Pool pressure reclaims unreferenced cached pages (LRU) instead of
/// refusing admission: a request needing the whole pool evicts the cache
/// left by a finished sequence and still completes.
#[test]
fn prefix_eviction_reclaims_cached_pages_under_pressure() {
    let mut e = sim_engine_prefix(7, 5, 4, ReadPath::Auto, true);
    e.submit(Request::new(1, vec![11, 12, 13, 14, 15, 16, 17, 18], 4));
    e.run_to_completion().unwrap();
    let cached = e.memory_stats().shared_pages;
    assert!(cached >= 2, "finished sequence must leave cached pages");
    // 12-token prompt + 8 gen = 20 tokens = all 5 pages: only fits after
    // the cache yields
    let big: Vec<i32> = (30..42).collect();
    e.submit(Request::new(2, big, 8));
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 2);
    assert!(
        e.metrics.prefix_evictions >= cached as u64,
        "cached pages must have been reclaimed ({} evictions)",
        e.metrics.prefix_evictions
    );
    assert_eq!(e.metrics.preemptions, 0, "no live work was preempted");
}

/// The node-store acceptance criterion: a shared-prefix workload split
/// round-robin over 2–4 replicas generates EXACTLY the same token
/// streams whether the fleet shares one node-level page store or each
/// replica keeps its own — on both read paths — and the node runs match
/// a single-replica run too. With the node store every replica reports
/// the SAME store identity, so fleet roll-ups count its pages once.
#[test]
fn node_store_fleet_emits_bit_identical_tokens_across_scopes() {
    let spec = WorkloadSpec {
        n_requests: 16,
        prompt_min: 2,
        prompt_max: 6,
        gen_min: 2,
        gen_max: 6,
        seed: 21,
        n_prefixes: 2,
        prefix_len: 12, // 3 full pages of 4 — matchable after one finish
        ..Default::default()
    };
    let solo = |path: ReadPath| -> Vec<(u64, Vec<i32>)> {
        let mut e = sim_engine_store(7, 256, 4, path, None);
        for req in workload::generate(&spec) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    let fleet = |path: ReadPath, replicas: usize, node: bool| -> Vec<(u64, Vec<i32>)> {
        let store = node.then(|| SharedPageStore::node(256 * replicas));
        let mut engines: Vec<Engine<SimExecutor>> = (0..replicas)
            .map(|_| sim_engine_store(7, 256, 4, path, store.clone()))
            .collect();
        for (i, req) in workload::generate(&spec).into_iter().enumerate() {
            engines[i % replicas].submit(req);
        }
        // interleaved ticking: every replica makes progress each round, so
        // harvest/adopt on the shared store genuinely interleave
        loop {
            let mut any = false;
            for e in engines.iter_mut() {
                if e.has_work() {
                    e.tick().unwrap();
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        let ids: Vec<u64> = engines.iter().map(|e| e.memory_stats().shared_store_id).collect();
        if node {
            assert!(
                ids.windows(2).all(|w| w[0] == w[1]),
                "node-scoped replicas must report one store identity: {ids:?}"
            );
        } else {
            let distinct: std::collections::HashSet<u64> = ids.iter().copied().collect();
            assert_eq!(distinct.len(), replicas, "replica stores must be distinct: {ids:?}");
        }
        let mut out = Vec::new();
        for e in engines.iter_mut() {
            out.extend(e.take_finished().into_iter().map(|s| (s.request.id, s.generated)));
        }
        out.sort();
        out
    };
    for path in [ReadPath::Fused, ReadPath::Reinflate] {
        let want = solo(path);
        for replicas in [2usize, 3, 4] {
            for node in [true, false] {
                assert_eq!(
                    fleet(path, replicas, node),
                    want,
                    "fleet diverged ({path:?}, {replicas} replicas, node={node})"
                );
            }
        }
    }
    assert_eq!(
        solo(ReadPath::Fused),
        solo(ReadPath::Reinflate),
        "read paths must agree on the reference run too"
    );
}

/// The cross-replica refcount scenario the ISSUE pins: replica B harvests
/// a prefix into the node store; replica A's first request re-harvests the
/// SAME content (the seal dedups onto B's physical pages); A's second
/// request ADOPTS those pages, is preempted while holding them (its swap
/// pins must keep B's pages alive), resumes, and generates exactly what
/// the replica-scoped runs do — on both read paths.
#[test]
fn preempted_adopter_resumes_on_prefix_harvested_by_peer_replica() {
    let shared_prompt: Vec<i32> = vec![10, 20, 30, 40, 50, 60, 70, 80];
    let competitor: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3, 2];
    let run = |path: ReadPath, node: bool| -> Vec<Vec<i32>> {
        let store = node.then(|| SharedPageStore::node(64));
        // node mode keeps adopted pages OUT of the replica pool, so a
        // smaller pool is needed to force the same preemption pressure
        let pool = if node { 4 } else { 6 };
        let mut a = sim_engine_store(7, pool, 4, path, store.clone());
        let mut b = sim_engine_store(7, 64, 4, path, store.clone());
        // replica B publishes the prefix
        b.submit(Request::new(100, shared_prompt.clone(), 8));
        b.run_to_completion().unwrap();
        assert!(b.memory_stats().shared_pages >= 2, "B must harvest the prompt");
        // A's first request harvests into A's OWN radix tree; with the
        // node store the seal dedups onto the pages B already published
        a.submit(Request::new(1, shared_prompt.clone(), 8));
        a.run_to_completion().unwrap();
        if node {
            let (ma, mb) = (a.memory_stats(), b.memory_stats());
            assert_eq!(ma.shared_store_id, mb.shared_store_id, "one physical store");
            assert_eq!(
                ma.shared_pages, mb.shared_pages,
                "same content must dedup onto the same physical pages"
            );
        }
        // A's second request adopts, decodes once, then gets preempted
        a.submit(Request::new(2, shared_prompt.clone(), 8));
        for _ in 0..100 {
            if a.tick().unwrap() == turboangle::coordinator::scheduler::Action::Prefill {
                break;
            }
        }
        a.tick().unwrap(); // at least one decode so the adopter is evictable
        a.submit(Request::new(3, competitor.clone(), 8));
        a.run_to_completion().unwrap();
        assert!(a.metrics.preemptions >= 1, "the adopter must be swapped out");
        assert!(a.metrics.swap_ins >= 1, "the adopter must be restored");
        assert!(a.metrics.prefix_hits >= 1, "request 2 must adopt the prefix");
        let mut fin = a.take_finished();
        fin.sort_by_key(|s| s.request.id);
        assert_eq!(fin.len(), 3);
        let mut out: Vec<Vec<i32>> = fin.into_iter().map(|s| s.generated).collect();
        out.push(b.take_finished().pop().unwrap().generated);
        out
    };
    let baseline = run(ReadPath::Reinflate, false);
    assert_eq!(baseline[0], baseline[1], "same prompt, same deterministic stream");
    for (path, node) in [
        (ReadPath::Reinflate, true),
        (ReadPath::Fused, false),
        (ReadPath::Fused, true),
    ] {
        assert_eq!(
            run(path, node),
            baseline,
            "preempted cross-replica adopter diverged ({path:?}, node={node})"
        );
    }
}

/// Threaded node-store churn (TSan-coverable): two OS threads each drive
/// their own engine against ONE tiny node store, so adopt / harvest /
/// LRU-evict genuinely race on the store lock. Both replicas must still
/// generate exactly the single-engine streams, and the store must respect
/// its capacity once the dust settles.
#[test]
fn node_store_survives_concurrent_replicas_on_threads() {
    // 8-token shared prefix (2 pages of 4) + 8-token distinct tails: each
    // prompt seals up to 4 pages, so 6 prompts want 2 + 6*2 = 14 unique
    // pages — far past the 8-page store, forcing real LRU eviction
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut p = vec![11, 22, 33, 44, 55, 66, 77, 88];
            p.extend([i as i32 + 1; 8]);
            p
        })
        .collect();
    let solo: Vec<(u64, Vec<i32>)> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let mut e = sim_engine_store(7, 64, 4, ReadPath::Auto, None);
            e.submit(Request::new(i as u64, p.clone(), 6));
            e.run_to_completion().unwrap();
            (i as u64, e.take_finished().pop().unwrap().generated)
        })
        .collect();
    // capacity 8 pages: the 14-page working set overflows it, so the
    // peers race adoption against each other's LRU evictions
    let store = SharedPageStore::node(8);
    let handles: Vec<std::thread::JoinHandle<Vec<(u64, Vec<i32>)>>> = (0..2u64)
        .map(|t| {
            let store = Arc::clone(&store);
            let prompts = prompts.clone();
            std::thread::spawn(move || {
                let mut e = sim_engine_store(7, 64, 4, ReadPath::Auto, Some(store));
                for (i, p) in prompts.iter().enumerate() {
                    e.submit(Request::new(t * 100 + i as u64, p.clone(), 6));
                    e.run_to_completion().unwrap();
                }
                let mut out: Vec<(u64, Vec<i32>)> = e
                    .take_finished()
                    .into_iter()
                    .map(|s| (s.request.id % 100, s.generated))
                    .collect();
                out.sort();
                out
            })
        })
        .collect();
    for h in handles {
        let got = h.join().expect("replica thread panicked");
        assert_eq!(got, solo, "a concurrent replica diverged from the solo streams");
    }
    assert!(
        store.page_count() <= 8,
        "node store exceeded its capacity: {} pages",
        store.page_count()
    );
}

/// The chunked-prefill acceptance criterion: for a whole mixed workload
/// (short chats + prompts longer than several chunks, with shared prefixes
/// so adoption advances the cursor), the generated token streams with
/// chunking ON equal the streams with it OFF — on BOTH read paths, at
/// several chunk sizes including ones that don't divide the prompt length.
/// The sim folds a checksum of every cache element into each token, so a
/// single mis-appended chunk position would change the streams.
#[test]
fn chunked_prefill_emits_bit_identical_tokens() {
    let spec = WorkloadSpec {
        n_requests: 10,
        prompt_min: 3,
        prompt_max: 28,
        gen_min: 2,
        gen_max: 8,
        seed: 17,
        n_prefixes: 2,
        prefix_len: 12, // 3 full pages of 4 — adopted once a donor finishes
        ..Default::default()
    };
    let run = |path: ReadPath, prefix: bool, chunk: Option<(usize, usize)>| {
        let mut e = match chunk {
            Some((chunk_tokens, budget)) => {
                sim_engine_chunked(7, 256, 4, path, prefix, chunk_tokens, budget)
            }
            None => sim_engine_prefix(7, 256, 4, path, prefix),
        };
        assert_eq!(e.is_chunked(), chunk.is_some());
        for req in workload::generate(&spec) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.requests_finished, 10);
        if chunk.is_some() {
            assert!(e.metrics.prefill_chunks > 0, "chunked mode must run chunks");
            assert_eq!(e.metrics.prefill_batches, 0, "no monolithic prefills");
        }
        if prefix {
            assert!(e.metrics.prefix_hits >= 1, "warm requests must adopt");
        }
        let mem = e.memory_stats();
        assert_eq!(mem.sequences, 0, "all sequences drained");
        assert_eq!(e.prefilling_sessions(), 0);
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        out
    };
    let baseline = run(ReadPath::Reinflate, false, None);
    for (path, prefix, chunk) in [
        (ReadPath::Reinflate, false, Some((5, 9))),
        (ReadPath::Reinflate, true, Some((5, 9))),
        (ReadPath::Fused, false, Some((5, 9))),
        (ReadPath::Fused, true, Some((5, 9))),
        (ReadPath::Fused, false, Some((1, 3))),
        (ReadPath::Fused, true, Some((16, 64))),
        (ReadPath::Fused, true, None),
    ] {
        assert_eq!(
            run(path, prefix, chunk),
            baseline,
            "chunked prefill changed tokens ({path:?}, prefix={prefix}, chunk={chunk:?})"
        );
    }
}

/// Half-prefilled preemption: a session mid-chunked-prefill is evicted to
/// the swap pool (its partial compressed pages move verbatim, the cursor
/// survives in the session), later resumes, finishes its remaining chunks,
/// and generates EXACTLY the tokens of an uninterrupted run.
#[test]
fn half_prefilled_session_preempted_and_resumed_bit_identically() {
    let long: Vec<i32> = (1..=24).collect();
    let other: Vec<i32> = vec![9, 8, 7, 6, 5, 4, 3, 2];
    let solo = |prompt: &[i32]| {
        let mut e = sim_engine_chunked(7, 64, 4, ReadPath::Auto, false, 4, 8);
        e.submit(Request::new(1, prompt.to_vec(), 4));
        e.run_to_completion().unwrap();
        assert_eq!(e.metrics.preemptions, 0);
        e.take_finished().pop().unwrap().generated
    };
    let baseline_long = solo(&long);
    let baseline_other = {
        let mut e = sim_engine_chunked(7, 64, 4, ReadPath::Auto, false, 4, 8);
        e.submit(Request::new(2, other.clone(), 8));
        e.run_to_completion().unwrap();
        e.take_finished().pop().unwrap().generated
    };

    // pool of 8 pages × 4 tokens: long needs 7 pages (24 prompt + 4 gen),
    // other needs 4 (8 + 8) — they can never be resident together
    let mut e = sim_engine_chunked(7, 8, 4, ReadPath::Auto, false, 4, 8);
    e.submit(Request::new(1, long.clone(), 4));
    // two ticks: 8 of 24 prompt tokens committed, no token produced yet
    e.tick().unwrap();
    e.tick().unwrap();
    assert!(e.metrics.prefill_chunks >= 2, "chunks must have run");
    assert_eq!(e.metrics.ttft.count(), 0, "long is still mid-prefill");
    assert_eq!(e.prefilling_sessions(), 1);
    // the competitor forces the half-prefilled session through the swap pool
    e.submit(Request::new(2, other.clone(), 8));
    e.run_to_completion().unwrap();
    assert!(e.metrics.preemptions >= 1, "long must have been swapped out");
    assert!(e.metrics.swap_ins >= 1, "long must have been restored");
    let mut finished = e.take_finished();
    finished.sort_by_key(|s| s.request.id);
    assert_eq!(finished.len(), 2);
    assert!(finished[0].preemptions >= 1, "session records its preemption");
    assert_eq!(
        finished[0].generated, baseline_long,
        "half-prefilled then resumed session must match the uninterrupted run"
    );
    assert_eq!(finished[1].generated, baseline_other, "the preemptor is unaffected");
    let mem = e.memory_stats();
    assert_eq!(mem.pages_allocated, 0);
    assert_eq!(mem.swapped_sequences, 0);
}

/// Scheduler fairness regression: with chunking on, an in-flight decoder
/// keeps producing a token EVERY tick while a stream of near-window-sized
/// prompts arrives and prefills — decode lanes are packed into the budget
/// first, so long-prompt ingestion can never starve generation (this is
/// the bounded-ITL property `BENCH_serving_latency.json` quantifies).
#[test]
fn long_prompt_stream_cannot_starve_inflight_decoder() {
    let mut e = sim_engine_chunked(7, 256, 8, ReadPath::Auto, false, 4, 8);
    e.submit(Request::new(1, vec![5, 6, 7, 8], 20));
    for _ in 0..50 {
        if e.metrics.ttft.count() >= 1 {
            break;
        }
        e.tick().unwrap();
    }
    assert_eq!(e.metrics.ttft.count(), 1, "the chat session must be decoding");
    // a stream of long prompts (28 tokens ≈ the 32-token prefill window,
    // 7 chunks each at chunk_tokens=4) arrives all at once
    for i in 0..3i32 {
        e.submit(Request::new(10 + i as u64, vec![30 + i; 28], 2));
    }
    // until the first session finishes, every tick must advance generation
    let mut last = e.metrics.tokens_generated;
    let mut stalls = 0;
    for _ in 0..500 {
        if e.metrics.requests_finished > 0 || !e.has_work() {
            break;
        }
        e.tick().unwrap();
        let now = e.metrics.tokens_generated;
        if now == last {
            stalls += 1;
        } else {
            stalls = 0;
        }
        assert!(
            stalls <= 1,
            "decoder starved: no token for {stalls} consecutive ticks while long prompts prefill"
        );
        last = now;
    }
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 4);
    assert!(e.metrics.prefill_chunks > 0, "long prompts must have chunked");
    assert!(e.metrics.itl.count() > 0, "ITL histogram must have samples");
}

#[test]
fn impossible_request_finishes_cache_full_and_queue_moves_on() {
    // pool: 2 pages * 4 tokens = 8 cache tokens max
    let mut e = sim_engine(7, 2, 4);
    // head request can never fit (4 + 16 = 20 tokens > 8): previously this
    // starved the queue forever; now it finishes CacheFull immediately
    e.submit(Request::new(1, vec![1, 2, 3, 4], 16));
    // a modest request behind it must still be served (7 tokens, 2 pages)
    e.submit(Request::new(2, vec![5, 6, 7], 4));
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.rejected_cache_full, 1);
    assert_eq!(e.metrics.requests_finished, 2);
    let finished = e.take_finished();
    let doomed = finished.iter().find(|s| s.request.id == 1).unwrap();
    assert_eq!(doomed.finished, Some(FinishReason::CacheFull));
    assert!(doomed.generated.is_empty());
    let ok = finished.iter().find(|s| s.request.id == 2).unwrap();
    assert!(matches!(
        ok.finished,
        Some(FinishReason::Length) | Some(FinishReason::Eos)
    ));
}

/// Drive one connection: write all lines up-front (pipelined), then read
/// `expect` responses. Returns (wire_id, replica, n_tokens) per response.
fn drive_conn(
    addr: std::net::SocketAddr,
    lines: &[String],
    expect: usize,
) -> Vec<(u64, usize, usize)> {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    for l in lines {
        stream.write_all(l.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
    }
    stream.flush().unwrap();
    let reader = BufReader::new(stream);
    let mut out = Vec::new();
    for line in reader.lines().take(expect) {
        let line = line.unwrap();
        let j = Json::parse(&line).unwrap_or_else(|e| panic!("bad response {line}: {e}"));
        out.push((
            j.get("id").unwrap().as_u64().unwrap(),
            j.get("replica").unwrap().as_usize().unwrap(),
            j.get("tokens").unwrap().as_arr().unwrap().len(),
        ));
    }
    out
}

#[test]
fn two_replica_tcp_server_answers_concurrent_requests_with_affinity() {
    let engines: Vec<Box<dyn EngineCore>> = (0..2)
        .map(|_| Box::new(sim_engine(7, 256, 8)) as Box<dyn EngineCore>)
        .collect();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_on(listener, engines, RoutePolicy::SessionAffinity, 8).unwrap()
    });

    // "alice" and "carol" land on DIFFERENT replicas of the 2-replica
    // consistent-hash ring (the ring is deterministic; picked so the test
    // exercises both engines rather than one vacuously)
    let alice: Vec<String> = (0..4)
        .map(|i| {
            format!(
                r#"{{"id": {}, "prompt": "hello number {}", "max_new_tokens": 5, "session_key": "alice"}}"#,
                10 + i, i
            )
        })
        .collect();
    let carol: Vec<String> = (0..4)
        .map(|i| {
            format!(
                r#"{{"id": {}, "prompt": "other text {}", "max_new_tokens": 5, "session_key": "carol"}}"#,
                20 + i, i
            )
        })
        .collect();
    // two concurrent pipelined connections
    let ha = std::thread::spawn(move || drive_conn(addr, &alice, 4));
    let hb = std::thread::spawn(move || drive_conn(addr, &carol, 4));
    let ra = ha.join().unwrap();
    let rb = hb.join().unwrap();
    let summary = server.join().unwrap();

    assert_eq!(ra.len(), 4);
    assert_eq!(rb.len(), 4);
    let mut ids: Vec<u64> = ra.iter().chain(&rb).map(|r| r.0).collect();
    ids.sort();
    assert_eq!(ids, (10..14).chain(20..24).collect::<Vec<u64>>());
    // session affinity: each key sticks to one replica across its requests
    assert!(ra.iter().all(|r| r.1 == ra[0].1), "alice moved replicas: {ra:?}");
    assert!(rb.iter().all(|r| r.1 == rb[0].1), "carol moved replicas: {rb:?}");
    assert_ne!(
        ra[0].1, rb[0].1,
        "alice and carol hash to different replicas — both engines must serve"
    );
    assert_eq!(summary.served, 8);
    for (i, m) in summary.replicas.iter().enumerate() {
        assert_eq!(m.requests_finished, 4, "replica {i} must serve one session");
    }
}

/// A chunked-prefill replica behind the real TCP front-end answers
/// generation requests AND the `{"stats": true}` metrics query — the wire
/// stats carry the itl/ttft histograms with p99 fields.
#[test]
fn tcp_server_serves_chunked_engine_and_stats_queries() {
    let engines: Vec<Box<dyn EngineCore>> =
        vec![Box::new(sim_engine_chunked(7, 256, 8, ReadPath::Auto, false, 8, 16))];
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_on(listener, engines, RoutePolicy::RoundRobin, 2).unwrap()
    });
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    for line in [
        r#"{"id": 1, "prompt": "hello chunked world", "max_new_tokens": 6}"#,
        r#"{"id": 2, "prompt": "second request padding", "max_new_tokens": 6}"#,
        r#"{"id": 3, "stats": true}"#,
    ] {
        stream.write_all(line.as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
    }
    stream.flush().unwrap();
    let reader = BufReader::new(stream);
    let mut gen_ids = Vec::new();
    let mut saw_stats = false;
    for line in reader.lines().take(3) {
        let line = line.unwrap();
        let j = Json::parse(&line).unwrap_or_else(|e| panic!("bad response {line}: {e}"));
        match j.opt("stats") {
            Some(stats) => {
                saw_stats = true;
                assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 3);
                // histogram fields present with microsecond quantiles
                assert!(stats.get("itl").unwrap().get("p99_us").unwrap().as_f64().is_ok());
                assert!(stats.get("ttft").unwrap().get("p50_us").unwrap().as_f64().is_ok());
            }
            None => gen_ids.push(j.get("id").unwrap().as_u64().unwrap()),
        }
    }
    let summary = server.join().unwrap();
    assert!(saw_stats, "the stats query must be answered");
    gen_ids.sort();
    assert_eq!(gen_ids, vec![1, 2]);
    assert_eq!(summary.served, 2, "stats responses do not count as served");
}

/// Tracing is observational only: a fully instrumented engine (trace ring
/// on, gauges + stage timers sampled every tick) generates bit-identical
/// token streams to an untraced twin, and its snapshot carries exactly one
/// `Finish` span per retired request with every `DecodeStep` nested inside
/// its request's lifetime span.
#[test]
fn tracing_preserves_token_streams_and_records_nested_spans() {
    let run = |traced: bool| {
        let mut e = if traced {
            sim_engine_traced(7)
        } else {
            sim_engine(7, 256, 8)
        };
        for req in workload::generate(&WorkloadSpec {
            n_requests: 5,
            prompt_min: 4,
            prompt_max: 24,
            gen_min: 3,
            gen_max: 8,
            seed: 13,
            sessions: 0,
            ..Default::default()
        }) {
            e.submit(req);
        }
        e.run_to_completion().unwrap();
        let snap = e.obs_snapshot();
        let finished = e.metrics.requests_finished;
        let mut out: Vec<(u64, Vec<i32>)> = e
            .take_finished()
            .into_iter()
            .map(|s| (s.request.id, s.generated))
            .collect();
        out.sort();
        (out, snap, finished)
    };
    let (plain, off_snap, _) = run(false);
    let (traced, snap, finished) = run(true);
    assert_eq!(plain, traced, "tracing must not perturb generated tokens");
    assert!(off_snap.events.is_empty(), "tracing off must record nothing");
    assert!(off_snap.gauges.is_empty(), "tracing off must sample nothing");

    assert_eq!(snap.dropped_events, 0, "5 small requests cannot wrap the ring");
    let finishes: Vec<&TraceEvent> = snap
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Finish)
        .collect();
    assert_eq!(finishes.len() as u64, finished, "one finish span per request");
    // Span nesting: every decode step lands inside its request's
    // arrival→retire span. Timestamps truncate to whole microseconds when
    // recorded, so each endpoint comparison tolerates ±2µs.
    for f in &finishes {
        for d in snap
            .events
            .iter()
            .filter(|e| e.kind == EventKind::DecodeStep && e.request_id == f.request_id)
        {
            assert!(
                d.at_us + 2 >= f.at_us,
                "decode step starts before its finish span: {d:?} vs {f:?}"
            );
            assert!(
                d.at_us + d.dur_us <= f.at_us + f.dur_us + 2,
                "decode step ends after its finish span: {d:?} vs {f:?}"
            );
        }
    }
    assert!(!snap.gauges.is_empty(), "stride-1 sampling must capture gauges");
    assert!(
        snap.stage.sampled_ticks > 0,
        "stride-1 sampling must time the fused read path"
    );
}

/// The full traced fleet path over TCP: two instrumented replicas behind
/// the front-end answer pipelined generations, a mid-stream fleet-scope
/// stats query whose merged histogram counts equal the sum of the
/// per-replica counts, a Prometheus `metrics` query, and — after shutdown —
/// the collected per-replica snapshots render to parseable Chrome
/// trace-event JSON with one `finish` span per served request.
#[test]
fn traced_two_replica_server_exports_fleet_stats_and_chrome_trace() {
    let engines: Vec<Box<dyn EngineCore>> = (0..2)
        .map(|_| Box::new(sim_engine_traced(7)) as Box<dyn EngineCore>)
        .collect();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        serve_on(listener, engines, RoutePolicy::SessionAffinity, 9).unwrap()
    });

    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let read_json = |reader: &mut BufReader<TcpStream>| -> Json {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line}: {e}"))
    };

    // 8 pipelined generations, 4 per session key — "alice" and "carol"
    // hash to different replicas of the 2-ring, so both engines trace.
    for (base, key) in [(10, "alice"), (20, "carol")] {
        for i in 0..4 {
            let line = format!(
                r#"{{"id": {}, "prompt": "traced request {}", "max_new_tokens": 5, "session_key": "{}"}}"#,
                base + i,
                i,
                key
            );
            stream.write_all(line.as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
        }
    }
    stream.flush().unwrap();
    let mut ids: Vec<u64> = (0..8)
        .map(|_| read_json(&mut reader).get("id").unwrap().as_u64().unwrap())
        .collect();
    ids.sort();
    assert_eq!(ids, (10..14).chain(20..24).collect::<Vec<u64>>());

    // All 8 responses arrived, so both engines have retired their share —
    // the fleet roll-up below is deterministic, not racing the workers.
    stream
        .write_all(b"{\"id\": 90, \"stats\": true, \"scope\": \"fleet\"}\n")
        .unwrap();
    stream.flush().unwrap();
    let fleet = read_json(&mut reader);
    assert_eq!(fleet.get("id").unwrap().as_u64().unwrap(), 90);
    assert_eq!(fleet.get("scope").unwrap().as_str().unwrap(), "fleet");
    assert_eq!(fleet.get("replicas").unwrap().as_usize().unwrap(), 2);
    let stats = fleet.get("stats").unwrap();
    assert_eq!(
        stats.get("requests_finished").unwrap().as_u64().unwrap(),
        8,
        "fleet counters must sum both replicas (4 + 4)"
    );
    assert_eq!(
        stats.get("e2e").unwrap().get("count").unwrap().as_u64().unwrap(),
        8,
        "merged histogram count must equal the sum of per-replica counts"
    );

    stream.write_all(b"{\"id\": 91, \"metrics\": true}\n").unwrap();
    stream.flush().unwrap();
    let metrics = read_json(&mut reader);
    assert_eq!(metrics.get("id").unwrap().as_u64().unwrap(), 91);
    let exposition = metrics.get("metrics").unwrap().as_str().unwrap().to_string();
    assert!(exposition.contains("# TYPE"), "not an exposition: {exposition}");
    assert!(exposition.contains("turboangle_requests_finished_total"));
    assert!(exposition.contains("turboangle_pool_pages_used"));

    // Ninth generation reaches max_requests and shuts the server down.
    stream
        .write_all(
            b"{\"id\": 30, \"prompt\": \"closing request\", \"max_new_tokens\": 4, \"session_key\": \"alice\"}\n",
        )
        .unwrap();
    stream.flush().unwrap();
    assert_eq!(read_json(&mut reader).get("id").unwrap().as_u64().unwrap(), 30);
    drop(reader);
    drop(stream);
    let summary = server.join().unwrap();

    assert_eq!(summary.served, 9, "stats/metrics responses do not count");
    assert_eq!(summary.replicas.len(), 2);
    assert_eq!(summary.traces.len(), 2, "one obs snapshot per replica");
    let finished: u64 = summary.replicas.iter().map(|m| m.requests_finished).sum();
    assert_eq!(finished, 9);

    // The collected snapshots round-trip through the Chrome exporter into
    // a parseable document: one complete-span event per retired request,
    // counter tracks from the sampled gauges, and a zero drop counter.
    let doc = export::chrome_trace(&summary.traces);
    let j = Json::parse(&doc).unwrap_or_else(|e| panic!("trace not parseable: {e}"));
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    let finish_spans = events
        .iter()
        .filter(|e| e.get("name").unwrap().as_str().unwrap() == "finish")
        .count();
    assert_eq!(
        finish_spans as u64, finished,
        "one finish span per request served anywhere in the fleet"
    );
    assert!(
        events
            .iter()
            .all(|e| e.get("pid").unwrap().as_usize().unwrap() < 2),
        "span pids must name one of the 2 replicas"
    );
    assert!(
        events
            .iter()
            .any(|e| e.get("ph").unwrap().as_str().unwrap() == "C"),
        "sampled gauges must appear as counter tracks"
    );
    let other = j.get("otherData").unwrap();
    assert_eq!(other.get("dropped_events").unwrap().as_u64().unwrap(), 0);
    assert_eq!(other.get("replicas").unwrap().as_usize().unwrap(), 2);
}

/// Build the engine against real artifacts + a real PJRT runtime. Returns
/// None (and the calling test SKIPS, passing vacuously) when either is
/// unavailable — artifacts need `make artifacts` (JAX), execution needs a
/// real xla binding instead of the rust/xla stub.
fn engine(quant: QuantConfig, capacity_pages: usize) -> Option<Engine> {
    let m = match Manifest::discover() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP: {e} (run `make artifacts`)");
            return None;
        }
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return None;
        }
    };
    let exec = ModelExecutor::load(&rt, &m, "smollm2-sim", Entry::Serve).unwrap();
    Some(Engine::new(
        exec,
        EngineConfig {
            capacity_pages,
            read_path: ReadPath::Auto, // PJRT backend: resolves to reinflate
            ..EngineConfig::new(quant)
        },
    ))
}

#[test]
fn full_workload_drains_and_frees_memory() {
    let quant = QuantConfig::paper_uniform(24).with_k8v4_log();
    let Some(mut e) = engine(quant, 2048) else { return };
    for req in workload::generate(&WorkloadSpec {
        n_requests: 6,
        prompt_min: 8,
        prompt_max: 40,
        gen_min: 3,
        gen_max: 8,
        seed: 11,
        sessions: 0,
        ..Default::default()
    }) {
        e.submit(req);
    }
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 6);
    assert!(e.metrics.tokens_generated >= 6 * 3_u64);
    let mem = e.memory_stats();
    assert_eq!(mem.sequences, 0, "all sequences freed");
    assert_eq!(mem.pages_allocated, 0, "all pages returned");
    let finished = e.take_finished();
    assert_eq!(finished.len(), 6);
    for s in finished {
        assert!(matches!(
            s.finished,
            Some(FinishReason::Length) | Some(FinishReason::Eos)
        ));
        assert!(s.generated.len() <= s.request.max_new_tokens);
    }
}

#[test]
fn compression_ratio_visible_in_cache() {
    let quant = QuantConfig::paper_uniform(24).with_k8v4_log();
    let Some(mut e) = engine(quant.clone(), 2048) else { return };
    // long generations so the cache fills up
    e.submit(Request::new(0, vec![100; 32], 24));
    e.submit(Request::new(1, vec![101; 32], 24));
    // drive until mid-flight, then inspect memory
    let mut ratio = 0.0;
    for _ in 0..2000 {
        e.tick().unwrap();
        let mem = e.memory_stats();
        if mem.tokens > 60 {
            ratio = mem.compression_ratio();
            break;
        }
        if !e.has_work() {
            break;
        }
    }
    // K8V4-log at K128V64, d=64: Eq.3 says 7.25 bits vs fp16's 16 ≈ 2.2x;
    // physical packing adds the page/word slack
    assert!(
        ratio > 1.8,
        "compressed cache ratio {ratio} below expectation"
    );
    e.run_to_completion().unwrap();
}

#[test]
fn fp_reference_mode_serves_too() {
    let mut quant = QuantConfig::none(24);
    quant.mode = Mode::None;
    quant = quant.with_norms(NormMode::FP32, NormMode::FP32);
    let Some(mut e) = engine(quant, 2048) else { return };
    e.submit(Request::new(0, vec![104, 101, 108, 108, 111], 4));
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 1);
}

#[test]
fn admission_control_holds_under_tiny_pool() {
    // pool of 8 pages * 16 tokens = 128 tokens; each request needs up to
    // prompt+gen; the batcher must reject what cannot fit and still finish
    // everything eventually as pages free up.
    let quant = QuantConfig::paper_uniform(24);
    let Some(mut e) = engine(quant, 8) else { return };
    for req in workload::generate(&WorkloadSpec {
        n_requests: 4,
        prompt_min: 8,
        prompt_max: 24,
        gen_min: 2,
        gen_max: 4,
        seed: 3,
        sessions: 0,
        ..Default::default()
    }) {
        e.submit(req);
    }
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 4, "all eventually served");
    assert_eq!(e.memory_stats().pages_allocated, 0);
}

#[test]
fn deterministic_generation_given_seeded_workload() {
    let quant = QuantConfig::paper_uniform(24);
    let run = || {
        let mut e = engine(quant.clone(), 1024)?;
        e.submit(Request::new(0, "the wodu zatu".bytes().map(|b| b as i32).collect(), 6));
        e.run_to_completion().unwrap();
        Some(e.take_finished().pop().unwrap().generated)
    };
    assert_eq!(run(), run(), "greedy decode must be deterministic");
}
