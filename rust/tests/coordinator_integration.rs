//! Coordinator integration: the full engine against real artifacts.
//! Requires `make artifacts`.

use turboangle::coordinator::{
    BatchPolicy, Engine, EngineConfig, FinishReason, Request, SchedulerPolicy,
};
use turboangle::quant::{Mode, NormMode, QuantConfig};
use turboangle::runtime::{Entry, Manifest, ModelExecutor, Runtime};
use turboangle::workload::{self, WorkloadSpec};

/// Build the engine against real artifacts + a real PJRT runtime. Returns
/// None (and the calling test SKIPS, passing vacuously) when either is
/// unavailable — artifacts need `make artifacts` (JAX), execution needs a
/// real xla binding instead of the rust/xla stub.
fn engine(quant: QuantConfig, capacity_pages: usize) -> Option<Engine> {
    let m = match Manifest::discover() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP: {e} (run `make artifacts`)");
            return None;
        }
    };
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("SKIP: {e:#}");
            return None;
        }
    };
    let exec = ModelExecutor::load(&rt, &m, "smollm2-sim", Entry::Serve).unwrap();
    Some(Engine::new(
        exec,
        EngineConfig {
            quant,
            batch_policy: BatchPolicy::default(),
            scheduler: SchedulerPolicy::default(),
            capacity_pages,
            page_tokens: 16,
        },
    ))
}

#[test]
fn full_workload_drains_and_frees_memory() {
    let quant = QuantConfig::paper_uniform(24).with_k8v4_log();
    let Some(mut e) = engine(quant, 2048) else { return };
    for req in workload::generate(&WorkloadSpec {
        n_requests: 6,
        prompt_min: 8,
        prompt_max: 40,
        gen_min: 3,
        gen_max: 8,
        seed: 11,
    }) {
        e.submit(req);
    }
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 6);
    assert!(e.metrics.tokens_generated >= 6 * 3_u64);
    let mem = e.memory_stats();
    assert_eq!(mem.sequences, 0, "all sequences freed");
    assert_eq!(mem.pages_allocated, 0, "all pages returned");
    let finished = e.take_finished();
    assert_eq!(finished.len(), 6);
    for s in finished {
        assert!(matches!(
            s.finished,
            Some(FinishReason::Length) | Some(FinishReason::Eos)
        ));
        assert!(s.generated.len() <= s.request.max_new_tokens);
    }
}

#[test]
fn compression_ratio_visible_in_cache() {
    let quant = QuantConfig::paper_uniform(24).with_k8v4_log();
    let Some(mut e) = engine(quant.clone(), 2048) else { return };
    // long generations so the cache fills up
    e.submit(Request::new(0, vec![100; 32], 24));
    e.submit(Request::new(1, vec![101; 32], 24));
    // drive until mid-flight, then inspect memory
    let mut ratio = 0.0;
    for _ in 0..2000 {
        e.tick().unwrap();
        let mem = e.memory_stats();
        if mem.tokens > 60 {
            ratio = mem.compression_ratio();
            break;
        }
        if !e.has_work() {
            break;
        }
    }
    // K8V4-log at K128V64, d=64: Eq.3 says 7.25 bits vs fp16's 16 ≈ 2.2x;
    // physical packing adds the page/word slack
    assert!(
        ratio > 1.8,
        "compressed cache ratio {ratio} below expectation"
    );
    e.run_to_completion().unwrap();
}

#[test]
fn fp_reference_mode_serves_too() {
    let mut quant = QuantConfig::none(24);
    quant.mode = Mode::None;
    quant = quant.with_norms(NormMode::FP32, NormMode::FP32);
    let Some(mut e) = engine(quant, 2048) else { return };
    e.submit(Request::new(0, vec![104, 101, 108, 108, 111], 4));
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 1);
}

#[test]
fn admission_control_holds_under_tiny_pool() {
    // pool of 8 pages * 16 tokens = 128 tokens; each request needs up to
    // prompt+gen; the batcher must reject what cannot fit and still finish
    // everything eventually as pages free up.
    let quant = QuantConfig::paper_uniform(24);
    let Some(mut e) = engine(quant, 8) else { return };
    for req in workload::generate(&WorkloadSpec {
        n_requests: 4,
        prompt_min: 8,
        prompt_max: 24,
        gen_min: 2,
        gen_max: 4,
        seed: 3,
    }) {
        e.submit(req);
    }
    e.run_to_completion().unwrap();
    assert_eq!(e.metrics.requests_finished, 4, "all eventually served");
    assert_eq!(e.memory_stats().pages_allocated, 0);
}

#[test]
fn deterministic_generation_given_seeded_workload() {
    let quant = QuantConfig::paper_uniform(24);
    let run = || {
        let mut e = engine(quant.clone(), 1024)?;
        e.submit(Request::new(0, "the wodu zatu".bytes().map(|b| b as i32).collect(), 6));
        e.run_to_completion().unwrap();
        Some(e.take_finished().pop().unwrap().generated)
    };
    assert_eq!(run(), run(), "greedy decode must be deterministic");
}
