//! STUB of the PJRT/XLA client binding used by `turboangle::runtime`.
//!
//! The real binding needs `libxla_extension` (see `/opt/xla-example` in the
//! build image), which is not linkable in every environment this repo must
//! compile in. This crate mirrors the exact API surface `runtime/pjrt.rs`
//! consumes so the whole workspace builds and tests everywhere:
//!
//! * [`Literal`] is a fully functional host-side tensor container
//!   (construct / reshape / read back round-trip for real),
//! * everything that would touch a device — [`PjRtClient::cpu`],
//!   compilation, execution — returns [`Error`] with an actionable message.
//!
//! Code that needs PJRT (artifact-backed tests, the serving CLI against
//! real HLOs) detects the error and skips or reports it. To use a real
//! binding, replace this crate or add a `[patch]` section in the root
//! `Cargo.toml` pointing `xla` at it.

use std::path::Path;

const UNAVAILABLE: &str = "PJRT/XLA backend unavailable: this build links the in-tree `xla` stub \
     crate (rust/xla). Native quantizer paths work; HLO execution requires \
     a real xla binding (see rust/xla/src/lib.rs)";

/// Error type matching the real binding's usage pattern (`{e:?}` formatting).
pub struct Error(String);

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error(UNAVAILABLE.to_string())
}

/// Element types a [`Literal`] can hold.
pub trait NativeType: Copy {
    const ELEM_BYTES: usize;
    fn write_le(self, out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Self;
}

macro_rules! native {
    ($t:ty, $n:expr) => {
        impl NativeType for $t {
            const ELEM_BYTES: usize = $n;
            fn write_le(self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn read_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("element width"))
            }
        }
    };
}

native!(f32, 4);
native!(f64, 8);
native!(i32, 4);
native!(i64, 8);
native!(u8, 1);

/// Host-side tensor literal: raw little-endian payload + dims.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<u8>,
    dims: Vec<i64>,
    elem_bytes: usize,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        let mut data = Vec::with_capacity(values.len() * T::ELEM_BYTES);
        for &v in values {
            v.write_le(&mut data);
        }
        Literal {
            data,
            dims: vec![values.len() as i64],
            elem_bytes: T::ELEM_BYTES,
        }
    }

    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        let mut data = Vec::with_capacity(T::ELEM_BYTES);
        value.write_le(&mut data);
        Literal {
            data,
            dims: Vec::new(),
            elem_bytes: T::ELEM_BYTES,
        }
    }

    /// Same payload under new dims; errors when the element count differs.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, Error> {
        let want: i64 = dims.iter().product();
        let have = (self.data.len() / self.elem_bytes) as i64;
        if want != have {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count {have} != {want}",
                self.dims
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            dims: dims.to_vec(),
            elem_bytes: self.elem_bytes,
        })
    }

    pub fn element_count(&self) -> usize {
        self.data.len() / self.elem_bytes
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read the payload back as `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        if self.elem_bytes != T::ELEM_BYTES {
            return Err(Error(format!(
                "to_vec: literal holds {}-byte elements, requested {}-byte",
                self.elem_bytes,
                T::ELEM_BYTES
            )));
        }
        Ok(self
            .data
            .chunks_exact(T::ELEM_BYTES)
            .map(T::read_le)
            .collect())
    }

    /// Decompose a tuple literal. Stub literals are never tuples (only real
    /// executions produce them), so this always errors.
    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}

/// Parsed HLO module (stub: retains the source path for error messages).
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto, Error> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error(format!("no such HLO artifact: {}", path.display())));
        }
        Ok(HloModuleProto {
            path: path.display().to_string(),
        })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    origin: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            origin: proto.path.clone(),
        }
    }
}

/// PJRT client (stub: construction fails so callers can gate early).
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(format!(
            "cannot compile {}: {UNAVAILABLE}",
            computation.origin
        )))
    }
}

/// A compiled executable (unreachable through the stub client).
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

/// A device buffer (unreachable through the stub client).
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.25]);
        assert_eq!(l.dims(), &[3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn literal_reshape_checks_count() {
        let l = Literal::vec1(&[0i32; 6]);
        assert_eq!(l.reshape(&[2, 3]).unwrap().dims(), &[2, 3]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_and_type_mismatch() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.element_count(), 1);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
        // same width type punning is allowed (f32/i32 both 4 bytes)…
        assert!(l.to_vec::<f32>().is_ok());
        // …but width mismatch is not
        assert!(l.to_vec::<f64>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e:?}").contains("stub"));
    }
}
