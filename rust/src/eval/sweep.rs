//! Table drivers: the parameter sweeps behind Tables 1, 2, 3, 5, 6.
//!
//! Each function returns structured rows; `report::` renders them in the
//! paper's layout and the `table*` CLI subcommands / benches call through
//! here. DESIGN.md §4 maps each table to its driver.

use super::ppl::PplHarness;
use crate::quant::{config::compact_ranges, Mode, NormMode, QuantConfig};
use anyhow::Result;

// ---------------------------------------------------------------------------
// Table 1: angular vs scalar quantization
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub method: String,
    pub bits: f64,
    pub delta_ppl: f64,
}

/// One model's Table-1 column. `fine` adds the §4.8 non-monotone probes
/// (n=56 vs 64); `centered` swaps in the centered-bin ablation decode.
pub fn table1(h: &PplHarness, fine: bool, centered: bool) -> Result<Vec<Table1Row>> {
    let l = h.n_layers();
    let mode = if centered { Mode::AngleCentered } else { Mode::Angle };
    let mut rows = Vec::new();
    let mut bins: Vec<u32> = vec![32, 48, 64, 128];
    if fine {
        bins.insert(2, 56);
    }
    for n in bins {
        let mut cfg = QuantConfig::uniform(l, n, n);
        cfg.mode = mode;
        rows.push(Table1Row {
            method: format!("TurboAngle (n={n})"),
            bits: cfg.angle_bits_per_element(),
            delta_ppl: h.delta_ppl(&cfg)?,
        });
    }
    for bits in [4u32, 3] {
        let cfg = QuantConfig::scalar_baseline(l, Mode::TqSymG4, bits);
        rows.push(Table1Row {
            method: format!("TQ-sym{bits}-g4"),
            bits: bits as f64,
            delta_ppl: h.delta_ppl(&cfg)?,
        });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 2 + 3: per-layer early-boost
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct BoostResult {
    pub model: String,
    pub n_layers: usize,
    pub ppl_base: f64,
    pub uniform_delta: f64,
    pub best_delta: f64,
    pub best_bits: f64,
    pub best_cfg: QuantConfig,
    pub boosted_layers: Vec<usize>,
    pub bottleneck: String,
    /// every sweep point, for the table-3 notes + EXPERIMENTS.md
    pub sweep_log: Vec<(String, f64)>,
}

/// The §3.2 heuristic sweep, extended the way §4.3 describes: contiguous
/// E∈{4,8,...} at (256,128)/(128,256)/(256,64), plus the phi-style
/// complement-of-worst-group selective config when contiguous stalls.
pub fn early_boost_sweep(h: &PplHarness, model: &str) -> Result<BoostResult> {
    let l = h.n_layers();
    let ppl_base = h.baseline_ppl()?;
    let uniform = QuantConfig::paper_uniform(l);
    let uniform_delta = h.delta_ppl(&uniform)?;
    let mut log: Vec<(String, f64)> = vec![("uniform".into(), uniform_delta)];

    let mut best: (f64, QuantConfig) = (uniform_delta, uniform.clone());
    let variants: [(u32, u32); 3] = [(256, 128), (128, 256), (256, 64)];
    let mut early_counts: Vec<usize> = vec![4, 8, 16];
    // include "almost all layers" probes for broad-sensitivity models
    early_counts.push(l * 2 / 3);
    early_counts.push(l - l / 8);
    early_counts.sort_unstable();
    early_counts.dedup();

    for &(nk, nv) in &variants {
        for &e in &early_counts {
            if e == 0 || e >= l {
                continue;
            }
            let cfg = QuantConfig::early_boost(l, e, nk, nv);
            let d = h.delta_ppl(&cfg)?;
            log.push((cfg.tag(), d));
            if d < best.0 {
                best = (d, cfg);
            }
        }
    }

    // selective probe: boost everything EXCEPT the middle third
    // (the phi-1.5 pattern — §4.4)
    let third = l / 3;
    let sel: Vec<usize> = (0..third).chain(2 * third..l).collect();
    let cfg = QuantConfig::selective_boost(l, &sel, 256, 128);
    let d = h.delta_ppl(&cfg)?;
    log.push((cfg.tag(), d));
    if d < best.0 {
        best = (d, cfg);
    }

    let (best_delta, best_cfg) = best;
    let base = best_cfg.majority_bins();
    let boosted: Vec<usize> = best_cfg
        .layers
        .iter()
        .enumerate()
        .filter(|(_, b)| **b != base)
        .map(|(i, _)| i)
        .collect();
    let bottleneck = if boosted.is_empty() {
        "none".to_string()
    } else {
        let hi = best_cfg.layers[boosted[0]];
        match (hi.n_k > base.n_k, hi.n_v > base.n_v) {
            (true, true) => "K+V".into(),
            (true, false) => "K-dom".into(),
            (false, true) => "V-dom".into(),
            _ => "none".into(),
        }
    };
    Ok(BoostResult {
        model: model.to_string(),
        n_layers: l,
        ppl_base,
        uniform_delta,
        best_delta,
        best_bits: best_cfg.angle_bits_per_element(),
        best_cfg,
        boosted_layers: boosted,
        bottleneck,
        sweep_log: log,
    })
}

impl BoostResult {
    pub fn boosted_range(&self) -> String {
        if self.boosted_layers.is_empty() {
            "-".into()
        } else {
            compact_ranges(&self.boosted_layers)
        }
    }
}

// ---------------------------------------------------------------------------
// Table 5: norm quantization
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table5Row {
    pub model: String,
    pub d_head: usize,
    pub fp32_delta: f64,
    pub norm8_delta: f64,
    pub k8v4_delta: f64,
    pub k8v4_bits: f64,
}

/// fp32 / norm8 / K8V4-log on top of a model's best per-layer config.
pub fn table5(h: &PplHarness, model: &str, best: &QuantConfig) -> Result<Table5Row> {
    let fp32 = best.clone().with_norms(NormMode::FP32, NormMode::FP32);
    let norm8 = best.clone().with_norm8();
    let k8v4 = best.clone().with_k8v4_log();
    Ok(Table5Row {
        model: model.to_string(),
        d_head: h.d_head(),
        fp32_delta: h.delta_ppl(&fp32)?,
        norm8_delta: h.delta_ppl(&norm8)?,
        k8v4_delta: h.delta_ppl(&k8v4)?,
        k8v4_bits: k8v4.total_bits_per_element(h.d_head()),
    })
}

// ---------------------------------------------------------------------------
// Table 6: vs calibration-style quantizers (reimplemented, same harness)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table6Row {
    pub method: String,
    pub total_bits: f64,
    pub delta_ppl: f64,
    pub calibration: bool,
    pub source: String,
}

/// Runs our reimplementations on the SAME model+data (apples-to-apples,
/// unlike the paper's Table 6 which quotes foreign numbers — DESIGN.md §2).
pub fn table6(h: &PplHarness, best: &QuantConfig) -> Result<Vec<Table6Row>> {
    let l = h.n_layers();
    let d = h.d_head();
    let mut rows = Vec::new();
    // KIVI-style per-channel asymmetric (2- and 4-bit)
    for bits in [2u32, 4] {
        let cfg = QuantConfig::scalar_baseline(l, Mode::Kivi, bits);
        rows.push(Table6Row {
            method: format!("KIVI-style ch-asym {bits}b"),
            total_bits: bits as f64,
            delta_ppl: h.delta_ppl(&cfg)?,
            calibration: true,
            source: "reimpl".into(),
        });
    }
    // KVQuant-style per-vector + 1% outliers (4-bit)
    let cfg = QuantConfig::scalar_baseline(l, Mode::KvQuant, 4);
    rows.push(Table6Row {
        method: "KVQuant-style 4b-1%".into(),
        total_bits: 4.32, // 4b + outlier overhead, as the paper reports it
        delta_ppl: h.delta_ppl(&cfg)?,
        calibration: true,
        source: "reimpl".into(),
    });
    // TurboAngle end-to-end configurations
    let k8v4 = best.clone().with_k8v4_log();
    rows.push(Table6Row {
        method: "TurboAngle K8V4-log".into(),
        total_bits: k8v4.total_bits_per_element(d),
        delta_ppl: h.delta_ppl(&k8v4)?,
        calibration: false,
        source: "this repro".into(),
    });
    let norm8 = best.clone().with_norm8();
    rows.push(Table6Row {
        method: "TurboAngle norm8".into(),
        total_bits: norm8.total_bits_per_element(d),
        delta_ppl: h.delta_ppl(&norm8)?,
        calibration: false,
        source: "this repro".into(),
    });
    Ok(rows)
}

// ---------------------------------------------------------------------------
// K vs V sensitivity (§4.5)
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct KvSensRow {
    pub variant: String,
    pub delta_ppl: f64,
}

pub fn kv_sensitivity(h: &PplHarness, n_early: usize) -> Result<Vec<KvSensRow>> {
    let l = h.n_layers();
    let mut rows = Vec::new();
    for (nk, nv) in [(256u32, 128u32), (128, 256), (256, 64), (512, 64)] {
        let cfg = QuantConfig::early_boost(l, n_early, nk, nv);
        rows.push(KvSensRow {
            variant: format!("E{n_early}(K{nk},V{nv})"),
            delta_ppl: h.delta_ppl(&cfg)?,
        });
    }
    Ok(rows)
}
