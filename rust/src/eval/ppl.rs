//! Perplexity harness — the paper's measurement protocol (§4.1).
//!
//! Held-out chunks (non-overlapping, fixed length; paper: 32×1024 on
//! WikiText-2, scaled via the manifest) are teacher-forced through the
//! eval_fwd artifact; PPL = exp(Σ nll / Σ tokens) and ΔPPL is relative to
//! the unquantized (mode=None) run of the SAME weights — mirroring the
//! paper's "relative to fp16 inference" convention.
//!
//! The harness programs against [`ModelBackend`], not a concrete executor:
//! [`PplHarness::new`] wires the PJRT-backed `ModelExecutor` to its
//! manifest-shipped chunk file, while [`PplHarness::sim`] synthesizes a
//! deterministic held-out stream for `SimExecutor` — so the full paper
//! loop (layer-group sweep → boosted schedule → serve) runs artifact-free
//! in CI.

use crate::quant::QuantConfig;
use crate::runtime::{tensorfile, Manifest, ModelBackend, ModelExecutor, SimExecutor};
use crate::util::hash::splitmix64 as mix;
use anyhow::{ensure, Result};
use std::cell::RefCell;
use std::collections::HashMap;

pub struct PplHarness {
    exec: Box<dyn ModelBackend>,
    chunks: Vec<i32>,
    n_chunks: usize,
    chunk_len: usize,
    batch: usize,
    cache: RefCell<HashMap<String, f64>>,
    baseline: RefCell<Option<f64>>,
    /// Executions performed (for EXPERIMENTS.md bookkeeping).
    pub evals_run: RefCell<usize>,
}

impl PplHarness {
    /// Harness over the PJRT executor, reading the manifest's held-out
    /// `eval_chunks.tang`.
    pub fn new(manifest: &Manifest, exec: ModelExecutor) -> Result<Self> {
        let t = tensorfile::read(manifest.path("eval_chunks.tang"))?;
        let chunks_t = &t["chunks"];
        ensure!(chunks_t.shape[0] == manifest.eval.chunks);
        ensure!(chunks_t.shape[1] == manifest.eval.chunk_len);
        Self::from_backend(Box::new(exec), chunks_t.as_i32()?)
    }

    /// Artifact-free harness over the deterministic sim: the held-out
    /// stream is synthesized from the backend's eval protocol, so the
    /// sensitivity loop needs no PJRT artifacts anywhere.
    pub fn sim(exec: SimExecutor) -> Result<Self> {
        let proto = ModelBackend::eval_protocol(&exec).clone();
        let top = ModelBackend::profile(&exec).vocab.min(250) as u64;
        let mut chunks = Vec::with_capacity(proto.chunks * proto.chunk_len);
        let mut h = 0xC0FF_EEu64;
        for _ in 0..proto.chunks * proto.chunk_len {
            h = mix(h ^ 0x9E37);
            chunks.push(1 + (h % top) as i32);
        }
        Self::from_backend(Box::new(exec), chunks)
    }

    /// Harness over any eval-capable backend and its held-out chunk
    /// stream (`eval_protocol().chunks × chunk_len` tokens, row-major).
    pub fn from_backend(exec: Box<dyn ModelBackend>, chunks: Vec<i32>) -> Result<Self> {
        let proto = exec.eval_protocol();
        let (n_chunks, chunk_len, batch) = (proto.chunks, proto.chunk_len, proto.batch);
        ensure!(
            chunks.len() == n_chunks * chunk_len,
            "chunk stream is {} tokens, protocol wants {}x{}",
            chunks.len(),
            n_chunks,
            chunk_len
        );
        ensure!(
            batch >= 1 && n_chunks % batch == 0,
            "eval chunk count {n_chunks} must be a positive multiple of the eval batch {batch}"
        );
        Ok(PplHarness {
            exec,
            chunks,
            n_chunks,
            chunk_len,
            batch,
            cache: RefCell::new(HashMap::new()),
            baseline: RefCell::new(None),
            evals_run: RefCell::new(0),
        })
    }

    /// PPL for a config (memoized by config tag).
    pub fn ppl(&self, cfg: &QuantConfig) -> Result<f64> {
        let key = format!("{cfg:?}");
        if let Some(&v) = self.cache.borrow().get(&key) {
            return Ok(v);
        }
        let mut nll_sum = 0.0f64;
        let mut cnt_sum = 0.0f64;
        let mut i = 0;
        while i < self.n_chunks {
            let rows = &self.chunks
                [i * self.chunk_len..(i + self.batch) * self.chunk_len];
            let (nll, cnt) = self.exec.eval_nll(rows, cfg)?;
            nll_sum += nll.iter().map(|&v| v as f64).sum::<f64>();
            cnt_sum += cnt.iter().map(|&v| v as f64).sum::<f64>();
            i += self.batch;
        }
        let ppl = (nll_sum / cnt_sum).exp();
        *self.evals_run.borrow_mut() += 1;
        self.cache.borrow_mut().insert(key, ppl);
        Ok(ppl)
    }

    /// Unquantized reference PPL (memoized).
    pub fn baseline_ppl(&self) -> Result<f64> {
        if let Some(v) = *self.baseline.borrow() {
            return Ok(v);
        }
        let v = self.ppl(&QuantConfig::none(self.n_layers()))?;
        *self.baseline.borrow_mut() = Some(v);
        Ok(v)
    }

    /// ΔPPL = PPL(cfg) − PPL(reference).
    pub fn delta_ppl(&self, cfg: &QuantConfig) -> Result<f64> {
        Ok(self.ppl(cfg)? - self.baseline_ppl()?)
    }

    /// The rotation diagonal currently in effect on the backend.
    pub fn sign(&self) -> Vec<f32> {
        self.exec.sign().to_vec()
    }

    /// Swap the rotation diagonal and invalidate every memoized PPL
    /// (including the reference run) — used by the D-seed sweep.
    pub fn set_sign(&mut self, sign: &[f32]) -> Result<()> {
        self.exec.set_sign(sign)?;
        self.cache.borrow_mut().clear();
        *self.baseline.borrow_mut() = None;
        Ok(())
    }

    pub fn n_layers(&self) -> usize {
        self.exec.profile().n_layers
    }

    pub fn d_head(&self) -> usize {
        self.exec.profile().d_head
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_harness_runs_the_paper_loop_without_artifacts() {
        let h = PplHarness::sim(SimExecutor::with_dims(1, 8, 2, 8, 4, 32, 64)).unwrap();
        let base = h.baseline_ppl().unwrap();
        assert!(base.is_finite() && base > 1.0);
        let uniform = h.delta_ppl(&QuantConfig::paper_uniform(8)).unwrap();
        let boosted = h.delta_ppl(&QuantConfig::early_boost(8, 4, 256, 128)).unwrap();
        assert!(uniform > 0.0, "{uniform}");
        assert!(boosted < uniform, "boost must help: {boosted} vs {uniform}");
        // memoization: re-asking runs no extra evals
        let runs = *h.evals_run.borrow();
        let _ = h.delta_ppl(&QuantConfig::paper_uniform(8)).unwrap();
        assert_eq!(*h.evals_run.borrow(), runs);
    }

    #[test]
    fn sign_swap_invalidates_memo() {
        let mut h = PplHarness::sim(SimExecutor::new(3)).unwrap();
        let cfg = QuantConfig::paper_uniform(2);
        let a = h.delta_ppl(&cfg).unwrap();
        let mut sign = h.sign();
        assert_eq!(sign.len(), h.d_head());
        sign[0] = -1.0;
        h.set_sign(&sign).unwrap();
        let b = h.delta_ppl(&cfg).unwrap();
        assert_ne!(a, b, "memo must not survive a diagonal swap");
    }
}
