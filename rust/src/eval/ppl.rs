//! Perplexity harness — the paper's measurement protocol (§4.1).
//!
//! Held-out chunks (non-overlapping, fixed length; paper: 32×1024 on
//! WikiText-2, scaled via the manifest) are teacher-forced through the
//! eval_fwd artifact; PPL = exp(Σ nll / Σ tokens) and ΔPPL is relative to
//! the unquantized (mode=None) run of the SAME weights — mirroring the
//! paper's "relative to fp16 inference" convention.

use crate::quant::QuantConfig;
use crate::runtime::{tensorfile, Manifest, ModelExecutor};
use anyhow::{ensure, Result};
use std::cell::RefCell;
use std::collections::HashMap;

pub struct PplHarness {
    pub exec: ModelExecutor,
    chunks: Vec<i32>,
    n_chunks: usize,
    chunk_len: usize,
    cache: RefCell<HashMap<String, f64>>,
    baseline: RefCell<Option<f64>>,
    /// Executions performed (for EXPERIMENTS.md bookkeeping).
    pub evals_run: RefCell<usize>,
}

impl PplHarness {
    pub fn new(manifest: &Manifest, exec: ModelExecutor) -> Result<Self> {
        let t = tensorfile::read(manifest.path("eval_chunks.tang"))?;
        let chunks_t = &t["chunks"];
        let n_chunks = chunks_t.shape[0];
        let chunk_len = chunks_t.shape[1];
        ensure!(n_chunks == manifest.eval.chunks);
        ensure!(chunk_len == manifest.eval.chunk_len);
        Ok(PplHarness {
            exec,
            chunks: chunks_t.as_i32()?,
            n_chunks,
            chunk_len,
            cache: RefCell::new(HashMap::new()),
            baseline: RefCell::new(None),
            evals_run: RefCell::new(0),
        })
    }

    /// PPL for a config (memoized by config tag).
    pub fn ppl(&self, cfg: &QuantConfig) -> Result<f64> {
        let key = format!("{cfg:?}");
        if let Some(&v) = self.cache.borrow().get(&key) {
            return Ok(v);
        }
        let batch = self.exec.eval_proto.batch;
        let mut nll_sum = 0.0f64;
        let mut cnt_sum = 0.0f64;
        let mut i = 0;
        while i < self.n_chunks {
            let rows = &self.chunks
                [i * self.chunk_len..(i + batch) * self.chunk_len];
            let (nll, cnt) = self.exec.eval_nll(rows, cfg)?;
            nll_sum += nll.iter().map(|&v| v as f64).sum::<f64>();
            cnt_sum += cnt.iter().map(|&v| v as f64).sum::<f64>();
            i += batch;
        }
        let ppl = (nll_sum / cnt_sum).exp();
        *self.evals_run.borrow_mut() += 1;
        self.cache.borrow_mut().insert(key, ppl);
        Ok(ppl)
    }

    /// Unquantized reference PPL (memoized).
    pub fn baseline_ppl(&self) -> Result<f64> {
        if let Some(v) = *self.baseline.borrow() {
            return Ok(v);
        }
        let v = self.ppl(&QuantConfig::none(self.exec.profile.n_layers))?;
        *self.baseline.borrow_mut() = Some(v);
        Ok(v)
    }

    /// ΔPPL = PPL(cfg) − PPL(reference).
    pub fn delta_ppl(&self, cfg: &QuantConfig) -> Result<f64> {
        Ok(self.ppl(cfg)? - self.baseline_ppl()?)
    }

    /// Swap the rotation diagonal and invalidate every memoized PPL
    /// (including the reference run) — used by the D-seed sweep.
    pub fn set_sign(&mut self, sign: &[f32]) -> Result<()> {
        self.exec.set_sign(sign)?;
        self.cache.borrow_mut().clear();
        *self.baseline.borrow_mut() = None;
        Ok(())
    }

    pub fn n_layers(&self) -> usize {
        self.exec.profile.n_layers
    }

    pub fn d_head(&self) -> usize {
        self.exec.profile.d_head
    }
}
