//! The §3.2 configuration-search heuristic, as the paper prescribes it:
//!
//!   1. test n_early ∈ {4, 8, 16} with (256,128) and (128,256),
//!   2. pick whichever gives lower ΔPPL,
//!   3. adjust n_early while improvement continues.
//!
//! Budgeted at 3–5 evaluation runs beyond the two reference runs — this is
//! the "zero calibration, few evals" deployment story, distinct from the
//! exhaustive `sweep::early_boost_sweep` used to regenerate Table 2.

use super::ppl::PplHarness;
use crate::quant::QuantConfig;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct SearchStep {
    pub tag: String,
    pub delta_ppl: f64,
}

#[derive(Clone, Debug)]
pub struct SearchResult {
    pub steps: Vec<SearchStep>,
    pub best: QuantConfig,
    pub best_delta: f64,
    pub evals_used: usize,
}

pub fn heuristic_search(h: &PplHarness, budget: usize) -> Result<SearchResult> {
    let l = h.n_layers();
    let mut steps = Vec::new();
    let mut evals = 0usize;
    let mut best = (f64::INFINITY, QuantConfig::paper_uniform(l));

    let try_cfg = |cfg: QuantConfig,
                       steps: &mut Vec<SearchStep>,
                       best: &mut (f64, QuantConfig),
                       evals: &mut usize|
     -> Result<f64> {
        let d = h.delta_ppl(&cfg)?;
        steps.push(SearchStep {
            tag: cfg.tag(),
            delta_ppl: d,
        });
        *evals += 1;
        if d < best.0 {
            *best = (d, cfg);
        }
        Ok(d)
    };

    // step 1: probe direction at E4 (2 evals)
    let d_k = try_cfg(
        QuantConfig::early_boost(l, 4, 256, 128),
        &mut steps,
        &mut best,
        &mut evals,
    )?;
    let d_v = try_cfg(
        QuantConfig::early_boost(l, 4, 128, 256),
        &mut steps,
        &mut best,
        &mut evals,
    )?;
    let (nk, nv) = if d_k <= d_v { (256, 128) } else { (128, 256) };

    // step 2/3: grow n_early while it helps, within budget
    let mut prev = best.0;
    for e in [8usize, 16, l * 2 / 3, l - l / 8] {
        if evals >= budget || e >= l {
            break;
        }
        let d = try_cfg(
            QuantConfig::early_boost(l, e, nk, nv),
            &mut steps,
            &mut best,
            &mut evals,
        )?;
        if d > prev {
            break; // §3.2: stop when improvement stops
        }
        prev = d;
    }

    Ok(SearchResult {
        best_delta: best.0,
        best: best.1,
        evals_used: evals,
        steps,
    })
}
