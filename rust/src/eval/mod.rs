//! Evaluation harness: the perplexity protocol and every table's sweep
//! driver (DESIGN.md §4 maps tables → functions here).

pub mod allocate;
pub mod ppl;
pub mod search;
pub mod seeds;
pub mod sensitivity;
pub mod sweep;

pub use ppl::PplHarness;
