//! Layer-group sensitivity analysis (paper §4.4, Table 4).
//!
//! Partition the layers into groups of `group_size`, boost exactly one
//! group at a time to (256,128), then test the paper's combination probes
//! (E8, E8+G4, E8+G5, E8+G4+G5, E8+G2+G4+G5) to expose non-additive and
//! negative-transfer structure.

use super::ppl::PplHarness;
use crate::quant::QuantConfig;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct GroupRow {
    pub group: String,
    pub layers: (usize, usize), // inclusive range
    pub delta_ppl: f64,
}

#[derive(Clone, Debug)]
pub struct SensitivityReport {
    pub uniform_delta: f64,
    pub singles: Vec<GroupRow>,
    pub combos: Vec<GroupRow>,
    /// groups whose single-boost ΔPPL exceeds uniform (negative transfer)
    pub negative_transfer: Vec<String>,
}

fn group_layers(g: usize, size: usize) -> Vec<usize> {
    (g * size..(g + 1) * size).collect()
}

pub fn layer_group_sweep(h: &PplHarness, group_size: usize) -> Result<SensitivityReport> {
    let l = h.n_layers();
    let n_groups = l / group_size;
    let uniform_delta = h.delta_ppl(&QuantConfig::paper_uniform(l))?;

    let mut singles = Vec::new();
    for g in 0..n_groups {
        let layers = group_layers(g, group_size);
        let cfg = QuantConfig::selective_boost(l, &layers, 256, 128);
        singles.push(GroupRow {
            group: format!("G{g}"),
            layers: (layers[0], *layers.last().unwrap()),
            delta_ppl: h.delta_ppl(&cfg)?,
        });
    }

    // the paper's combination probes, generalized to n_groups
    let mut combos = Vec::new();
    let mut probe = |name: String, groups: &[usize]| -> Result<()> {
        let layers: Vec<usize> = groups
            .iter()
            .flat_map(|&g| group_layers(g, group_size))
            .collect();
        let cfg = QuantConfig::selective_boost(l, &layers, 256, 128);
        combos.push(GroupRow {
            group: name,
            layers: (layers[0], *layers.last().unwrap()),
            delta_ppl: h.delta_ppl(&cfg)?,
        });
        Ok(())
    };
    let last = n_groups - 1;
    let second_last = n_groups - 2;
    probe("E8 (G0+G1)".into(), &[0, 1])?;
    probe(format!("E8+G{second_last}"), &[0, 1, second_last])?;
    probe(format!("E8+G{last}"), &[0, 1, last])?;
    probe(
        format!("E8+G{second_last}+G{last}"),
        &[0, 1, second_last, last],
    )?;
    probe(
        format!("E8+G2+G{second_last}+G{last}"),
        &[0, 1, 2, second_last, last],
    )?;

    let negative_transfer = singles
        .iter()
        .filter(|r| r.delta_ppl > uniform_delta)
        .map(|r| r.group.clone())
        .collect();

    Ok(SensitivityReport {
        uniform_delta,
        singles,
        combos,
        negative_transfer,
    })
}
