//! Greedy per-layer bit allocation — an EXTENSION beyond the paper's
//! early-boost heuristic (§3.2 stops at contiguous/selective hand
//! schedules; the paper's future-work direction is automatic allocation).
//!
//! Algorithm: start from the uniform baseline; repeatedly take the single
//! (layer, side) doubling with the best measured ΔPPL improvement per
//! added bit, until the bit budget is exhausted or no doubling helps.
//! Pure measurement-driven, still zero calibration *data* (only the same
//! eval chunks every config search uses).

use super::ppl::PplHarness;
use crate::quant::{LayerBins, QuantConfig};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct AllocStep {
    pub layer: usize,
    pub side: char, // 'K' or 'V'
    pub new_bins: u32,
    pub delta_ppl: f64,
    pub bits: f64,
}

#[derive(Clone, Debug)]
pub struct AllocResult {
    pub steps: Vec<AllocStep>,
    pub best: QuantConfig,
    pub best_delta: f64,
    pub evals_used: usize,
}

/// Greedy allocation. `bit_budget` is the max average angle bits/element
/// (Eq. 1); `group` coarsens the search: layers are moved in blocks of
/// `group` to keep the eval count practical.
pub fn greedy_allocate(
    h: &PplHarness,
    bit_budget: f64,
    group: usize,
    max_bins: u32,
) -> Result<AllocResult> {
    let l = h.n_layers();
    let mut cfg = QuantConfig::paper_uniform(l);
    let mut cur_delta = h.delta_ppl(&cfg)?;
    let mut evals = 1usize;
    let mut steps = Vec::new();
    let n_groups = l.div_ceil(group);

    loop {
        // candidate moves: double n_K or n_V of one group
        let mut best_move: Option<(QuantConfig, f64, usize, char, u32)> = None;
        for g in 0..n_groups {
            let lo = g * group;
            let hi = ((g + 1) * group).min(l);
            for side in ['K', 'V'] {
                let mut cand = cfg.clone();
                let mut new_bins = 0;
                for layer in lo..hi {
                    let LayerBins { n_k, n_v } = cand.layers[layer];
                    match side {
                        'K' if n_k < max_bins => {
                            cand.layers[layer].n_k = n_k * 2;
                            new_bins = n_k * 2;
                        }
                        'V' if n_v < max_bins => {
                            cand.layers[layer].n_v = n_v * 2;
                            new_bins = n_v * 2;
                        }
                        _ => {}
                    }
                }
                if new_bins == 0 || cand.angle_bits_per_element() > bit_budget {
                    continue;
                }
                let d = h.delta_ppl(&cand)?;
                evals += 1;
                if best_move.as_ref().is_none_or(|(_, bd, ..)| d < *bd) {
                    best_move = Some((cand, d, lo, side, new_bins));
                }
            }
        }
        match best_move {
            Some((cand, d, layer, side, new_bins)) if d < cur_delta => {
                steps.push(AllocStep {
                    layer,
                    side,
                    new_bins,
                    delta_ppl: d,
                    bits: cand.angle_bits_per_element(),
                });
                cfg = cand;
                cur_delta = d;
            }
            _ => break, // no improving move within budget
        }
    }
    Ok(AllocResult {
        steps,
        best_delta: cur_delta,
        best: cfg,
        evals_used: evals,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_formula_guard() {
        // a doubling of one side of one 4-layer group on L=24 adds
        // 4 * (1/4) / 24 bits — make sure Eq.1 in QuantConfig agrees
        let base = QuantConfig::paper_uniform(24);
        let mut boosted = base.clone();
        for l in 0..4 {
            boosted.layers[l].n_k *= 2;
        }
        let diff = boosted.angle_bits_per_element() - base.angle_bits_per_element();
        assert!((diff - 4.0 * 0.25 / 24.0).abs() < 1e-12);
    }
}
