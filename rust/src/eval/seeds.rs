//! D-seed sensitivity — the paper's stated limitation ("confidence
//! intervals over multiple seeds for the random diagonal D are not
//! reported").
//!
//! The sign diagonal is a *runtime input* to every artifact here, so we
//! can re-evaluate any config under fresh ±1 diagonals without
//! recompiling anything and report ΔPPL mean ± spread across seeds.

use super::ppl::PplHarness;
use crate::quant::QuantConfig;
use crate::runtime::{Manifest, ModelExecutor};
use anyhow::Result;

/// Deterministic ±1 diagonal from a seed (xorshift*; independent of the
/// numpy-generated build-time diagonal, which is seed index 0).
pub fn sign_diag(d: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..d)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            if s.wrapping_mul(0x2545F4914F6CDD1D) >> 63 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect()
}

#[derive(Clone, Debug)]
pub struct SeedSweep {
    pub deltas: Vec<f64>,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// ΔPPL for `cfg` under `n_seeds` independent diagonals (seed 0 = the
/// build-time numpy diagonal shipped in the weights).
pub fn seed_sweep(h: &mut PplHarness, cfg: &QuantConfig, n_seeds: usize) -> Result<SeedSweep> {
    let d = h.d_head();
    let original = h.sign();
    let mut deltas = Vec::new();
    for seed in 0..n_seeds as u64 {
        let sign = if seed == 0 {
            original.clone()
        } else {
            sign_diag(d, seed)
        };
        h.set_sign(&sign)?; // clears the PPL memo (baseline included)
        deltas.push(h.delta_ppl(cfg)?);
    }
    h.set_sign(&original)?;
    let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
    let var = deltas.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
        / (deltas.len() as f64 - 1.0).max(1.0);
    Ok(SeedSweep {
        mean,
        std: var.sqrt(),
        min: deltas.iter().cloned().fold(f64::INFINITY, f64::min),
        max: deltas.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        deltas,
    })
}

/// Sweep the standard config set over a prebuilt harness — any
/// eval-capable backend works, so the sim harness runs this artifact-free.
pub fn run_with(h: &mut PplHarness, n_seeds: usize) -> Result<Vec<(String, SeedSweep)>> {
    let l = h.n_layers();
    let mut out = Vec::new();
    for cfg in [
        QuantConfig::paper_uniform(l),
        QuantConfig::early_boost(l, 4, 256, 128),
        QuantConfig::paper_uniform(l).with_k8v4_log(),
    ] {
        let sweep = seed_sweep(h, &cfg, n_seeds)?;
        out.push((cfg.tag(), sweep));
    }
    Ok(out)
}

/// Convenience: build the PJRT harness and sweep the standard config set.
pub fn run(
    manifest: &Manifest,
    exec: ModelExecutor,
    n_seeds: usize,
) -> Result<Vec<(String, SeedSweep)>> {
    let mut h = PplHarness::new(manifest, exec)?;
    run_with(&mut h, n_seeds)
}
