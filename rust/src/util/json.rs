//! Minimal JSON parser (offline environment: serde is unavailable, so the
//! manifest contract gets a first-class in-tree parser instead).
//!
//! Supports the full JSON grammar minus `\uXXXX` surrogate pairs (the
//! manifest is ASCII). Numbers parse as f64; helpers coerce.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing data at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        Ok(self.as_usize()? as u64)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found '{}'",
                c as char,
                self.i,
                self.peek()? as char
            )
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found '{}'", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found '{}'", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| anyhow!("bad codepoint {cp}"))?,
                            );
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = match c {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "c");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u00e9\"").unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(
            Json::parse("\"héllo\"").unwrap(),
            Json::Str("héllo".into())
        );
    }

    #[test]
    fn real_manifest_shape() {
        let j = Json::parse(
            r#"{"version": 1, "eval": {"chunks": 16}, "profiles": {"x": {"n_layers": 24}}}"#,
        )
        .unwrap();
        assert_eq!(j.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(
            j.get("profiles").unwrap().get("x").unwrap().get("n_layers").unwrap()
                .as_usize()
                .unwrap(),
            24
        );
    }
}
