//! Shared integer hashing: splitmix64, the one mixing step used by the
//! router's consistent-hash ring, session-key hashing, and the simulated
//! model backend. One definition so a constant tweak reaches every user.

/// splitmix64 — a single, well-mixed avalanche step.
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixes_and_is_deterministic() {
        assert_eq!(splitmix64(1), splitmix64(1));
        assert_ne!(splitmix64(1), splitmix64(2));
        // reference value from the splitmix64 paper's test vector chain:
        // seeding with 0 must not return 0 (degenerate fixed point check)
        assert_ne!(splitmix64(0), 0);
    }
}
