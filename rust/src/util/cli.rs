//! Tiny CLI flag parser (clap is unavailable offline).
//!
//! Grammar: `prog [--global-flags] <subcommand> [--flags]`, where flags are
//! `--name value` or bare `--name` (boolean). Unknown flags error with the
//! accepted set.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse `std::env::args()` style input (excluding program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.flags.insert(name.to_string(), v);
                    }
                    _ => out.bools.push(name.to_string()),
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = tok;
            } else {
                bail!("unexpected positional argument '{tok}'");
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u32(&self, name: &str, default: u32) -> Result<u32> {
        match self.flag(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.flag(name) {
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Error on unrecognized flags (catches typos).
    pub fn check_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys().chain(self.bools.iter()) {
            if !known.contains(&k.as_str()) {
                bail!("unknown flag --{k}; accepted: {known:?}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("table1 --models a,b --fine");
        assert_eq!(a.subcommand, "table1");
        assert_eq!(a.get_list("models", &[]), vec!["a", "b"]);
        assert!(a.get_bool("fine"));
        assert!(!a.get_bool("centered"));
    }

    #[test]
    fn defaults() {
        let a = parse("serve");
        assert_eq!(a.get_str("model", "smollm2-sim"), "smollm2-sim");
        assert_eq!(a.get_usize("requests", 12).unwrap(), 12);
    }

    #[test]
    fn numeric_values() {
        let a = parse("eval --nk 256 --n-early 4");
        assert_eq!(a.get_u32("nk", 0).unwrap(), 256);
        assert_eq!(a.get_usize("n-early", 0).unwrap(), 4);
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse("x --typo 1");
        assert!(a.check_known(&["model"]).is_err());
        assert!(a.check_known(&["typo"]).is_ok());
    }

    #[test]
    fn rejects_extra_positional() {
        assert!(Args::parse(["a".into(), "b".into()]).is_err());
    }
}
