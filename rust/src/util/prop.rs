//! Mini property-testing helpers (proptest is unavailable offline).
//!
//! `Gen` is a seeded generator; `run_cases` executes a property over N
//! seeded cases and reports the failing seed so cases reproduce exactly.

/// Seeded xorshift* generator for property inputs.
pub struct Gen(u64);

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen(seed | 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + (self.u64() as usize) % (hi - lo + 1).max(1)
    }

    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        lo + (self.u64() as u32) % (hi - lo + 1).max(1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.u64() >> 40) as f32 / (1u64 << 24) as f32 * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.u64() & 1 == 1
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    pub fn f32_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }
}

/// Case-count override for slow interpreters: `TURBOANGLE_PROP_CASES`
/// caps every `run_cases` call (the CI Miri job sets it to 8 so the
/// pointer-level checks stay within budget; seeds are deterministic, so
/// a capped run is a strict prefix of the full one).
fn case_budget(cases: u64) -> u64 {
    match std::env::var("TURBOANGLE_PROP_CASES") {
        Ok(v) => match v.parse::<u64>() {
            Ok(cap) if cap > 0 => cases.min(cap),
            _ => cases,
        },
        Err(_) => cases,
    }
}

/// Run `prop` over `cases` seeded generators; panic with the failing seed.
/// Case counts respect the `TURBOANGLE_PROP_CASES` cap (see [`case_budget`]).
pub fn run_cases<F: FnMut(&mut Gen)>(cases: u64, mut prop: F) {
    let cases = case_budget(cases);
    for seed in 1..=cases {
        let mut g = Gen::new(seed.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g);
        }));
        if let Err(e) = result {
            eprintln!("property failed on case seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gen_ranges_hold() {
        let mut g = Gen::new(7);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
        }
    }

    #[test]
    fn run_cases_executes_all() {
        let mut n = 0;
        run_cases(25, |_| n += 1);
        // Budget-aware so the suite still passes under a
        // TURBOANGLE_PROP_CASES cap (e.g. the CI Miri job).
        assert_eq!(n, case_budget(25));
    }

    #[test]
    #[should_panic]
    fn run_cases_propagates_failure() {
        run_cases(10, |g| assert!(g.u64() % 3 != 0));
    }
}
