//! Micro-bench harness (criterion is unavailable offline).
//!
//! Measures wall time over warmup + timed iterations, reports mean / p50 /
//! p95 / min and derived throughput. Used by every file in rust/benches/.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// items/second at `items` per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }

    pub fn line(&self, items: Option<(f64, &str)>) -> String {
        let tp = items
            .map(|(n, unit)| format!("  {:>12.2} {unit}/s", self.throughput(n)))
            .unwrap_or_default();
        format!(
            "{:<44} {:>10.2?} mean  {:>10.2?} p50  {:>10.2?} p95  {:>10.2?} min{tp}",
            self.name, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Time `f` with auto-calibrated iteration count targeting ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 10_000.0) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let sum: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: sum / iters as u32,
        p50: samples[iters / 2],
        p95: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        min: samples[0],
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", Duration::from_millis(20), || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(10),
            p50: Duration::from_millis(10),
            p95: Duration::from_millis(10),
            min: Duration::from_millis(10),
        };
        assert!((r.throughput(100.0) - 10_000.0).abs() < 1e-6);
    }
}
