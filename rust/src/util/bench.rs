//! Micro-bench harness (criterion is unavailable offline).
//!
//! Measures wall time over warmup + timed iterations, reports mean / p50 /
//! p95 / min and derived throughput. Used by every file in rust/benches/.
//! [`JsonReport`] collects results into a machine-readable `BENCH_*.json`
//! so CI can archive the perf trajectory run over run.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl BenchResult {
    /// items/second at `items` per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean.as_secs_f64()
    }

    pub fn line(&self, items: Option<(f64, &str)>) -> String {
        let tp = items
            .map(|(n, unit)| format!("  {:>12.2} {unit}/s", self.throughput(n)))
            .unwrap_or_default();
        format!(
            "{:<44} {:>10.2?} mean  {:>10.2?} p50  {:>10.2?} p95  {:>10.2?} min{tp}",
            self.name, self.mean, self.p50, self.p95, self.min
        )
    }
}

/// Time `f` with auto-calibrated iteration count targeting ~`budget`.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let one = t0.elapsed().max(Duration::from_nanos(100));
    let iters = (budget.as_secs_f64() / one.as_secs_f64()).clamp(3.0, 10_000.0) as usize;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let sum: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters,
        mean: sum / iters as u32,
        p50: samples[iters / 2],
        p95: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        min: samples[0],
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Quote + escape a string as a JSON string literal (Rust's `{:?}` is NOT
/// JSON: it emits `\u{NN}` escapes that JSON parsers reject).
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// One JSON scalar for a [`JsonReport`] tag.
#[derive(Clone, Debug)]
pub enum JsonVal {
    Num(f64),
    Str(String),
}

impl JsonVal {
    fn render(&self) -> String {
        match self {
            // {:?} on f64 always keeps a decimal point/exponent — valid JSON
            JsonVal::Num(n) if n.is_finite() => format!("{n:?}"),
            JsonVal::Num(_) => "null".to_string(),
            JsonVal::Str(s) => json_str(s),
        }
    }
}

impl From<f64> for JsonVal {
    fn from(n: f64) -> Self {
        JsonVal::Num(n)
    }
}

impl From<usize> for JsonVal {
    fn from(n: usize) -> Self {
        JsonVal::Num(n as f64)
    }
}

impl From<&str> for JsonVal {
    fn from(s: &str) -> Self {
        JsonVal::Str(s.to_string())
    }
}

/// Machine-readable bench collector: each [`BenchResult`] becomes one
/// object in a `results` array, tagged with caller-supplied dimensions
/// (op, mode, d, rows, …); `summary` holds derived scalars like parallel
/// speedups. Serialized with the same no-serde discipline as util::json.
#[derive(Debug, Default)]
pub struct JsonReport {
    results: Vec<String>,
    summary: Vec<(String, JsonVal)>,
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one result with its throughput and identifying tags.
    pub fn push(&mut self, r: &BenchResult, items: f64, unit: &str, tags: &[(&str, JsonVal)]) {
        let mut obj = format!(
            "{{\"name\": {}, \"iters\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \
             \"p95_ns\": {}, \"min_ns\": {}, \"items_per_iter\": {}, \
             \"unit\": {}, \"items_per_sec\": {}",
            json_str(&r.name),
            r.iters,
            r.mean.as_nanos(),
            r.p50.as_nanos(),
            r.p95.as_nanos(),
            r.min.as_nanos(),
            JsonVal::Num(items).render(),
            json_str(unit),
            JsonVal::Num(r.throughput(items)).render(),
        );
        for (k, v) in tags {
            obj.push_str(&format!(", {}: {}", json_str(k), v.render()));
        }
        obj.push('}');
        self.results.push(obj);
    }

    /// Add a derived top-level scalar (e.g. a speedup ratio).
    pub fn summary(&mut self, key: &str, val: impl Into<JsonVal>) {
        self.summary.push((key.to_string(), val.into()));
    }

    pub fn render(&self) -> String {
        let mut out = String::from("{\n  \"results\": [\n    ");
        out.push_str(&self.results.join(",\n    "));
        out.push_str("\n  ],\n  \"summary\": {");
        let entries: Vec<String> = self
            .summary
            .iter()
            .map(|(k, v)| format!("{}: {}", json_str(k), v.render()))
            .collect();
        out.push_str(&entries.join(", "));
        out.push_str("}\n}\n");
        out
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let r = bench("spin", Duration::from_millis(20), || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(r.iters >= 3);
        assert!(r.min <= r.p50 && r.p50 <= r.p95);
        assert!(r.mean.as_nanos() > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean: Duration::from_millis(10),
            p50: Duration::from_millis(10),
            p95: Duration::from_millis(10),
            min: Duration::from_millis(10),
        };
        assert!((r.throughput(100.0) - 10_000.0).abs() < 1e-6);
    }

    #[test]
    fn json_report_is_valid_json() {
        use crate::util::json::Json;
        let r = BenchResult {
            name: "encode d=64 \"quoted\"".into(),
            iters: 5,
            mean: Duration::from_micros(250),
            p50: Duration::from_micros(240),
            p95: Duration::from_micros(300),
            min: Duration::from_micros(200),
        };
        let mut rep = JsonReport::new();
        rep.push(
            &r,
            4096.0 * 64.0,
            "elem",
            &[("op", "encode".into()), ("d", 64usize.into()), ("rows", 4096usize.into())],
        );
        rep.summary("encode_parallel_speedup_d128", 2.5);
        let j = Json::parse(&rep.render()).unwrap();
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("d").unwrap().as_usize().unwrap(), 64);
        assert_eq!(results[0].get("unit").unwrap().as_str().unwrap(), "elem");
        let tput = results[0].get("items_per_sec").unwrap().as_f64().unwrap();
        assert!((tput - 4096.0 * 64.0 / 250e-6).abs() / tput < 1e-9);
        let s = j.get("summary").unwrap();
        let speedup = s.get("encode_parallel_speedup_d128").unwrap().as_f64().unwrap();
        assert!((speedup - 2.5).abs() < 1e-12);
    }

    #[test]
    fn json_report_empty_still_parses() {
        use crate::util::json::Json;
        let rep = JsonReport::new();
        let j = Json::parse(&rep.render()).unwrap();
        assert_eq!(j.get("results").unwrap().as_arr().unwrap().len(), 0);
    }
}
