//! In-tree substrates for the offline environment: JSON parsing, CLI flag
//! parsing, a micro-bench harness, property-testing helpers, and shared
//! integer hashing.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
