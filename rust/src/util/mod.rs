//! In-tree substrates for the offline environment: JSON parsing, CLI flag
//! parsing, a micro-bench harness, and property-testing helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
