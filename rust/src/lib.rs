//! # TurboAngle — near-lossless KV cache compression via uniform angle
//! # quantization
//!
//! Reproduction of *TurboAngle: Near-Lossless KV Cache Compression via
//! Uniform Angle Quantization* (Patel, 2026) as a three-layer system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): FWHT + angular
//!   quantization, lowered at build time.
//! * **L2** — JAX transformer with in-graph KV quantization
//!   (`python/compile/model.py`), AOT-lowered to HLO text.
//! * **L3** — this crate: the serving coordinator (compressed paged KV
//!   cache, dynamic batcher, prefill/decode scheduler, router), the PJRT
//!   runtime that executes the AOT artifacts, the native quantizer mirror,
//!   and the evaluation harness that regenerates every paper table.
//!
//! Quick taste (native quantizer, no artifacts needed — `no_run` only
//! because rustdoc test binaries lack the libxla_extension rpath; the same
//! code runs in examples/quickstart.rs):
//!
//! ```no_run
//! use turboangle::quant::{angle, fwht};
//! let sign = fwht::test_sign_diag(64, 7);
//! let x: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
//! let enc = angle::encode(&x, &sign, 64);          // 3.0 angle bits/elem
//! let xh = angle::decode(&enc.r, &enc.k, &sign, 64, false);
//! let mse: f32 = x.iter().zip(&xh).map(|(a, b)| (a - b).powi(2)).sum::<f32>() / 64.0;
//! assert!(mse < 0.05);
//! ```
//!
//! The full pipeline (artifacts required — `make artifacts`):
//! see `examples/quickstart.rs`, `examples/serve_e2e.rs`, and the
//! `turboangle` CLI (`table1..table6`, `serve`, `search`, `uniformity`).
//!
//! System-level documentation lives in `docs/ARCHITECTURE.md` (module map,
//! sequence lifecycle, bit-identity invariants) and
//! `docs/BENCH_GLOSSARY.md` (every `BENCH_*.json` field).

// Public items in the paper-facing quantizer (`quant/`) and the serving
// coordinator (`coordinator/`) must be documented — the CI `docs` job runs
// rustdoc with `-D warnings`, so a regression fails the build. The
// support layers below carry targeted allows until their sweep lands.
#![warn(missing_docs)]
// Explicit portable-SIMD lanes in quant::kernels (nightly-only, opt-in).
// Without the feature the same kernels compile as batched scalar loops
// with identical output — see docs/ARCHITECTURE.md "Kernel layer".
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod coordinator;
#[allow(missing_docs)]
pub mod eval;
pub mod obs;
pub mod quant;
#[allow(missing_docs)]
pub mod report;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod util;
#[allow(missing_docs)]
pub mod workload;
