//! Paper-style table rendering for the CLI and benches.

use crate::eval::sensitivity::SensitivityReport;
use crate::eval::sweep::{BoostResult, KvSensRow, Table1Row, Table5Row, Table6Row};

fn hrule(widths: &[usize]) -> String {
    widths
        .iter()
        .map(|w| "-".repeat(w + 2))
        .collect::<Vec<_>>()
        .join("+")
}

/// Render a simple aligned table.
pub fn render(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, c) in row.iter().enumerate() {
            widths[i] = widths[i].max(c.len());
        }
    }
    let fmt_row = |cells: &[String]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
            .collect::<Vec<_>>()
            .join("|")
    };
    let mut out = String::new();
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
    ));
    out.push('\n');
    out.push_str(&hrule(&widths));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row));
        out.push('\n');
    }
    out
}

pub fn fmt_delta(d: f64) -> String {
    format!("{d:+.4}")
}

pub fn table1(title: &str, rows: &[Table1Row]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                format!("{:.2}", r.bits),
                fmt_delta(r.delta_ppl),
            ]
        })
        .collect();
    format!(
        "Table 1 — angular vs scalar quantization ({title})\n{}",
        render(&["Method", "Bits/elem", "dPPL"], &body)
    )
}

pub fn table2(rows: &[BoostResult]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.n_layers.to_string(),
                format!("{:.3}", r.ppl_base),
                fmt_delta(r.uniform_delta),
                fmt_delta(r.best_delta),
                format!("{:.2}", r.best_bits),
            ]
        })
        .collect();
    format!(
        "Table 2 — per-layer early-boost (uniform = K128V64, 3.25 angle bits)\n{}",
        render(
            &["Model", "L", "PPL_base", "Uniform dPPL", "Best dPPL", "bits"],
            &body
        )
    )
}

pub fn table3(rows: &[BoostResult]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let hi = r
                .boosted_layers
                .first()
                .map(|&l| r.best_cfg.layers[l])
                .unwrap_or_else(|| r.best_cfg.majority_bins());
            vec![
                r.model.clone(),
                r.boosted_range(),
                hi.n_k.to_string(),
                hi.n_v.to_string(),
                r.bottleneck.clone(),
            ]
        })
        .collect();
    format!(
        "Table 3 — optimal per-layer configurations\n{}",
        render(&["Model", "Boosted layers", "nK", "nV", "Type"], &body)
    )
}

pub fn table4(rep: &SensitivityReport) -> String {
    let mut body: Vec<Vec<String>> = rep
        .singles
        .iter()
        .map(|r| {
            vec![
                r.group.clone(),
                format!("{}-{}", r.layers.0, r.layers.1),
                fmt_delta(r.delta_ppl),
            ]
        })
        .collect();
    body.push(vec!["uniform".into(), "-".into(), fmt_delta(rep.uniform_delta)]);
    let mut out = format!(
        "Table 4 — layer-group sensitivity (each row boosts one group to K256V128)\n{}",
        render(&["Group", "Layers", "dPPL"], &body)
    );
    out.push_str("\nCombination probes:\n");
    let body: Vec<Vec<String>> = rep
        .combos
        .iter()
        .map(|r| vec![r.group.clone(), fmt_delta(r.delta_ppl)])
        .collect();
    out.push_str(&render(&["Combo", "dPPL"], &body));
    if !rep.negative_transfer.is_empty() {
        out.push_str(&format!(
            "\nNegative-transfer groups (worse than uniform): {:?}\n",
            rep.negative_transfer
        ));
    }
    out
}

pub fn table5(rows: &[Table5Row]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.model.clone(),
                r.d_head.to_string(),
                fmt_delta(r.fp32_delta),
                fmt_delta(r.norm8_delta),
                fmt_delta(r.k8v4_delta),
                format!("~{:.2}", r.k8v4_bits),
            ]
        })
        .collect();
    format!(
        "Table 5 — norm quantization\n{}",
        render(
            &["Model", "d", "FP32 dPPL", "norm8 dPPL", "K8V4-log dPPL", "K8V4 bits"],
            &body
        )
    )
}

pub fn table6(rows: &[Table6Row]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.method.clone(),
                format!("{:.2}", r.total_bits),
                fmt_delta(r.delta_ppl),
                if r.calibration { "Yes" } else { "No" }.into(),
                r.source.clone(),
            ]
        })
        .collect();
    format!(
        "Table 6 — vs calibration-style quantizers (all rows RUN on the same\n\
         model+data here; the paper's Table 6 quotes foreign setups)\n{}",
        render(
            &["Method", "Total bits", "dPPL", "Calibration", "Source"],
            &body
        )
    )
}

pub fn kv_sens(model: &str, rows: &[KvSensRow]) -> String {
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| vec![r.variant.clone(), fmt_delta(r.delta_ppl)])
        .collect();
    format!(
        "K vs V sensitivity ({model})\n{}",
        render(&["Variant", "dPPL"], &body)
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let s = render(
            &["A", "Bcd"],
            &[
                vec!["x".into(), "y".into()],
                vec!["longer".into(), "z".into()],
            ],
        );
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn fmt_delta_sign() {
        assert_eq!(fmt_delta(0.0014), "+0.0014");
        assert_eq!(fmt_delta(-0.0022), "-0.0022");
    }
}
