//! Paged COMPRESSED KV-cache manager — where TurboAngle's rate actually
//! becomes resident memory.
//!
//! Each sequence's cache is stored per (layer, head) as:
//!   * angle indices bit-packed at exactly ceil(log2(n)) bits (packing.rs),
//!   * norm codes bit-packed at the configured norm bits, with one fp32
//!     (min,max) window per vector (Eq. 3's 64/d overhead term),
//!   * or raw f32 norms when the config says fp32.
//!
//! Storage is **page-granular**: a sequence's compressed streams are split
//! into [`PageBlock`]s of `page_tokens` tokens each, covering every
//! (layer, head, K/V) chunk of that token window. Only the open tail page
//! is mutable; a page that fills becomes immutable. Full pages can be
//! sealed into a content-addressed, refcounted shared store
//! ([`PagedKvCache::finish_seq_share`]) so later sequences with the same
//! token prefix adopt one physical copy
//! ([`PagedKvCache::new_seq_with_prefix`]) — the substrate the
//! prefix-cache radix tree (`prefix_cache.rs`) indexes.
//!
//! Pages are drawn from a global pool — the vLLM-style block allocator
//! that gives admission control and a fragmentation-free memory bound.
//! Shared pages are charged to the pool exactly once, no matter how many
//! sequences reference them. `fill_dense` reinflates a sequence into the
//! (L,B,H,Tmax,d/2) tensors the decode_step HLO consumes; the fused read
//! path walks the same chunks page-tile by page-tile.

use crate::obs::stage::{self, Stage};
use crate::quant::kernels::{self, KernelKind};
use crate::quant::norm::{self, NormMode};
use crate::quant::packing::{bits_for, BitVec};
use crate::quant::{LayerBins, QuantConfig};
use crate::runtime::{KvTileReader, KvTileView};
use crate::util::hash::splitmix64 as mix;
use anyhow::{bail, ensure, Result};
use rayon::prelude::*;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// Below this many touched elements a reinflation runs single-threaded.
/// Multi-token refills only — the one-token incremental top-up never goes
/// parallel regardless of model size (see `fill_dense_range`).
const PAR_FILL_ELEM_THRESHOLD: usize = 4096;

/// Per-token append work (L·H·d/2 elements) below which the strided append
/// stays single-threaded. Higher than the fill threshold because each
/// element is only a few bit-pushes — layer tasks must be worth a rayon
/// dispatch on their own.
const PAR_APPEND_ELEM_THRESHOLD: usize = 8192;

/// Identifier of one immutable shared page in the store. Ids are never
/// reused, so a stale id can only miss, not alias.
pub type PageId = u64;

/// Chain-hash parent of a page with no predecessor (ids start at 1).
const ROOT_PARENT: PageId = 0;

/// Global page-pool accounting (pages are bookkeeping units; bytes live in
/// the per-sequence stores).
///
/// The pool tracks two numbers: `allocated_pages` (pages physically held
/// by resident sequences or the shared store) and `reserved_pages`
/// (worst-case pages *promised* at admission, plus one per shared page).
/// Admission checks reservations, not allocations — so a sequence admitted
/// for `prompt + max_new_tokens` can always grow to that bound without a
/// mid-decode "pool exhausted" failure, and preemption's swap-out releases
/// a well-defined quantity.
///
/// With refcounted page sharing, silent accounting drift is far more
/// dangerous than it was for private streams — underflow and over-reserve
/// are therefore hard errors in release builds too, not `debug_assert!`s.
#[derive(Debug)]
pub struct PagePool {
    page_tokens: usize,
    capacity_pages: usize,
    allocated_pages: usize,
    reserved_pages: usize,
}

impl PagePool {
    /// An empty pool of `capacity_pages` pages of `page_tokens` tokens.
    pub fn new(capacity_pages: usize, page_tokens: usize) -> Self {
        PagePool {
            page_tokens,
            capacity_pages,
            allocated_pages: 0,
            reserved_pages: 0,
        }
    }

    fn can_reserve(&self, pages: usize) -> bool {
        self.reserved_pages + pages <= self.capacity_pages
    }

    fn try_reserve(&mut self, pages: usize) -> bool {
        if self.can_reserve(pages) {
            self.reserved_pages += pages;
            true
        } else {
            false
        }
    }

    /// Move pages from "promised" to "physically held". Only valid within
    /// an existing reservation — admission already accounted for them.
    fn alloc_reserved(&mut self, pages: usize) -> Result<()> {
        ensure!(
            self.allocated_pages + pages <= self.reserved_pages,
            "page pool accounting: allocating {pages} beyond the reservation \
             ({}/{} allocated/reserved)",
            self.allocated_pages,
            self.reserved_pages
        );
        self.allocated_pages += pages;
        Ok(())
    }

    /// Take over a footprint from outside the pool (swap-in, or a page
    /// moving into the shared store): `allocated` pages physically held
    /// plus a fresh `reserved` promise.
    fn adopt(&mut self, allocated: usize, reserved: usize) -> Result<()> {
        ensure!(
            allocated <= reserved,
            "page pool accounting: adopting {allocated} allocated > {reserved} reserved"
        );
        ensure!(
            self.can_reserve(reserved),
            "page pool cannot adopt {reserved} pages ({}/{} reserved/capacity)",
            self.reserved_pages,
            self.capacity_pages
        );
        self.reserved_pages += reserved;
        self.allocated_pages += allocated;
        Ok(())
    }

    fn release(&mut self, allocated: usize, reserved: usize) -> Result<()> {
        ensure!(
            self.allocated_pages >= allocated && self.reserved_pages >= reserved,
            "page pool release underflow: {allocated}/{reserved} from {}/{}",
            self.allocated_pages,
            self.reserved_pages
        );
        self.allocated_pages -= allocated;
        self.reserved_pages -= reserved;
        Ok(())
    }

    /// Pages physically held by resident sequences and the shared store.
    pub fn allocated(&self) -> usize {
        self.allocated_pages
    }

    /// Worst-case pages promised at admission (>= allocated).
    pub fn reserved(&self) -> usize {
        self.reserved_pages
    }

    /// Total pool capacity in pages.
    pub fn capacity(&self) -> usize {
        self.capacity_pages
    }
}

/// One (layer, head) compressed stream chunk for one sequence side (K or
/// V), covering at most `page_tokens` tokens of ONE page.
#[derive(Clone, Debug, Default, PartialEq)]
struct SideStore {
    angles: BitVec,
    norm_codes: BitVec,
    /// one (vmin, vmax) per token vector; empty when norms are fp32
    windows: Vec<(f32, f32)>,
    /// raw norms when NormMode::FP32
    raw_norms: Vec<f32>,
}

impl SideStore {
    fn bytes(&self) -> usize {
        self.angles.storage_bytes()
            + self.norm_codes.storage_bytes()
            + self.windows.len() * 8
            + self.raw_norms.len() * 4
    }

    /// Exact packed angle payload in bits.
    fn angle_bits(&self) -> u64 {
        self.angles.len_bits() as u64
    }

    /// Exact norm payload in bits: packed codes plus one fp32 (min, max)
    /// window per token vector in quantized modes, or raw fp32 norms in
    /// passthrough mode.
    fn norm_bits(&self) -> u64 {
        self.norm_codes.len_bits() as u64
            + self.windows.len() as u64 * 64
            + self.raw_norms.len() as u64 * 32
    }

    /// Token vectors stored in this chunk (`half` = d/2 pair norms each).
    fn token_vectors(&self, half: usize) -> u64 {
        if self.raw_norms.is_empty() {
            self.windows.len() as u64
        } else {
            (self.raw_norms.len() / half) as u64
        }
    }

    /// Fold every stored bit into `h` — part of a page's content address.
    fn fold_hash(&self, mut h: u64) -> u64 {
        for &w in self.angles.words() {
            h = mix(h ^ w);
        }
        h = mix(h ^ self.angles.len_bits() as u64);
        for &w in self.norm_codes.words() {
            h = mix(h ^ w);
        }
        h = mix(h ^ self.norm_codes.len_bits() as u64);
        for &(a, b) in &self.windows {
            h = mix(h ^ (a.to_bits() as u64) ^ ((b.to_bits() as u64) << 32));
        }
        for &r in &self.raw_norms {
            h = mix(h ^ r.to_bits() as u64);
        }
        h
    }
}

/// All (layer, head) K/V chunks for one page of `page_tokens` tokens.
/// The unit of sharing: once full, a block is immutable — append paths
/// only ever touch a sequence's open tail block.
#[derive(Clone, Debug, PartialEq)]
struct PageBlock {
    /// [layer][head] -> (K chunk, V chunk)
    chunks: Vec<Vec<(SideStore, SideStore)>>,
}

impl PageBlock {
    fn new(n_layers: usize, n_heads: usize) -> Self {
        PageBlock {
            chunks: (0..n_layers)
                .map(|_| {
                    (0..n_heads)
                        .map(|_| (SideStore::default(), SideStore::default()))
                        .collect()
                })
                .collect(),
        }
    }

    fn bytes(&self) -> usize {
        self.chunks
            .iter()
            .flatten()
            .map(|(k, v)| k.bytes() + v.bytes())
            .sum()
    }

    /// Exact payload accounting over every (layer, head, side) chunk:
    /// (angle bits, norm bits, token vectors stored). Each token vector
    /// encodes `d_head` original fp16 elements, so achieved
    /// bits-per-element falls straight out of these sums.
    fn bit_stats(&self, half: usize) -> (u64, u64, u64) {
        let (mut a, mut n, mut t) = (0u64, 0u64, 0u64);
        for row in &self.chunks {
            for (k, v) in row {
                a += k.angle_bits() + v.angle_bits();
                n += k.norm_bits() + v.norm_bits();
                t += k.token_vectors(half) + v.token_vectors(half);
            }
        }
        (a, n, t)
    }

    /// Content address of this block, chained through its predecessor's
    /// page id, the token window the block covers, AND the quant config's
    /// [`QuantConfig::content_fingerprint`]. The chain + window binding
    /// means a page id identifies the bits, the tokens they encode, and
    /// the whole-prefix position they decode at — two different prefixes
    /// never dedup into one id (the dedup equality check compares the
    /// stored window too, so even a hash collision cannot merge them), so
    /// a page appears at exactly one radix-tree position and tree eviction
    /// can never free a page another node still points at. The config
    /// fingerprint keeps mixed-precision pages apart: two configs can pack
    /// identical tokens into byte-identical streams (same physical widths,
    /// e.g. 48- and 64-bin codebooks both pack 6-bit codes), yet they
    /// decode differently — so pages must never dedup across configs.
    fn content_hash(&self, parent: PageId, window: &[i32], cfg_fp: u64) -> u64 {
        let mut h = mix(parent ^ 0x9A6E_B10C ^ cfg_fp);
        for &t in window {
            h = mix(h ^ (t as u64));
        }
        for row in &self.chunks {
            for (k, v) in row {
                h = k.fold_hash(h);
                h = v.fold_hash(h);
            }
        }
        h
    }
}

/// One immutable, refcounted page in the shared store. `refs` counts live
/// AND swapped sequences referencing the page — the prefix cache may only
/// evict at `refs == 0`, so a page under a running (or preempted)
/// generation can never be freed out from under it.
#[derive(Debug)]
struct SharedEntry {
    /// Arc'd so adopting sequences hold the block directly: the decode hot
    /// path dereferences the Arc it already holds and never takes the
    /// store lock.
    block: Arc<PageBlock>,
    refs: usize,
    hash: u64,
    /// the exact token window this page's KV encodes, and the page id it
    /// chains from — both compared (with the block bits) before dedup, so
    /// a hash collision can never alias two different prefixes onto one
    /// page id
    key: Vec<i32>,
    parent: PageId,
    /// logical clock of the last seal/adopt touching this page — the LRU
    /// order a node-scoped store evicts refs==0 pages in under pressure
    last_used: u64,
}

/// One adopted shared page as a sequence carries it: the page id (for
/// refcount bookkeeping on control paths) plus the Arc'd block itself, so
/// every read is a plain pointer dereference — no store lock, no hash
/// lookup, no allocation on the decode hot path.
#[derive(Debug)]
struct AdoptedPage {
    pid: PageId,
    block: Arc<PageBlock>,
}

/// Interior of a [`SharedPageStore`], guarded by one mutex. All fields are
/// touched only on control paths (seal, adopt, free, stats) — never during
/// decode.
#[derive(Debug)]
struct StoreInner {
    pages: HashMap<PageId, SharedEntry>,
    /// chain content hash -> page id, for dedup at seal time
    by_hash: HashMap<u64, PageId>,
    next_page_id: PageId,
    clock: u64,
}

/// Monotonic store identities, so fleet roll-ups can count a store shared
/// by several replicas exactly once (see [`MemoryStats::shared_store_id`]).
static NEXT_STORE_ID: AtomicU64 = AtomicU64::new(1);

/// The content-addressed, refcounted shared page store — the substrate
/// behind prefix caching, promoted out of [`PagedKvCache`] so it can be
/// **node-scoped**: one store shared by every engine replica on a node
/// (`Arc<SharedPageStore>`), storing a popular prefix once per node
/// instead of once per replica.
///
/// Two scopes:
/// * **replica** ([`SharedPageStore::replica`]): the pre-existing
///   semantics — one store per cache, every page charged one allocated +
///   one reserved page to that replica's pool, freed only by explicit
///   prefix-cache eviction.
/// * **node** ([`SharedPageStore::node`]): shared across replicas with its
///   own page capacity. Pages are NOT charged to any replica pool; when a
///   seal would exceed capacity the store evicts least-recently-used
///   refs==0 pages itself. Pages referenced by any sequence on any replica
///   are never evicted, and adoption simply truncates at the first evicted
///   page of a chain — replica radix trees tolerate stale ids.
///
/// Lock discipline: one mutex over [`StoreInner`], taken only on control
/// paths (seal / adopt / unref / free / stats). Sequences hold
/// `Arc<PageBlock>` clones of every page they adopt, so decode reads never
/// touch the store at all.
#[derive(Debug)]
pub struct SharedPageStore {
    store_id: u64,
    /// `None` = replica-scoped (pool-charged pages, no self-eviction);
    /// `Some(cap)` = node-scoped with its own LRU-evicted page budget.
    node_capacity: Option<usize>,
    inner: Mutex<StoreInner>,
}

impl SharedPageStore {
    fn with_scope(node_capacity: Option<usize>) -> Arc<Self> {
        Arc::new(SharedPageStore {
            store_id: NEXT_STORE_ID.fetch_add(1, Ordering::Relaxed),
            node_capacity,
            inner: Mutex::new(StoreInner {
                pages: HashMap::new(),
                by_hash: HashMap::new(),
                next_page_id: 1,
                clock: 0,
            }),
        })
    }

    /// A replica-scoped store (the default every [`PagedKvCache::new`]
    /// builds privately).
    pub fn replica() -> Arc<Self> {
        Self::with_scope(None)
    }

    /// A node-scoped store holding at most `capacity_pages` pages, to be
    /// shared across every replica cache on the node via
    /// [`PagedKvCache::with_store`].
    pub fn node(capacity_pages: usize) -> Arc<Self> {
        assert!(capacity_pages > 0, "node store needs a positive capacity");
        Self::with_scope(Some(capacity_pages))
    }

    /// Whether this store is node-scoped (shared across replicas, outside
    /// the replica pools).
    pub fn is_node_scoped(&self) -> bool {
        self.node_capacity.is_some()
    }

    /// Process-unique identity of this store — equal across every replica
    /// sharing it, distinct otherwise.
    pub fn store_id(&self) -> u64 {
        self.store_id
    }

    /// Immutable pages currently resident.
    pub fn page_count(&self) -> usize {
        self.lock().pages.len()
    }

    /// Whether `pid` is resident (a stale id can only miss, never alias).
    pub fn contains(&self, pid: PageId) -> bool {
        self.lock().pages.contains_key(&pid)
    }

    /// Refcount of a page (None if unknown).
    pub fn refs_of(&self, pid: PageId) -> Option<usize> {
        self.lock().pages.get(&pid).map(|e| e.refs)
    }

    /// Content-chain hash of a page (None if unknown).
    pub fn hash_of(&self, pid: PageId) -> Option<u64> {
        self.lock().pages.get(&pid).map(|e| e.hash)
    }

    /// Lock the interior, recovering from poison: every field is valid at
    /// every instruction boundary (refcounts and maps are updated under
    /// one guard), so a peer replica thread panicking mid-operation leaves
    /// a usable store — propagating the poison would take down every
    /// replica on the node.
    fn lock(&self) -> MutexGuard<'_, StoreInner> {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Adopt the longest still-resident leading run of `prefix`, bumping
    /// each page's refcount under ONE lock acquisition — check + bump are
    /// atomic against a concurrent evicting sealer on another replica.
    /// Node-scoped stores may have evicted a chain tail, so adoption
    /// truncates at the first missing page; a replica-scoped store errors
    /// instead (nothing else can legally remove its pages).
    fn lease_prefix(&self, prefix: &[PageId]) -> Result<Vec<AdoptedPage>> {
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        let mut adopted: Vec<AdoptedPage> = Vec::with_capacity(prefix.len());
        for &pid in prefix {
            match inner.pages.get_mut(&pid) {
                Some(e) => {
                    e.refs += 1;
                    e.last_used = clock;
                    adopted.push(AdoptedPage {
                        pid,
                        block: Arc::clone(&e.block),
                    });
                }
                None if self.node_capacity.is_some() => break,
                None => {
                    for a in &adopted {
                        let e = inner.pages.get_mut(&a.pid).expect("just leased");
                        e.refs -= 1;
                    }
                    bail!("unknown shared page {pid}");
                }
            }
        }
        Ok(adopted)
    }

    /// Drop one reference per adopted page (the rollback of a lease whose
    /// pool reservation failed).
    fn unlease(&self, adopted: &[AdoptedPage]) -> Result<()> {
        for a in adopted {
            self.unref(a.pid)?;
        }
        Ok(())
    }

    fn unref(&self, pid: PageId) -> Result<()> {
        let mut inner = self.lock();
        let e = inner
            .pages
            .get_mut(&pid)
            .ok_or_else(|| anyhow::anyhow!("unknown shared page {pid}"))?;
        ensure!(e.refs > 0, "shared page {pid} refcount underflow");
        e.refs -= 1;
        Ok(())
    }

    /// Seal one full page: dedup onto an existing entry on true equality
    /// of parent chain, window AND bits, else insert fresh. Returns the
    /// page id and whether it was newly inserted, or `None` when a
    /// node-scoped store is at capacity and cannot evict enough refs==0
    /// pages — the caller must stop sealing the chain there (children
    /// cannot chain past a missing parent).
    fn seal_page(
        &self,
        block: PageBlock,
        parent: PageId,
        window: &[i32],
        cfg_fp: u64,
    ) -> Option<(PageId, bool)> {
        let h = block.content_hash(parent, window, cfg_fp);
        let mut inner = self.lock();
        inner.clock += 1;
        let clock = inner.clock;
        let existing = inner.by_hash.get(&h).copied().filter(|pid| {
            let e = &inner.pages[pid];
            e.parent == parent && e.key == window && *e.block == block
        });
        if let Some(pid) = existing {
            let e = inner.pages.get_mut(&pid).expect("dedup hit is resident");
            e.last_used = clock;
            return Some((pid, false));
        }
        if let Some(cap) = self.node_capacity {
            while inner.pages.len() >= cap {
                if !Self::evict_one_lru(&mut inner) {
                    return None; // every resident page is referenced
                }
            }
        }
        let pid = inner.next_page_id;
        inner.next_page_id += 1;
        inner.by_hash.insert(h, pid);
        inner.pages.insert(
            pid,
            SharedEntry {
                block: Arc::new(block),
                refs: 0,
                hash: h,
                key: window.to_vec(),
                parent,
                last_used: clock,
            },
        );
        Some((pid, true))
    }

    /// Evict the least-recently-used refs==0 page (node scope only);
    /// false when every resident page is referenced by some sequence —
    /// remote refs included, so a replica can never evict a page another
    /// replica's sequences still read.
    fn evict_one_lru(inner: &mut StoreInner) -> bool {
        let victim = inner
            .pages
            .iter()
            .filter(|(_, e)| e.refs == 0)
            .min_by_key(|(pid, e)| (e.last_used, **pid))
            .map(|(pid, _)| *pid);
        match victim {
            Some(pid) => {
                let e = inner.pages.remove(&pid).expect("victim is resident");
                if inner.by_hash.get(&e.hash) == Some(&pid) {
                    inner.by_hash.remove(&e.hash);
                }
                true
            }
            None => false,
        }
    }

    /// Free an UNREFERENCED page. Errors if any sequence on any replica
    /// still references it.
    fn free_page(&self, pid: PageId) -> Result<()> {
        let mut inner = self.lock();
        let e = inner
            .pages
            .get(&pid)
            .ok_or_else(|| anyhow::anyhow!("unknown shared page {pid}"))?;
        ensure!(
            e.refs == 0,
            "shared page {pid} still referenced by {} sequence(s)",
            e.refs
        );
        let e = inner.pages.remove(&pid).expect("checked above");
        if inner.by_hash.get(&e.hash) == Some(&pid) {
            inner.by_hash.remove(&e.hash);
        }
        Ok(())
    }

    /// Fold the store's pages into a [`MemoryStats`] snapshot. In node
    /// scope every replica's snapshot reports the FULL node store — fleet
    /// roll-ups dedup by [`MemoryStats::shared_store_id`].
    fn fold_memory(&self, half: usize, d_head: u64, st: &mut MemoryStats) {
        let inner = self.lock();
        for e in inner.pages.values() {
            st.shared_pages += 1;
            st.shared_refs += e.refs;
            st.shared_bytes += e.block.bytes();
            let (a, n, t) = e.block.bit_stats(half);
            st.angle_bits += a;
            st.norm_bits += n;
            st.stored_elements += t * d_head;
        }
    }

    /// Fold per-layer bit/element tallies (the per-layer refinement used
    /// by the sampled gauges).
    fn fold_layer_bits(&self, half: usize, d_head: u64, bits: &mut [u64], elems: &mut [u64]) {
        let inner = self.lock();
        for e in inner.pages.values() {
            for (layer, row) in e.block.chunks.iter().enumerate() {
                for (k, v) in row {
                    bits[layer] +=
                        k.angle_bits() + v.angle_bits() + k.norm_bits() + v.norm_bits();
                    elems[layer] += (k.token_vectors(half) + v.token_vectors(half)) * d_head;
                }
            }
        }
    }
}

struct SeqCache {
    len: usize,
    /// PRIVATE pages (the owned blocks). The pool charge is released while
    /// swapped out, but the count is kept — swap-in re-adopts exactly this
    /// many allocated pages.
    pages: usize,
    /// worst-case private pages promised at admission (`pages` never
    /// exceeds it while resident; zero while swapped out)
    reserved: usize,
    /// adopted shared prefix pages, in token order (immutable, refcounted
    /// in the store — this sequence holds one ref AND one `Arc` clone of
    /// each block, so reads never consult the store)
    shared: Vec<AdoptedPage>,
    /// privately written pages; the last one is the open tail
    owned: Vec<PageBlock>,
}

impl SeqCache {
    fn owned_bytes(&self) -> usize {
        self.owned.iter().map(PageBlock::bytes).sum()
    }

    /// Make sure the open tail page exists for a write at position
    /// `self.len`. Sealed pages are never revisited: the write position is
    /// always inside the LAST owned block after this call.
    fn ensure_tail(&mut self, page_tokens: usize, n_layers: usize, n_heads: usize) {
        let shared_tokens = self.shared.len() * page_tokens;
        debug_assert!(self.len >= shared_tokens);
        let need = (self.len - shared_tokens) / page_tokens + 1;
        while self.owned.len() < need {
            self.owned.push(PageBlock::new(n_layers, n_heads));
        }
    }

    /// The (K, V) chunk of `page` (global page index: shared prefix pages
    /// first, then owned) for one (layer, head). Shared pages read through
    /// the `Arc` held at adoption — no store lock, no hash lookup.
    fn chunk(&self, page: usize, layer: usize, head: usize) -> &(SideStore, SideStore) {
        if page < self.shared.len() {
            &self.shared[page].block.chunks[layer][head]
        } else {
            &self.owned[page - self.shared.len()].chunks[layer][head]
        }
    }
}

/// The compressed paged KV cache for one engine replica: per-sequence
/// page-granular bit-packed streams, the global page pool, the swap store
/// for preempted sequences, and the content-addressed refcounted shared
/// store behind prefix caching. See the module docs for the layout.
pub struct PagedKvCache {
    /// Quantizer configuration the streams are packed under.
    pub cfg: QuantConfig,
    /// Model layer count (one chunk row per layer per page).
    pub n_layers: usize,
    /// KV head count per layer.
    pub n_kv_heads: usize,
    /// Head dimension (streams store d/2 polar pairs per token).
    pub d_head: usize,
    /// Maximum tokens per sequence (the serving protocol bound).
    pub tmax: usize,
    pool: PagePool,
    seqs: HashMap<u64, SeqCache>,
    /// Preempted sequences: compressed streams moved out of the page pool
    /// verbatim (a few hundred bytes/token — no dequantization). Swap-in
    /// moves them back bit-identically. Their shared-page refs stay held,
    /// pinning those pages against prefix-cache eviction.
    swapped: HashMap<u64, SeqCache>,
    /// The content-addressed shared page store. Replica-scoped (the
    /// default): private to this cache, each page charged one allocated +
    /// one reserved pool page for as long as it lives. Node-scoped (via
    /// [`PagedKvCache::with_store`]): shared across replicas, pages live
    /// outside the replica pools under the store's own capacity.
    store: Arc<SharedPageStore>,
    /// pages THIS cache newly inserted at seal time (monotonic — lets the
    /// engine count its own insertions without racing other replicas on a
    /// shared store's page count)
    sealed_new: u64,
    /// memoized [`QuantConfig::content_fingerprint`] of `cfg`, folded into
    /// every sealed page's content hash
    cfg_fp: u64,
    /// which dequant kernel both read paths run
    /// ([`KernelKind::auto`]-resolved at construction; settable for
    /// in-process scalar-vs-simd comparisons)
    kernel: KernelKind,
}

/// Point-in-time memory accounting of one [`PagedKvCache`].
#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryStats {
    /// resident (non-swapped) sequences
    pub sequences: usize,
    /// tokens held by resident sequences (shared prefix included)
    pub tokens: usize,
    /// heap bytes of resident compressed streams (shared pages counted once)
    pub compressed_bytes: usize,
    /// what the same tokens would occupy as fp16 dense K+V tensors
    pub fp16_reference_bytes: usize,
    /// pool pages physically held (private + shared)
    pub pages_allocated: usize,
    /// pool pages promised at admission (>= allocated)
    pub pages_reserved: usize,
    /// pool capacity in pages
    pub pages_capacity: usize,
    /// preempted sequences parked in the swap store
    pub swapped_sequences: usize,
    /// tokens held by swapped sequences
    pub swapped_tokens: usize,
    /// heap bytes of swapped compressed streams (outside the pool)
    pub swapped_bytes: usize,
    /// immutable pages in the content-addressed shared store
    pub shared_pages: usize,
    /// total sequence references onto shared pages (live + swapped)
    pub shared_refs: usize,
    /// heap bytes of the shared store's compressed pages
    pub shared_bytes: usize,
    /// process-unique identity of the shared store this snapshot counted —
    /// replicas sharing one node-scoped store report the SAME id, so a
    /// fleet roll-up sums shared pages over distinct ids to count each
    /// physical store exactly once (0 only in `Default` snapshots)
    pub shared_store_id: u64,
    /// what the swapped sequences' tokens would occupy as fp16 dense K+V
    pub fp16_swapped_reference_bytes: usize,
    /// exact packed angle-code bits across resident, shared, and swapped
    /// streams (each stream counted once)
    pub angle_bits: u64,
    /// exact norm payload bits (codes + minmax windows, or raw fp32)
    /// across the same streams
    pub norm_bits: u64,
    /// original fp16 elements those streams encode (token vectors × d)
    pub stored_elements: u64,
}

impl MemoryStats {
    /// fp16 reference bytes / compressed bytes, swap-pool-resident bytes
    /// included on both sides (0 when empty). Preempted sequences' streams
    /// still occupy host memory, so excluding them (the old behavior) made
    /// the ratio improve spuriously the moment a sequence was swapped out.
    pub fn compression_ratio(&self) -> f64 {
        let compressed = self.compressed_bytes + self.swapped_bytes;
        if compressed == 0 {
            return 0.0;
        }
        (self.fp16_reference_bytes + self.fp16_swapped_reference_bytes) as f64 / compressed as f64
    }

    /// Achieved angle bits per original fp16 element (Eq. 1's physical
    /// counterpart; 0 when nothing is stored).
    pub fn angle_bits_per_element(&self) -> f64 {
        if self.stored_elements == 0 {
            return 0.0;
        }
        self.angle_bits as f64 / self.stored_elements as f64
    }

    /// Achieved norm payload bits per original fp16 element (Eq. 3's
    /// `b_norm/2 + 64/d` term as actually stored).
    pub fn norm_bits_per_element(&self) -> f64 {
        if self.stored_elements == 0 {
            return 0.0;
        }
        self.norm_bits as f64 / self.stored_elements as f64
    }

    /// Achieved total bits per original fp16 element — must match
    /// `QuantConfig::bits_per_element(d_head)` within 1% (the quality_sweep
    /// bench asserts this; exact for power-of-two codebooks, where the
    /// packed width equals log2(n)).
    pub fn total_bits_per_element(&self) -> f64 {
        self.angle_bits_per_element() + self.norm_bits_per_element()
    }

    /// Pool pages charged to resident sequences' private streams.
    pub fn pages_private(&self) -> usize {
        self.pages_allocated.saturating_sub(self.shared_pages)
    }

    /// Reservation promised to resident sequences (the rest of
    /// `pages_reserved` is the shared store's one-per-page charge).
    pub fn reserved_private(&self) -> usize {
        self.pages_reserved.saturating_sub(self.shared_pages)
    }

    /// One operator-facing line: live footprint, the shared/private page
    /// and reservation split (the dedup savings at a glance), swap depth,
    /// and the achieved bit rate against the paper's Eq. 3 accounting.
    pub fn report(&self) -> String {
        format!(
            "kv: {} seqs, {} tok, {} B compressed ({:.2}x vs fp16)\n\
             rate   {:.3} b/elem ({:.3} angle + {:.3} norm) over {} elements\n\
             pages  {}/{} allocated (shared {} + private {}) | reserved {} \
             (shared {} + private {})\n\
             shared {} pages, {} refs, {} B | swapped {} seqs ({} tok, {} B)",
            self.sequences,
            self.tokens,
            self.compressed_bytes,
            self.compression_ratio(),
            self.total_bits_per_element(),
            self.angle_bits_per_element(),
            self.norm_bits_per_element(),
            self.stored_elements,
            self.pages_allocated,
            self.pages_capacity,
            self.shared_pages,
            self.pages_private(),
            self.pages_reserved,
            self.shared_pages,
            self.reserved_private(),
            self.shared_pages,
            self.shared_refs,
            self.shared_bytes,
            self.swapped_sequences,
            self.swapped_tokens,
            self.swapped_bytes,
        )
    }
}

impl PagedKvCache {
    /// An empty cache for the given geometry over a fresh
    /// `capacity_pages × page_tokens` pool. Panics on an invalid quant
    /// config (see [`QuantConfig::validate`]).
    pub fn new(
        cfg: QuantConfig,
        n_layers: usize,
        n_kv_heads: usize,
        d_head: usize,
        tmax: usize,
        capacity_pages: usize,
        page_tokens: usize,
    ) -> Self {
        Self::with_store(
            cfg,
            n_layers,
            n_kv_heads,
            d_head,
            tmax,
            capacity_pages,
            page_tokens,
            SharedPageStore::replica(),
        )
    }

    /// Like [`Self::new`], but sealing into and adopting from the given
    /// shared store — pass one [`SharedPageStore::node`] to every replica
    /// cache on a node to store shared prefixes once per node. The store's
    /// quant-config fingerprint folding keeps divergent per-replica boost
    /// schedules apart: pages sealed under different configs never dedup.
    #[allow(clippy::too_many_arguments)]
    pub fn with_store(
        cfg: QuantConfig,
        n_layers: usize,
        n_kv_heads: usize,
        d_head: usize,
        tmax: usize,
        capacity_pages: usize,
        page_tokens: usize,
        store: Arc<SharedPageStore>,
    ) -> Self {
        assert_eq!(cfg.layers.len(), n_layers);
        // closes the u16-truncation hole for configs whose `layers` were
        // mutated after construction (constructors assert, mutation
        // doesn't) — enforced here, in release builds too, because every
        // serving path builds its cache through this constructor
        cfg.validate().expect("invalid quant config");
        let cfg_fp = cfg.content_fingerprint();
        PagedKvCache {
            cfg,
            n_layers,
            n_kv_heads,
            d_head,
            tmax,
            pool: PagePool::new(capacity_pages, page_tokens),
            seqs: HashMap::new(),
            swapped: HashMap::new(),
            store,
            sealed_new: 0,
            cfg_fp,
            kernel: KernelKind::auto(),
        }
    }

    /// The shared page store this cache seals into / adopts from.
    pub fn shared_store(&self) -> &Arc<SharedPageStore> {
        &self.store
    }

    /// Whether the shared store is node-scoped (shared across replicas,
    /// outside this replica's page pool).
    pub fn store_is_node_scoped(&self) -> bool {
        self.store.is_node_scoped()
    }

    /// Cumulative count of pages THIS cache newly inserted at seal time
    /// (monotonic; deltas around [`Self::finish_seq_share`] give the
    /// engine a race-free "pages inserted" metric even when other replicas
    /// seal into the same node store concurrently).
    pub fn sealed_pages_total(&self) -> u64 {
        self.sealed_new
    }

    /// The dequant [`KernelKind`] both read paths currently run.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Override the dequant kernel (tests and benches compare
    /// [`KernelKind::Scalar`] and [`KernelKind::Simd`] in one process —
    /// outputs are bit-identical, only throughput differs).
    pub fn set_kernel(&mut self, kernel: KernelKind) {
        self.kernel = kernel;
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.pool.page_tokens)
    }

    /// Pages a sequence of `tokens` tokens needs — for callers that batch
    /// several admissions in one pass and must sum their footprints.
    pub fn pages_for_tokens(&self, tokens: usize) -> usize {
        self.pages_for(tokens)
    }

    /// Admission: can the pool *promise* `pages` more pages on top of what
    /// resident sequences and the shared store already hold? Callers
    /// admitting several requests in one pass accumulate their page counts
    /// into a single check — each request alone fitting does NOT mean they
    /// fit together.
    pub fn can_admit_pages(&self, pages: usize) -> bool {
        self.pool.can_reserve(pages)
    }

    /// Admission for one sequence of `expected_tokens`.
    pub fn can_admit(&self, expected_tokens: usize) -> bool {
        self.can_admit_pages(self.pages_for(expected_tokens))
    }

    /// Pages that must be freed (e.g. by prefix-cache eviction) before
    /// `pages` more can be reserved. Zero when they already fit.
    pub fn admit_deficit(&self, pages: usize) -> usize {
        (self.pool.reserved() + pages).saturating_sub(self.pool.capacity())
    }

    /// Could a sequence of `expected_tokens` fit an *empty* pool? A request
    /// failing this can never be admitted — the engine finishes it with
    /// `CacheFull` instead of letting it starve at the head of the queue.
    /// (Deliberately ignores prefix sharing, so the verdict is identical
    /// with the prefix cache on or off.)
    pub fn fits_capacity(&self, expected_tokens: usize) -> bool {
        self.pages_for(expected_tokens) <= self.pool.capacity_pages
    }

    /// Start a sequence, reserving worst-case pages for `expected_tokens`.
    pub fn new_seq(&mut self, id: u64, expected_tokens: usize) -> Result<()> {
        match self.new_seq_with_prefix(id, expected_tokens, &[])? {
            Some(_) => Ok(()),
            None => bail!(
                "page pool cannot reserve {} pages for sequence {id}",
                self.pages_for(expected_tokens)
            ),
        }
    }

    /// Start a sequence that adopts `prefix` shared pages as its first
    /// tokens (bumping each page's refcount) and reserves worst-case pages
    /// only for the UNSHARED remainder of `expected_tokens`. The adopted
    /// pages are immutable; the sequence appends its own tokens after
    /// them.
    ///
    /// Returns `Ok(Some(adopted_pages))` — the number of prefix pages
    /// actually adopted. Against a node-scoped store another replica may
    /// have evicted a chain tail between match and admission, so adoption
    /// can truncate (`adopted_pages < prefix.len()`); check + refcount
    /// bump happen under one store lock, so pages adopted here can no
    /// longer be evicted. Returns `Ok(None)` — with NO sequence created
    /// and no refs held — when the pool cannot reserve the (possibly
    /// truncation-enlarged) remainder; the caller requeues the request.
    /// Replica-scoped stores still hard-error on an unknown page id.
    pub fn new_seq_with_prefix(
        &mut self,
        id: u64,
        expected_tokens: usize,
        prefix: &[PageId],
    ) -> Result<Option<usize>> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} exists");
        ensure!(!self.swapped.contains_key(&id), "sequence {id} is swapped out");
        let prefix_tokens = prefix.len() * self.pool.page_tokens;
        ensure!(
            prefix_tokens <= expected_tokens,
            "prefix ({prefix_tokens} tokens) longer than the sequence bound ({expected_tokens})"
        );
        let adopted = self.store.lease_prefix(prefix)?;
        let reserve = self.pages_for(expected_tokens) - adopted.len();
        if !self.pool.try_reserve(reserve) {
            self.store.unlease(&adopted)?;
            return Ok(None);
        }
        let n = adopted.len();
        self.seqs.insert(
            id,
            SeqCache {
                len: n * self.pool.page_tokens,
                pages: 0,
                reserved: reserve,
                shared: adopted,
                owned: Vec::new(),
            },
        );
        Ok(Some(n))
    }

    /// Free a sequence (resident or swapped) without sealing anything into
    /// the shared store: private pages and the reservation return to the
    /// pool, adopted shared pages lose this sequence's reference.
    pub fn free_seq(&mut self, id: u64) -> Result<()> {
        if let Some(s) = self.seqs.remove(&id) {
            self.pool.release(s.pages, s.reserved)?;
            for a in &s.shared {
                self.store.unref(a.pid)?;
            }
        } else if let Some(s) = self.swapped.remove(&id) {
            // swapped sequences hold no pool pages, only shared refs
            for a in &s.shared {
                self.store.unref(a.pid)?;
            }
        }
        Ok(())
    }

    /// Finish a resident sequence, sealing its full owned pages covering
    /// the first `tokens.len()` positions into the content-addressed
    /// shared store (`tokens` is the token stream those positions encode —
    /// bit-identical pages for the same token window dedup onto the
    /// existing copy and return their pool charge immediately). Returns
    /// the sealed full-page chain — adopted prefix pages first, then the
    /// newly sealed ones — for the caller to index in the prefix tree.
    /// Pages beyond `tokens.len()`, the partial tail, and the remaining
    /// reservation are released.
    ///
    /// The engine passes the (truncated) prompt: prefill-emitted pages
    /// only. Decode-emitted KV is a different (deterministic) function of
    /// the token prefix than prefill's in the sim backend, so sharing a
    /// generated position with a future PROMPT covering the same tokens
    /// would break the prefix-cache-on/off bit-identity guarantee.
    pub fn finish_seq_share(&mut self, id: u64, tokens: &[i32]) -> Result<Vec<PageId>> {
        let page_tokens = self.pool.page_tokens;
        // validate BEFORE removing: an error here must leave the sequence
        // (pool charge, reservation, shared refs) fully intact, not leak it
        {
            let s = match self.seqs.get(&id) {
                Some(s) => s,
                None => bail!("unknown sequence {id}"),
            };
            let seal_pages = s.len.min(tokens.len()) / page_tokens;
            ensure!(
                s.shared.len() <= seal_pages,
                "cannot seal fewer pages ({seal_pages}) than sequence {id} adopted ({})",
                s.shared.len()
            );
        }
        let mut s = self.seqs.remove(&id).expect("checked above");
        let seal_pages = s.len.min(tokens.len()) / page_tokens;
        self.pool.release(s.pages, s.reserved)?;
        let mut chain: Vec<PageId> = Vec::with_capacity(seal_pages);
        let adopted = std::mem::take(&mut s.shared);
        for a in &adopted {
            // drop this sequence's reference; the page stays cached
            self.store.unref(a.pid)?;
            chain.push(a.pid);
        }
        let full = seal_pages - adopted.len();
        let mut parent = chain.last().copied().unwrap_or(ROOT_PARENT);
        let node_scoped = self.store.is_node_scoped();
        for (j, block) in s.owned.drain(..).take(full).enumerate() {
            let start = (adopted.len() + j) * page_tokens;
            let window = &tokens[start..start + page_tokens];
            // dedup only on true equality of parent chain, window, AND
            // bits — a hash collision falls through to a private insert
            // (losing dedup, never correctness or tree-position
            // uniqueness: one page id maps to exactly one prefix)
            let (pid, inserted) = match self.store.seal_page(block, parent, window, self.cfg_fp)
            {
                Some(x) => x,
                // node store at capacity with every page referenced: stop
                // the chain here — children cannot chain past a missing
                // parent, and the unsealed tail simply isn't cached
                None => break,
            };
            if inserted {
                self.sealed_new += 1;
                if !node_scoped {
                    // replica scope charges the pool one page per entry —
                    // within the footprint released above, so always fits
                    self.pool.adopt(1, 1)?;
                }
            }
            parent = pid;
            chain.push(pid);
        }
        Ok(chain)
    }

    /// Immutable pages currently resident in the shared store.
    pub fn shared_page_count(&self) -> usize {
        self.store.page_count()
    }

    /// Refcount of a shared page (None if unknown) — the prefix cache's
    /// eviction guard.
    pub fn shared_page_refs(&self, pid: PageId) -> Option<usize> {
        self.store.refs_of(pid)
    }

    /// Whether a shared page is still resident (node-scoped stores evict
    /// refs==0 pages under pressure, so replica radix trees can go stale).
    pub fn shared_page_present(&self, pid: PageId) -> bool {
        self.store.contains(pid)
    }

    /// Content-chain hash of a shared page (None if unknown). The hash
    /// binds parent chain, token window, packed bits, AND the quant
    /// config's fingerprint — tests use this to pin that identical token
    /// streams under different per-layer configs never collide.
    pub fn shared_page_hash(&self, pid: PageId) -> Option<u64> {
        self.store.hash_of(pid)
    }

    /// Free an UNREFERENCED shared page, returning its pool charge in
    /// replica scope (node-scoped pages never held one). Errors if any
    /// live or swapped sequence — on ANY replica — still references it:
    /// eviction can never pull a page out from under a generation.
    pub fn free_shared_page(&mut self, pid: PageId) -> Result<()> {
        self.store.free_page(pid)?;
        if self.store.is_node_scoped() {
            Ok(())
        } else {
            self.pool.release(1, 1)
        }
    }

    /// Preempt: move the sequence's compressed streams out of the pool into
    /// the swap store, releasing its private pages AND its reservation. The
    /// bytes are moved verbatim — no dequantization, no re-encoding — and
    /// its shared-page references stay held (the pages must survive).
    pub fn swap_out(&mut self, id: u64) -> Result<()> {
        let mut s = match self.seqs.remove(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        self.pool.release(s.pages, s.reserved)?;
        s.reserved = 0;
        self.swapped.insert(id, s);
        Ok(())
    }

    /// The private reservation a swapped sequence needs to re-admit at
    /// `expected_tokens` (None if `id` is not swapped out) — lets callers
    /// compute a re-admission deficit without mutating anything.
    pub fn swap_in_reserve(&self, id: u64, expected_tokens: usize) -> Option<usize> {
        self.swapped.get(&id).map(|s| {
            self.pages_for(expected_tokens)
                .saturating_sub(s.shared.len())
                .max(s.pages)
        })
    }

    /// Re-admit a swapped sequence, reserving for `expected_tokens` total
    /// (current length + remaining generation, including the shared prefix
    /// it still references). Returns false — leaving the sequence swapped —
    /// when the pool cannot promise that much yet.
    pub fn swap_in(&mut self, id: u64, expected_tokens: usize) -> Result<bool> {
        let reserve = match self.swap_in_reserve(id, expected_tokens) {
            Some(r) => r,
            None => bail!("sequence {id} is not swapped out"),
        };
        if !self.pool.can_reserve(reserve) {
            return Ok(false);
        }
        let mut s = self.swapped.remove(&id).unwrap();
        self.pool.adopt(s.pages, reserve)?;
        s.reserved = reserve;
        self.seqs.insert(id, s);
        Ok(true)
    }

    /// Whether `id` currently sits in the swap store.
    pub fn is_swapped(&self, id: u64) -> bool {
        self.swapped.contains_key(&id)
    }

    fn append_side(
        store: &mut SideStore,
        r: &[f32],
        k_idx: &[f32],
        bins: u32,
        mode: NormMode,
    ) {
        let width = bits_for(bins);
        for &k in k_idx {
            store.angles.push(k as u32, width);
        }
        if mode.bits == 0 {
            store.raw_norms.extend_from_slice(r);
        } else {
            let q = norm::quantize(r, mode);
            for &c in &q.codes {
                store.norm_codes.push(c as u32, mode.bits as u32);
            }
            store.windows.push((q.vmin, q.vmax));
        }
    }

    /// Append one token's compressed KV for (seq, layer, head).
    /// `kr/ki/vr/vi` are the d/2-length raw norms and angle indices the
    /// prefill/decode HLOs emit (indices as f32 codes). Writes land in the
    /// sequence's open tail page only.
    #[allow(clippy::too_many_arguments)]
    pub fn append_token_lh(
        &mut self,
        id: u64,
        layer: usize,
        head: usize,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<()> {
        let half = self.d_head / 2;
        ensure!(kr.len() == half && ki.len() == half);
        ensure!(vr.len() == half && vi.len() == half);
        let bins = self.cfg.layers[layer];
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        let (page_tokens, l_n, h_n) = (self.pool.page_tokens, self.n_layers, self.n_kv_heads);
        let seq = match self.seqs.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        seq.ensure_tail(page_tokens, l_n, h_n);
        let block = seq.owned.last_mut().expect("tail ensured");
        let (ks, vs) = &mut block.chunks[layer][head];
        Self::append_side(ks, kr, ki, bins.n_k, k_norm);
        Self::append_side(vs, vr, vi, bins.n_v, v_norm);
        Ok(())
    }

    /// Append one token's compressed KV across ALL (layer, head) pairs in
    /// one call — the batched form of [`Self::append_token_lh`]. The slabs
    /// are dense prefill/decode HLO outputs; the d/2-length row for
    /// (layer `l`, head `h`) starts at `offset + l*l_stride + h*h_stride`.
    /// Layers fan out across rayon when the per-token work is large enough;
    /// output is identical to calling `append_token_lh` per (layer, head)
    /// in order, since each (layer, head) owns a disjoint chunk of the
    /// tail page.
    #[allow(clippy::too_many_arguments)]
    pub fn append_token_strided(
        &mut self,
        id: u64,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
        offset: usize,
        l_stride: usize,
        h_stride: usize,
    ) -> Result<()> {
        let half = self.d_head / 2;
        let (l_n, h_n) = (self.n_layers, self.n_kv_heads);
        if l_n == 0 || h_n == 0 {
            return Ok(());
        }
        let max_base = offset + (l_n - 1) * l_stride + (h_n - 1) * h_stride;
        ensure!(
            kr.len() >= max_base + half
                && ki.len() >= max_base + half
                && vr.len() >= max_base + half
                && vi.len() >= max_base + half,
            "strided append: slab too small for (L={l_n}, H={h_n}) layout"
        );
        let layers = &self.cfg.layers;
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        let page_tokens = self.pool.page_tokens;
        let seq = match self.seqs.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        seq.ensure_tail(page_tokens, l_n, h_n);
        let block = seq.owned.last_mut().expect("tail ensured");
        let append_layer = |l: usize, chunks_l: &mut Vec<(SideStore, SideStore)>| {
            let bins = layers[l];
            for (h, (ks, vs)) in chunks_l.iter_mut().enumerate() {
                let base = offset + l * l_stride + h * h_stride;
                let end = base + half;
                Self::append_side(ks, &kr[base..end], &ki[base..end], bins.n_k, k_norm);
                Self::append_side(vs, &vr[base..end], &vi[base..end], bins.n_v, v_norm);
            }
        };
        if l_n * h_n * half >= PAR_APPEND_ELEM_THRESHOLD {
            block
                .chunks
                .par_iter_mut()
                .enumerate()
                .for_each(|(l, s)| append_layer(l, s));
        } else {
            for (l, s) in block.chunks.iter_mut().enumerate() {
                append_layer(l, s);
            }
        }
        Ok(())
    }

    /// Advance the sequence length by one token (after all layers/heads of
    /// that token were appended), allocating pages as needed. Allocation
    /// inside the admission reservation cannot fail; growth beyond it
    /// (a sequence outliving its declared bound) extends the reservation
    /// when capacity allows and errors otherwise.
    pub fn commit_token(&mut self, id: u64) -> Result<()> {
        let page_tokens = self.pool.page_tokens;
        let seq = match self.seqs.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        ensure!(seq.len < self.tmax, "sequence {id} at tmax");
        if seq.len % page_tokens == 0 {
            if seq.pages + 1 > seq.reserved {
                // outgrew the admission promise (shouldn't happen for
                // engine-admitted sequences): extend if capacity allows
                if !self.pool.try_reserve(1) {
                    bail!("page pool exhausted");
                }
                seq.reserved += 1;
            }
            self.pool.alloc_reserved(1)?;
            seq.pages += 1;
        }
        seq.len += 1;
        Ok(())
    }

    /// Committed token count of a resident sequence (0 for unknown).
    pub fn seq_len(&self, id: u64) -> usize {
        self.seqs.get(&id).map_or(0, |s| s.len)
    }

    /// Tokens of `id` served from adopted shared pages (0 for unknown).
    pub fn seq_shared_tokens(&self, id: u64) -> usize {
        self.seqs
            .get(&id)
            .map_or(0, |s| s.shared.len() * self.pool.page_tokens)
    }

    /// Dequantize + unpack one sequence into batch slot `b` of the dense
    /// (L,B,H,Tmax,d/2) buffers the decode HLO takes. Slots beyond the
    /// sequence length are left untouched (they're masked by pos).
    #[allow(clippy::too_many_arguments)]
    pub fn fill_dense(
        &self,
        id: u64,
        b: usize,
        batch: usize,
        kr: &mut [f32],
        ki: &mut [f32],
        vr: &mut [f32],
        vi: &mut [f32],
    ) -> Result<usize> {
        self.fill_dense_range(id, b, batch, 0, kr, ki, vr, vi)
    }

    /// Incremental variant: reinflate only tokens `from_t..len` — the
    /// engine keeps per-slot dense buffers warm and tops up one token per
    /// decode step, making the per-step coordinator cost O(1) in sequence
    /// length instead of O(T) (EXPERIMENTS.md §Perf). Full refills (new
    /// sequences, large `len - from_t`) fan layers out across rayon: each
    /// layer writes a disjoint `batch*H*Tmax*d/2` chunk of the dense
    /// tensors, so the split is safe and the output identical to the
    /// serial loop. Reads walk the page chunks — shared prefix pages and
    /// owned pages decode through the same kernel.
    #[allow(clippy::too_many_arguments)]
    pub fn fill_dense_range(
        &self,
        id: u64,
        b: usize,
        batch: usize,
        from_t: usize,
        kr: &mut [f32],
        ki: &mut [f32],
        vr: &mut [f32],
        vi: &mut [f32],
    ) -> Result<usize> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        let half = self.d_head / 2;
        let (h_n, tmax) = (self.n_kv_heads, self.tmax);
        let layer_elems = batch * h_n * tmax * half;
        if self.n_layers == 0 || layer_elems == 0 {
            return Ok(seq.len);
        }
        ensure!(
            kr.len() >= self.n_layers * layer_elems
                && ki.len() >= self.n_layers * layer_elems
                && vr.len() >= self.n_layers * layer_elems
                && vi.len() >= self.n_layers * layer_elems,
            "dense buffers too small for (L,B,H,Tmax,d/2)"
        );
        let job = FillJob {
            b,
            h_n,
            tmax,
            half,
            from_t,
            len: seq.len,
            kernel: self.kernel,
        };
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        let page_tokens = self.pool.page_tokens;
        let span = seq.len.saturating_sub(from_t);
        let work = span * self.n_layers * h_n * half;
        // span > 1: the per-decode-step one-token top-up must stay on the
        // serial path at ANY model size — it is the engine's O(1) cost
        if span > 1 && work >= PAR_FILL_ELEM_THRESHOLD {
            kr.par_chunks_mut(layer_elems)
                .zip(ki.par_chunks_mut(layer_elems))
                .zip(vr.par_chunks_mut(layer_elems))
                .zip(vi.par_chunks_mut(layer_elems))
                .take(self.n_layers)
                .enumerate()
                .try_for_each(|(l, (((kr, ki), vr), vi))| {
                    let bins = self.cfg.layers[l];
                    fill_layer(seq, page_tokens, l, job, bins, k_norm, v_norm, kr, ki, vr, vi)
                })?;
        } else {
            for (l, (((kr, ki), vr), vi)) in kr
                .chunks_mut(layer_elems)
                .zip(ki.chunks_mut(layer_elems))
                .zip(vr.chunks_mut(layer_elems))
                .zip(vi.chunks_mut(layer_elems))
                .take(self.n_layers)
                .enumerate()
            {
                fill_layer(
                    seq,
                    page_tokens,
                    l,
                    job,
                    self.cfg.layers[l],
                    k_norm,
                    v_norm,
                    kr,
                    ki,
                    vr,
                    vi,
                )?;
            }
        }
        Ok(seq.len)
    }

    /// Tokens per page — also the token depth of a fused-read tile.
    pub fn page_tokens(&self) -> usize {
        self.pool.page_tokens
    }

    /// Random-access tile decode: dequantize tokens `t0..t0+tokens` of
    /// (`id`, `layer`, `head`) into caller buffers (each ≥ `tokens*d/2`
    /// f32, token-major rows). The page-granular building block behind
    /// [`Self::visit_seq_tiles`], exposed for backends that schedule their
    /// own tile walk. Values are bit-identical to what [`Self::fill_dense`]
    /// would put in the corresponding dense rows. The range may cross page
    /// boundaries (and the shared/owned seam).
    #[allow(clippy::too_many_arguments)]
    pub fn decode_tile_into(
        &self,
        id: u64,
        layer: usize,
        head: usize,
        t0: usize,
        tokens: usize,
        kr: &mut [f32],
        ki: &mut [f32],
        vr: &mut [f32],
        vi: &mut [f32],
    ) -> Result<()> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        ensure!(
            layer < self.n_layers && head < self.n_kv_heads,
            "tile (layer {layer}, head {head}) out of range"
        );
        ensure!(
            t0 + tokens <= seq.len,
            "tile {t0}..{} beyond sequence length {}",
            t0 + tokens,
            seq.len
        );
        let half = self.d_head / 2;
        let elems = tokens * half;
        ensure!(
            kr.len() >= elems && ki.len() >= elems && vr.len() >= elems && vi.len() >= elems,
            "tile buffers smaller than tokens*d/2"
        );
        let bins = self.cfg.layers[layer];
        decode_lh_range(
            self.kernel,
            seq,
            self.pool.page_tokens,
            layer,
            head,
            bins,
            self.cfg.k_norm,
            self.cfg.v_norm,
            t0,
            tokens,
            half,
            &mut kr[..elems],
            &mut ki[..elems],
            &mut vr[..elems],
            &mut vi[..elems],
        )?;
        Ok(())
    }

    /// The fused read path: visit `id`'s cache for one layer as dequantized
    /// page tiles — heads ascending, then token ranges ascending, covering
    /// exactly tokens `0..upto` (clamped to the sequence length). Each tile
    /// is exactly one page chunk (at most `page_tokens` rows) decoded into
    /// `scratch`, which grows once to a single page and never again: no
    /// per-token allocation, and the dense `(L,B,H,Tmax,d/2)` tensors never
    /// materialize. Shared prefix pages and owned pages stream through the
    /// same kernel, so adoption is invisible to the backend.
    pub fn visit_seq_tiles(
        &self,
        id: u64,
        layer: usize,
        upto: usize,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&KvTileView<'_>),
    ) -> Result<()> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        ensure!(layer < self.n_layers, "layer {layer} out of range");
        let upto = upto.min(seq.len);
        let half = self.d_head / 2;
        let tile_tokens = self.pool.page_tokens;
        scratch.ensure(tile_tokens * half);
        let bins = self.cfg.layers[layer];
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        for head in 0..self.n_kv_heads {
            let mut t0 = 0usize;
            while t0 < upto {
                let tokens = tile_tokens.min(upto - t0);
                let elems = tokens * half;
                // t0 is always page-aligned, so one tile == one page chunk
                let (ks, vs) = seq.chunk(t0 / tile_tokens, layer, head);
                let (kn, s) = (self.kernel, &mut *scratch);
                stage::time(Stage::Unpack, || -> Result<()> {
                    decode_side_range(kn, ks, bins.n_k, k_norm, 0, tokens, half, &mut s.kr, &mut s.ki)?;
                    decode_side_range(kn, vs, bins.n_v, v_norm, 0, tokens, half, &mut s.vr, &mut s.vi)
                })?;
                f(&KvTileView {
                    layer,
                    head,
                    t0,
                    tokens,
                    half,
                    kr: &scratch.kr[..elems],
                    ki: &scratch.ki[..elems],
                    vr: &scratch.vr[..elems],
                    vi: &scratch.vi[..elems],
                });
                t0 += tokens;
            }
        }
        Ok(())
    }

    /// Compute a [`MemoryStats`] snapshot (walks every resident stream).
    /// Against a node-scoped store the shared-page section reports the
    /// FULL node store (every replica's snapshot agrees) — fleet roll-ups
    /// dedup by [`MemoryStats::shared_store_id`] to count it once.
    pub fn memory_stats(&self) -> MemoryStats {
        let mut st = MemoryStats {
            sequences: self.seqs.len(),
            pages_allocated: self.pool.allocated(),
            pages_reserved: self.pool.reserved(),
            pages_capacity: self.pool.capacity(),
            swapped_sequences: self.swapped.len(),
            shared_store_id: self.store.store_id(),
            ..Default::default()
        };
        let half = self.d_head / 2;
        let add_bits = |st: &mut MemoryStats, block: &PageBlock| {
            let (a, n, t) = block.bit_stats(half);
            st.angle_bits += a;
            st.norm_bits += n;
            st.stored_elements += t * self.d_head as u64;
        };
        for s in self.seqs.values() {
            st.tokens += s.len;
            st.compressed_bytes += s.owned_bytes();
            // fp16 reference: K and V, n_layers*n_heads*len*d_head*2 bytes
            // each — the FULL length, shared prefix included, so dedup
            // shows up as a better compression ratio
            st.fp16_reference_bytes +=
                2 * self.n_layers * self.n_kv_heads * s.len * self.d_head * 2;
            for block in &s.owned {
                add_bits(&mut st, block);
            }
        }
        for s in self.swapped.values() {
            st.swapped_tokens += s.len;
            st.swapped_bytes += s.owned_bytes();
            st.fp16_swapped_reference_bytes +=
                2 * self.n_layers * self.n_kv_heads * s.len * self.d_head * 2;
            for block in &s.owned {
                add_bits(&mut st, block);
            }
        }
        self.store.fold_memory(half, self.d_head as u64, &mut st);
        // shared pages are resident memory, charged exactly once
        st.compressed_bytes += st.shared_bytes;
        st
    }

    /// Achieved total (angle + norm) bits per original fp16 element, per
    /// layer, across resident, swapped, and shared streams (each stream
    /// counted once — the per-layer refinement of
    /// [`MemoryStats::angle_bits`] + [`MemoryStats::norm_bits`]). Layers
    /// holding nothing report 0. This feeds the sampled
    /// `bits_per_element` gauge track in exported traces, making
    /// per-layer boost schedules visible as a time series instead of one
    /// end-of-run number.
    pub fn per_layer_bits_per_element(&self) -> Vec<f64> {
        let half = self.d_head / 2;
        let d_head = self.d_head as u64;
        let mut bits = vec![0u64; self.n_layers];
        let mut elems = vec![0u64; self.n_layers];
        let mut add = |bits: &mut [u64], elems: &mut [u64], block: &PageBlock| {
            for (layer, row) in block.chunks.iter().enumerate() {
                for (k, v) in row {
                    bits[layer] +=
                        k.angle_bits() + v.angle_bits() + k.norm_bits() + v.norm_bits();
                    elems[layer] += (k.token_vectors(half) + v.token_vectors(half)) * d_head;
                }
            }
        };
        for s in self.seqs.values().chain(self.swapped.values()) {
            for block in &s.owned {
                add(&mut bits, &mut elems, block);
            }
        }
        self.store
            .fold_layer_bits(half, d_head, &mut bits, &mut elems);
        bits.iter()
            .zip(&elems)
            .map(|(&b, &e)| if e == 0 { 0.0 } else { b as f64 / e as f64 })
            .collect()
    }
}

/// Reused dequant scratch for the fused read path: four page-sized
/// `(page_tokens × d/2)` slabs. Grows once to the page size and stays
/// there — the bounded-scratch contract the fused bench reports via
/// [`TileScratch::bytes`]. Contrast with the dense reinflation buffers,
/// which are `L·B·H·Tmax·d/2` floats *each*.
#[derive(Debug, Default)]
pub struct TileScratch {
    kr: Vec<f32>,
    ki: Vec<f32>,
    vr: Vec<f32>,
    vi: Vec<f32>,
}

impl TileScratch {
    /// Empty scratch; grows to one page on first use.
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, elems: usize) {
        if self.kr.len() < elems {
            self.kr.resize(elems, 0.0);
            self.ki.resize(elems, 0.0);
            self.vr.resize(elems, 0.0);
            self.vi.resize(elems, 0.0);
        }
    }

    /// Bytes held across all four slabs.
    pub fn bytes(&self) -> usize {
        (self.kr.len() + self.ki.len() + self.vr.len() + self.vi.len()) * 4
    }
}

/// Adapter handing a decode batch's lanes to
/// [`crate::runtime::ModelBackend::run_decode_fused`]: maps each lane to
/// its live sequence (if any) and walks [`PagedKvCache::visit_seq_tiles`]
/// with one shared scratch. Empty lanes visit nothing, matching the dense
/// path's zero-length scan of an inactive slot.
pub struct BatchTileReader<'a> {
    /// The cache whose pages the tiles decode from.
    pub kv: &'a PagedKvCache,
    /// Per-lane sequence ids (None = idle lane, visits nothing).
    pub lanes: &'a [Option<u64>],
    /// The one shared page-sized dequant scratch.
    pub scratch: &'a mut TileScratch,
}

impl KvTileReader for BatchTileReader<'_> {
    fn visit(
        &mut self,
        lane: usize,
        layer: usize,
        upto: usize,
        f: &mut dyn FnMut(&KvTileView<'_>),
    ) -> Result<()> {
        match self.lanes.get(lane).copied().flatten() {
            Some(id) => self.kv.visit_seq_tiles(id, layer, upto, self.scratch, f),
            None => Ok(()),
        }
    }
}

/// Geometry of one reinflation pass (shared by every layer's worker).
#[derive(Clone, Copy)]
struct FillJob {
    b: usize,
    h_n: usize,
    tmax: usize,
    half: usize,
    from_t: usize,
    len: usize,
    kernel: KernelKind,
}

/// Reinflate one layer's chunks into that layer's slice of the dense
/// tensors. `kr/ki/vr/vi` are the `batch*H*Tmax*d/2` slices for this layer,
/// so the base index drops the leading layer term of the (L,B,H,Tmax,d/2)
/// layout. Consecutive tokens of one (head, side) are contiguous in the
/// dense layout; the page walk happens inside [`decode_lh_range`].
#[allow(clippy::too_many_arguments)]
fn fill_layer(
    seq: &SeqCache,
    page_tokens: usize,
    layer: usize,
    job: FillJob,
    bins: LayerBins,
    k_norm: NormMode,
    v_norm: NormMode,
    kr: &mut [f32],
    ki: &mut [f32],
    vr: &mut [f32],
    vi: &mut [f32],
) -> Result<()> {
    let FillJob { b, h_n, tmax, half, from_t, len, kernel } = job;
    if from_t >= len {
        return Ok(());
    }
    let tokens = len - from_t;
    for h in 0..h_n {
        let base = ((b * h_n + h) * tmax + from_t) * half;
        let end = base + tokens * half;
        let (kr, ki) = (&mut kr[base..end], &mut ki[base..end]);
        let (vr, vi) = (&mut vr[base..end], &mut vi[base..end]);
        decode_lh_range(
            kernel,
            seq,
            page_tokens,
            layer,
            h,
            bins,
            k_norm,
            v_norm,
            from_t,
            tokens,
            half,
            kr,
            ki,
            vr,
            vi,
        )?;
    }
    Ok(())
}

/// Dequantize tokens `t0..t0+tokens` of one (layer, head) into contiguous
/// token-major rows, walking the sequence's page chunks (shared prefix
/// pages first, then owned pages). Each chunk's sub-range goes through
/// [`decode_side_range`], so chunked output is bit-identical to what the
/// old monolithic stream produced.
#[allow(clippy::too_many_arguments)]
fn decode_lh_range(
    kernel: KernelKind,
    seq: &SeqCache,
    page_tokens: usize,
    layer: usize,
    head: usize,
    bins: LayerBins,
    k_norm: NormMode,
    v_norm: NormMode,
    t0: usize,
    tokens: usize,
    half: usize,
    kr: &mut [f32],
    ki: &mut [f32],
    vr: &mut [f32],
    vi: &mut [f32],
) -> Result<()> {
    let mut t = t0;
    while t < t0 + tokens {
        let page = t / page_tokens;
        let local = t % page_tokens;
        let run = (page_tokens - local).min(t0 + tokens - t);
        let (ks, vs) = seq.chunk(page, layer, head);
        let o = (t - t0) * half;
        let e = o + run * half;
        let (kr, ki) = (&mut kr[o..e], &mut ki[o..e]);
        let (vr, vi) = (&mut vr[o..e], &mut vi[o..e]);
        decode_side_range(kernel, ks, bins.n_k, k_norm, local, run, half, kr, ki)?;
        decode_side_range(kernel, vs, bins.n_v, v_norm, local, run, half, vr, vi)?;
        t += run;
    }
    Ok(())
}

/// Dequantize tokens `t0..t0+tokens` of one side CHUNK (`t0` is
/// chunk-local) into contiguous token-major (norms, codes-as-f32) rows.
/// This is THE dequant entry for both read paths — the dense reinflation
/// ([`fill_layer`]) and the fused tile iterator
/// ([`PagedKvCache::visit_seq_tiles`]) call it, so their outputs cannot
/// drift: fused-vs-reinflate bit-identity holds by construction. The
/// actual unpack + dequant work lives in
/// [`kernels::decode_side_range`], which dispatches on `kernel` between
/// the sequential scalar path and the bulk-unpack vector path (the two
/// are bit-identical; see docs/ARCHITECTURE.md "Kernel layer").
#[allow(clippy::too_many_arguments)]
fn decode_side_range(
    kernel: KernelKind,
    store: &SideStore,
    bins: u32,
    mode: NormMode,
    t0: usize,
    tokens: usize,
    half: usize,
    out_r: &mut [f32],
    out_i: &mut [f32],
) -> Result<()> {
    kernels::decode_side_range(
        kernel,
        &store.angles,
        bins,
        &store.norm_codes,
        &store.windows,
        &store.raw_norms,
        mode,
        t0,
        tokens,
        half,
        out_r,
        out_i,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{angle, fwht::test_sign_diag};

    fn mk_cache(norms: (NormMode, NormMode)) -> PagedKvCache {
        let cfg = QuantConfig::paper_uniform(2).with_norms(norms.0, norms.1);
        PagedKvCache::new(cfg, 2, 1, 8, 16, 64, 4)
    }

    fn fake_entry(seed: u64, half: usize, bins: u32) -> (Vec<f32>, Vec<f32>) {
        let mut s = seed | 1;
        let mut r = Vec::new();
        let mut k = Vec::new();
        for _ in 0..half {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            r.push(0.1 + (s % 1000) as f32 / 250.0);
            k.push((s % bins as u64) as f32);
        }
        (r, k)
    }

    #[test]
    fn roundtrip_fp32_norms() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        c.new_seq(7, 16).unwrap();
        let half = 4;
        let mut want = Vec::new();
        for t in 0..5u64 {
            for l in 0..2 {
                let (kr, ki) = fake_entry(t * 10 + l as u64, half, 128);
                let (vr, vi) = fake_entry(t * 10 + l as u64 + 5, half, 64);
                c.append_token_lh(7, l, 0, &kr, &ki, &vr, &vi).unwrap();
                want.push((l, kr, ki, vr, vi));
            }
            c.commit_token(7).unwrap();
        }
        let (lb, b, h, tmax, _) = (2, 1usize, 1, 16, half);
        let n = lb * b * h * tmax * half;
        let (mut kr, mut ki, mut vr, mut vi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let len = c.fill_dense(7, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
        assert_eq!(len, 5);
        for (idx, (l, wkr, wki, wvr, wvi)) in want.iter().enumerate() {
            let t = idx / 2;
            let base = ((l * b) * h * tmax + t) * half;
            assert_eq!(&kr[base..base + half], &wkr[..]);
            assert_eq!(&ki[base..base + half], &wki[..]);
            assert_eq!(&vr[base..base + half], &wvr[..]);
            assert_eq!(&vi[base..base + half], &wvi[..]);
        }
    }

    #[test]
    fn norm_quant_roundtrip_within_step() {
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        c.new_seq(1, 16).unwrap();
        let half = 4;
        let (kr, ki) = fake_entry(3, half, 128);
        let (vr, vi) = fake_entry(4, half, 64);
        for l in 0..2 {
            c.append_token_lh(1, l, 0, &kr, &ki, &vr, &vi).unwrap();
        }
        c.commit_token(1).unwrap();
        let n = 2 * 16 * half;
        let (mut okr, mut oki, mut ovr, mut ovi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        c.fill_dense(1, 0, 1, &mut okr, &mut oki, &mut ovr, &mut ovi).unwrap();
        // angles exact
        assert_eq!(&oki[..half], &ki[..]);
        assert_eq!(&ovi[..half], &vi[..]);
        // norms within quantization error
        let kspan = kr.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - kr.iter().cloned().fold(f32::INFINITY, f32::min);
        for (a, b) in kr.iter().zip(&okr[..half]) {
            assert!((a - b).abs() <= kspan / 255.0 * 0.51 + 1e-6);
        }
        for (a, b) in vr.iter().zip(&ovr[..half]) {
            assert!((b / a - 1.0).abs() < 0.25, "{a} {b}"); // 4-bit log coarse
        }
    }

    /// A committed token whose appends skipped a layer leaves that layer's
    /// packed streams short. Both read paths must surface that as a clean
    /// `Err` from the kernel entry's release-mode validation — never an
    /// out-of-bounds word read (what `debug_assert!` alone degraded to in
    /// release builds).
    #[test]
    fn truncated_layer_stream_errors_cleanly() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        c.new_seq(9, 16).unwrap();
        let half = 4;
        let (kr, ki) = fake_entry(2, half, 128);
        // Layer 0 only — layer 1 never sees this token's codes.
        c.append_token_lh(9, 0, 0, &kr, &ki, &kr, &ki).unwrap();
        c.commit_token(9).unwrap();
        let n = 2 * 16 * half;
        let (mut okr, mut oki, mut ovr, mut ovi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let err = c
            .fill_dense(9, 0, 1, &mut okr, &mut oki, &mut ovr, &mut ovi)
            .unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // The fused tile path hits the same validation.
        let mut scratch = TileScratch::default();
        let err = c
            .visit_seq_tiles(9, 1, 1, &mut scratch, &mut |_| {})
            .unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // The healthy layer still decodes.
        c.visit_seq_tiles(9, 0, 1, &mut scratch, &mut |_| {}).unwrap();
    }

    #[test]
    fn page_accounting() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        c.new_seq(1, 12).unwrap();
        let half = 4;
        let (kr, ki) = fake_entry(1, half, 128);
        for t in 0..9 {
            for l in 0..2 {
                c.append_token_lh(1, l, 0, &kr, &ki, &kr, &ki).unwrap();
            }
            c.commit_token(1).unwrap();
            let _ = t;
        }
        // 9 tokens at 4 tokens/page -> 3 pages
        assert_eq!(c.memory_stats().pages_allocated, 3);
        c.free_seq(1).unwrap();
        assert_eq!(c.memory_stats().pages_allocated, 0);
    }

    #[test]
    fn pool_exhaustion_rejects() {
        let cfg = QuantConfig::paper_uniform(1);
        let mut c = PagedKvCache::new(cfg, 1, 1, 8, 64, 2, 4);
        c.new_seq(1, 8).unwrap();
        let (kr, ki) = fake_entry(1, 4, 128);
        let mut committed = 0;
        for _ in 0..12 {
            c.append_token_lh(1, 0, 0, &kr, &ki, &kr, &ki).unwrap();
            if c.commit_token(1).is_ok() {
                committed += 1;
            } else {
                break;
            }
        }
        assert_eq!(committed, 8); // 2 pages * 4 tokens
    }

    #[test]
    fn compression_ratio_beats_4x_with_k8v4() {
        // d=64, K128V64 + K8V4-log ≈ 7.25 bits/elem vs fp16's 16 -> >2.2x;
        // with fp32-norm storage it's much worse — this pins the ordering.
        let cfg_a = QuantConfig::paper_uniform(2).with_k8v4_log();
        let cfg_b = QuantConfig::paper_uniform(2);
        let mut ratios = Vec::new();
        for cfg in [cfg_a, cfg_b] {
            let mut c = PagedKvCache::new(cfg, 2, 1, 64, 64, 1024, 16);
            c.new_seq(1, 48).unwrap();
            let (kr, ki) = fake_entry(1, 32, 128);
            let (vr, vi) = fake_entry(2, 32, 64);
            for _ in 0..48 {
                for l in 0..2 {
                    c.append_token_lh(1, l, 0, &kr, &ki, &vr, &vi).unwrap();
                }
                c.commit_token(1).unwrap();
            }
            ratios.push(c.memory_stats().compression_ratio());
        }
        assert!(ratios[0] > 2.0, "k8v4 ratio {}", ratios[0]);
        assert!(ratios[0] > ratios[1], "quantized norms must beat fp32");
    }

    #[test]
    fn rejects_unknown_seq() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        let (kr, ki) = fake_entry(1, 4, 128);
        assert!(c.append_token_lh(9, 0, 0, &kr, &ki, &kr, &ki).is_err());
        assert!(c.commit_token(9).is_err());
        assert!(c
            .append_token_strided(9, &kr, &ki, &kr, &ki, 0, 0, 0)
            .is_err());
    }

    #[test]
    fn strided_append_matches_per_lh() {
        // two caches fed the same prefill-style slab: one through the
        // per-(layer,head) path, one through the batched strided path —
        // every reinflated byte must agree
        let (l_n, h_n, d, tp) = (2usize, 2usize, 8usize, 3usize);
        let half = d / 2;
        let cfg = QuantConfig::paper_uniform(l_n).with_norms(NormMode::LINEAR8, NormMode::LOG4);
        let mut via_lh = PagedKvCache::new(cfg.clone(), l_n, h_n, d, 16, 64, 4);
        let mut via_strided = PagedKvCache::new(cfg, l_n, h_n, d, 16, 64, 4);
        via_lh.new_seq(1, 16).unwrap();
        via_strided.new_seq(1, 16).unwrap();
        // dense (L, B=1, H, Tp, d/2) slabs
        let n = l_n * h_n * tp * half;
        let (mut kr, mut ki, mut vr, mut vi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        for l in 0..l_n {
            for h in 0..h_n {
                for t in 0..tp {
                    let base = ((l * h_n + h) * tp + t) * half;
                    let seed = (l * 100 + h * 10 + t) as u64 + 1;
                    let (r, i) = fake_entry(seed, half, 128);
                    kr[base..base + half].copy_from_slice(&r);
                    ki[base..base + half].copy_from_slice(&i);
                    let (r, i) = fake_entry(seed + 500, half, 64);
                    vr[base..base + half].copy_from_slice(&r);
                    vi[base..base + half].copy_from_slice(&i);
                }
            }
        }
        for t in 0..tp {
            for l in 0..l_n {
                for h in 0..h_n {
                    let base = ((l * h_n + h) * tp + t) * half;
                    via_lh
                        .append_token_lh(
                            1,
                            l,
                            h,
                            &kr[base..base + half],
                            &ki[base..base + half],
                            &vr[base..base + half],
                            &vi[base..base + half],
                        )
                        .unwrap();
                }
            }
            via_lh.commit_token(1).unwrap();
            via_strided
                .append_token_strided(1, &kr, &ki, &vr, &vi, t * half, h_n * tp * half, tp * half)
                .unwrap();
            via_strided.commit_token(1).unwrap();
        }
        let m = l_n * h_n * 16 * half;
        let mut a = (vec![0.0; m], vec![0.0; m], vec![0.0; m], vec![0.0; m]);
        let mut b = (vec![0.0; m], vec![0.0; m], vec![0.0; m], vec![0.0; m]);
        via_lh.fill_dense(1, 0, 1, &mut a.0, &mut a.1, &mut a.2, &mut a.3).unwrap();
        via_strided.fill_dense(1, 0, 1, &mut b.0, &mut b.1, &mut b.2, &mut b.3).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            via_lh.memory_stats().compressed_bytes,
            via_strided.memory_stats().compressed_bytes
        );
    }

    #[test]
    fn swap_roundtrip_is_bit_identical_and_frees_pages() {
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        c.new_seq(5, 12).unwrap();
        let half = 4;
        for t in 0..6u64 {
            for l in 0..2 {
                let (kr, ki) = fake_entry(t * 9 + l as u64 + 1, half, 128);
                let (vr, vi) = fake_entry(t * 9 + l as u64 + 77, half, 64);
                c.append_token_lh(5, l, 0, &kr, &ki, &vr, &vi).unwrap();
            }
            c.commit_token(5).unwrap();
        }
        let n = 2 * 16 * half;
        let mut before = (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        c.fill_dense(5, 0, 1, &mut before.0, &mut before.1, &mut before.2, &mut before.3)
            .unwrap();
        let resident = c.memory_stats();
        assert!(resident.pages_allocated > 0 && resident.pages_reserved > 0);

        c.swap_out(5).unwrap();
        assert!(c.is_swapped(5));
        let st = c.memory_stats();
        assert_eq!(st.pages_allocated, 0, "swap releases pages");
        assert_eq!(st.pages_reserved, 0, "swap releases the reservation");
        assert_eq!(st.swapped_sequences, 1);
        assert_eq!(st.swapped_tokens, 6);
        assert!(st.swapped_bytes > 0);
        let mut scratch = (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        assert!(
            c.fill_dense(5, 0, 1, &mut scratch.0, &mut scratch.1, &mut scratch.2, &mut scratch.3)
                .is_err(),
            "swapped sequences are not reinflatable"
        );

        assert!(c.swap_in(5, 12).unwrap());
        assert!(!c.is_swapped(5));
        let mut after = (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        c.fill_dense(5, 0, 1, &mut after.0, &mut after.1, &mut after.2, &mut after.3)
            .unwrap();
        assert_eq!(before, after, "restore must be bit-identical");
        assert_eq!(c.memory_stats().pages_allocated, resident.pages_allocated);
    }

    #[test]
    fn swap_in_respects_pool_pressure() {
        // capacity 2 pages of 4 tokens; seq 1 takes both, seq 2 must wait
        let cfg = QuantConfig::paper_uniform(1);
        let mut c = PagedKvCache::new(cfg, 1, 1, 8, 64, 2, 4);
        let (kr, ki) = fake_entry(1, 4, 128);
        c.new_seq(1, 8).unwrap();
        for _ in 0..8 {
            c.append_token_lh(1, 0, 0, &kr, &ki, &kr, &ki).unwrap();
            c.commit_token(1).unwrap();
        }
        c.swap_out(1).unwrap();
        c.new_seq(2, 8).unwrap();
        assert!(!c.swap_in(1, 8).unwrap(), "no room while seq 2 holds the pool");
        c.free_seq(2).unwrap();
        assert!(c.swap_in(1, 8).unwrap(), "room after seq 2 freed");
        assert_eq!(c.seq_len(1), 8);
        // unknown / double operations error
        assert!(c.swap_in(1, 8).is_err());
        assert!(c.swap_out(99).is_err());
    }

    #[test]
    fn reservation_blocks_overadmission() {
        // seq 1 reserves the whole pool up-front: a second sequence must
        // not be admitted even though few pages are *allocated* yet
        let cfg = QuantConfig::paper_uniform(1);
        let mut c = PagedKvCache::new(cfg, 1, 1, 8, 64, 4, 4);
        c.new_seq(1, 16).unwrap(); // reserves all 4 pages
        assert_eq!(c.memory_stats().pages_allocated, 0);
        assert!(!c.can_admit(4), "reservation counts against admission");
        assert!(c.new_seq(2, 4).is_err());
        c.free_seq(1).unwrap();
        assert!(c.can_admit(16));
    }

    #[test]
    fn tiles_bit_identical_to_fill_dense() {
        // fused tiles and the dense reinflation must agree to the bit for
        // every (layer, head, token) — page boundaries, quantized norms,
        // partial visits included
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        let (l_n, h_n, half, tmax) = (2usize, 1usize, 4usize, 16usize);
        c.new_seq(3, 11).unwrap();
        for t in 0..11u64 {
            for l in 0..l_n {
                let (kr, ki) = fake_entry(t * 13 + l as u64 + 1, half, 128);
                let (vr, vi) = fake_entry(t * 13 + l as u64 + 99, half, 64);
                c.append_token_lh(3, l, 0, &kr, &ki, &vr, &vi).unwrap();
            }
            c.commit_token(3).unwrap();
        }
        let n = l_n * h_n * tmax * half;
        let mut dense = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(3, 0, 1, &mut dense.0, &mut dense.1, &mut dense.2, &mut dense.3)
            .unwrap();
        let mut scratch = TileScratch::new();
        for upto in [11usize, 7, 1, 0] {
            for l in 0..l_n {
                let mut covered = vec![false; upto];
                c.visit_seq_tiles(3, l, upto, &mut scratch, &mut |tile| {
                    assert!(tile.tokens <= c.page_tokens(), "tile beyond one page");
                    for tr in 0..tile.tokens {
                        let t = tile.t0 + tr;
                        covered[t] = true;
                        let dbase = ((l * h_n + tile.head) * tmax + t) * half;
                        let tbase = tr * half;
                        assert_eq!(&tile.kr[tbase..tbase + half], &dense.0[dbase..dbase + half]);
                        assert_eq!(&tile.ki[tbase..tbase + half], &dense.1[dbase..dbase + half]);
                        assert_eq!(&tile.vr[tbase..tbase + half], &dense.2[dbase..dbase + half]);
                        assert_eq!(&tile.vi[tbase..tbase + half], &dense.3[dbase..dbase + half]);
                    }
                })
                .unwrap();
                assert!(covered.iter().all(|&x| x), "upto={upto} l={l}: gap in tile coverage");
            }
        }
        // random-access tile decode agrees too (range crosses pages)
        let mut kr = vec![0.0f32; 3 * half];
        let mut ki = vec![0.0f32; 3 * half];
        let mut vr = vec![0.0f32; 3 * half];
        let mut vi = vec![0.0f32; 3 * half];
        c.decode_tile_into(3, 1, 0, 5, 3, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
        let dbase = (h_n * tmax + 5) * half; // layer 1, head 0, t=5
        assert_eq!(&kr[..3 * half], &dense.0[dbase..dbase + 3 * half]);
        assert_eq!(&vi[..3 * half], &dense.3[dbase..dbase + 3 * half]);
        // bounds are checked, not zipped short
        assert!(c.decode_tile_into(3, 0, 0, 10, 2, &mut kr, &mut ki, &mut vr, &mut vi).is_err());
        assert!(c.decode_tile_into(3, 9, 0, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).is_err());
        // bounded scratch: one page of four d/2 slabs
        assert_eq!(scratch.bytes(), c.page_tokens() * half * 4 * 4);
    }

    #[test]
    fn parallel_fill_exact_for_fp32_norms() {
        // large enough that fill_dense takes the rayon path (work =
        // 10 tokens * 24 layers * 32 half = 7680 >= threshold); fp32 norms
        // make the expected reinflated values exactly the appended ones
        let (l_n, d, tmax, toks) = (24usize, 64usize, 32usize, 10usize);
        let half = d / 2;
        let cfg = QuantConfig::paper_uniform(l_n);
        let mut c = PagedKvCache::new(cfg, l_n, 1, d, tmax, 1024, 16);
        c.new_seq(1, toks).unwrap();
        let mut want = Vec::new();
        for t in 0..toks {
            let mut per_layer = Vec::new();
            for l in 0..l_n {
                let seed = (t * 64 + l) as u64 + 3;
                let (kr, ki) = fake_entry(seed, half, 128);
                let (vr, vi) = fake_entry(seed + 9000, half, 64);
                c.append_token_lh(1, l, 0, &kr, &ki, &vr, &vi).unwrap();
                per_layer.push((kr, ki, vr, vi));
            }
            c.commit_token(1).unwrap();
            want.push(per_layer);
        }
        let n = l_n * tmax * half;
        let (mut kr, mut ki, mut vr, mut vi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let len = c.fill_dense(1, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
        assert_eq!(len, toks);
        for (t, per_layer) in want.iter().enumerate() {
            for (l, (wkr, wki, wvr, wvi)) in per_layer.iter().enumerate() {
                let base = (l * tmax + t) * half;
                assert_eq!(&kr[base..base + half], &wkr[..], "t={t} l={l}");
                assert_eq!(&ki[base..base + half], &wki[..], "t={t} l={l}");
                assert_eq!(&vr[base..base + half], &wvr[..], "t={t} l={l}");
                assert_eq!(&vi[base..base + half], &wvi[..], "t={t} l={l}");
            }
        }
    }

    #[test]
    fn pool_accounting_checks_error_in_release_builds() {
        // satellite: underflow/over-reserve used to be debug_assert! only —
        // with refcounted sharing they are hard errors everywhere
        let mut p = PagePool::new(4, 4);
        assert!(p.try_reserve(2));
        p.alloc_reserved(1).unwrap();
        assert!(p.release(2, 1).is_err(), "allocated underflow must error");
        assert!(p.release(1, 3).is_err(), "reserved underflow must error");
        p.release(1, 2).unwrap();
        assert_eq!((p.allocated(), p.reserved()), (0, 0));
        // allocating beyond the reservation errors
        let mut p = PagePool::new(4, 4);
        assert!(p.try_reserve(1));
        assert!(p.alloc_reserved(2).is_err());
        // adopting beyond capacity / with allocated > reserved errors
        let mut p = PagePool::new(2, 4);
        assert!(p.adopt(1, 3).is_err());
        assert!(p.adopt(2, 1).is_err());
        p.adopt(1, 2).unwrap();
    }

    /// Deterministic per-(token,layer) entries derived from a seed so two
    /// sequences with the same logical prefix produce bit-identical pages.
    fn append_stream(c: &mut PagedKvCache, id: u64, from_t: usize, to_t: usize, tag: u64) {
        let half = c.d_head / 2;
        for t in from_t..to_t {
            for l in 0..c.n_layers {
                let (kr, ki) = fake_entry(tag + (t * 31 + l) as u64 + 1, half, 128);
                let (vr, vi) = fake_entry(tag + (t * 31 + l) as u64 + 501, half, 64);
                c.append_token_lh(id, l, 0, &kr, &ki, &vr, &vi).unwrap();
            }
            c.commit_token(id).unwrap();
        }
    }

    #[test]
    fn finish_share_adopt_roundtrip_bit_identical_with_dedup() {
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        let half = 4;
        // the logical token stream the compressed pages encode
        let toks: Vec<i32> = (100..110).collect();
        // seq 1: 10 tokens = 2 full pages of 4 + a partial tail
        c.new_seq(1, 10).unwrap();
        append_stream(&mut c, 1, 0, 10, 7000);
        let n = 2 * 16 * half;
        let mut a = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(1, 0, 1, &mut a.0, &mut a.1, &mut a.2, &mut a.3).unwrap();
        let chain = c.finish_seq_share(1, &toks).unwrap();
        assert_eq!(chain.len(), 2, "two full pages sealed, tail dropped");
        let st = c.memory_stats();
        assert_eq!(st.shared_pages, 2);
        assert_eq!(st.shared_refs, 0);
        assert_eq!(st.pages_allocated, 2, "cached pages stay charged");
        assert_eq!(st.pages_reserved, 2);

        // seq 2 adopts the chain and appends the same tail content
        assert_eq!(c.new_seq_with_prefix(2, 10, &chain).unwrap(), Some(2));
        assert_eq!(c.seq_len(2), 8);
        assert_eq!(c.seq_shared_tokens(2), 8);
        assert_eq!(c.shared_page_refs(chain[0]), Some(1));
        append_stream(&mut c, 2, 8, 10, 7000);
        let mut b = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(2, 0, 1, &mut b.0, &mut b.1, &mut b.2, &mut b.3).unwrap();
        assert_eq!(a, b, "adopted prefix must reinflate bit-identically");
        // fused tiles across the shared/owned seam agree too
        let mut scratch = TileScratch::new();
        c.visit_seq_tiles(2, 1, 10, &mut scratch, &mut |tile| {
            let dbase = (16 + tile.t0) * half; // layer 1, head 0
            let span = tile.tokens * half;
            assert_eq!(&tile.kr[..span], &a.0[dbase..dbase + span]);
            assert_eq!(&tile.vi[..span], &a.3[dbase..dbase + span]);
        })
        .unwrap();

        // referenced pages cannot be freed
        assert!(c.free_shared_page(chain[0]).is_err());

        // seq 3 writes the identical stream privately; sealing dedups onto
        // the existing pages and returns the duplicate pool charge
        c.new_seq(3, 10).unwrap();
        append_stream(&mut c, 3, 0, 10, 7000);
        let chain3 = c.finish_seq_share(3, &toks).unwrap();
        assert_eq!(chain3, chain, "identical content must dedup to the same ids");
        // same bits under DIFFERENT tokens must NOT dedup (tree-position
        // uniqueness: a page id binds to exactly one token window)
        let other: Vec<i32> = (200..210).collect();
        c.new_seq(4, 10).unwrap();
        append_stream(&mut c, 4, 0, 10, 7000);
        let chain4 = c.finish_seq_share(4, &other).unwrap();
        assert_ne!(chain4, chain, "different windows must get their own pages");
        assert_eq!(c.memory_stats().shared_pages, 4, "no cross-window dedup");
        for pid in &chain4 {
            c.free_shared_page(*pid).unwrap();
        }
        let st = c.memory_stats();
        assert_eq!(st.shared_pages, 2, "no duplicate blocks stored");

        // drop seq 2, then eviction can free the unreferenced pages
        c.free_seq(2).unwrap();
        for pid in &chain {
            assert_eq!(c.shared_page_refs(*pid), Some(0));
            c.free_shared_page(*pid).unwrap();
        }
        let st = c.memory_stats();
        assert_eq!((st.pages_allocated, st.pages_reserved, st.shared_pages), (0, 0, 0));
    }

    #[test]
    fn swapped_sequence_pins_shared_pages() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        c.new_seq(1, 8).unwrap();
        append_stream(&mut c, 1, 0, 8, 42);
        let toks: Vec<i32> = (50..58).collect();
        let chain = c.finish_seq_share(1, &toks).unwrap();
        assert_eq!(chain.len(), 2);
        assert_eq!(c.new_seq_with_prefix(2, 12, &chain).unwrap(), Some(2));
        append_stream(&mut c, 2, 8, 9, 42);
        c.swap_out(2).unwrap();
        // swapped: private pages returned, shared refs still held
        let st = c.memory_stats();
        assert_eq!(st.pages_allocated, 2, "only the shared pages stay charged");
        assert_eq!(st.shared_refs, 2, "one ref per adopted page survives the swap");
        assert!(c.free_shared_page(chain[0]).is_err(), "pinned by the swapped seq");
        assert!(c.swap_in(2, 12).unwrap());
        let mut out = (vec![0.0f32; 256], vec![0.0f32; 256], vec![0.0f32; 256], vec![0.0f32; 256]);
        let len = c.fill_dense(2, 0, 1, &mut out.0, &mut out.1, &mut out.2, &mut out.3).unwrap();
        assert_eq!(len, 9);
        c.free_seq(2).unwrap();
        assert_eq!(c.memory_stats().shared_refs, 0);
    }

    #[test]
    fn achieved_rate_matches_eq3_for_pow2_configs() {
        // power-of-two codebooks pack exactly log2(n) bits per code, so the
        // physically-stored rate must equal Eq. 3's closed form to the bit —
        // uniform and boosted, fp32 and quantized norms alike
        let d = 8usize;
        for cfg in [
            QuantConfig::paper_uniform(2),
            QuantConfig::paper_uniform(2).with_k8v4_log(),
            QuantConfig::early_boost(2, 1, 256, 128).with_k8v4_log(),
        ] {
            let want = cfg.bits_per_element(d);
            let want_angle = cfg.angle_bits_per_element();
            let mut c = PagedKvCache::new(cfg, 2, 1, d, 16, 64, 4);
            c.new_seq(1, 10).unwrap();
            append_stream(&mut c, 1, 0, 10, 31);
            let st = c.memory_stats();
            assert_eq!(st.stored_elements, 2 * 2 * 10 * d as u64);
            assert!(
                (st.angle_bits_per_element() - want_angle).abs() < 1e-9,
                "angle rate {} != Eq.1 {}",
                st.angle_bits_per_element(),
                want_angle
            );
            assert!(
                (st.total_bits_per_element() - want).abs() < 1e-9,
                "achieved rate {} != Eq.3 {}",
                st.total_bits_per_element(),
                want
            );
        }
        // empty cache reports a zero rate, not NaN
        let c = mk_cache((NormMode::FP32, NormMode::FP32));
        assert_eq!(c.memory_stats().total_bits_per_element(), 0.0);
    }

    #[test]
    fn compression_ratio_is_swap_invariant() {
        // streams move to the swap pool verbatim, so preempting a sequence
        // must not move the reported ratio — the old accounting dropped
        // swapped bytes AND their fp16 reference, improving it spuriously
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        c.new_seq(1, 8).unwrap();
        append_stream(&mut c, 1, 0, 8, 5);
        c.new_seq(2, 8).unwrap();
        append_stream(&mut c, 2, 0, 8, 900);
        let before = c.memory_stats();
        c.swap_out(2).unwrap();
        let after = c.memory_stats();
        assert!(after.swapped_bytes > 0 && after.fp16_swapped_reference_bytes > 0);
        assert!(
            (before.compression_ratio() - after.compression_ratio()).abs() < 1e-12,
            "swap must not change the ratio: {} vs {}",
            before.compression_ratio(),
            after.compression_ratio()
        );
        // the achieved bit rate keeps counting swapped streams too
        assert_eq!(before.stored_elements, after.stored_elements);
        assert_eq!(before.angle_bits, after.angle_bits);
        assert_eq!(before.norm_bits, after.norm_bits);
    }

    fn mk_cache_on(store: &Arc<SharedPageStore>) -> PagedKvCache {
        let cfg = QuantConfig::paper_uniform(2).with_norms(NormMode::LINEAR8, NormMode::LOG4);
        PagedKvCache::with_store(cfg, 2, 1, 8, 16, 64, 4, Arc::clone(store))
    }

    #[test]
    fn node_store_shares_pages_across_replicas_bit_identically() {
        let store = SharedPageStore::node(32);
        let mut a = mk_cache_on(&store);
        let mut b = mk_cache_on(&store);
        assert_eq!(a.memory_stats().shared_store_id, b.memory_stats().shared_store_id);
        assert!(a.store_is_node_scoped());

        let toks: Vec<i32> = (100..110).collect();
        // replica A seals the prefix
        a.new_seq(1, 10).unwrap();
        append_stream(&mut a, 1, 0, 10, 7000);
        let half = 4;
        let n = 2 * 16 * half;
        let mut want = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        a.fill_dense(1, 0, 1, &mut want.0, &mut want.1, &mut want.2, &mut want.3).unwrap();
        let chain = a.finish_seq_share(1, &toks).unwrap();
        assert_eq!(chain.len(), 2);
        // node-scoped pages are NOT charged to the replica pool
        assert_eq!(a.memory_stats().pages_allocated, 0);
        assert_eq!(a.memory_stats().pages_reserved, 0);
        // both replicas see the same store contents
        assert_eq!(a.shared_page_count(), 2);
        assert_eq!(b.shared_page_count(), 2);
        assert_eq!(a.sealed_pages_total(), 2);
        assert_eq!(b.sealed_pages_total(), 0, "B inserted nothing");

        // replica B adopts A's pages and reads them bit-identically on
        // both read paths
        assert_eq!(b.new_seq_with_prefix(9, 10, &chain).unwrap(), Some(2));
        assert_eq!(b.seq_shared_tokens(9), 8);
        append_stream(&mut b, 9, 8, 10, 7000);
        let mut got = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        b.fill_dense(9, 0, 1, &mut got.0, &mut got.1, &mut got.2, &mut got.3).unwrap();
        assert_eq!(want, got, "cross-replica adoption must be bit-identical");
        let mut scratch = TileScratch::new();
        b.visit_seq_tiles(9, 1, 10, &mut scratch, &mut |tile| {
            let dbase = (16 + tile.t0) * half; // layer 1, head 0
            let span = tile.tokens * half;
            assert_eq!(&tile.kr[..span], &want.0[dbase..dbase + span]);
            assert_eq!(&tile.vi[..span], &want.3[dbase..dbase + span]);
        })
        .unwrap();

        // B sealing the identical stream dedups onto A's pages
        b.new_seq(10, 10).unwrap();
        append_stream(&mut b, 10, 0, 10, 7000);
        let chain_b = b.finish_seq_share(10, &toks).unwrap();
        assert_eq!(chain_b, chain, "cross-replica dedup onto one node copy");
        assert_eq!(store.page_count(), 2, "stored once per node");

        // a page referenced from replica B cannot be freed via replica A
        assert_eq!(a.shared_page_refs(chain[0]), Some(1));
        assert!(a.free_shared_page(chain[0]).is_err(), "remote ref refuses free");
        b.free_seq(9).unwrap();
        a.free_shared_page(chain[1]).unwrap();
        a.free_shared_page(chain[0]).unwrap();
        assert_eq!(store.page_count(), 0);
    }

    #[test]
    fn node_store_lru_eviction_truncates_adoption_and_respects_refs() {
        // capacity 2 pages: sealing a second 2-page chain evicts the first
        // chain's refs==0 pages LRU-first
        let store = SharedPageStore::node(2);
        let mut a = mk_cache_on(&store);
        let mut b = mk_cache_on(&store);

        a.new_seq(1, 8).unwrap();
        append_stream(&mut a, 1, 0, 8, 11);
        let toks1: Vec<i32> = (0..8).collect();
        let chain1 = a.finish_seq_share(1, &toks1).unwrap();
        assert_eq!(chain1.len(), 2);

        b.new_seq(2, 8).unwrap();
        append_stream(&mut b, 2, 0, 8, 2200);
        let toks2: Vec<i32> = (50..58).collect();
        let chain2 = b.finish_seq_share(2, &toks2).unwrap();
        assert_eq!(chain2.len(), 2);
        assert_eq!(store.page_count(), 2, "chain1 evicted under pressure");
        assert!(!a.shared_page_present(chain1[0]));

        // adopting the stale chain truncates to zero instead of erroring —
        // the radix tree entry went stale, the request just misses
        assert_eq!(a.new_seq_with_prefix(3, 8, &chain1).unwrap(), Some(0));
        assert_eq!(a.seq_shared_tokens(3), 0);
        a.free_seq(3).unwrap();

        // with chain2 fully referenced (remote replica A adopts it), a
        // further seal cannot evict: the chain stops instead
        assert_eq!(a.new_seq_with_prefix(4, 8, &chain2).unwrap(), Some(2));
        b.new_seq(5, 8).unwrap();
        append_stream(&mut b, 5, 0, 8, 3300);
        let toks3: Vec<i32> = (80..88).collect();
        let chain3 = b.finish_seq_share(5, &toks3).unwrap();
        assert!(chain3.is_empty(), "no evictable page -> nothing sealed");
        assert!(b.shared_page_present(chain2[0]), "remote refs pin against eviction");
        assert_eq!(store.page_count(), 2);

        // a partially-evicted chain truncates adoption at the seam: free
        // A's lease, reseal a fresh chain (evicting LRU = chain2's tail
        // first? No — whole chain2 unreferenced now, oldest evicts first)
        a.free_seq(4).unwrap();
        b.new_seq(6, 4).unwrap();
        append_stream(&mut b, 6, 0, 4, 4400);
        let toks4: Vec<i32> = (90..94).collect();
        let chain4 = b.finish_seq_share(6, &toks4).unwrap();
        assert_eq!(chain4.len(), 1);
        // chain2[0] (older) was evicted, chain2[1] may survive; adopting
        // chain2 now truncates at its missing head
        assert_eq!(b.new_seq_with_prefix(7, 8, &chain2).unwrap(), Some(0));
        b.free_seq(7).unwrap();
    }

    #[test]
    fn replica_scoped_store_still_errors_on_unknown_page() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        assert!(c.new_seq_with_prefix(1, 8, &[999]).is_err());
        // failed adoption leaks nothing: the pool is untouched
        let st = c.memory_stats();
        assert_eq!((st.pages_reserved, st.shared_refs), (0, 0));
    }
}
