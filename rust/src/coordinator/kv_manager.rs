//! Paged COMPRESSED KV-cache manager — where TurboAngle's rate actually
//! becomes resident memory.
//!
//! Each sequence's cache is stored per (layer, head) as:
//!   * angle indices bit-packed at exactly ceil(log2(n)) bits (packing.rs),
//!   * norm codes bit-packed at the configured norm bits, with one fp32
//!     (min,max) window per vector (Eq. 3's 64/d overhead term),
//!   * or raw f32 norms when the config says fp32.
//!
//! Pages of `page_tokens` tokens are drawn from a global pool — the
//! vLLM-style block allocator that gives admission control and a
//! fragmentation-free memory bound. `fill_dense` reinflates a sequence into
//! the (L,B,H,Tmax,d/2) tensors the decode_step HLO consumes.

use crate::quant::norm::{self, NormMode};
use crate::quant::packing::{bits_for, BitCursor, BitVec};
use crate::quant::{LayerBins, QuantConfig};
use crate::runtime::{KvTileReader, KvTileView};
use anyhow::{bail, ensure, Result};
use rayon::prelude::*;
use std::collections::HashMap;

/// Below this many touched elements a reinflation runs single-threaded.
/// Multi-token refills only — the one-token incremental top-up never goes
/// parallel regardless of model size (see `fill_dense_range`).
const PAR_FILL_ELEM_THRESHOLD: usize = 4096;

/// Per-token append work (L·H·d/2 elements) below which the strided append
/// stays single-threaded. Higher than the fill threshold because each
/// element is only a few bit-pushes — layer tasks must be worth a rayon
/// dispatch on their own.
const PAR_APPEND_ELEM_THRESHOLD: usize = 8192;

/// Global page-pool accounting (pages are bookkeeping units; bytes live in
/// the per-sequence stores).
///
/// The pool tracks two numbers: `allocated_pages` (pages physically held
/// by resident sequences) and `reserved_pages` (worst-case pages *promised*
/// to resident sequences at admission). Admission checks reservations, not
/// allocations — so a sequence admitted for `prompt + max_new_tokens` can
/// always grow to that bound without a mid-decode "pool exhausted" failure,
/// and preemption's swap-out releases a well-defined quantity.
#[derive(Debug)]
pub struct PagePool {
    page_tokens: usize,
    capacity_pages: usize,
    allocated_pages: usize,
    reserved_pages: usize,
}

impl PagePool {
    pub fn new(capacity_pages: usize, page_tokens: usize) -> Self {
        PagePool {
            page_tokens,
            capacity_pages,
            allocated_pages: 0,
            reserved_pages: 0,
        }
    }

    fn can_reserve(&self, pages: usize) -> bool {
        self.reserved_pages + pages <= self.capacity_pages
    }

    fn try_reserve(&mut self, pages: usize) -> bool {
        if self.can_reserve(pages) {
            self.reserved_pages += pages;
            true
        } else {
            false
        }
    }

    /// Move pages from "promised" to "physically held". Only valid within
    /// an existing reservation — admission already accounted for them.
    fn alloc_reserved(&mut self, pages: usize) {
        self.allocated_pages += pages;
        debug_assert!(self.allocated_pages <= self.reserved_pages);
    }

    /// Take over a swapped-in sequence's footprint: `allocated` pages it
    /// physically holds again plus its fresh `reserved` promise. The
    /// caller has already checked `can_reserve(reserved)`.
    fn adopt(&mut self, allocated: usize, reserved: usize) {
        debug_assert!(allocated <= reserved && self.can_reserve(reserved));
        self.reserved_pages += reserved;
        self.allocated_pages += allocated;
    }

    fn release(&mut self, allocated: usize, reserved: usize) {
        debug_assert!(self.allocated_pages >= allocated && self.reserved_pages >= reserved);
        self.allocated_pages -= allocated;
        self.reserved_pages -= reserved;
    }

    pub fn allocated(&self) -> usize {
        self.allocated_pages
    }

    pub fn reserved(&self) -> usize {
        self.reserved_pages
    }

    pub fn capacity(&self) -> usize {
        self.capacity_pages
    }
}

/// One (layer, head) compressed stream for one sequence side (K or V).
#[derive(Clone, Debug, Default)]
struct SideStore {
    angles: BitVec,
    norm_codes: BitVec,
    /// one (vmin, vmax) per token vector; empty when norms are fp32
    windows: Vec<(f32, f32)>,
    /// raw norms when NormMode::FP32
    raw_norms: Vec<f32>,
}

impl SideStore {
    fn bytes(&self) -> usize {
        self.angles.storage_bytes()
            + self.norm_codes.storage_bytes()
            + self.windows.len() * 8
            + self.raw_norms.len() * 4
    }
}

struct SeqCache {
    len: usize,
    pages: usize,
    /// worst-case pages promised at admission (`pages` never exceeds it
    /// while resident; zero while swapped out)
    reserved: usize,
    /// [layer][head] -> (K store, V store)
    stores: Vec<Vec<(SideStore, SideStore)>>,
}

impl SeqCache {
    fn bytes(&self) -> usize {
        self.stores
            .iter()
            .flatten()
            .map(|(k, v)| k.bytes() + v.bytes())
            .sum()
    }
}

pub struct PagedKvCache {
    pub cfg: QuantConfig,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub tmax: usize,
    pool: PagePool,
    seqs: HashMap<u64, SeqCache>,
    /// Preempted sequences: compressed streams moved out of the page pool
    /// verbatim (a few hundred bytes/token — no dequantization). Swap-in
    /// moves them back bit-identically.
    swapped: HashMap<u64, SeqCache>,
}

#[derive(Clone, Copy, Debug, Default)]
pub struct MemoryStats {
    pub sequences: usize,
    pub tokens: usize,
    pub compressed_bytes: usize,
    pub fp16_reference_bytes: usize,
    pub pages_allocated: usize,
    pub pages_reserved: usize,
    pub pages_capacity: usize,
    pub swapped_sequences: usize,
    pub swapped_tokens: usize,
    pub swapped_bytes: usize,
}

impl MemoryStats {
    pub fn compression_ratio(&self) -> f64 {
        if self.compressed_bytes == 0 {
            return 0.0;
        }
        self.fp16_reference_bytes as f64 / self.compressed_bytes as f64
    }
}

impl PagedKvCache {
    pub fn new(
        cfg: QuantConfig,
        n_layers: usize,
        n_kv_heads: usize,
        d_head: usize,
        tmax: usize,
        capacity_pages: usize,
        page_tokens: usize,
    ) -> Self {
        assert_eq!(cfg.layers.len(), n_layers);
        // closes the u16-truncation hole for configs whose `layers` were
        // mutated after construction (constructors assert, mutation
        // doesn't) — enforced here, in release builds too, because every
        // serving path builds its cache through this constructor
        cfg.validate().expect("invalid quant config");
        PagedKvCache {
            cfg,
            n_layers,
            n_kv_heads,
            d_head,
            tmax,
            pool: PagePool::new(capacity_pages, page_tokens),
            seqs: HashMap::new(),
            swapped: HashMap::new(),
        }
    }

    fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.pool.page_tokens)
    }

    /// Pages a sequence of `tokens` tokens needs — for callers that batch
    /// several admissions in one pass and must sum their footprints.
    pub fn pages_for_tokens(&self, tokens: usize) -> usize {
        self.pages_for(tokens)
    }

    /// Admission: can the pool *promise* `pages` more pages on top of what
    /// resident sequences already hold? Callers admitting several requests
    /// in one pass accumulate their page counts into a single check — each
    /// request alone fitting does NOT mean they fit together.
    pub fn can_admit_pages(&self, pages: usize) -> bool {
        self.pool.can_reserve(pages)
    }

    /// Admission for one sequence of `expected_tokens`.
    pub fn can_admit(&self, expected_tokens: usize) -> bool {
        self.can_admit_pages(self.pages_for(expected_tokens))
    }

    /// Could a sequence of `expected_tokens` fit an *empty* pool? A request
    /// failing this can never be admitted — the engine finishes it with
    /// `CacheFull` instead of letting it starve at the head of the queue.
    pub fn fits_capacity(&self, expected_tokens: usize) -> bool {
        self.pages_for(expected_tokens) <= self.pool.capacity_pages
    }

    /// Start a sequence, reserving worst-case pages for `expected_tokens`.
    pub fn new_seq(&mut self, id: u64, expected_tokens: usize) -> Result<()> {
        ensure!(!self.seqs.contains_key(&id), "sequence {id} exists");
        ensure!(!self.swapped.contains_key(&id), "sequence {id} is swapped out");
        let reserve = self.pages_for(expected_tokens);
        ensure!(
            self.pool.try_reserve(reserve),
            "page pool cannot reserve {reserve} pages for sequence {id}"
        );
        let stores = (0..self.n_layers)
            .map(|_| {
                (0..self.n_kv_heads)
                    .map(|_| (SideStore::default(), SideStore::default()))
                    .collect()
            })
            .collect();
        self.seqs.insert(
            id,
            SeqCache {
                len: 0,
                pages: 0,
                reserved: reserve,
                stores,
            },
        );
        Ok(())
    }

    pub fn free_seq(&mut self, id: u64) {
        if let Some(s) = self.seqs.remove(&id) {
            self.pool.release(s.pages, s.reserved);
        }
        self.swapped.remove(&id); // swapped sequences hold no pool pages
    }

    /// Preempt: move the sequence's compressed streams out of the pool into
    /// the swap store, releasing its pages AND its reservation. The bytes
    /// are moved verbatim — no dequantization, no re-encoding.
    pub fn swap_out(&mut self, id: u64) -> Result<()> {
        let mut s = match self.seqs.remove(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        self.pool.release(s.pages, s.reserved);
        s.reserved = 0;
        self.swapped.insert(id, s);
        Ok(())
    }

    /// Re-admit a swapped sequence, reserving for `expected_tokens` total
    /// (current length + remaining generation). Returns false — leaving the
    /// sequence swapped — when the pool cannot promise that much yet.
    pub fn swap_in(&mut self, id: u64, expected_tokens: usize) -> Result<bool> {
        let s = match self.swapped.get(&id) {
            Some(s) => s,
            None => bail!("sequence {id} is not swapped out"),
        };
        let reserve = self.pages_for(expected_tokens).max(s.pages);
        if !self.pool.can_reserve(reserve) {
            return Ok(false);
        }
        let mut s = self.swapped.remove(&id).unwrap();
        self.pool.adopt(s.pages, reserve);
        s.reserved = reserve;
        self.seqs.insert(id, s);
        Ok(true)
    }

    pub fn is_swapped(&self, id: u64) -> bool {
        self.swapped.contains_key(&id)
    }

    fn append_side(
        store: &mut SideStore,
        r: &[f32],
        k_idx: &[f32],
        bins: u32,
        mode: NormMode,
    ) {
        let width = bits_for(bins);
        for &k in k_idx {
            store.angles.push(k as u32, width);
        }
        if mode.bits == 0 {
            store.raw_norms.extend_from_slice(r);
        } else {
            let q = norm::quantize(r, mode);
            for &c in &q.codes {
                store.norm_codes.push(c as u32, mode.bits as u32);
            }
            store.windows.push((q.vmin, q.vmax));
        }
    }

    /// Append one token's compressed KV for (seq, layer, head).
    /// `kr/ki/vr/vi` are the d/2-length raw norms and angle indices the
    /// prefill/decode HLOs emit (indices as f32 codes).
    #[allow(clippy::too_many_arguments)]
    pub fn append_token_lh(
        &mut self,
        id: u64,
        layer: usize,
        head: usize,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<()> {
        let half = self.d_head / 2;
        ensure!(kr.len() == half && ki.len() == half);
        ensure!(vr.len() == half && vi.len() == half);
        let bins = self.cfg.layers[layer];
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        let seq = match self.seqs.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        let (ks, vs) = &mut seq.stores[layer][head];
        Self::append_side(ks, kr, ki, bins.n_k, k_norm);
        Self::append_side(vs, vr, vi, bins.n_v, v_norm);
        Ok(())
    }

    /// Append one token's compressed KV across ALL (layer, head) pairs in
    /// one call — the batched form of [`Self::append_token_lh`]. The slabs
    /// are dense prefill/decode HLO outputs; the d/2-length row for
    /// (layer `l`, head `h`) starts at `offset + l*l_stride + h*h_stride`.
    /// Layers fan out across rayon when the per-token work is large enough;
    /// output is identical to calling `append_token_lh` per (layer, head)
    /// in order, since each (layer, head) owns a disjoint store.
    #[allow(clippy::too_many_arguments)]
    pub fn append_token_strided(
        &mut self,
        id: u64,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
        offset: usize,
        l_stride: usize,
        h_stride: usize,
    ) -> Result<()> {
        let half = self.d_head / 2;
        let (l_n, h_n) = (self.n_layers, self.n_kv_heads);
        if l_n == 0 || h_n == 0 {
            return Ok(());
        }
        let max_base = offset + (l_n - 1) * l_stride + (h_n - 1) * h_stride;
        ensure!(
            kr.len() >= max_base + half
                && ki.len() >= max_base + half
                && vr.len() >= max_base + half
                && vi.len() >= max_base + half,
            "strided append: slab too small for (L={l_n}, H={h_n}) layout"
        );
        let layers = &self.cfg.layers;
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        let seq = match self.seqs.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        let append_layer = |l: usize, stores_l: &mut Vec<(SideStore, SideStore)>| {
            let bins = layers[l];
            for (h, (ks, vs)) in stores_l.iter_mut().enumerate() {
                let base = offset + l * l_stride + h * h_stride;
                let end = base + half;
                Self::append_side(ks, &kr[base..end], &ki[base..end], bins.n_k, k_norm);
                Self::append_side(vs, &vr[base..end], &vi[base..end], bins.n_v, v_norm);
            }
        };
        if l_n * h_n * half >= PAR_APPEND_ELEM_THRESHOLD {
            seq.stores
                .par_iter_mut()
                .enumerate()
                .for_each(|(l, s)| append_layer(l, s));
        } else {
            for (l, s) in seq.stores.iter_mut().enumerate() {
                append_layer(l, s);
            }
        }
        Ok(())
    }

    /// Advance the sequence length by one token (after all layers/heads of
    /// that token were appended), allocating pages as needed. Allocation
    /// inside the admission reservation cannot fail; growth beyond it
    /// (a sequence outliving its declared bound) extends the reservation
    /// when capacity allows and errors otherwise.
    pub fn commit_token(&mut self, id: u64) -> Result<()> {
        let page_tokens = self.pool.page_tokens;
        let seq = match self.seqs.get_mut(&id) {
            Some(s) => s,
            None => bail!("unknown sequence {id}"),
        };
        ensure!(seq.len < self.tmax, "sequence {id} at tmax");
        if seq.len % page_tokens == 0 {
            if seq.pages + 1 > seq.reserved {
                // outgrew the admission promise (shouldn't happen for
                // engine-admitted sequences): extend if capacity allows
                if !self.pool.try_reserve(1) {
                    bail!("page pool exhausted");
                }
                seq.reserved += 1;
            }
            self.pool.alloc_reserved(1);
            seq.pages += 1;
        }
        seq.len += 1;
        Ok(())
    }

    pub fn seq_len(&self, id: u64) -> usize {
        self.seqs.get(&id).map_or(0, |s| s.len)
    }

    /// Dequantize + unpack one sequence into batch slot `b` of the dense
    /// (L,B,H,Tmax,d/2) buffers the decode HLO takes. Slots beyond the
    /// sequence length are left untouched (they're masked by pos).
    #[allow(clippy::too_many_arguments)]
    pub fn fill_dense(
        &self,
        id: u64,
        b: usize,
        batch: usize,
        kr: &mut [f32],
        ki: &mut [f32],
        vr: &mut [f32],
        vi: &mut [f32],
    ) -> Result<usize> {
        self.fill_dense_range(id, b, batch, 0, kr, ki, vr, vi)
    }

    /// Incremental variant: reinflate only tokens `from_t..len` — the
    /// engine keeps per-slot dense buffers warm and tops up one token per
    /// decode step, making the per-step coordinator cost O(1) in sequence
    /// length instead of O(T) (EXPERIMENTS.md §Perf). Full refills (new
    /// sequences, large `len - from_t`) fan layers out across rayon: each
    /// layer writes a disjoint `batch*H*Tmax*d/2` chunk of the dense
    /// tensors, so the split is safe and the output identical to the
    /// serial loop.
    #[allow(clippy::too_many_arguments)]
    pub fn fill_dense_range(
        &self,
        id: u64,
        b: usize,
        batch: usize,
        from_t: usize,
        kr: &mut [f32],
        ki: &mut [f32],
        vr: &mut [f32],
        vi: &mut [f32],
    ) -> Result<usize> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        let half = self.d_head / 2;
        let (h_n, tmax) = (self.n_kv_heads, self.tmax);
        let layer_elems = batch * h_n * tmax * half;
        if self.n_layers == 0 || layer_elems == 0 {
            return Ok(seq.len);
        }
        ensure!(
            kr.len() >= self.n_layers * layer_elems
                && ki.len() >= self.n_layers * layer_elems
                && vr.len() >= self.n_layers * layer_elems
                && vi.len() >= self.n_layers * layer_elems,
            "dense buffers too small for (L,B,H,Tmax,d/2)"
        );
        let job = FillJob {
            b,
            h_n,
            tmax,
            half,
            from_t,
            len: seq.len,
        };
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        let span = seq.len.saturating_sub(from_t);
        let work = span * self.n_layers * h_n * half;
        // span > 1: the per-decode-step one-token top-up must stay on the
        // serial path at ANY model size — it is the engine's O(1) cost
        if span > 1 && work >= PAR_FILL_ELEM_THRESHOLD {
            kr.par_chunks_mut(layer_elems)
                .zip(ki.par_chunks_mut(layer_elems))
                .zip(vr.par_chunks_mut(layer_elems))
                .zip(vi.par_chunks_mut(layer_elems))
                .take(self.n_layers)
                .enumerate()
                .for_each(|(l, (((kr, ki), vr), vi))| {
                    let bins = self.cfg.layers[l];
                    fill_layer(job, &seq.stores[l], bins, k_norm, v_norm, kr, ki, vr, vi);
                });
        } else {
            for (l, (((kr, ki), vr), vi)) in kr
                .chunks_mut(layer_elems)
                .zip(ki.chunks_mut(layer_elems))
                .zip(vr.chunks_mut(layer_elems))
                .zip(vi.chunks_mut(layer_elems))
                .take(self.n_layers)
                .enumerate()
            {
                fill_layer(job, &seq.stores[l], self.cfg.layers[l], k_norm, v_norm, kr, ki, vr, vi);
            }
        }
        Ok(seq.len)
    }

    /// Tokens per page — also the token depth of a fused-read tile.
    pub fn page_tokens(&self) -> usize {
        self.pool.page_tokens
    }

    /// Random-access tile decode: dequantize tokens `t0..t0+tokens` of
    /// (`id`, `layer`, `head`) into caller buffers (each ≥ `tokens*d/2`
    /// f32, token-major rows). The page-granular building block behind
    /// [`Self::visit_seq_tiles`], exposed for backends that schedule their
    /// own tile walk. Values are bit-identical to what [`Self::fill_dense`]
    /// would put in the corresponding dense rows.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_tile_into(
        &self,
        id: u64,
        layer: usize,
        head: usize,
        t0: usize,
        tokens: usize,
        kr: &mut [f32],
        ki: &mut [f32],
        vr: &mut [f32],
        vi: &mut [f32],
    ) -> Result<()> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        ensure!(
            layer < self.n_layers && head < self.n_kv_heads,
            "tile (layer {layer}, head {head}) out of range"
        );
        ensure!(
            t0 + tokens <= seq.len,
            "tile {t0}..{} beyond sequence length {}",
            t0 + tokens,
            seq.len
        );
        let half = self.d_head / 2;
        let elems = tokens * half;
        ensure!(
            kr.len() >= elems && ki.len() >= elems && vr.len() >= elems && vi.len() >= elems,
            "tile buffers smaller than tokens*d/2"
        );
        let bins = self.cfg.layers[layer];
        let (ks, vs) = &seq.stores[layer][head];
        decode_side_range(ks, bins.n_k, self.cfg.k_norm, t0, tokens, half, kr, ki);
        decode_side_range(vs, bins.n_v, self.cfg.v_norm, t0, tokens, half, vr, vi);
        Ok(())
    }

    /// The fused read path: visit `id`'s cache for one layer as dequantized
    /// page tiles — heads ascending, then token ranges ascending, covering
    /// exactly tokens `0..upto` (clamped to the sequence length). Each tile
    /// is at most `page_tokens` rows decoded into `scratch`, which grows
    /// once to a single page and never again: no per-token allocation, and
    /// the dense `(L,B,H,Tmax,d/2)` tensors never materialize.
    pub fn visit_seq_tiles(
        &self,
        id: u64,
        layer: usize,
        upto: usize,
        scratch: &mut TileScratch,
        f: &mut dyn FnMut(&KvTileView<'_>),
    ) -> Result<()> {
        let seq = self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow::anyhow!("unknown sequence {id}"))?;
        ensure!(layer < self.n_layers, "layer {layer} out of range");
        let upto = upto.min(seq.len);
        let half = self.d_head / 2;
        let tile_tokens = self.pool.page_tokens;
        scratch.ensure(tile_tokens * half);
        let bins = self.cfg.layers[layer];
        let (k_norm, v_norm) = (self.cfg.k_norm, self.cfg.v_norm);
        for (head, (ks, vs)) in seq.stores[layer].iter().enumerate() {
            let mut t0 = 0usize;
            while t0 < upto {
                let tokens = tile_tokens.min(upto - t0);
                let elems = tokens * half;
                let s = &mut *scratch;
                decode_side_range(ks, bins.n_k, k_norm, t0, tokens, half, &mut s.kr, &mut s.ki);
                decode_side_range(vs, bins.n_v, v_norm, t0, tokens, half, &mut s.vr, &mut s.vi);
                f(&KvTileView {
                    layer,
                    head,
                    t0,
                    tokens,
                    half,
                    kr: &scratch.kr[..elems],
                    ki: &scratch.ki[..elems],
                    vr: &scratch.vr[..elems],
                    vi: &scratch.vi[..elems],
                });
                t0 += tokens;
            }
        }
        Ok(())
    }

    pub fn memory_stats(&self) -> MemoryStats {
        let mut st = MemoryStats {
            sequences: self.seqs.len(),
            pages_allocated: self.pool.allocated(),
            pages_reserved: self.pool.reserved(),
            pages_capacity: self.pool.capacity(),
            swapped_sequences: self.swapped.len(),
            ..Default::default()
        };
        for s in self.seqs.values() {
            st.tokens += s.len;
            st.compressed_bytes += s.bytes();
            // fp16 reference: K and V, n_layers*n_heads*len*d_head*2 bytes each
            st.fp16_reference_bytes +=
                2 * self.n_layers * self.n_kv_heads * s.len * self.d_head * 2;
        }
        for s in self.swapped.values() {
            st.swapped_tokens += s.len;
            st.swapped_bytes += s.bytes();
        }
        st
    }
}

/// Reused dequant scratch for the fused read path: four page-sized
/// `(page_tokens × d/2)` slabs. Grows once to the page size and stays
/// there — the bounded-scratch contract the fused bench reports via
/// [`TileScratch::bytes`]. Contrast with the dense reinflation buffers,
/// which are `L·B·H·Tmax·d/2` floats *each*.
#[derive(Debug, Default)]
pub struct TileScratch {
    kr: Vec<f32>,
    ki: Vec<f32>,
    vr: Vec<f32>,
    vi: Vec<f32>,
}

impl TileScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, elems: usize) {
        if self.kr.len() < elems {
            self.kr.resize(elems, 0.0);
            self.ki.resize(elems, 0.0);
            self.vr.resize(elems, 0.0);
            self.vi.resize(elems, 0.0);
        }
    }

    /// Bytes held across all four slabs.
    pub fn bytes(&self) -> usize {
        (self.kr.len() + self.ki.len() + self.vr.len() + self.vi.len()) * 4
    }
}

/// Adapter handing a decode batch's lanes to
/// [`crate::runtime::ModelBackend::run_decode_fused`]: maps each lane to
/// its live sequence (if any) and walks [`PagedKvCache::visit_seq_tiles`]
/// with one shared scratch. Empty lanes visit nothing, matching the dense
/// path's zero-length scan of an inactive slot.
pub struct BatchTileReader<'a> {
    pub kv: &'a PagedKvCache,
    pub lanes: &'a [Option<u64>],
    pub scratch: &'a mut TileScratch,
}

impl KvTileReader for BatchTileReader<'_> {
    fn visit(
        &mut self,
        lane: usize,
        layer: usize,
        upto: usize,
        f: &mut dyn FnMut(&KvTileView<'_>),
    ) -> Result<()> {
        match self.lanes.get(lane).copied().flatten() {
            Some(id) => self.kv.visit_seq_tiles(id, layer, upto, self.scratch, f),
            None => Ok(()),
        }
    }
}

/// Geometry of one reinflation pass (shared by every layer's worker).
#[derive(Clone, Copy)]
struct FillJob {
    b: usize,
    h_n: usize,
    tmax: usize,
    half: usize,
    from_t: usize,
    len: usize,
}

/// Reinflate one layer's stores into that layer's chunk of the dense
/// tensors. `kr/ki/vr/vi` are the `batch*H*Tmax*d/2` slices for this layer,
/// so the base index drops the leading layer term of the (L,B,H,Tmax,d/2)
/// layout. Consecutive tokens of one (head, side) are contiguous in the
/// dense layout, so the whole `from_t..len` span is one
/// [`decode_side_range`] call per side.
#[allow(clippy::too_many_arguments)]
fn fill_layer(
    job: FillJob,
    stores: &[(SideStore, SideStore)],
    bins: LayerBins,
    k_norm: NormMode,
    v_norm: NormMode,
    kr: &mut [f32],
    ki: &mut [f32],
    vr: &mut [f32],
    vi: &mut [f32],
) {
    let FillJob { b, h_n, tmax, half, from_t, len } = job;
    if from_t >= len {
        return;
    }
    let tokens = len - from_t;
    for (h, (ks, vs)) in stores.iter().enumerate() {
        let base = ((b * h_n + h) * tmax + from_t) * half;
        let end = base + tokens * half;
        let (kr, ki) = (&mut kr[base..end], &mut ki[base..end]);
        let (vr, vi) = (&mut vr[base..end], &mut vi[base..end]);
        decode_side_range(ks, bins.n_k, k_norm, from_t, tokens, half, kr, ki);
        decode_side_range(vs, bins.n_v, v_norm, from_t, tokens, half, vr, vi);
    }
}

/// Dequantize tokens `t0..t0+tokens` of one side store into contiguous
/// token-major (norms, codes-as-f32) rows. This is THE dequant kernel for
/// both read paths — the dense reinflation ([`fill_layer`]) and the fused
/// tile iterator ([`PagedKvCache::visit_seq_tiles`]) call it, so their
/// outputs cannot drift: fused-vs-reinflate bit-identity holds by
/// construction. Streams the bit-packed codes through [`BitCursor`]s
/// instead of random-access `get`s.
#[allow(clippy::too_many_arguments)]
fn decode_side_range(
    store: &SideStore,
    bins: u32,
    mode: NormMode,
    t0: usize,
    tokens: usize,
    half: usize,
    out_r: &mut [f32],
    out_i: &mut [f32],
) {
    let elems = tokens * half;
    debug_assert!(out_r.len() >= elems && out_i.len() >= elems);
    let width = bits_for(bins);
    let mut ang = BitCursor::new(&store.angles, t0 * half, width);
    for o in out_i[..elems].iter_mut() {
        *o = ang.next(width) as f32;
    }
    if mode.bits == 0 {
        out_r[..elems].copy_from_slice(&store.raw_norms[t0 * half..t0 * half + elems]);
    } else {
        let bits = mode.bits as u32;
        let levels = mode.levels().max(1.0);
        let mut codes = BitCursor::new(&store.norm_codes, t0 * half, bits);
        for (t, row) in out_r[..elems].chunks_exact_mut(half).enumerate() {
            let (vmin, vmax) = store.windows[t0 + t];
            let scale = if vmax > vmin { vmax - vmin } else { 1.0 };
            // `(c*scale)/levels` — the exact expression of
            // `norm::dequantize_into` and the pre-tile reinflation; do NOT
            // hoist `scale/levels` (it shifts the result by 1 ulp and
            // breaks bit-parity with the norm module / oracle)
            if mode.log_space {
                for o in row.iter_mut() {
                    *o = (vmin + codes.next(bits) as f32 * scale / levels).exp();
                }
            } else {
                for o in row.iter_mut() {
                    *o = vmin + codes.next(bits) as f32 * scale / levels;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{angle, fwht::test_sign_diag};

    fn mk_cache(norms: (NormMode, NormMode)) -> PagedKvCache {
        let cfg = QuantConfig::paper_uniform(2).with_norms(norms.0, norms.1);
        PagedKvCache::new(cfg, 2, 1, 8, 16, 64, 4)
    }

    fn fake_entry(seed: u64, half: usize, bins: u32) -> (Vec<f32>, Vec<f32>) {
        let mut s = seed | 1;
        let mut r = Vec::new();
        let mut k = Vec::new();
        for _ in 0..half {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            r.push(0.1 + (s % 1000) as f32 / 250.0);
            k.push((s % bins as u64) as f32);
        }
        (r, k)
    }

    #[test]
    fn roundtrip_fp32_norms() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        c.new_seq(7, 16).unwrap();
        let half = 4;
        let mut want = Vec::new();
        for t in 0..5u64 {
            for l in 0..2 {
                let (kr, ki) = fake_entry(t * 10 + l as u64, half, 128);
                let (vr, vi) = fake_entry(t * 10 + l as u64 + 5, half, 64);
                c.append_token_lh(7, l, 0, &kr, &ki, &vr, &vi).unwrap();
                want.push((l, kr, ki, vr, vi));
            }
            c.commit_token(7).unwrap();
        }
        let (lb, b, h, tmax, _) = (2, 1usize, 1, 16, half);
        let n = lb * b * h * tmax * half;
        let (mut kr, mut ki, mut vr, mut vi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let len = c.fill_dense(7, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
        assert_eq!(len, 5);
        for (idx, (l, wkr, wki, wvr, wvi)) in want.iter().enumerate() {
            let t = idx / 2;
            let base = ((l * b) * h * tmax + t) * half;
            assert_eq!(&kr[base..base + half], &wkr[..]);
            assert_eq!(&ki[base..base + half], &wki[..]);
            assert_eq!(&vr[base..base + half], &wvr[..]);
            assert_eq!(&vi[base..base + half], &wvi[..]);
        }
    }

    #[test]
    fn norm_quant_roundtrip_within_step() {
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        c.new_seq(1, 16).unwrap();
        let half = 4;
        let (kr, ki) = fake_entry(3, half, 128);
        let (vr, vi) = fake_entry(4, half, 64);
        for l in 0..2 {
            c.append_token_lh(1, l, 0, &kr, &ki, &vr, &vi).unwrap();
        }
        c.commit_token(1).unwrap();
        let n = 2 * 16 * half;
        let (mut okr, mut oki, mut ovr, mut ovi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        c.fill_dense(1, 0, 1, &mut okr, &mut oki, &mut ovr, &mut ovi).unwrap();
        // angles exact
        assert_eq!(&oki[..half], &ki[..]);
        assert_eq!(&ovi[..half], &vi[..]);
        // norms within quantization error
        let kspan = kr.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
            - kr.iter().cloned().fold(f32::INFINITY, f32::min);
        for (a, b) in kr.iter().zip(&okr[..half]) {
            assert!((a - b).abs() <= kspan / 255.0 * 0.51 + 1e-6);
        }
        for (a, b) in vr.iter().zip(&ovr[..half]) {
            assert!((b / a - 1.0).abs() < 0.25, "{a} {b}"); // 4-bit log coarse
        }
    }

    #[test]
    fn page_accounting() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        c.new_seq(1, 12).unwrap();
        let half = 4;
        let (kr, ki) = fake_entry(1, half, 128);
        for t in 0..9 {
            for l in 0..2 {
                c.append_token_lh(1, l, 0, &kr, &ki, &kr, &ki).unwrap();
            }
            c.commit_token(1).unwrap();
            let _ = t;
        }
        // 9 tokens at 4 tokens/page -> 3 pages
        assert_eq!(c.memory_stats().pages_allocated, 3);
        c.free_seq(1);
        assert_eq!(c.memory_stats().pages_allocated, 0);
    }

    #[test]
    fn pool_exhaustion_rejects() {
        let cfg = QuantConfig::paper_uniform(1);
        let mut c = PagedKvCache::new(cfg, 1, 1, 8, 64, 2, 4);
        c.new_seq(1, 8).unwrap();
        let (kr, ki) = fake_entry(1, 4, 128);
        let mut committed = 0;
        for _ in 0..12 {
            c.append_token_lh(1, 0, 0, &kr, &ki, &kr, &ki).unwrap();
            if c.commit_token(1).is_ok() {
                committed += 1;
            } else {
                break;
            }
        }
        assert_eq!(committed, 8); // 2 pages * 4 tokens
    }

    #[test]
    fn compression_ratio_beats_4x_with_k8v4() {
        // d=64, K128V64 + K8V4-log ≈ 7.25 bits/elem vs fp16's 16 -> >2.2x;
        // with fp32-norm storage it's much worse — this pins the ordering.
        let cfg_a = QuantConfig::paper_uniform(2).with_k8v4_log();
        let cfg_b = QuantConfig::paper_uniform(2);
        let mut ratios = Vec::new();
        for cfg in [cfg_a, cfg_b] {
            let mut c = PagedKvCache::new(cfg, 2, 1, 64, 64, 1024, 16);
            c.new_seq(1, 48).unwrap();
            let (kr, ki) = fake_entry(1, 32, 128);
            let (vr, vi) = fake_entry(2, 32, 64);
            for _ in 0..48 {
                for l in 0..2 {
                    c.append_token_lh(1, l, 0, &kr, &ki, &vr, &vi).unwrap();
                }
                c.commit_token(1).unwrap();
            }
            ratios.push(c.memory_stats().compression_ratio());
        }
        assert!(ratios[0] > 2.0, "k8v4 ratio {}", ratios[0]);
        assert!(ratios[0] > ratios[1], "quantized norms must beat fp32");
    }

    #[test]
    fn rejects_unknown_seq() {
        let mut c = mk_cache((NormMode::FP32, NormMode::FP32));
        let (kr, ki) = fake_entry(1, 4, 128);
        assert!(c.append_token_lh(9, 0, 0, &kr, &ki, &kr, &ki).is_err());
        assert!(c.commit_token(9).is_err());
        assert!(c
            .append_token_strided(9, &kr, &ki, &kr, &ki, 0, 0, 0)
            .is_err());
    }

    #[test]
    fn strided_append_matches_per_lh() {
        // two caches fed the same prefill-style slab: one through the
        // per-(layer,head) path, one through the batched strided path —
        // every reinflated byte must agree
        let (l_n, h_n, d, tp) = (2usize, 2usize, 8usize, 3usize);
        let half = d / 2;
        let cfg = QuantConfig::paper_uniform(l_n).with_norms(NormMode::LINEAR8, NormMode::LOG4);
        let mut via_lh = PagedKvCache::new(cfg.clone(), l_n, h_n, d, 16, 64, 4);
        let mut via_strided = PagedKvCache::new(cfg, l_n, h_n, d, 16, 64, 4);
        via_lh.new_seq(1, 16).unwrap();
        via_strided.new_seq(1, 16).unwrap();
        // dense (L, B=1, H, Tp, d/2) slabs
        let n = l_n * h_n * tp * half;
        let (mut kr, mut ki, mut vr, mut vi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        for l in 0..l_n {
            for h in 0..h_n {
                for t in 0..tp {
                    let base = ((l * h_n + h) * tp + t) * half;
                    let seed = (l * 100 + h * 10 + t) as u64 + 1;
                    let (r, i) = fake_entry(seed, half, 128);
                    kr[base..base + half].copy_from_slice(&r);
                    ki[base..base + half].copy_from_slice(&i);
                    let (r, i) = fake_entry(seed + 500, half, 64);
                    vr[base..base + half].copy_from_slice(&r);
                    vi[base..base + half].copy_from_slice(&i);
                }
            }
        }
        for t in 0..tp {
            for l in 0..l_n {
                for h in 0..h_n {
                    let base = ((l * h_n + h) * tp + t) * half;
                    via_lh
                        .append_token_lh(
                            1,
                            l,
                            h,
                            &kr[base..base + half],
                            &ki[base..base + half],
                            &vr[base..base + half],
                            &vi[base..base + half],
                        )
                        .unwrap();
                }
            }
            via_lh.commit_token(1).unwrap();
            via_strided
                .append_token_strided(1, &kr, &ki, &vr, &vi, t * half, h_n * tp * half, tp * half)
                .unwrap();
            via_strided.commit_token(1).unwrap();
        }
        let m = l_n * h_n * 16 * half;
        let mut a = (vec![0.0; m], vec![0.0; m], vec![0.0; m], vec![0.0; m]);
        let mut b = (vec![0.0; m], vec![0.0; m], vec![0.0; m], vec![0.0; m]);
        via_lh.fill_dense(1, 0, 1, &mut a.0, &mut a.1, &mut a.2, &mut a.3).unwrap();
        via_strided.fill_dense(1, 0, 1, &mut b.0, &mut b.1, &mut b.2, &mut b.3).unwrap();
        assert_eq!(a, b);
        assert_eq!(
            via_lh.memory_stats().compressed_bytes,
            via_strided.memory_stats().compressed_bytes
        );
    }

    #[test]
    fn swap_roundtrip_is_bit_identical_and_frees_pages() {
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        c.new_seq(5, 12).unwrap();
        let half = 4;
        for t in 0..6u64 {
            for l in 0..2 {
                let (kr, ki) = fake_entry(t * 9 + l as u64 + 1, half, 128);
                let (vr, vi) = fake_entry(t * 9 + l as u64 + 77, half, 64);
                c.append_token_lh(5, l, 0, &kr, &ki, &vr, &vi).unwrap();
            }
            c.commit_token(5).unwrap();
        }
        let n = 2 * 16 * half;
        let mut before = (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        c.fill_dense(5, 0, 1, &mut before.0, &mut before.1, &mut before.2, &mut before.3)
            .unwrap();
        let resident = c.memory_stats();
        assert!(resident.pages_allocated > 0 && resident.pages_reserved > 0);

        c.swap_out(5).unwrap();
        assert!(c.is_swapped(5));
        let st = c.memory_stats();
        assert_eq!(st.pages_allocated, 0, "swap releases pages");
        assert_eq!(st.pages_reserved, 0, "swap releases the reservation");
        assert_eq!(st.swapped_sequences, 1);
        assert_eq!(st.swapped_tokens, 6);
        assert!(st.swapped_bytes > 0);
        let mut scratch = (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        assert!(
            c.fill_dense(5, 0, 1, &mut scratch.0, &mut scratch.1, &mut scratch.2, &mut scratch.3)
                .is_err(),
            "swapped sequences are not reinflatable"
        );

        assert!(c.swap_in(5, 12).unwrap());
        assert!(!c.is_swapped(5));
        let mut after = (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        c.fill_dense(5, 0, 1, &mut after.0, &mut after.1, &mut after.2, &mut after.3)
            .unwrap();
        assert_eq!(before, after, "restore must be bit-identical");
        assert_eq!(c.memory_stats().pages_allocated, resident.pages_allocated);
    }

    #[test]
    fn swap_in_respects_pool_pressure() {
        // capacity 2 pages of 4 tokens; seq 1 takes both, seq 2 must wait
        let cfg = QuantConfig::paper_uniform(1);
        let mut c = PagedKvCache::new(cfg, 1, 1, 8, 64, 2, 4);
        let (kr, ki) = fake_entry(1, 4, 128);
        c.new_seq(1, 8).unwrap();
        for _ in 0..8 {
            c.append_token_lh(1, 0, 0, &kr, &ki, &kr, &ki).unwrap();
            c.commit_token(1).unwrap();
        }
        c.swap_out(1).unwrap();
        c.new_seq(2, 8).unwrap();
        assert!(!c.swap_in(1, 8).unwrap(), "no room while seq 2 holds the pool");
        c.free_seq(2);
        assert!(c.swap_in(1, 8).unwrap(), "room after seq 2 freed");
        assert_eq!(c.seq_len(1), 8);
        // unknown / double operations error
        assert!(c.swap_in(1, 8).is_err());
        assert!(c.swap_out(99).is_err());
    }

    #[test]
    fn reservation_blocks_overadmission() {
        // seq 1 reserves the whole pool up-front: a second sequence must
        // not be admitted even though few pages are *allocated* yet
        let cfg = QuantConfig::paper_uniform(1);
        let mut c = PagedKvCache::new(cfg, 1, 1, 8, 64, 4, 4);
        c.new_seq(1, 16).unwrap(); // reserves all 4 pages
        assert_eq!(c.memory_stats().pages_allocated, 0);
        assert!(!c.can_admit(4), "reservation counts against admission");
        assert!(c.new_seq(2, 4).is_err());
        c.free_seq(1);
        assert!(c.can_admit(16));
    }

    #[test]
    fn tiles_bit_identical_to_fill_dense() {
        // fused tiles and the dense reinflation must agree to the bit for
        // every (layer, head, token) — page boundaries, quantized norms,
        // partial visits included
        let mut c = mk_cache((NormMode::LINEAR8, NormMode::LOG4));
        let (l_n, h_n, half, tmax) = (2usize, 1usize, 4usize, 16usize);
        c.new_seq(3, 11).unwrap();
        for t in 0..11u64 {
            for l in 0..l_n {
                let (kr, ki) = fake_entry(t * 13 + l as u64 + 1, half, 128);
                let (vr, vi) = fake_entry(t * 13 + l as u64 + 99, half, 64);
                c.append_token_lh(3, l, 0, &kr, &ki, &vr, &vi).unwrap();
            }
            c.commit_token(3).unwrap();
        }
        let n = l_n * h_n * tmax * half;
        let mut dense = (vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n], vec![0.0f32; n]);
        c.fill_dense(3, 0, 1, &mut dense.0, &mut dense.1, &mut dense.2, &mut dense.3)
            .unwrap();
        let mut scratch = TileScratch::new();
        for upto in [11usize, 7, 1, 0] {
            for l in 0..l_n {
                let mut covered = vec![false; upto];
                c.visit_seq_tiles(3, l, upto, &mut scratch, &mut |tile| {
                    assert!(tile.tokens <= c.page_tokens(), "tile beyond one page");
                    for tr in 0..tile.tokens {
                        let t = tile.t0 + tr;
                        covered[t] = true;
                        let dbase = ((l * h_n + tile.head) * tmax + t) * half;
                        let tbase = tr * half;
                        assert_eq!(&tile.kr[tbase..tbase + half], &dense.0[dbase..dbase + half]);
                        assert_eq!(&tile.ki[tbase..tbase + half], &dense.1[dbase..dbase + half]);
                        assert_eq!(&tile.vr[tbase..tbase + half], &dense.2[dbase..dbase + half]);
                        assert_eq!(&tile.vi[tbase..tbase + half], &dense.3[dbase..dbase + half]);
                    }
                })
                .unwrap();
                assert!(covered.iter().all(|&x| x), "upto={upto} l={l}: gap in tile coverage");
            }
        }
        // random-access tile decode agrees too
        let mut kr = vec![0.0f32; 3 * half];
        let mut ki = vec![0.0f32; 3 * half];
        let mut vr = vec![0.0f32; 3 * half];
        let mut vi = vec![0.0f32; 3 * half];
        c.decode_tile_into(3, 1, 0, 5, 3, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
        let dbase = (h_n * tmax + 5) * half; // layer 1, head 0, t=5
        assert_eq!(&kr[..3 * half], &dense.0[dbase..dbase + 3 * half]);
        assert_eq!(&vi[..3 * half], &dense.3[dbase..dbase + 3 * half]);
        // bounds are checked, not zipped short
        assert!(c.decode_tile_into(3, 0, 0, 10, 2, &mut kr, &mut ki, &mut vr, &mut vi).is_err());
        assert!(c.decode_tile_into(3, 9, 0, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).is_err());
        // bounded scratch: one page of four d/2 slabs
        assert_eq!(scratch.bytes(), c.page_tokens() * half * 4 * 4);
    }

    #[test]
    fn parallel_fill_exact_for_fp32_norms() {
        // large enough that fill_dense takes the rayon path (work =
        // 10 tokens * 24 layers * 32 half = 7680 >= threshold); fp32 norms
        // make the expected reinflated values exactly the appended ones
        let (l_n, d, tmax, toks) = (24usize, 64usize, 32usize, 10usize);
        let half = d / 2;
        let cfg = QuantConfig::paper_uniform(l_n);
        let mut c = PagedKvCache::new(cfg, l_n, 1, d, tmax, 1024, 16);
        c.new_seq(1, toks).unwrap();
        let mut want = Vec::new();
        for t in 0..toks {
            let mut per_layer = Vec::new();
            for l in 0..l_n {
                let seed = (t * 64 + l) as u64 + 3;
                let (kr, ki) = fake_entry(seed, half, 128);
                let (vr, vi) = fake_entry(seed + 9000, half, 64);
                c.append_token_lh(1, l, 0, &kr, &ki, &vr, &vi).unwrap();
                per_layer.push((kr, ki, vr, vi));
            }
            c.commit_token(1).unwrap();
            want.push(per_layer);
        }
        let n = l_n * tmax * half;
        let (mut kr, mut ki, mut vr, mut vi) =
            (vec![0.0; n], vec![0.0; n], vec![0.0; n], vec![0.0; n]);
        let len = c.fill_dense(1, 0, 1, &mut kr, &mut ki, &mut vr, &mut vi).unwrap();
        assert_eq!(len, toks);
        for (t, per_layer) in want.iter().enumerate() {
            for (l, (wkr, wki, wvr, wvi)) in per_layer.iter().enumerate() {
                let base = (l * tmax + t) * half;
                assert_eq!(&kr[base..base + half], &wkr[..], "t={t} l={l}");
                assert_eq!(&ki[base..base + half], &wki[..], "t={t} l={l}");
                assert_eq!(&vr[base..base + half], &wvr[..], "t={t} l={l}");
                assert_eq!(&vi[base..base + half], &wvi[..], "t={t} l={l}");
            }
        }
    }
}
