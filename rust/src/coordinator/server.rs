//! Line-delimited-JSON TCP front-end for the engine — the deployable
//! surface: one request per line, one response per line.
//!
//!   → {"id": 1, "prompt": "the wodu zatu", "max_new_tokens": 8}
//!   ← {"id": 1, "text": "...", "tokens": [ ... ], "prompt_tokens": 13,
//!      "finish": "length"}
//!
//! Connections are handled by threads that feed an mpsc queue; the engine
//! runs its tick loop on the serving thread (PJRT handles stay on one
//! thread). Responses travel back through per-request channels.

use super::engine::Engine;
use super::session::{FinishReason, Request};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

/// A parsed wire request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub prompt: String,
    pub max_new_tokens: usize,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line)?;
    Ok(WireRequest {
        id: j.get("id")?.as_u64()?,
        prompt: j.get("prompt")?.as_str()?.to_string(),
        max_new_tokens: j
            .opt("max_new_tokens")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(16),
    })
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format one response line (no trailing newline).
pub fn format_response(
    id: u64,
    prompt_tokens: usize,
    generated: &[i32],
    finish: Option<FinishReason>,
) -> String {
    let text: String = generated
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8 as char)
        .collect();
    let toks = generated
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let finish = match finish {
        Some(FinishReason::Length) => "length",
        Some(FinishReason::Eos) => "eos",
        Some(FinishReason::CacheFull) => "cache_full",
        None => "unknown",
    };
    format!(
        "{{\"id\": {id}, \"text\": \"{}\", \"tokens\": [{toks}], \"prompt_tokens\": {prompt_tokens}, \"finish\": \"{finish}\"}}",
        json_escape(&text)
    )
}

type Queued = (WireRequest, mpsc::Sender<String>);

/// Serve until `max_requests` have completed (0 = forever). Returns the
/// number served. Binds `addr`; prints the bound address to stderr.
pub fn serve(engine: &mut Engine, addr: &str, max_requests: usize) -> Result<usize> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    eprintln!("turboangle serving on {local}");
    let (tx, rx) = mpsc::channel::<Queued>();

    // acceptor thread: one handler thread per connection
    std::thread::spawn(move || {
        for stream in listener.incoming().flatten() {
            let tx = tx.clone();
            std::thread::spawn(move || {
                let _ = handle_conn(stream, tx);
            });
        }
    });

    let mut next_id: u64 = 1 << 32; // engine-side ids; wire ids are echoed
    let mut pending: HashMap<u64, (u64, mpsc::Sender<String>)> = HashMap::new();
    let mut served = 0usize;
    loop {
        // ingest whatever arrived
        while let Ok((wire, resp_tx)) = rx.try_recv() {
            let prompt: Vec<i32> = wire.prompt.bytes().map(|b| b as i32).collect();
            let id = next_id;
            next_id += 1;
            pending.insert(id, (wire.id, resp_tx));
            engine.submit(Request::new(id, prompt, wire.max_new_tokens));
        }
        if engine.has_work() {
            engine.tick()?;
        } else {
            std::thread::sleep(Duration::from_millis(2));
        }
        for sess in engine.take_finished() {
            if let Some((wire_id, resp_tx)) = pending.remove(&sess.request.id) {
                let line = format_response(
                    wire_id,
                    sess.prompt_len,
                    &sess.generated,
                    sess.finished,
                );
                let _ = resp_tx.send(line);
                served += 1;
            }
        }
        if max_requests > 0 && served >= max_requests && pending.is_empty() {
            return Ok(served);
        }
    }
}

fn handle_conn(stream: TcpStream, tx: mpsc::Sender<Queued>) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(wire) => {
                let (resp_tx, resp_rx) = mpsc::channel();
                tx.send((wire, resp_tx))
                    .map_err(|_| anyhow!("engine gone"))?;
                // block this connection until its response is ready
                let resp = resp_rx.recv().map_err(|_| anyhow!("engine dropped"))?;
                writer.write_all(resp.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            Err(e) => {
                let msg = format!("{{\"error\": \"{}\"}}\n", json_escape(&e.to_string()));
                writer.write_all(msg.as_bytes())?;
                writer.flush()?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_requests() {
        let r = parse_request(r#"{"id": 3, "prompt": "hi", "max_new_tokens": 5}"#).unwrap();
        assert_eq!(r, WireRequest { id: 3, prompt: "hi".into(), max_new_tokens: 5 });
        // default max_new_tokens
        let r = parse_request(r#"{"id": 1, "prompt": "x"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 16);
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt": "x"}"#).is_err());
    }

    #[test]
    fn formats_responses() {
        let line = format_response(7, 3, &[104, 105, 257], Some(FinishReason::Eos));
        assert!(line.contains("\"id\": 7"));
        assert!(line.contains("\"text\": \"hi\""));
        assert!(line.contains("\"finish\": \"eos\""));
        // round-trips through our own parser
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("prompt_tokens").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escaping_is_safe() {
        let line = format_response(1, 0, &[34, 92, 10], None);
        assert!(Json::parse(&line).is_ok(), "{line}");
    }
}
