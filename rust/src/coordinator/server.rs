//! Line-delimited-JSON TCP front-end for the multi-replica serving stack —
//! the deployable surface: one request per line, one response per line.
//!
//!   → {"id": 1, "prompt": "the wodu zatu", "max_new_tokens": 8,
//!      "session_key": "user-42"}
//!   ← {"id": 1, "text": "...", "tokens": [ ... ], "prompt_tokens": 13,
//!      "replica": 0, "finish": "length"}
//!
//! A line `{"id": N, "stats": true}` is a metrics query instead of a
//! generation request: the router picks one replica (least-loaded) and the
//! response carries that replica's [`EngineMetrics::to_json`] snapshot —
//! counters plus ttft/itl/e2e/decode-step histograms with p50/p95/p99 in
//! microseconds (schema in `docs/BENCH_GLOSSARY.md`):
//!
//!   ← {"id": N, "replica": 0, "stats": {"requests_finished": …,
//!      "itl": {"count": …, "p99_us": …}, …}}
//!
//! Stats responses do not count toward `max_requests`.
//!
//! Two more query forms ride the same line protocol:
//!
//! * `{"id": N, "stats": true, "scope": "fleet"}` — fleet aggregation:
//!   the dispatcher probes EVERY replica, merges the snapshots
//!   ([`EngineMetrics::merge`]: counters add, histograms merge
//!   bucket-wise) and answers with one roll-up —
//!   `{"id": N, "scope": "fleet", "replicas": R, "stats": {…}}`.
//! * `{"id": N, "metrics": true}` — Prometheus-style text exposition of
//!   one replica's counters/gauges/quantiles, JSON-escaped into
//!   `{"id": N, "replica": 0, "metrics": "# HELP …"}` so the one-line
//!   protocol is preserved (schema: `docs/OBSERVABILITY.md`).
//!
//! Topology:
//!
//!   conns ──(reader threads)──► ingest ──► dispatcher ──► per-replica
//!                                            │ Router       mpsc queues
//!   conns ◄──(writer threads)◄── responses ◄─┴─ N replica worker threads,
//!                                               each owning one
//!                                               `Box<dyn EngineCore>`
//!
//! * Connections are **pipelined**: the reader forwards every parsed line
//!   immediately and a dedicated writer thread sends responses as they
//!   complete, so one connection can have many ids in flight (responses
//!   are matched by `id`, order is not guaranteed).
//! * The dispatcher routes each request through [`Router`] — round-robin,
//!   least-loaded, consistent-hash session affinity via the optional
//!   `session_key` field (string keys are hashed, numeric keys used
//!   directly), or prefix routing ([`RoutePolicy::Prefix`]): the
//!   dispatcher fingerprints the prompt's first cache page
//!   ([`prefix_fingerprint`]) so requests sharing a cacheable prefix land
//!   on the replica whose radix tree already indexes it.
//! * Stats responses carry a `shared_store` object next to `stats`; the
//!   fleet roll-up dedups it by store identity
//!   ([`MemoryStats::shared_store_id`]) so replicas sharing one
//!   node-level page store count its pages exactly once
//!   (`pages_gross` keeps the per-replica sum for comparison).
//! * Replica workers block on `recv_timeout` when idle — an idle replica
//!   burns no CPU — and keep ticking while they still hold work after the
//!   dispatcher hangs up, so shutdown drains cleanly.

use super::engine::EngineCore;
use super::kv_manager::MemoryStats;
use super::router::{hash_session_key, prefix_fingerprint, RoutePolicy, Router};
use super::scheduler::Action;
use super::session::{FinishReason, Request};
use crate::coordinator::metrics::EngineMetrics;
use crate::obs::{export, ObsSnapshot};
use crate::util::json::Json;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

/// How long an idle replica (or the dispatcher) blocks waiting for work
/// before re-checking shutdown conditions.
const IDLE_WAIT: Duration = Duration::from_millis(25);

/// Lock the shared router, recovering from poison instead of panicking.
///
/// The router holds only load counters and the hash ring — every field is
/// valid at every instruction boundary, so the state behind a poisoned
/// lock (some peer thread panicked while holding it) is still a usable
/// routing heuristic. Propagating the poison would let one crashed thread
/// take down the dispatcher and every replica worker with it.
fn lock_router(router: &Mutex<Router>) -> std::sync::MutexGuard<'_, Router> {
    router.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A parsed wire request.
#[derive(Debug, Clone, PartialEq)]
pub struct WireRequest {
    /// Client-chosen id, echoed verbatim on the response line.
    pub id: u64,
    /// Prompt text (byte-level tokens; empty for stats queries).
    pub prompt: String,
    /// Generation budget (wire default 16; 0 for stats queries).
    pub max_new_tokens: usize,
    /// Optional routing affinity key (`"session_key"`: string or number).
    pub session_key: Option<u64>,
    /// `{"stats": true}`: a metrics query, not a generation request.
    pub stats: bool,
    /// `{"scope": "fleet"}` on a stats query: merge every replica's
    /// snapshot into one roll-up instead of answering from one replica.
    pub fleet: bool,
    /// `{"metrics": true}`: a Prometheus text-exposition query.
    pub metrics: bool,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<WireRequest> {
    let j = Json::parse(line)?;
    if matches!(j.opt("metrics"), Some(Json::Bool(true))) {
        return Ok(WireRequest {
            id: j.get("id")?.as_u64()?,
            prompt: String::new(),
            max_new_tokens: 0,
            session_key: None,
            stats: false,
            fleet: false,
            metrics: true,
        });
    }
    if matches!(j.opt("stats"), Some(Json::Bool(true))) {
        let fleet = match j.opt("scope") {
            None => false,
            Some(v) => match v.as_str()? {
                "fleet" => true,
                "replica" => false,
                other => {
                    return Err(anyhow!(
                        "unknown stats scope '{other}' (expected \"replica\" or \"fleet\")"
                    ))
                }
            },
        };
        return Ok(WireRequest {
            id: j.get("id")?.as_u64()?,
            prompt: String::new(),
            max_new_tokens: 0,
            session_key: None,
            stats: true,
            fleet,
            metrics: false,
        });
    }
    let session_key = match j.opt("session_key") {
        None => None,
        Some(v) => Some(match v.as_u64() {
            Ok(n) => n,
            Err(_) => hash_session_key(v.as_str()?),
        }),
    };
    Ok(WireRequest {
        id: j.get("id")?.as_u64()?,
        prompt: j.get("prompt")?.as_str()?.to_string(),
        max_new_tokens: j
            .opt("max_new_tokens")
            .map(|v| v.as_usize())
            .transpose()?
            .unwrap_or(16),
        session_key,
        stats: false,
        fleet: false,
        metrics: false,
    })
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Format one response line (no trailing newline).
pub fn format_response(
    id: u64,
    replica: usize,
    prompt_tokens: usize,
    generated: &[i32],
    finish: Option<FinishReason>,
) -> String {
    let text: String = generated
        .iter()
        .filter(|&&t| (0..256).contains(&t))
        .map(|&t| t as u8 as char)
        .collect();
    let toks = generated
        .iter()
        .map(|t| t.to_string())
        .collect::<Vec<_>>()
        .join(",");
    let finish = match finish {
        Some(FinishReason::Length) => "length",
        Some(FinishReason::Eos) => "eos",
        Some(FinishReason::CacheFull) => "cache_full",
        None => "unknown",
    };
    format!(
        "{{\"id\": {id}, \"text\": \"{}\", \"tokens\": [{toks}], \"prompt_tokens\": {prompt_tokens}, \"replica\": {replica}, \"finish\": \"{finish}\"}}",
        json_escape(&text)
    )
}

/// Format one stats response line (no trailing newline): the queried
/// replica's metrics snapshot as JSON, plus its shared-store gauge
/// (`id` is the store's process-unique identity — replicas on one
/// node-level store report the same id).
pub fn format_stats_response(id: u64, replica: usize, m: &EngineMetrics, mem: &MemoryStats) -> String {
    format!(
        "{{\"id\": {id}, \"replica\": {replica}, \"shared_store\": {{\"id\": {}, \"pages\": {}, \"refs\": {}, \"bytes\": {}}}, \"stats\": {}}}",
        mem.shared_store_id, mem.shared_pages, mem.shared_refs, mem.shared_bytes, m.to_json()
    )
}

/// The fleet's shared-store occupancy, deduplicated by store identity:
/// replicas sharing one node-level [`super::SharedPageStore`] all report
/// the same `shared_store_id`, so each physical store contributes its
/// pages/refs/bytes exactly once. `pages_gross` is the raw per-replica
/// sum — with one node store and R replicas it is R× `pages`, which is
/// how smoke tests verify the dedup actually happened.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct FleetSharedStats {
    /// distinct physical stores seen across the probed replicas
    pub stores: usize,
    /// shared pages, each physical store counted once
    pub pages: usize,
    /// sequence references onto shared pages, each store counted once
    pub refs: usize,
    /// shared-store heap bytes, each store counted once
    pub bytes: usize,
    /// per-replica sum of shared pages WITHOUT dedup (node store: R×pages)
    pub pages_gross: usize,
}

/// Fold per-replica memory snapshots into the fleet's deduped
/// shared-store roll-up. First snapshot per store id wins — replicas of
/// one node store observe the same store, so their figures agree.
pub fn fleet_shared_stats(mem: &[MemoryStats]) -> FleetSharedStats {
    let mut seen: HashMap<u64, (usize, usize, usize)> = HashMap::new();
    let mut gross = 0usize;
    for ms in mem {
        gross += ms.shared_pages;
        seen.entry(ms.shared_store_id)
            .or_insert((ms.shared_pages, ms.shared_refs, ms.shared_bytes));
    }
    let mut out = FleetSharedStats {
        stores: seen.len(),
        pages_gross: gross,
        ..FleetSharedStats::default()
    };
    for (p, r, b) in seen.values() {
        out.pages += p;
        out.refs += r;
        out.bytes += b;
    }
    out
}

/// Format one fleet-scope stats response line (no trailing newline): the
/// merged roll-up of `replicas` replica snapshots plus the deduped
/// shared-store occupancy.
pub fn format_fleet_stats_response(
    id: u64,
    replicas: usize,
    m: &EngineMetrics,
    shared: &FleetSharedStats,
) -> String {
    format!(
        "{{\"id\": {id}, \"scope\": \"fleet\", \"replicas\": {replicas}, \"shared_store\": {{\"stores\": {}, \"pages\": {}, \"refs\": {}, \"bytes\": {}, \"pages_gross\": {}}}, \"stats\": {}}}",
        shared.stores, shared.pages, shared.refs, shared.bytes, shared.pages_gross, m.to_json()
    )
}

/// Format one metrics response line (no trailing newline): a Prometheus
/// text exposition JSON-escaped into the one-line wire protocol.
pub fn format_metrics_response(id: u64, replica: usize, exposition: &str) -> String {
    format!(
        "{{\"id\": {id}, \"replica\": {replica}, \"metrics\": \"{}\"}}",
        json_escape(exposition)
    )
}

/// One line headed for a connection's writer thread. `counts` marks real
/// responses (not error lines): the WRITER increments the served counter
/// after pushing the bytes to the socket, so a bounded serve cannot
/// return — and let the process exit — with a response still buffered.
struct ConnLine {
    line: String,
    counts: bool,
}

/// One parsed request plus the channel its response line travels back on.
type Ingest = (WireRequest, mpsc::Sender<ConnLine>);

/// What the dispatcher hands a replica worker.
enum ReplicaJob {
    /// A generation request headed for the engine.
    Gen {
        req: Request,
        wire_id: u64,
        conn: mpsc::Sender<ConnLine>,
    },
    /// A metrics query: the worker answers immediately from its engine's
    /// snapshot, without touching the tick loop.
    Stats {
        wire_id: u64,
        conn: mpsc::Sender<ConnLine>,
    },
    /// A Prometheus text-exposition query, answered like `Stats`.
    Metrics {
        wire_id: u64,
        conn: mpsc::Sender<ConnLine>,
    },
    /// A fleet roll-up probe: the worker sends its metrics + memory
    /// snapshots to the dispatcher's aggregator channel instead of the
    /// connection (memory carries the shared-store gauge the fleet
    /// response dedups by store id).
    Snapshot {
        tx: mpsc::Sender<(EngineMetrics, MemoryStats)>,
    },
}

/// Aggregate result of one `serve` run.
#[derive(Debug)]
pub struct ServeSummary {
    /// Generation responses delivered (stats responses excluded).
    pub served: usize,
    /// Final metrics snapshot per replica, index-aligned with the engines.
    pub replicas: Vec<EngineMetrics>,
    /// Final observability snapshot per replica (trace events, gauges,
    /// stage timers), index-aligned with `replicas`. Empty snapshots when
    /// tracing was off — feed them to
    /// [`crate::obs::export::chrome_trace`] for `--trace-out`.
    pub traces: Vec<ObsSnapshot>,
}

/// Bind `addr` and serve until `max_requests` have completed (0 = forever).
pub fn serve(
    engines: Vec<Box<dyn EngineCore>>,
    addr: &str,
    policy: RoutePolicy,
    max_requests: usize,
) -> Result<ServeSummary> {
    let listener = TcpListener::bind(addr)?;
    serve_on(listener, engines, policy, max_requests)
}

/// Serve on an already-bound listener (tests bind port 0 themselves to
/// learn the address). One worker thread per engine replica; the calling
/// thread runs the dispatcher.
pub fn serve_on(
    listener: TcpListener,
    engines: Vec<Box<dyn EngineCore>>,
    policy: RoutePolicy,
    max_requests: usize,
) -> Result<ServeSummary> {
    anyhow::ensure!(!engines.is_empty(), "need at least one engine replica");
    let n_replicas = engines.len();
    // prefix routing fingerprints the first page_tokens-aligned window of
    // every prompt; all replicas of one fleet share a page geometry, so
    // replica 0 speaks for all of them
    let page_tokens = engines[0].page_tokens();
    let local = listener.local_addr()?;
    eprintln!("turboangle serving on {local} ({n_replicas} replicas, {policy:?})");

    let (ingest_tx, ingest_rx) = mpsc::channel::<Ingest>();
    // served = responses actually written to sockets (incremented by the
    // per-connection writer threads, or by workers for dead connections)
    let served = Arc::new(AtomicUsize::new(0));
    // acceptor thread: one reader thread per connection. The listener is
    // non-blocking so the acceptor can observe shutdown and release the
    // port when a bounded serve finishes (late clients get
    // connection-refused instead of silently-swallowed requests).
    listener.set_nonblocking(true)?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let acceptor = {
        let shutdown = Arc::clone(&shutdown);
        let served = Arc::clone(&served);
        std::thread::spawn(move || {
            while !shutdown.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        // accepted sockets may inherit non-blocking mode
                        if stream.set_nonblocking(false).is_err() {
                            continue;
                        }
                        let tx = ingest_tx.clone();
                        let served = Arc::clone(&served);
                        std::thread::spawn(move || {
                            let _ = handle_conn(stream, tx, served);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(IDLE_WAIT);
                    }
                    Err(_) => break,
                }
            }
        })
    };

    let router = Arc::new(Mutex::new(Router::new(n_replicas, policy)));
    let mut replica_txs = Vec::with_capacity(n_replicas);
    let mut workers = Vec::with_capacity(n_replicas);
    for (idx, engine) in engines.into_iter().enumerate() {
        let (tx, rx) = mpsc::channel::<ReplicaJob>();
        replica_txs.push(tx);
        let router = Arc::clone(&router);
        let served = Arc::clone(&served);
        workers.push(std::thread::spawn(move || {
            replica_worker(idx, engine, rx, router, served)
        }));
    }

    // dispatcher: route every ingested request to a replica queue
    let mut next_id: u64 = 1 << 32; // engine-side ids; wire ids are echoed
    loop {
        if max_requests > 0 && served.load(Ordering::Relaxed) >= max_requests {
            break;
        }
        // a worker can only exit mid-serve on error (normal exit requires
        // the queues we still hold to disconnect) — stop instead of waiting
        // forever for a served-count that will never arrive
        if workers.iter().any(|w| w.is_finished()) {
            break;
        }
        match ingest_rx.recv_timeout(IDLE_WAIT) {
            Ok((wire, conn)) => {
                if wire.stats && wire.fleet {
                    // fleet roll-up: probe EVERY replica, merge off-thread
                    // so a slow replica never stalls the dispatcher
                    let (snap_tx, snap_rx) = mpsc::channel::<(EngineMetrics, MemoryStats)>();
                    let mut alive = 0usize;
                    for tx in &replica_txs {
                        let probe = ReplicaJob::Snapshot {
                            tx: snap_tx.clone(),
                        };
                        if tx.send(probe).is_ok() {
                            alive += 1;
                        }
                    }
                    drop(snap_tx);
                    if alive == 0 {
                        break; // all workers died; surface errors below
                    }
                    let wire_id = wire.id;
                    std::thread::spawn(move || {
                        // the channel closes once every probed worker has
                        // answered (or died and dropped its sender)
                        let mut merged = EngineMetrics::default();
                        let mut mems: Vec<MemoryStats> = Vec::new();
                        for (m, ms) in snap_rx {
                            merged.merge(&m);
                            mems.push(ms);
                        }
                        let shared = fleet_shared_stats(&mems);
                        let line =
                            format_fleet_stats_response(wire_id, mems.len(), &merged, &shared);
                        let _ = conn.send(ConnLine { line, counts: false });
                    });
                    continue;
                }
                if wire.stats || wire.metrics {
                    // single-replica query: route like a (keyless) request
                    // so repeated queries sample the replicas
                    let replica = lock_router(&router).route(None);
                    let job = if wire.stats {
                        ReplicaJob::Stats {
                            wire_id: wire.id,
                            conn,
                        }
                    } else {
                        ReplicaJob::Metrics {
                            wire_id: wire.id,
                            conn,
                        }
                    };
                    if replica_txs[replica].send(job).is_err() {
                        break; // worker died; surface its error below
                    }
                    continue;
                }
                let prompt: Vec<i32> = wire.prompt.bytes().map(|b| b as i32).collect();
                // the routing key is policy-dependent: prefix routing keys
                // on the prompt's first-page fingerprint (prompts too short
                // to fill a page have nothing adoptable — route by load);
                // every other policy keys on the wire session key
                let key = match policy {
                    RoutePolicy::Prefix { .. } => prefix_fingerprint(&prompt, page_tokens),
                    _ => wire.session_key,
                };
                let id = next_id;
                next_id += 1;
                let mut req = Request::new(id, prompt, wire.max_new_tokens);
                req.session_key = wire.session_key;
                let replica = lock_router(&router).route(key);
                let job = ReplicaJob::Gen {
                    req,
                    wire_id: wire.id,
                    conn,
                };
                if replica_txs[replica].send(job).is_err() {
                    break; // worker died; surface its error below
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    drop(replica_txs); // workers drain their queues and exit
    shutdown.store(true, Ordering::Relaxed);
    let _ = acceptor.join(); // closes the listener, releasing the port

    let mut replicas = Vec::with_capacity(n_replicas);
    let mut traces = Vec::with_capacity(n_replicas);
    for w in workers {
        let (metrics, obs) = w
            .join()
            .map_err(|_| anyhow!("replica worker panicked"))??;
        replicas.push(metrics);
        traces.push(obs);
    }
    Ok(ServeSummary {
        served: served.load(Ordering::Relaxed),
        replicas,
        traces,
    })
}

/// One replica's serving loop: ingest from its queue, tick the engine,
/// push finished responses to their connections. Blocks on `recv_timeout`
/// when idle (no busy-wait); after the dispatcher hangs up it keeps
/// ticking until its remaining work drains.
fn replica_worker(
    idx: usize,
    mut engine: Box<dyn EngineCore>,
    rx: mpsc::Receiver<ReplicaJob>,
    router: Arc<Mutex<Router>>,
    served: Arc<AtomicUsize>,
) -> Result<(EngineMetrics, ObsSnapshot)> {
    let mut pending: HashMap<u64, (u64, mpsc::Sender<ConnLine>)> = HashMap::new();
    // ingest one routed job: generation requests enter the engine; stats /
    // metrics queries answer immediately from the engine's snapshots, and
    // fleet probes answer to the dispatcher's aggregator channel
    fn take_job(
        job: ReplicaJob,
        idx: usize,
        engine: &mut dyn EngineCore,
        pending: &mut HashMap<u64, (u64, mpsc::Sender<ConnLine>)>,
        router: &Mutex<Router>,
    ) {
        match job {
            ReplicaJob::Gen { req, wire_id, conn } => {
                pending.insert(req.id, (wire_id, conn));
                engine.submit(req);
            }
            ReplicaJob::Stats { wire_id, conn } => {
                let line =
                    format_stats_response(wire_id, idx, &engine.metrics(), &engine.memory_stats());
                // stats lines never count toward a bounded serve
                let _ = conn.send(ConnLine { line, counts: false });
                lock_router(router).complete(idx);
            }
            ReplicaJob::Metrics { wire_id, conn } => {
                let text = export::prometheus(
                    idx,
                    &engine.metrics(),
                    &engine.memory_stats(),
                    engine.load(),
                    &engine.obs_snapshot().stage,
                );
                let line = format_metrics_response(wire_id, idx, &text);
                let _ = conn.send(ConnLine { line, counts: false });
                lock_router(router).complete(idx);
            }
            ReplicaJob::Snapshot { tx } => {
                // not router-dispatched: no complete(); the aggregator's
                // channel closes once every probed replica has answered
                let _ = tx.send((engine.metrics(), engine.memory_stats()));
            }
        }
    }
    let mut open = true;
    while open || engine.has_work() {
        // drain whatever the dispatcher routed here
        loop {
            match rx.try_recv() {
                Ok(job) => take_job(job, idx, engine.as_mut(), &mut pending, &router),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }
        if engine.has_work() {
            if engine.tick()? == Action::Idle {
                // work queued but the batcher is inside its max_wait
                // window: yield briefly rather than spinning the tick loop
                std::thread::sleep(Duration::from_millis(1));
            }
        } else if open {
            // idle replica: block instead of spinning
            match rx.recv_timeout(IDLE_WAIT) {
                Ok(job) => take_job(job, idx, engine.as_mut(), &mut pending, &router),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => open = false,
            }
        }
        for sess in engine.take_finished() {
            if let Some((wire_id, conn)) = pending.remove(&sess.request.id) {
                let line = format_response(
                    wire_id,
                    idx,
                    sess.prompt_len,
                    &sess.generated,
                    sess.finished,
                );
                // the writer thread counts the response once it reaches
                // the socket; a dead connection counts here so a bounded
                // serve still terminates
                if conn.send(ConnLine { line, counts: true }).is_err() {
                    served.fetch_add(1, Ordering::Relaxed);
                }
                lock_router(&router).complete(idx);
            }
        }
    }
    Ok((engine.metrics(), engine.obs_snapshot()))
}

/// Connection handler: this thread reads and parses lines; a paired writer
/// thread owns the write half and serializes responses from all in-flight
/// requests. Multiple requests per connection proceed concurrently.
fn handle_conn(
    stream: TcpStream,
    ingest: mpsc::Sender<Ingest>,
    served: Arc<AtomicUsize>,
) -> Result<()> {
    let mut write_half = stream.try_clone()?;
    let (conn_tx, conn_rx) = mpsc::channel::<ConnLine>();
    let writer = std::thread::spawn(move || {
        // never exits early: even with a dead socket, every queued
        // response must still be counted or a bounded serve would wait
        // forever for deliveries that can no longer happen
        let mut dead = false;
        for msg in conn_rx {
            if !dead {
                dead = write_half.write_all(msg.line.as_bytes()).is_err()
                    || write_half.write_all(b"\n").is_err()
                    || write_half.flush().is_err();
            }
            if msg.counts {
                served.fetch_add(1, Ordering::Relaxed);
            }
        }
    });
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        // A dead or misbehaving peer (reset mid-line, invalid UTF-8) only
        // ends THIS connection: drop it and drain the writer. Propagating
        // the error here would skip the writer join below and leak queued
        // responses.
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match parse_request(&line) {
            Ok(wire) => {
                // The dispatcher hanging up (bounded serve complete) is a
                // normal shutdown signal, not a connection error.
                if ingest.send((wire, conn_tx.clone())).is_err() {
                    break;
                }
            }
            Err(e) => {
                // Malformed request line: answer on the wire, keep reading.
                let line = format!("{{\"error\": \"{}\"}}", json_escape(&e.to_string()));
                let _ = conn_tx.send(ConnLine { line, counts: false });
            }
        }
    }
    // reader EOF: drop our sender; the writer exits once every in-flight
    // response (whose jobs hold clones) has been delivered
    drop(conn_tx);
    let _ = writer.join();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_requests() {
        let r = parse_request(r#"{"id": 3, "prompt": "hi", "max_new_tokens": 5}"#).unwrap();
        assert_eq!(
            r,
            WireRequest {
                id: 3,
                prompt: "hi".into(),
                max_new_tokens: 5,
                session_key: None,
                stats: false,
                fleet: false,
                metrics: false,
            }
        );
        // default max_new_tokens
        let r = parse_request(r#"{"id": 1, "prompt": "x"}"#).unwrap();
        assert_eq!(r.max_new_tokens, 16);
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"prompt": "x"}"#).is_err());
    }

    #[test]
    fn parses_stats_queries() {
        let r = parse_request(r#"{"id": 9, "stats": true}"#).unwrap();
        assert!(r.stats);
        assert!(!r.fleet);
        assert_eq!(r.id, 9);
        // stats: false (or any non-true value) is an ordinary request
        assert!(parse_request(r#"{"id": 1, "stats": false}"#).is_err(), "needs a prompt");
        let r = parse_request(r#"{"id": 1, "prompt": "x", "stats": false}"#).unwrap();
        assert!(!r.stats);
        // a stats query still needs an id to echo
        assert!(parse_request(r#"{"stats": true}"#).is_err());
    }

    #[test]
    fn parses_fleet_and_metrics_queries() {
        let r = parse_request(r#"{"id": 4, "stats": true, "scope": "fleet"}"#).unwrap();
        assert!(r.stats && r.fleet);
        let r = parse_request(r#"{"id": 4, "stats": true, "scope": "replica"}"#).unwrap();
        assert!(r.stats && !r.fleet);
        // unknown scopes fail loudly instead of silently picking a replica
        assert!(parse_request(r#"{"id": 4, "stats": true, "scope": "galaxy"}"#).is_err());
        let r = parse_request(r#"{"id": 6, "metrics": true}"#).unwrap();
        assert!(r.metrics && !r.stats);
        assert!(parse_request(r#"{"metrics": true}"#).is_err(), "needs an id");
    }

    #[test]
    fn formats_fleet_stats_responses() {
        let mut a = EngineMetrics::default();
        a.requests_finished = 2;
        a.itl.record(std::time::Duration::from_micros(80));
        let mut b = EngineMetrics::default();
        b.requests_finished = 3;
        b.itl.record(std::time::Duration::from_micros(40));
        let mut merged = EngineMetrics::default();
        merged.merge(&a);
        merged.merge(&b);
        // two replicas on ONE node store: same id, pages counted once
        let mut ma = crate::coordinator::MemoryStats::default();
        ma.shared_store_id = 7;
        ma.shared_pages = 4;
        ma.shared_refs = 6;
        ma.shared_bytes = 4096;
        let mb = ma; // Copy: both replicas report the same store
        let shared = fleet_shared_stats(&[ma, mb]);
        assert_eq!(shared.stores, 1);
        assert_eq!(shared.pages, 4, "one store counts once");
        assert_eq!(shared.pages_gross, 8, "gross keeps the per-replica sum");
        let line = format_fleet_stats_response(11, 2, &merged, &shared);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 11);
        assert_eq!(j.get("scope").unwrap().as_str().unwrap(), "fleet");
        assert_eq!(j.get("replicas").unwrap().as_usize().unwrap(), 2);
        let ss = j.get("shared_store").unwrap();
        assert_eq!(ss.get("stores").unwrap().as_usize().unwrap(), 1);
        assert_eq!(ss.get("pages").unwrap().as_usize().unwrap(), 4);
        assert_eq!(ss.get("refs").unwrap().as_usize().unwrap(), 6);
        assert_eq!(ss.get("pages_gross").unwrap().as_usize().unwrap(), 8);
        let stats = j.get("stats").unwrap();
        assert_eq!(stats.get("requests_finished").unwrap().as_usize().unwrap(), 5);
        assert_eq!(stats.get("itl").unwrap().get("count").unwrap().as_usize().unwrap(), 2);
    }

    #[test]
    fn fleet_shared_stats_sums_distinct_stores() {
        // replica-scoped stores: distinct ids, everything sums
        let mut a = crate::coordinator::MemoryStats::default();
        a.shared_store_id = 1;
        a.shared_pages = 3;
        a.shared_refs = 3;
        a.shared_bytes = 300;
        let mut b = crate::coordinator::MemoryStats::default();
        b.shared_store_id = 2;
        b.shared_pages = 5;
        b.shared_refs = 1;
        b.shared_bytes = 500;
        let s = fleet_shared_stats(&[a, b]);
        assert_eq!(s.stores, 2);
        assert_eq!(s.pages, 8);
        assert_eq!(s.refs, 4);
        assert_eq!(s.bytes, 800);
        assert_eq!(s.pages_gross, 8, "no dedup to do: gross == deduped");
        assert_eq!(fleet_shared_stats(&[]), FleetSharedStats::default());
    }

    #[test]
    fn formats_metrics_responses() {
        let mut m = EngineMetrics::default();
        m.tokens_generated = 9;
        let text = export::prometheus(
            0,
            &m,
            &crate::coordinator::MemoryStats::default(),
            0,
            &crate::obs::StageStats::default(),
        );
        let line = format_metrics_response(8, 0, &text);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 8);
        let body = j.get("metrics").unwrap().as_str().unwrap().to_string();
        assert!(body.contains("turboangle_tokens_generated_total{replica=\"0\"} 9"));
    }

    #[test]
    fn formats_stats_responses() {
        let mut m = EngineMetrics::default();
        m.itl.record(std::time::Duration::from_micros(80));
        let mut mem = crate::coordinator::MemoryStats::default();
        mem.shared_store_id = 3;
        mem.shared_pages = 2;
        let line = format_stats_response(5, 1, &m, &mem);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("id").unwrap().as_u64().unwrap(), 5);
        assert_eq!(j.get("replica").unwrap().as_usize().unwrap(), 1);
        let ss = j.get("shared_store").unwrap();
        assert_eq!(ss.get("id").unwrap().as_u64().unwrap(), 3);
        assert_eq!(ss.get("pages").unwrap().as_usize().unwrap(), 2);
        let stats = j.get("stats").unwrap();
        assert_eq!(stats.get("itl").unwrap().get("count").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn parses_session_keys() {
        let n = parse_request(r#"{"id": 1, "prompt": "x", "session_key": 42}"#).unwrap();
        assert_eq!(n.session_key, Some(42));
        let s = parse_request(r#"{"id": 1, "prompt": "x", "session_key": "user-7"}"#).unwrap();
        assert_eq!(s.session_key, Some(hash_session_key("user-7")));
        let s2 = parse_request(r#"{"id": 2, "prompt": "y", "session_key": "user-7"}"#).unwrap();
        assert_eq!(s.session_key, s2.session_key, "string keys hash stably");
    }

    #[test]
    fn formats_responses() {
        let line = format_response(7, 1, 3, &[104, 105, 257], Some(FinishReason::Eos));
        assert!(line.contains("\"id\": 7"));
        assert!(line.contains("\"text\": \"hi\""));
        assert!(line.contains("\"replica\": 1"));
        assert!(line.contains("\"finish\": \"eos\""));
        // round-trips through our own parser
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("prompt_tokens").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(j.get("replica").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn escaping_is_safe() {
        let line = format_response(1, 0, 0, &[34, 92, 10], None);
        assert!(Json::parse(&line).is_ok(), "{line}");
    }
}
