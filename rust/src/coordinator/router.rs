//! Request router across engine replicas (vllm-project/router-style).
//!
//! Single-process here (replicas are engine instances), but the policy
//! layer is the real thing: least-loaded with optional session affinity
//! (consistent hashing on a session key keeps multi-turn requests on the
//! replica that may still hold their prefix).

use crate::util::hash::splitmix64;
use std::collections::BTreeMap;

/// How the dispatcher picks a replica for each request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// cycle through replicas in order
    RoundRobin,
    /// fewest in-flight requests wins (index breaks ties)
    LeastLoaded,
    /// consistent-hash by session key, falling back to least-loaded
    SessionAffinity,
}

/// Replica picker + in-flight load tracker (one per server dispatcher).
#[derive(Debug)]
pub struct Router {
    /// The active routing policy.
    pub policy: RoutePolicy,
    loads: Vec<usize>,
    rr_next: usize,
    /// virtual nodes -> replica (consistent hash ring)
    ring: BTreeMap<u64, usize>,
}

/// Hash a wire-level string session key into the u64 the ring consumes
/// (FNV-1a then splitmix for avalanche). Numeric wire keys skip this.
pub fn hash_session_key(key: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    splitmix64(h)
}

impl Router {
    /// A router over `replicas` replicas (16 ring points each).
    pub fn new(replicas: usize, policy: RoutePolicy) -> Self {
        let mut ring = BTreeMap::new();
        for r in 0..replicas {
            for v in 0..16u64 {
                ring.insert(splitmix64((r as u64) << 32 | v), r);
            }
        }
        Router {
            policy,
            loads: vec![0; replicas],
            rr_next: 0,
            ring,
        }
    }

    /// Number of replicas routed across.
    pub fn replicas(&self) -> usize {
        self.loads.len()
    }

    /// Pick a replica for a request. `session_key` enables affinity.
    pub fn route(&mut self, session_key: Option<u64>) -> usize {
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr_next % self.loads.len();
                self.rr_next += 1;
                r
            }
            RoutePolicy::LeastLoaded => self.least_loaded(),
            RoutePolicy::SessionAffinity => match session_key {
                Some(key) => self.ring_lookup(splitmix64(key)),
                None => self.least_loaded(),
            },
        };
        self.loads[r] += 1;
        r
    }

    /// A request finished on `replica`.
    pub fn complete(&mut self, replica: usize) {
        debug_assert!(self.loads[replica] > 0);
        self.loads[replica] = self.loads[replica].saturating_sub(1);
    }

    fn least_loaded(&self) -> usize {
        self.loads
            .iter()
            .enumerate()
            .min_by_key(|(i, &l)| (l, *i))
            .map(|(i, _)| i)
            .unwrap()
    }

    fn ring_lookup(&self, h: u64) -> usize {
        *self
            .ring
            .range(h..)
            .next()
            .map(|(_, r)| r)
            .unwrap_or_else(|| self.ring.values().next().unwrap())
    }

    /// Current in-flight request count per replica.
    pub fn loads(&self) -> &[usize] {
        &self.loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        assert_eq!(
            (0..6).map(|_| r.route(None)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        for _ in 0..9 {
            r.route(None);
        }
        assert_eq!(r.loads(), &[3, 3, 3]);
    }

    #[test]
    fn least_loaded_fills_gaps() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let a = r.route(None);
        let _b = r.route(None);
        r.complete(a);
        assert_eq!(r.route(None), a);
    }

    #[test]
    fn affinity_is_sticky() {
        let mut r = Router::new(4, RoutePolicy::SessionAffinity);
        let first = r.route(Some(42));
        for _ in 0..5 {
            assert_eq!(r.route(Some(42)), first);
        }
    }

    #[test]
    fn affinity_spreads_sessions() {
        let mut r = Router::new(4, RoutePolicy::SessionAffinity);
        let mut seen = std::collections::HashSet::new();
        for k in 0..64u64 {
            seen.insert(r.route(Some(k)));
        }
        assert!(seen.len() >= 3, "ring should spread keys, got {seen:?}");
    }
}
