//! Request router across engine replicas (vllm-project/router-style).
//!
//! Single-process here (replicas are engine instances), but the policy
//! layer is the real thing: least-loaded with optional session affinity
//! (consistent hashing on a session key keeps multi-turn requests on the
//! replica that may still hold their prefix), plus **prefix routing**
//! ([`RoutePolicy::Prefix`]): consistent-hash by the prompt's first-page
//! fingerprint ([`prefix_fingerprint`]), so requests sharing a cacheable
//! prefix land on the replica whose radix tree already indexes it —
//! round-robin actively destroys that locality. A configurable imbalance
//! bound spills to least-loaded before a hot prefix can overload its home
//! replica.

use crate::util::hash::splitmix64;
use std::collections::BTreeMap;

/// How the dispatcher picks a replica for each request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// cycle through replicas in order
    RoundRobin,
    /// fewest in-flight requests wins (index breaks ties)
    LeastLoaded,
    /// consistent-hash by session key, falling back to least-loaded
    SessionAffinity,
    /// consistent-hash by prompt-prefix fingerprint, falling back to
    /// least-loaded when the prompt has no full-page fingerprint or the
    /// ring target already carries more than `imbalance_bound` in-flight
    /// requests above the least-loaded replica
    Prefix {
        /// max jobs the ring target may sit above the minimum load before
        /// the request spills to least-loaded (0 = spill on any imbalance)
        imbalance_bound: usize,
    },
}

/// Fingerprint of the FIRST `page_tokens` tokens of a prompt — the
/// consistent-hash key [`RoutePolicy::Prefix`] routes by. Prompts sharing
/// their first cache page (system prompts, few-shot templates) collocate
/// on one replica, so its radix tree — and, with a node-level store, its
/// already-warm adoption path — sees every reuse opportunity. Hashing ONLY
/// the first aligned window (not the longest) is deliberate: prompts that
/// share a long system prompt but diverge later must still land together.
/// `None` when the prompt has no full page — nothing adoptable exists, so
/// the router falls back to least-loaded. FNV-1a over the little-endian
/// token bytes, then splitmix64 for avalanche.
pub fn prefix_fingerprint(tokens: &[i32], page_tokens: usize) -> Option<u64> {
    if page_tokens == 0 || tokens.len() < page_tokens {
        return None;
    }
    let mut h: u64 = 0xCBF29CE484222325;
    for &t in &tokens[..page_tokens] {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001B3);
        }
    }
    Some(splitmix64(h))
}

/// Replica picker + in-flight load tracker (one per server dispatcher).
#[derive(Debug)]
pub struct Router {
    /// The active routing policy.
    pub policy: RoutePolicy,
    loads: Vec<usize>,
    rr_next: usize,
    /// virtual nodes -> replica (consistent hash ring)
    ring: BTreeMap<u64, usize>,
}

/// Hash a wire-level string session key into the u64 the ring consumes
/// (FNV-1a then splitmix for avalanche). Numeric wire keys skip this.
pub fn hash_session_key(key: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in key.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001B3);
    }
    splitmix64(h)
}

impl Router {
    /// A router over `replicas` replicas (16 ring points each).
    pub fn new(replicas: usize, policy: RoutePolicy) -> Self {
        let mut ring = BTreeMap::new();
        for r in 0..replicas {
            for v in 0..16u64 {
                ring.insert(splitmix64((r as u64) << 32 | v), r);
            }
        }
        Router {
            policy,
            loads: vec![0; replicas],
            rr_next: 0,
            ring,
        }
    }

    /// Number of replicas routed across.
    pub fn replicas(&self) -> usize {
        self.loads.len()
    }

    /// Pick a replica for a request. `key` is policy-dependent: the
    /// session key under [`RoutePolicy::SessionAffinity`], the
    /// [`prefix_fingerprint`] under [`RoutePolicy::Prefix`] (the server's
    /// dispatcher computes the right one), ignored otherwise.
    pub fn route(&mut self, key: Option<u64>) -> usize {
        let r = match self.policy {
            RoutePolicy::RoundRobin => {
                let r = self.rr_next % self.loads.len();
                self.rr_next += 1;
                r
            }
            RoutePolicy::LeastLoaded => self.least_loaded(),
            RoutePolicy::SessionAffinity => match key {
                Some(key) => self.ring_lookup(splitmix64(key)),
                None => self.least_loaded(),
            },
            RoutePolicy::Prefix { imbalance_bound } => match key {
                Some(fp) => {
                    let target = self.ring_lookup(splitmix64(fp));
                    let min = self.loads.iter().copied().min().unwrap_or(0);
                    if self.loads[target] > min + imbalance_bound {
                        // a hot prefix must not melt its home replica:
                        // spill to least-loaded (the prefix becomes warm
                        // on the spill target too — sharing, not pinning)
                        self.least_loaded()
                    } else {
                        target
                    }
                }
                None => self.least_loaded(),
            },
        };
        self.loads[r] += 1;
        r
    }

    /// The ring target for a fingerprint, ignoring load — what
    /// [`Self::route`] picks before the imbalance fallback. Deterministic
    /// and side-effect-free, for tests and capacity planning.
    pub fn target_of(&self, fp: u64) -> usize {
        self.ring_lookup(splitmix64(fp))
    }

    /// A request finished on `replica`.
    pub fn complete(&mut self, replica: usize) {
        debug_assert!(self.loads[replica] > 0);
        self.loads[replica] = self.loads[replica].saturating_sub(1);
    }

    fn least_loaded(&self) -> usize {
        self.loads
            .iter()
            .enumerate()
            .min_by_key(|(i, &l)| (l, *i))
            .map(|(i, _)| i)
            .unwrap()
    }

    fn ring_lookup(&self, h: u64) -> usize {
        *self
            .ring
            .range(h..)
            .next()
            .map(|(_, r)| r)
            .unwrap_or_else(|| self.ring.values().next().unwrap())
    }

    /// Current in-flight request count per replica.
    pub fn loads(&self) -> &[usize] {
        &self.loads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        assert_eq!(
            (0..6).map(|_| r.route(None)).collect::<Vec<_>>(),
            vec![0, 1, 2, 0, 1, 2]
        );
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(3, RoutePolicy::LeastLoaded);
        for _ in 0..9 {
            r.route(None);
        }
        assert_eq!(r.loads(), &[3, 3, 3]);
    }

    #[test]
    fn least_loaded_fills_gaps() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let a = r.route(None);
        let _b = r.route(None);
        r.complete(a);
        assert_eq!(r.route(None), a);
    }

    #[test]
    fn affinity_is_sticky() {
        let mut r = Router::new(4, RoutePolicy::SessionAffinity);
        let first = r.route(Some(42));
        for _ in 0..5 {
            assert_eq!(r.route(Some(42)), first);
        }
    }

    #[test]
    fn affinity_spreads_sessions() {
        let mut r = Router::new(4, RoutePolicy::SessionAffinity);
        let mut seen = std::collections::HashSet::new();
        for k in 0..64u64 {
            seen.insert(r.route(Some(k)));
        }
        assert!(seen.len() >= 3, "ring should spread keys, got {seen:?}");
    }

    #[test]
    fn fingerprint_covers_exactly_the_first_page() {
        // same first page, different tails: same fingerprint
        assert_eq!(
            prefix_fingerprint(&[1, 2, 3, 4, 9, 9], 4),
            prefix_fingerprint(&[1, 2, 3, 4], 4)
        );
        // one token differs inside the window: different fingerprint
        assert_ne!(
            prefix_fingerprint(&[1, 2, 3, 5], 4),
            prefix_fingerprint(&[1, 2, 3, 4], 4)
        );
        // no full page: nothing to route by
        assert!(prefix_fingerprint(&[1, 2, 3], 4).is_none());
        assert!(prefix_fingerprint(&[], 4).is_none());
        assert!(prefix_fingerprint(&[1], 0).is_none());
    }

    #[test]
    fn prefix_routes_sticky_until_imbalance_bound_spills() {
        let mut r = Router::new(3, RoutePolicy::Prefix { imbalance_bound: 2 });
        let fp = prefix_fingerprint(&[7, 7, 7, 7], 4).expect("full page");
        let target = r.target_of(fp);
        // sticky while within the bound: loads 1, 2 above an empty fleet
        assert_eq!(r.route(Some(fp)), target);
        assert_eq!(r.route(Some(fp)), target);
        // load 2 == min 0 + bound 2: still allowed
        assert_eq!(r.route(Some(fp)), target);
        // load 3 > bound: spill to least-loaded, NOT the home replica
        let spill = r.route(Some(fp));
        assert_ne!(spill, target, "imbalance bound must spill");
        // draining the home replica restores stickiness
        r.complete(target);
        r.complete(target);
        r.complete(target);
        assert_eq!(r.route(Some(fp)), target);
        // no fingerprint: least-loaded fallback
        let mut lb = Router::new(2, RoutePolicy::Prefix { imbalance_bound: 0 });
        assert_eq!(lb.route(None), 0);
        assert_eq!(lb.route(None), 1);
    }
}
