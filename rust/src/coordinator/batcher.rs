//! Dynamic batcher: admission queue + batch formation policy.
//!
//! Continuous-batching flavor: the engine owns `B` slots; the batcher
//! decides *when* to run a prefill (enough waiting work, or the oldest
//! request has waited past `max_wait`) and which requests join it.
//! Admission also consults the kv page pool so a prefill never starts a
//! sequence the cache cannot hold.

use super::session::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// run a prefill as soon as this many requests wait (≤ engine batch)
    pub min_batch: usize,
    /// …or when the oldest request has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            min_batch: 2,
            max_wait: Duration::from_millis(20),
        }
    }
}

#[derive(Debug, Default)]
pub struct BatcherStats {
    pub submitted: u64,
    pub admitted: u64,
    pub rejected_cache: u64,
}

pub struct DynamicBatcher {
    pub policy: BatchPolicy,
    queue: VecDeque<Request>,
    pub stats: BatcherStats,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher {
            policy,
            queue: VecDeque::new(),
            stats: BatcherStats::default(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.stats.submitted += 1;
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should a prefill run now, given `free_slots`? (`now` injected for
    /// deterministic tests.)
    pub fn should_prefill(&self, free_slots: usize, now: Instant) -> bool {
        if free_slots == 0 || self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.policy.min_batch.min(free_slots) {
            return true;
        }
        self.queue
            .front()
            .map(|r| now.duration_since(r.arrival) >= self.policy.max_wait)
            .unwrap_or(false)
    }

    /// Pop up to `free_slots` admissible requests. `can_admit` is the kv
    /// pool check (expected tokens -> fits?). Non-admissible requests stay
    /// queued (head-of-line blocking is intentional: FIFO fairness).
    pub fn take_batch<F>(&mut self, free_slots: usize, mut can_admit: F) -> Vec<Request>
    where
        F: FnMut(&Request) -> bool,
    {
        let mut out = Vec::new();
        while out.len() < free_slots {
            match self.queue.front() {
                Some(req) if can_admit(req) => {
                    self.stats.admitted += 1;
                    out.push(self.queue.pop_front().unwrap());
                }
                Some(_) => {
                    self.stats.rejected_cache += 1;
                    break;
                }
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn batches_when_min_reached() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            min_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        let now = Instant::now();
        b.submit(req(1));
        assert!(!b.should_prefill(4, now));
        b.submit(req(2));
        assert!(b.should_prefill(4, now));
        let batch = b.take_batch(4, |_| true);
        assert_eq!(batch.len(), 2);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fires_on_max_wait() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            min_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        b.submit(req(1));
        let later = Instant::now() + Duration::from_millis(6);
        assert!(b.should_prefill(4, later));
    }

    #[test]
    fn no_prefill_without_slots() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.submit(req(1));
        b.submit(req(2));
        assert!(!b.should_prefill(0, Instant::now()));
    }

    #[test]
    fn respects_free_slots() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        for i in 0..5 {
            b.submit(req(i));
        }
        let batch = b.take_batch(3, |_| true);
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 2);
        // FIFO order preserved
        assert_eq!(batch[0].id, 0);
        assert_eq!(batch[2].id, 2);
    }

    #[test]
    fn cache_rejection_blocks_head() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.submit(req(1));
        b.submit(req(2));
        let batch = b.take_batch(2, |r| r.id != 1);
        assert!(batch.is_empty(), "FIFO head blocked => no batch");
        assert_eq!(b.stats.rejected_cache, 1);
        assert_eq!(b.pending(), 2);
    }
}
