//! Dynamic batcher: admission queue + batch formation policy.
//!
//! Continuous-batching flavor: the engine owns `B` slots; the batcher
//! decides *when* to run a prefill (enough waiting work, or the oldest
//! request has waited past `max_wait`) and which requests join it.
//! Admission also consults the kv page pool so a prefill never starts a
//! sequence the cache cannot hold.

use super::session::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// When to form a prefill batch from the waiting queue.
#[derive(Clone, Debug)]
pub struct BatchPolicy {
    /// run a prefill as soon as this many requests wait (≤ engine batch)
    pub min_batch: usize,
    /// …or when the oldest request has waited this long
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            min_batch: 2,
            max_wait: Duration::from_millis(20),
        }
    }
}

/// Lifetime counters of one batcher's admission decisions.
#[derive(Debug, Default)]
pub struct BatcherStats {
    /// requests submitted to the queue
    pub submitted: u64,
    /// requests admitted into a prefill batch
    pub admitted: u64,
    /// head-of-line deferrals: the pool cannot admit the head *right now*
    pub rejected_cache: u64,
    /// terminal rejections: the request can never fit the pool at all
    pub rejected_capacity: u64,
}

/// Admission verdict for one queued request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// fits now — seat it
    Admit,
    /// cannot fit *yet* — keep it queued (FIFO head-of-line blocking)
    Defer,
    /// can NEVER fit (exceeds total pool capacity) — pop it so the caller
    /// finishes it with `CacheFull` instead of starving the queue forever
    Reject,
}

/// Result of one batch-formation pass.
#[derive(Debug, Default)]
pub struct TakenBatch {
    /// requests popped for seating, FIFO order preserved
    pub admitted: Vec<Request>,
    /// requests popped for terminal `CacheFull` finishing
    pub rejected: Vec<Request>,
}

/// The admission queue plus its batch-formation policy (see module docs).
pub struct DynamicBatcher {
    /// When prefills fire and which requests join them.
    pub policy: BatchPolicy,
    queue: VecDeque<Request>,
    /// Lifetime admission counters.
    pub stats: BatcherStats,
}

impl DynamicBatcher {
    /// An empty queue under `policy`.
    pub fn new(policy: BatchPolicy) -> Self {
        DynamicBatcher {
            policy,
            queue: VecDeque::new(),
            stats: BatcherStats::default(),
        }
    }

    /// Enqueue a request (FIFO).
    pub fn submit(&mut self, req: Request) {
        self.stats.submitted += 1;
        self.queue.push_back(req);
    }

    /// Requests waiting in the queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Should a prefill run now, given `free_slots`? (`now` injected for
    /// deterministic tests.)
    pub fn should_prefill(&self, free_slots: usize, now: Instant) -> bool {
        if free_slots == 0 || self.queue.is_empty() {
            return false;
        }
        if self.queue.len() >= self.policy.min_batch.min(free_slots) {
            return true;
        }
        self.queue
            .front()
            .map(|r| now.duration_since(r.arrival) >= self.policy.max_wait)
            .unwrap_or(false)
    }

    /// Peek the queue head (the request head-of-line blocking waits on).
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Put a request back at the queue head, restoring its FIFO position,
    /// after a seating attempt failed post-admission — e.g. a node-level
    /// shared store whose matched prefix pages another replica evicted
    /// between the admission pass and adoption. Not a new submission: the
    /// `submitted` counter is untouched.
    pub fn requeue_front(&mut self, req: Request) {
        self.queue.push_front(req);
    }

    /// Pop up to `free_slots` admissible requests. `admit` is the kv pool
    /// check. `Defer` keeps the head queued and stops the pass (head-of-line
    /// blocking is intentional: FIFO fairness); `Reject` pops the request
    /// into `rejected` — it can never be served and must be finished with
    /// `CacheFull` — and keeps scanning, so an impossible request no longer
    /// starves everything behind it.
    pub fn take_batch<F>(&mut self, free_slots: usize, mut admit: F) -> TakenBatch
    where
        F: FnMut(&Request) -> Admission,
    {
        let mut out = TakenBatch::default();
        while out.admitted.len() < free_slots {
            match self.queue.front() {
                Some(req) => match admit(req) {
                    Admission::Admit => {
                        self.stats.admitted += 1;
                        out.admitted.push(self.queue.pop_front().unwrap());
                    }
                    Admission::Reject => {
                        self.stats.rejected_capacity += 1;
                        out.rejected.push(self.queue.pop_front().unwrap());
                    }
                    Admission::Defer => {
                        self.stats.rejected_cache += 1;
                        break;
                    }
                },
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request::new(id, vec![1, 2, 3], 4)
    }

    #[test]
    fn batches_when_min_reached() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            min_batch: 2,
            max_wait: Duration::from_secs(10),
        });
        let now = Instant::now();
        b.submit(req(1));
        assert!(!b.should_prefill(4, now));
        b.submit(req(2));
        assert!(b.should_prefill(4, now));
        let batch = b.take_batch(4, |_| Admission::Admit);
        assert_eq!(batch.admitted.len(), 2);
        assert!(batch.rejected.is_empty());
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn fires_on_max_wait() {
        let mut b = DynamicBatcher::new(BatchPolicy {
            min_batch: 8,
            max_wait: Duration::from_millis(5),
        });
        b.submit(req(1));
        let later = Instant::now() + Duration::from_millis(6);
        assert!(b.should_prefill(4, later));
    }

    #[test]
    fn no_prefill_without_slots() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.submit(req(1));
        b.submit(req(2));
        assert!(!b.should_prefill(0, Instant::now()));
    }

    #[test]
    fn respects_free_slots() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        for i in 0..5 {
            b.submit(req(i));
        }
        let batch = b.take_batch(3, |_| Admission::Admit);
        assert_eq!(batch.admitted.len(), 3);
        assert_eq!(b.pending(), 2);
        // FIFO order preserved
        assert_eq!(batch.admitted[0].id, 0);
        assert_eq!(batch.admitted[2].id, 2);
    }

    #[test]
    fn cache_deferral_blocks_head() {
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.submit(req(1));
        b.submit(req(2));
        let batch = b.take_batch(2, |r| {
            if r.id == 1 {
                Admission::Defer
            } else {
                Admission::Admit
            }
        });
        assert!(batch.admitted.is_empty(), "FIFO head blocked => no batch");
        assert_eq!(b.stats.rejected_cache, 1);
        assert_eq!(b.pending(), 2);
    }

    #[test]
    fn capacity_rejection_unblocks_queue() {
        // an impossible head request is popped for CacheFull finishing and
        // the requests behind it are admitted in the same pass
        let mut b = DynamicBatcher::new(BatchPolicy::default());
        b.submit(req(1));
        b.submit(req(2));
        b.submit(req(3));
        let batch = b.take_batch(2, |r| {
            if r.id == 1 {
                Admission::Reject
            } else {
                Admission::Admit
            }
        });
        assert_eq!(batch.rejected.len(), 1);
        assert_eq!(batch.rejected[0].id, 1);
        assert_eq!(batch.admitted.len(), 2);
        assert_eq!(b.stats.rejected_capacity, 1);
        assert_eq!(b.pending(), 0);
    }
}
