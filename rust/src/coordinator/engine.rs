//! The serving engine: continuous batching over a compressed KV cache.
//!
//! One tick = one scheduler action:
//!   * Prefill — batcher-formed prompt batch → prefill HLO → compressed
//!     entries packed into the kv_manager, sessions seated in slots.
//!   * Decode — active slots' caches reinflated (norm dequant + angle
//!     unpack) into the dense HLO inputs, one fused decode step, new
//!     tokens sampled greedily, new compressed entries appended.
//!   * Preempt — a prefill tick that could not admit the queue head evicts
//!     the youngest active session instead: its compressed `SeqCache`
//!     (angles + norm codes + windows, a few hundred bytes per token) moves
//!     verbatim into the kv_manager's swap pool and the session joins the
//!     preemption queue. Re-admission restores the stream bit-identically,
//!     so generation resumes exactly where it left off.
//!
//! The engine is generic over [`ModelBackend`], so the same tick loop runs
//! against PJRT-compiled HLOs in production and the deterministic
//! [`crate::runtime::SimExecutor`] in tests. [`EngineCore`] is the
//! object-safe surface replica worker threads program against — the
//! multi-replica server (`server.rs`) only ever sees `dyn EngineCore`.

use super::batcher::{Admission, BatchPolicy, DynamicBatcher};
use super::kv_manager::{BatchTileReader, MemoryStats, PagedKvCache, TileScratch};
use super::metrics::EngineMetrics;
use super::scheduler::{next_action, Action, SchedulerPolicy};
use super::session::{FinishReason, Request, Session};
use crate::quant::QuantConfig;
use crate::runtime::{ModelBackend, ModelExecutor};
use anyhow::Result;
use std::collections::VecDeque;
use std::time::Instant;

pub const PAD: i32 = 258;
pub const EOS: i32 = 257;

/// The object-safe engine surface a serving replica exposes: submit work,
/// advance the tick loop, drain results, report memory and load. Worker
/// threads in the multi-replica server own one `Box<dyn EngineCore>` each;
/// everything model- or backend-specific stays behind this trait.
pub trait EngineCore: Send {
    /// Enqueue a request (may finish it immediately with `CacheFull` when
    /// it can never fit the page pool).
    fn submit(&mut self, req: Request);

    /// One scheduler tick. Returns the action taken.
    fn tick(&mut self) -> Result<Action>;

    /// Drain finished sessions accumulated since the last call.
    fn take_finished(&mut self) -> Vec<Session>;

    fn memory_stats(&self) -> MemoryStats;

    /// Replica depth gauge: queued + active + preempted sessions. The TCP
    /// front-end's `Router` tracks its own dispatched-minus-completed
    /// counts for routing; this gauge is the engine-side truth for
    /// embedders, tests, and future schedulers that want queue depth
    /// rather than in-flight request count.
    fn load(&self) -> usize;

    fn has_work(&self) -> bool {
        self.load() > 0
    }

    /// Snapshot of the serving counters/histograms.
    fn metrics(&self) -> EngineMetrics;
}

/// How decode reads the compressed cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPath {
    /// Fused when the backend supports it, dense reinflation otherwise.
    #[default]
    Auto,
    /// Force the fused tile path; panics at engine construction if the
    /// backend has none.
    Fused,
    /// Force the legacy path: keep dense (L,B,H,Tmax,d/2) buffers warm via
    /// incremental reinflation and hand them to `run_decode` every tick.
    Reinflate,
}

pub struct EngineConfig {
    pub quant: QuantConfig,
    pub batch_policy: BatchPolicy,
    pub scheduler: SchedulerPolicy,
    /// kv pool capacity in pages of `page_tokens`
    pub capacity_pages: usize,
    pub page_tokens: usize,
    /// decode read path (fused tiles vs dense reinflation)
    pub read_path: ReadPath,
}

pub struct Engine<B: ModelBackend = ModelExecutor> {
    pub exec: B,
    pub kv: PagedKvCache,
    pub batcher: DynamicBatcher,
    pub scheduler: SchedulerPolicy,
    pub metrics: EngineMetrics,
    pub quant: QuantConfig,
    slots: Vec<Option<Session>>,
    /// Sessions evicted under memory pressure, FIFO. Their compressed
    /// caches live in the kv_manager swap pool until re-admission.
    preempted: VecDeque<Session>,
    /// resolved read path: true = decode consumes compressed pages
    /// tile-by-tile, the dense buffers below stay empty
    fused: bool,
    /// page-sized dequant scratch for the fused path (bounded: never grows
    /// past one page of four d/2 slabs, regardless of sequence length)
    tile_scratch: TileScratch,
    // reusable dense cache buffers (L,B,H,Tmax,d/2) — reinflate path only
    kr: Vec<f32>,
    ki: Vec<f32>,
    vr: Vec<f32>,
    vi: Vec<f32>,
    /// tokens already reinflated into the dense buffers, per slot — the
    /// incremental fill keeps per-step coordinator cost O(1) in seq length
    slot_filled: Vec<usize>,
    /// whether the slot's session has survived >= 1 decode step since it
    /// was (re)seated — the anti-thrash gate: only such sessions are
    /// eviction candidates, so admission churn cannot starve token
    /// progress (every preemption cycle advances its victim first)
    slot_decoded: Vec<bool>,
    finished: Vec<Session>,
}

impl<B: ModelBackend> Engine<B> {
    pub fn new(exec: B, cfg: EngineConfig) -> Self {
        let (l, b, h, tmax, half) = exec.cache_dims();
        let fused = match cfg.read_path {
            ReadPath::Reinflate => false,
            ReadPath::Auto => exec.supports_fused_decode(),
            ReadPath::Fused => {
                assert!(
                    exec.supports_fused_decode(),
                    "ReadPath::Fused requires a backend with a fused decode path"
                );
                true
            }
        };
        // the fused path never materializes the dense tensors — this is
        // the memory the tentpole removes: 4 slabs of L·B·H·Tmax·d/2 f32
        let n = if fused { 0 } else { l * b * h * tmax * half };
        let kv = PagedKvCache::new(
            cfg.quant.clone(),
            l,
            h,
            exec.profile().d_head,
            tmax,
            cfg.capacity_pages,
            cfg.page_tokens,
        );
        Engine {
            exec,
            kv,
            batcher: DynamicBatcher::new(cfg.batch_policy),
            scheduler: cfg.scheduler,
            metrics: EngineMetrics::default(),
            quant: cfg.quant,
            slots: (0..b).map(|_| None).collect(),
            preempted: VecDeque::new(),
            fused,
            tile_scratch: TileScratch::new(),
            slot_filled: vec![0; b],
            slot_decoded: vec![false; b],
            kr: vec![0.0; n],
            ki: vec![0.0; n],
            vr: vec![0.0; n],
            vi: vec![0.0; n],
            finished: Vec::new(),
        }
    }

    /// Whether decode consumes compressed pages directly (the fused path).
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// Bytes of fused-path dequant scratch currently held (one page of
    /// four d/2 slabs once warmed — the bounded-scratch contract).
    pub fn tile_scratch_bytes(&self) -> usize {
        self.tile_scratch.bytes()
    }

    /// Bytes of dense reinflation buffers held (0 on the fused path).
    pub fn dense_buffer_bytes(&self) -> usize {
        (self.kr.len() + self.ki.len() + self.vr.len() + self.vi.len()) * 4
    }

    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_submitted += 1;
        let tp = self.exec.serve().prefill_len;
        let tmax = self.exec.serve().tmax;
        let expected = expected_tokens(req.prompt.len(), req.max_new_tokens, tp, tmax);
        if !self.kv.fits_capacity(expected) {
            // can never fit even an empty pool: finish NOW — needs no slot,
            // no pages, and must not block the queue behind it
            self.reject_cache_full(req);
            return;
        }
        self.batcher.submit(req);
    }

    /// Terminally finish a request that can never fit the page pool.
    fn reject_cache_full(&mut self, req: Request) {
        let plen = req.prompt.len().min(self.exec.serve().prefill_len);
        let mut sess = Session::new(req, plen);
        sess.finished = Some(FinishReason::CacheFull);
        self.metrics.rejected_cache_full += 1;
        self.retire(sess);
    }

    /// The single retire path: every finished session — rejected, done at
    /// prefill, or done at decode — goes through here so the finish-side
    /// counters and histograms cannot drift apart. Callers free the kv
    /// sequence first when one exists.
    fn retire(&mut self, sess: Session) {
        self.metrics
            .e2e
            .record(Instant::now().duration_since(sess.request.arrival));
        self.metrics.requests_finished += 1;
        self.finished.push(sess);
    }

    pub fn active_sessions(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.batcher.pending() > 0 || self.active_sessions() > 0 || !self.preempted.is_empty()
    }

    /// Drain finished sessions accumulated since the last call.
    pub fn take_finished(&mut self) -> Vec<Session> {
        std::mem::take(&mut self.finished)
    }

    pub fn memory_stats(&self) -> MemoryStats {
        self.kv.memory_stats()
    }

    /// One scheduler tick. Returns the action taken.
    pub fn tick(&mut self) -> Result<Action> {
        self.try_readmit()?;
        let action = next_action(
            &self.scheduler,
            &self.batcher,
            self.active_sessions(),
            self.slots.len(),
            Instant::now(),
        );
        match action {
            Action::Prefill => {
                let took = self.run_prefill()?;
                // work-conserving: a prefill tick that seated nothing
                // (head blocked, nothing evictable) must not stall the
                // active sessions — run the decode step it displaced
                if took != Action::Prefill && self.active_sessions() > 0 {
                    self.run_decode()?;
                    return Ok(Action::Decode);
                }
                return Ok(took);
            }
            Action::Decode => self.run_decode()?,
            Action::Preempt | Action::Idle => {}
        }
        Ok(action)
    }

    /// Run ticks until queue, slots, and the preemption queue drain.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            self.tick()?;
        }
        Ok(())
    }

    fn free_slot_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// Restore preempted sessions (FIFO) into free slots while the pool can
    /// re-promise their remaining footprint. The swap-in moves the
    /// compressed stream back verbatim; a full dense refill on the next
    /// decode tick resumes generation bit-identically.
    fn try_readmit(&mut self) -> Result<()> {
        while !self.preempted.is_empty() {
            let Some(slot) = self.slots.iter().position(|s| s.is_none()) else {
                break;
            };
            let sess = self.preempted.front().expect("checked non-empty");
            let remaining = sess
                .request
                .max_new_tokens
                .saturating_sub(sess.generated.len());
            // same bound as admission: cache_len + remaining == prompt +
            // max_new, so the re-reservation never exceeds the original
            let expected = (sess.cache_len() + remaining).min(self.exec.serve().tmax);
            if !self.kv.swap_in(sess.request.id, expected)? {
                break; // FIFO: don't let younger preemptees jump the queue
            }
            let sess = self.preempted.pop_front().expect("checked non-empty");
            self.metrics.swap_ins += 1;
            self.slot_filled[slot] = 0; // restored stream: full refill
            self.slot_decoded[slot] = false; // must decode before re-eviction
            self.slots[slot] = Some(sess);
        }
        Ok(())
    }

    /// The eviction candidate: among sessions that have decoded at least
    /// once since being (re)seated (the anti-thrash gate), the one with
    /// the latest request arrival. Sustained overload therefore cycles
    /// admissions at a bounded rate — every victim generated a token
    /// first — instead of thrashing prefill-only sessions through the
    /// swap pool.
    fn youngest_active_slot(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, _)| self.slot_decoded[*i])
            .filter_map(|(i, s)| s.as_ref().map(|sess| (i, sess.request.arrival)))
            .max_by_key(|&(i, arrival)| (arrival, i))
            .map(|(i, _)| i)
    }

    /// Evict one active session: compressed cache → swap pool, session →
    /// preemption queue. No dequantization happens; the page pool gets the
    /// session's pages AND its admission reservation back.
    fn evict_slot(&mut self, slot: usize) -> Result<()> {
        let mut sess = self.slots[slot].take().expect("evicting an empty slot");
        self.kv.swap_out(sess.request.id)?;
        sess.preemptions += 1;
        self.metrics.preemptions += 1;
        self.preempted.push_back(sess);
        Ok(())
    }

    /// A prefill tick. Forms a batch; requests that can never fit the pool
    /// are finished immediately with `CacheFull` (no more head-of-line
    /// starvation). When the queue head is blocked only by *current*
    /// memory pressure, active sessions are evicted youngest-first until it
    /// fits — each eviction loop iteration either seats new work or
    /// shrinks the active set, so this terminates.
    fn run_prefill(&mut self) -> Result<Action> {
        let mut evicted = false;
        loop {
            let free = self.free_slot_indices();
            if free.is_empty() {
                return Ok(if evicted { Action::Preempt } else { Action::Idle });
            }
            let tp = self.exec.serve().prefill_len;
            let tmax = self.exec.serve().tmax;
            let kv = &self.kv;
            // pages promised to requests admitted earlier in THIS pass —
            // the pool won't see their reservations until seat_prefill, so
            // the check must accumulate them or a jointly-over-capacity
            // batch would pass admission and fail its reservation later
            let mut batch_pages = 0usize;
            let taken = self.batcher.take_batch(free.len(), |r| {
                let expected = expected_tokens(r.prompt.len(), r.max_new_tokens, tp, tmax);
                let pages = kv.pages_for_tokens(expected);
                if !kv.fits_capacity(expected) {
                    Admission::Reject
                } else if kv.can_admit_pages(batch_pages + pages) {
                    batch_pages += pages;
                    Admission::Admit
                } else {
                    Admission::Defer
                }
            });
            // submit() already rejects capacity-impossible requests, but
            // keep the take_batch Reject arm as belt-and-braces (e.g. for
            // requests enqueued through a raw DynamicBatcher)
            for req in taken.rejected {
                self.reject_cache_full(req);
            }
            if !taken.admitted.is_empty() {
                self.seat_prefill(taken.admitted, &free)?;
                return Ok(Action::Prefill);
            }
            if self.batcher.pending() == 0 {
                // nothing admissible and nothing deferred: only rejects ran
                return Ok(if evicted { Action::Preempt } else { Action::Idle });
            }
            // head deferred on memory pressure: evict eligible victims
            // until its pages fit, THEN retry the batch pass once — a
            // single deferral count per blocked tick, not one per victim
            let head_pages = {
                let head = self.batcher.peek().expect("pending > 0");
                self.kv.pages_for_tokens(expected_tokens(
                    head.prompt.len(),
                    head.max_new_tokens,
                    tp,
                    tmax,
                ))
            };
            while !self.kv.can_admit_pages(head_pages) {
                match self.youngest_active_slot() {
                    Some(victim) => {
                        self.evict_slot(victim)?;
                        evicted = true;
                    }
                    None => {
                        // nothing (more) evictable; the head waits for
                        // running sessions to finish or decode first
                        return Ok(if evicted { Action::Preempt } else { Action::Idle });
                    }
                }
            }
        }
    }

    /// Run the prefill HLO for an admitted batch and seat the sessions.
    fn seat_prefill(&mut self, reqs: Vec<Request>, free: &[usize]) -> Result<()> {
        let tp = self.exec.serve().prefill_len;
        let tmax = self.exec.serve().tmax;
        let b_total = self.slots.len();
        let mut tokens = vec![PAD; b_total * tp];
        let mut lengths = vec![1i32; b_total]; // dummy lanes: len 1
        for (lane, req) in reqs.iter().enumerate() {
            let plen = req.prompt.len().min(tp);
            tokens[lane * tp..lane * tp + plen].copy_from_slice(&req.prompt[..plen]);
            lengths[lane] = plen as i32;
        }
        let out = self.exec.run_prefill(&tokens, &lengths, &self.quant)?;
        self.metrics.prefill_batches += 1;

        let (b_n, h_n, half) = (
            b_total,
            self.exec.profile().n_kv_heads,
            self.exec.profile().d_head / 2,
        );
        let vocab = self.exec.profile().vocab;
        for (lane, req) in reqs.into_iter().enumerate() {
            let plen = req.prompt.len().min(tp);
            let expected = expected_tokens(req.prompt.len(), req.max_new_tokens, tp, tmax);
            self.kv.new_seq(req.id, expected)?;
            // pack the prompt's compressed entries: only t < plen. One
            // strided append per token covers every (layer, head) at once
            // (kv_manager fans layers out across rayon when worthwhile).
            for t in 0..plen {
                self.kv.append_token_strided(
                    req.id,
                    &out.kr,
                    &out.ki,
                    &out.vr,
                    &out.vi,
                    (lane * h_n * tp + t) * half,
                    b_n * h_n * tp * half,
                    tp * half,
                )?;
                self.kv.commit_token(req.id)?;
            }
            self.metrics.prefill_sequences += 1;
            // first generated token from the prefill logits
            let logits = &out.logits[lane * vocab..(lane + 1) * vocab];
            let tok = argmax(logits);
            let mut sess = Session::new(req, plen);
            sess.push_token(tok, EOS, tmax);
            self.metrics
                .ttft
                .record(Instant::now().duration_since(sess.request.arrival));
            if sess.finished.is_some() {
                // finished on its very first token (EOS, or max_new_tokens
                // == 1): retire now instead of burning a decode step
                self.kv.free_seq(sess.request.id);
                self.retire(sess);
                continue;
            }
            let slot = free[lane];
            self.slot_filled[slot] = 0; // new sequence: full refill needed
            self.slot_decoded[slot] = false; // evictable only after a decode
            self.slots[slot] = Some(sess);
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        let b_total = self.slots.len();
        let mut token = vec![0i32; b_total];
        let mut pos = vec![0i32; b_total];
        let mut any = false;
        let t_coord = Instant::now();
        for (b, slot) in self.slots.iter().enumerate() {
            if let Some(sess) = slot {
                any = true;
                token[b] = *sess.generated.last().expect("session has a token");
                pos[b] = (sess.cache_len() - 1) as i32;
                // fused path: no dense buffers to keep warm — the backend
                // reads compressed pages directly during the decode call
                if !self.fused {
                    let filled = self.kv.fill_dense_range(
                        sess.request.id,
                        b,
                        b_total,
                        self.slot_filled[b],
                        &mut self.kr,
                        &mut self.ki,
                        &mut self.vr,
                        &mut self.vi,
                    )?;
                    self.slot_filled[b] = filled;
                }
            }
        }
        if !any {
            return Ok(());
        }
        let coord_prep = t_coord.elapsed();
        let t0 = Instant::now();
        let out = if self.fused {
            let lanes: Vec<Option<u64>> = self
                .slots
                .iter()
                .map(|s| s.as_ref().map(|sess| sess.request.id))
                .collect();
            let mut reader = BatchTileReader {
                kv: &self.kv,
                lanes: &lanes,
                scratch: &mut self.tile_scratch,
            };
            self.exec
                .run_decode_fused(&token, &pos, &self.quant, &mut reader)?
        } else {
            self.exec.run_decode(
                &token, &pos, &self.quant, &self.kr, &self.ki, &self.vr, &self.vi,
            )?
        };
        self.metrics.decode_step_latency.record(t0.elapsed());
        self.metrics.decode_steps += 1;
        self.metrics.decode_slot_steps += b_total as u64;

        let t_post = Instant::now();
        let (h_n, half) = (
            self.exec.profile().n_kv_heads,
            self.exec.profile().d_head / 2,
        );
        let vocab = self.exec.profile().vocab;
        let tmax = self.exec.serve().tmax;
        for b in 0..b_total {
            let Some(sess) = self.slots[b].as_mut() else {
                continue;
            };
            self.slot_decoded[b] = true;
            // append the *processed* token's compressed KV across all
            // (layer, head) pairs in one batched call
            self.kv.append_token_strided(
                sess.request.id,
                &out.kr,
                &out.ki,
                &out.vr,
                &out.vi,
                b * h_n * half,
                b_total * h_n * half,
                half,
            )?;
            self.kv.commit_token(sess.request.id)?;
            let tok = argmax(&out.logits[b * vocab..(b + 1) * vocab]);
            sess.push_token(tok, EOS, tmax);
            self.metrics.tokens_generated += 1;
            if sess.finished.is_some() {
                let sess = self.slots[b].take().unwrap();
                self.kv.free_seq(sess.request.id);
                self.retire(sess);
            }
        }
        self.metrics
            .coordinator_overhead
            .record(coord_prep + t_post.elapsed());
        Ok(())
    }
}

impl<B: ModelBackend> EngineCore for Engine<B> {
    fn submit(&mut self, req: Request) {
        Engine::submit(self, req)
    }

    fn tick(&mut self) -> Result<Action> {
        Engine::tick(self)
    }

    fn take_finished(&mut self) -> Vec<Session> {
        Engine::take_finished(self)
    }

    fn memory_stats(&self) -> MemoryStats {
        Engine::memory_stats(self)
    }

    fn load(&self) -> usize {
        self.batcher.pending() + self.active_sessions() + self.preempted.len()
    }

    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }

    fn metrics(&self) -> EngineMetrics {
        self.metrics.clone()
    }
}

/// Worst-case cache tokens a request can consume: prompt truncated to the
/// prefill window, plus its full generation budget, capped at tmax. The
/// SINGLE formula behind admission verdicts and page reservations — they
/// must never disagree, or admission re-opens the over-admission hole.
fn expected_tokens(prompt_len: usize, max_new: usize, prefill_len: usize, tmax: usize) -> usize {
    (prompt_len.min(prefill_len) + max_new).min(tmax)
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
