//! The serving engine: continuous batching over a compressed KV cache.
//!
//! One tick = one scheduler action (monolithic mode):
//!   * Prefill — batcher-formed prompt batch → prefill HLO → compressed
//!     entries packed into the kv_manager, sessions seated in slots.
//!   * Decode — active slots' caches reinflated (norm dequant + angle
//!     unpack) into the dense HLO inputs, one fused decode step, new
//!     tokens sampled greedily, new compressed entries appended.
//!   * Preempt — a prefill tick that could not admit the queue head evicts
//!     the youngest active session instead: its compressed `SeqCache`
//!     (angles + norm codes + windows, a few hundred bytes per token) moves
//!     verbatim into the kv_manager's swap pool and the session joins the
//!     preemption queue. Re-admission restores the stream bit-identically,
//!     so generation resumes exactly where it left off.
//!
//! With **chunked prefill** on ([`EngineConfig::chunked_prefill`], CLI
//! `--chunked-prefill on`), monolithic prefill ticks are replaced by a
//! per-tick token-budget planner: every tick packs the decode lanes first
//! (each costs one budget token), then fills the remaining
//! [`EngineConfig::tick_token_budget`] with prefill chunks of at most
//! [`EngineConfig::chunk_tokens`] tokens, granted FIFO by request arrival.
//! A long prompt therefore never stalls in-flight decoders for a whole
//! prefill — the stall is bounded by one chunk — while every appended
//! chunk is bit-identical to what one-shot prefill would have produced
//! (the `run_prefill_chunk` backend contract), so token streams with
//! chunking on and off are equal. Sessions carry a `prefill_cursor`;
//! prefix-cache adoption starts the cursor past the adopted pages, and
//! half-prefilled sessions can be preempted and resumed mid-prompt.
//!
//! The engine is generic over [`ModelBackend`], so the same tick loop runs
//! against PJRT-compiled HLOs in production and the deterministic
//! [`crate::runtime::SimExecutor`] in tests. [`EngineCore`] is the
//! object-safe surface replica worker threads program against — the
//! multi-replica server (`server.rs`) only ever sees `dyn EngineCore`.

use super::batcher::{Admission, BatchPolicy, DynamicBatcher};
use super::kv_manager::{
    BatchTileReader, MemoryStats, PageId, PagedKvCache, SharedPageStore, TileScratch,
};
use super::metrics::EngineMetrics;
use super::prefix_cache::PrefixCache;
use super::scheduler::{next_action, Action, SchedulerPolicy};
use super::session::{FinishReason, Request, Session};
use crate::obs::{stage, EventKind, GaugeSample, GaugeSeries, ObsSnapshot, Recorder, StageStats};
use crate::quant::QuantConfig;
use crate::runtime::{ModelBackend, ModelExecutor};
use anyhow::Result;
use std::collections::{HashMap, VecDeque};
use std::sync::Arc;
use std::time::Instant;

/// Padding token id for unused prefill positions (matches the L2 protocol).
pub const PAD: i32 = 258;
/// End-of-sequence token id (matches the L2 protocol).
pub const EOS: i32 = 257;

/// The object-safe engine surface a serving replica exposes: submit work,
/// advance the tick loop, drain results, report memory and load. Worker
/// threads in the multi-replica server own one `Box<dyn EngineCore>` each;
/// everything model- or backend-specific stays behind this trait.
pub trait EngineCore: Send {
    /// Enqueue a request (may finish it immediately with `CacheFull` when
    /// it can never fit the page pool).
    fn submit(&mut self, req: Request);

    /// One scheduler tick. Returns the action taken.
    fn tick(&mut self) -> Result<Action>;

    /// Drain finished sessions accumulated since the last call.
    fn take_finished(&mut self) -> Vec<Session>;

    /// Snapshot of the replica's cache memory accounting.
    fn memory_stats(&self) -> MemoryStats;

    /// Tokens per kv page — the paging/sharing granularity. The server's
    /// prefix-fingerprint routing aligns its hash window to this, so every
    /// replica behind one router must agree on it (they do: one CLI flag
    /// configures all of them).
    fn page_tokens(&self) -> usize;

    /// Replica depth gauge: queued + active + preempted sessions. The TCP
    /// front-end's `Router` tracks its own dispatched-minus-completed
    /// counts for routing; this gauge is the engine-side truth for
    /// embedders, tests, and future schedulers that want queue depth
    /// rather than in-flight request count.
    fn load(&self) -> usize;

    /// Whether any queued, seated, or preempted work remains.
    fn has_work(&self) -> bool {
        self.load() > 0
    }

    /// Snapshot of the serving counters/histograms.
    fn metrics(&self) -> EngineMetrics;

    /// Clone the replica's observability state — trace-ring contents,
    /// sampled gauge series, and fused-path stage timers — for the trace
    /// and metrics exporters. Default: empty, for cores without tracing.
    fn obs_snapshot(&self) -> ObsSnapshot {
        ObsSnapshot::default()
    }
}

/// How decode reads the compressed cache.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ReadPath {
    /// Fused when the backend supports it, dense reinflation otherwise.
    #[default]
    Auto,
    /// Force the fused tile path; panics at engine construction if the
    /// backend has none.
    Fused,
    /// Force the legacy path: keep dense (L,B,H,Tmax,d/2) buffers warm via
    /// incremental reinflation and hand them to `run_decode` every tick.
    Reinflate,
}

/// Everything an [`Engine`] needs besides its backend. Build one with
/// [`EngineConfig::new`] and override fields (functional-update syntax
/// works: `EngineConfig { page_tokens: 8, ..EngineConfig::new(quant) }`).
pub struct EngineConfig {
    /// Quantizer configuration shared by the backend and the kv_manager.
    pub quant: QuantConfig,
    /// When prefills fire and which requests join them.
    pub batch_policy: BatchPolicy,
    /// Monolithic prefill/decode interleave policy (ignored with
    /// [`Self::chunked_prefill`] on — the token budget replaces it).
    pub scheduler: SchedulerPolicy,
    /// kv pool capacity in pages of `page_tokens`
    pub capacity_pages: usize,
    /// Tokens per kv page — the paging/sharing/tile granularity.
    pub page_tokens: usize,
    /// decode read path (fused tiles vs dense reinflation)
    pub read_path: ReadPath,
    /// share full compressed pages across requests with common prompt
    /// prefixes (radix-tree prefix cache; CLI `--prefix-cache on|off`).
    /// Token streams are bit-identical either way — the cache only skips
    /// recomputing KV entries that deterministic prefill would reproduce.
    pub prefix_cache: bool,
    /// Split prompt ingestion into fixed token-budget chunks so every tick
    /// interleaves prefill chunks with decode steps (CLI
    /// `--chunked-prefill on|off`). Token streams are bit-identical to
    /// monolithic prefill; only tail latency changes.
    pub chunked_prefill: bool,
    /// Tokens per prefill chunk per session per tick (chunked mode; must
    /// be >= 1; CLI `--chunk-tokens N`). Smaller chunks bound the decode
    /// stall tighter at more per-chunk overhead.
    pub chunk_tokens: usize,
    /// Per-tick token budget (chunked mode; must be >= 1; CLI
    /// `--tick-token-budget N`): each decode lane costs 1 token, the
    /// remainder is granted to prefill chunks FIFO by arrival. Budgets
    /// below `batch + chunk_tokens` throttle prefill while the engine is
    /// decode-saturated (the work still completes as decoders finish).
    pub tick_token_budget: usize,
    /// Record request-lifecycle trace events and sampled gauges (CLI
    /// `--trace on|off`). Off by default: every record site is then a
    /// single branch and token streams are bit-identical either way.
    pub trace: bool,
    /// Trace ring capacity in events per replica. Bounded: when full the
    /// oldest events are overwritten and the drop counter advances.
    pub trace_events: usize,
    /// Gauge/stage sampling stride in ticks (must be >= 1; CLI
    /// `--sample-every N`). Stride 1 samples every tick; larger strides
    /// cut sampling overhead proportionally.
    pub sample_every: usize,
    /// Node-level shared page store (CLI `--shared-store node`): hand every
    /// engine replica on the node one clone of the same
    /// [`SharedPageStore::node`] Arc, so a prefix harvested by any replica
    /// is adopted — zero bytes copied — by all of them, and stored once per
    /// NODE instead of once per replica. `None` keeps the classic
    /// replica-private store. Token streams are bit-identical either way.
    pub shared_store: Option<Arc<SharedPageStore>>,
}

impl EngineConfig {
    /// Baseline config for `quant`: default batch/scheduler policies, a
    /// 4096-page pool of 16-token pages, automatic read-path resolution,
    /// prefix cache off, and chunked prefill off (chunk 16 / budget 64
    /// once enabled).
    pub fn new(quant: QuantConfig) -> Self {
        EngineConfig {
            quant,
            batch_policy: BatchPolicy::default(),
            scheduler: SchedulerPolicy::default(),
            capacity_pages: 4096,
            page_tokens: 16,
            read_path: ReadPath::default(),
            prefix_cache: false,
            chunked_prefill: false,
            chunk_tokens: 16,
            tick_token_budget: 64,
            trace: false,
            trace_events: 65_536,
            sample_every: 32,
            shared_store: None,
        }
    }
}

/// The serving engine for one replica: slots, compressed cache, batcher,
/// and the tick loop. See the module docs for the tick state machine.
pub struct Engine<B: ModelBackend = ModelExecutor> {
    /// The model backend (PJRT executor or the deterministic sim).
    pub exec: B,
    /// The compressed paged KV cache (pool, swap store, shared pages).
    pub kv: PagedKvCache,
    /// Admission queue + batch-formation policy.
    pub batcher: DynamicBatcher,
    /// Monolithic prefill/decode interleave policy.
    pub scheduler: SchedulerPolicy,
    /// Serving counters and latency histograms.
    pub metrics: EngineMetrics,
    /// Quantizer configuration handed to every backend call.
    pub quant: QuantConfig,
    /// chunked-prefill mode: replace monolithic prefill ticks with the
    /// token-budget planner (see module docs)
    chunked: bool,
    /// tokens per prefill chunk per session per tick (chunked mode)
    chunk_tokens: usize,
    /// per-tick token budget: decode lanes first, then prefill chunks
    tick_budget: usize,
    slots: Vec<Option<Session>>,
    /// Sessions evicted under memory pressure, FIFO. Their compressed
    /// caches live in the kv_manager swap pool until re-admission.
    preempted: VecDeque<Session>,
    /// Prompt-prefix radix tree over shared compressed pages (None = off).
    /// Admission matches against it, finished sequences insert into it,
    /// and pool pressure evicts its unreferenced pages LRU-first.
    prefix: Option<PrefixCache>,
    /// resolved read path: true = decode consumes compressed pages
    /// tile-by-tile, the dense buffers below stay empty
    fused: bool,
    /// page-sized dequant scratch for the fused path (bounded: never grows
    /// past one page of four d/2 slabs, regardless of sequence length)
    tile_scratch: TileScratch,
    // reusable dense cache buffers (L,B,H,Tmax,d/2) — reinflate path only
    kr: Vec<f32>,
    ki: Vec<f32>,
    vr: Vec<f32>,
    vi: Vec<f32>,
    /// tokens already reinflated into the dense buffers, per slot — the
    /// incremental fill keeps per-step coordinator cost O(1) in seq length
    slot_filled: Vec<usize>,
    /// whether the slot's session has made progress (>= 1 decode step, or
    /// >= 1 appended prefill chunk in chunked mode) since it was
    /// (re)seated — the anti-thrash gate: only such sessions are eviction
    /// candidates, so admission churn cannot starve progress (every
    /// preemption cycle advances its victim first). Chunk progress counts
    /// so half-prefilled sessions stay preemptible under pressure.
    slot_decoded: Vec<bool>,
    finished: Vec<Session>,
    /// request-lifecycle trace ring (disabled: every record is one branch)
    obs: Recorder,
    /// tick-sampled gauge series (pool/shared/swap/queue/per-layer bits)
    gauges: GaugeSeries,
    /// fused read-path stage timers accumulated over sampled ticks
    stage: StageStats,
    /// gauge/stage sampling stride in ticks (>= 1)
    sample_every: u64,
    /// monotonically increasing tick counter (timestamps trace events)
    ticks: u64,
}

impl<B: ModelBackend> Engine<B> {
    /// Build an engine around `exec`. Panics on inconsistent configs
    /// (`ReadPath::Fused` without backend support, a zero chunk size or
    /// tick budget with chunked prefill on, a zero sampling stride) — the
    /// CLI validates the same conditions earlier with actionable errors.
    pub fn new(exec: B, cfg: EngineConfig) -> Self {
        assert!(
            cfg.sample_every >= 1,
            "sample_every must be >= 1 (the tick stride between gauge/stage samples)"
        );
        if cfg.chunked_prefill {
            assert!(
                cfg.chunk_tokens >= 1,
                "chunked prefill requires chunk_tokens >= 1"
            );
            assert!(
                cfg.tick_token_budget >= 1,
                "chunked prefill requires tick_token_budget >= 1"
            );
        }
        let (l, b, h, tmax, half) = exec.cache_dims();
        let fused = match cfg.read_path {
            ReadPath::Reinflate => false,
            ReadPath::Auto => exec.supports_fused_decode(),
            ReadPath::Fused => {
                assert!(
                    exec.supports_fused_decode(),
                    "ReadPath::Fused requires a backend with a fused decode path"
                );
                true
            }
        };
        // the fused path never materializes the dense tensors — this is
        // the memory the tentpole removes: 4 slabs of L·B·H·Tmax·d/2 f32
        let n = if fused { 0 } else { l * b * h * tmax * half };
        let kv = match cfg.shared_store {
            Some(store) => PagedKvCache::with_store(
                cfg.quant.clone(),
                l,
                h,
                exec.profile().d_head,
                tmax,
                cfg.capacity_pages,
                cfg.page_tokens,
                store,
            ),
            None => PagedKvCache::new(
                cfg.quant.clone(),
                l,
                h,
                exec.profile().d_head,
                tmax,
                cfg.capacity_pages,
                cfg.page_tokens,
            ),
        };
        Engine {
            exec,
            kv,
            batcher: DynamicBatcher::new(cfg.batch_policy),
            scheduler: cfg.scheduler,
            metrics: EngineMetrics::default(),
            quant: cfg.quant,
            chunked: cfg.chunked_prefill,
            chunk_tokens: cfg.chunk_tokens,
            tick_budget: cfg.tick_token_budget,
            slots: (0..b).map(|_| None).collect(),
            preempted: VecDeque::new(),
            prefix: cfg.prefix_cache.then(|| PrefixCache::new(cfg.page_tokens)),
            fused,
            tile_scratch: TileScratch::new(),
            slot_filled: vec![0; b],
            slot_decoded: vec![false; b],
            kr: vec![0.0; n],
            ki: vec![0.0; n],
            vr: vec![0.0; n],
            vi: vec![0.0; n],
            finished: Vec::new(),
            obs: Recorder::new(cfg.trace, cfg.trace_events),
            gauges: GaugeSeries::default(),
            stage: StageStats::default(),
            sample_every: cfg.sample_every as u64,
            ticks: 0,
        }
    }

    /// Whether decode consumes compressed pages directly (the fused path).
    pub fn is_fused(&self) -> bool {
        self.fused
    }

    /// Whether chunked prefill (the token-budget tick planner) is on.
    pub fn is_chunked(&self) -> bool {
        self.chunked
    }

    /// Seated sessions still mid-prefill (always 0 in monolithic mode) —
    /// observability for tests and schedulers.
    pub fn prefilling_sessions(&self) -> usize {
        self.slots
            .iter()
            .flatten()
            .filter(|s| !s.decode_ready())
            .count()
    }

    /// Whether the prompt-prefix cache is enabled.
    pub fn prefix_cache_enabled(&self) -> bool {
        self.prefix.is_some()
    }

    /// Bytes of fused-path dequant scratch currently held (one page of
    /// four d/2 slabs once warmed — the bounded-scratch contract).
    pub fn tile_scratch_bytes(&self) -> usize {
        self.tile_scratch.bytes()
    }

    /// Bytes of dense reinflation buffers held (0 on the fused path).
    pub fn dense_buffer_bytes(&self) -> usize {
        (self.kr.len() + self.ki.len() + self.vr.len() + self.vi.len()) * 4
    }

    /// Whether request-lifecycle tracing is recording.
    pub fn tracing(&self) -> bool {
        self.obs.enabled()
    }

    /// Clone the replica's observability state — trace-ring contents,
    /// sampled gauge series, and fused-path stage timers — for export
    /// (`--trace-out`, the `metrics` wire query, tests).
    pub fn obs_snapshot(&self) -> ObsSnapshot {
        ObsSnapshot {
            events: self.obs.snapshot(),
            gauges: self.gauges.snapshot(),
            dropped_events: self.obs.dropped(),
            stage: self.stage,
        }
    }

    /// Enqueue a request (may finish it immediately with `CacheFull` when
    /// it can never fit the page pool).
    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_submitted += 1;
        self.obs
            .record(EventKind::Queued, req.id, self.ticks, req.prompt.len() as u64);
        let tp = self.exec.serve().prefill_len;
        let tmax = self.exec.serve().tmax;
        let expected = expected_tokens(req.prompt.len(), req.max_new_tokens, tp, tmax);
        if !self.kv.fits_capacity(expected) {
            // can never fit even an empty pool: finish NOW — needs no slot,
            // no pages, and must not block the queue behind it
            self.reject_cache_full(req);
            return;
        }
        self.batcher.submit(req);
    }

    /// Terminally finish a request that can never fit the page pool.
    fn reject_cache_full(&mut self, req: Request) {
        self.obs.record(EventKind::Rejected, req.id, self.ticks, 0);
        let plen = req.prompt.len().min(self.exec.serve().prefill_len);
        let mut sess = Session::new(req, plen);
        sess.finished = Some(FinishReason::CacheFull);
        self.metrics.rejected_cache_full += 1;
        self.retire(sess);
    }

    /// Return a finished session's cache to the pool. With prefix caching
    /// on, its PROMPT-covered full pages are sealed into the shared store
    /// (content-addressed, deduped) and indexed in the radix tree so
    /// future prompts sharing the prefix adopt them. With it off,
    /// everything is freed outright.
    ///
    /// Only prefill-emitted pages are cached: decode-emitted KV is a
    /// different deterministic function of the token prefix than prefill's
    /// (sim backend), so sharing generated positions with a future prompt
    /// that happens to spell the same tokens would break the
    /// prefix-cache-on/off bit-identity guarantee. Multi-turn reuse is
    /// unaffected — the next turn's prompt contains this whole
    /// conversation and gets cached from its OWN prefill when it finishes.
    fn finish_kv(&mut self, sess: &Session) -> Result<()> {
        let id = sess.request.id;
        let Some(p) = self.prefix.as_mut() else {
            return self.kv.free_seq(id);
        };
        let prompt = &sess.request.prompt[..sess.prompt_len];
        // count inserts by this cache's own monotonic seal counter, not a
        // before/after of the store's page count — a node store's count
        // moves under concurrent replicas' seals and evictions
        let before = self.kv.sealed_pages_total();
        let chain = self.kv.finish_seq_share(id, prompt)?;
        self.metrics.prefix_pages_inserted += self.kv.sealed_pages_total() - before;
        // index the chain. A tree node whose old page a node store has
        // since evicted is repointed at the freshly sealed id; a chain id
        // the tree still could not link (hash-collision dedup fallback, or
        // a conflicting page that is still resident) is indexed nowhere —
        // free it or it leaks its pool page
        let kv = &self.kv;
        let orphans = p.insert_with(prompt, &chain, &|pid| kv.shared_page_present(pid));
        for pid in orphans {
            if self.kv.shared_page_refs(pid) == Some(0) {
                self.kv.free_shared_page(pid)?;
            }
        }
        Ok(())
    }

    /// The single admission-side registration for one seated sequence —
    /// shared by monolithic and chunked seating so their kv creation and
    /// prefix accounting can never drift: create the kv sequence adopting
    /// `shared` prefix pages, record the hit/miss/reuse counters, and
    /// return the ACTUALLY adopted token count (a node-scoped store may
    /// have evicted part of the matched chain since the admission pass, so
    /// adoption can truncate — the caller must size the prefill suffix by
    /// this return, never by `shared.len()`). Returns `Ok(None)` — with no
    /// sequence created — when truncation re-priced the reservation past
    /// what the pool can promise; the caller requeues the request.
    fn admit_seq(&mut self, id: u64, expected: usize, shared: &[PageId]) -> Result<Option<usize>> {
        let adopted = self.kv.new_seq_with_prefix(id, expected, shared)?;
        if adopted.unwrap_or(0) < shared.len() {
            // part of the matched chain is gone from the node store: drop
            // the dead tree entries so retries and future matches stop
            // offering pages that can no longer be adopted
            if let Some(p) = self.prefix.as_mut() {
                let kv = &self.kv;
                p.prune_missing(&|pid| kv.shared_page_present(pid));
            }
        }
        let Some(adopted_pages) = adopted else {
            return Ok(None);
        };
        let shared_tokens = adopted_pages * self.kv.page_tokens();
        self.obs
            .record(EventKind::Admitted, id, self.ticks, expected as u64);
        if adopted_pages > 0 {
            self.obs
                .record(EventKind::PrefixAdopt, id, self.ticks, adopted_pages as u64);
        }
        if self.prefix.is_some() {
            if adopted_pages == 0 {
                self.metrics.prefix_misses += 1;
            } else {
                self.metrics.prefix_hits += 1;
                self.metrics.prefix_tokens_reused += shared_tokens as u64;
                self.metrics.prefix_pages_adopted += adopted_pages as u64;
            }
        }
        self.metrics.prefill_sequences += 1;
        Ok(Some(shared_tokens))
    }

    /// The single retire path: every finished session — rejected, done at
    /// prefill, or done at decode — goes through here so the finish-side
    /// counters and histograms cannot drift apart. Callers free the kv
    /// sequence first when one exists.
    fn retire(&mut self, sess: Session) {
        let e2e = Instant::now().duration_since(sess.request.arrival);
        self.metrics.e2e.record(e2e);
        // the Finish span covers the whole arrival→retirement lifetime, so
        // every other event of the same request nests inside it
        self.obs.record_span(
            EventKind::Finish,
            sess.request.id,
            self.ticks,
            e2e,
            sess.generated.len() as u64,
        );
        self.metrics.requests_finished += 1;
        self.finished.push(sess);
    }

    /// Seated sessions (decoding or mid-prefill).
    pub fn active_sessions(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Queued, seated, or preempted work remains.
    pub fn has_work(&self) -> bool {
        self.batcher.pending() > 0 || self.active_sessions() > 0 || !self.preempted.is_empty()
    }

    /// Drain finished sessions accumulated since the last call.
    pub fn take_finished(&mut self) -> Vec<Session> {
        std::mem::take(&mut self.finished)
    }

    /// Snapshot of the cache's memory accounting.
    pub fn memory_stats(&self) -> MemoryStats {
        self.kv.memory_stats()
    }

    /// One scheduler tick. Returns the action taken. With tracing on,
    /// every `sample_every`-th tick also snapshots the gauges and times
    /// the fused read path's stages; with it off the tick body runs with
    /// zero observability work beyond one branch.
    pub fn tick(&mut self) -> Result<Action> {
        self.ticks += 1;
        let sampled = self.obs.enabled() && self.ticks % self.sample_every == 0;
        if sampled {
            self.sample_gauges();
            stage::set_enabled(true);
        }
        let action = self.tick_inner();
        if sampled {
            stage::set_enabled(false);
            self.stage.add_sample(stage::take());
        }
        action
    }

    /// Take one gauge sample (pool, shared store, swap, queue depth,
    /// per-layer achieved bits) at the current tick.
    fn sample_gauges(&mut self) {
        let mem = self.kv.memory_stats();
        self.gauges.push(GaugeSample {
            tick: self.ticks,
            at_us: self.obs.now_us(),
            pages_used: mem.pages_allocated as u64,
            pages_reserved: mem.pages_reserved as u64,
            pages_capacity: mem.pages_capacity as u64,
            shared_pages: mem.shared_pages as u64,
            shared_refs: mem.shared_refs as u64,
            swap_bytes: mem.swapped_bytes as u64,
            queue_depth: (self.batcher.pending() + self.active_sessions() + self.preempted.len())
                as u64,
            layer_bits_per_element: self.kv.per_layer_bits_per_element(),
        });
    }

    /// The untraced tick body (the pre-observability `tick`).
    fn tick_inner(&mut self) -> Result<Action> {
        self.try_readmit()?;
        if self.chunked {
            return self.tick_chunked();
        }
        let action = next_action(
            &self.scheduler,
            &self.batcher,
            self.active_sessions(),
            self.slots.len(),
            Instant::now(),
        );
        match action {
            Action::Prefill => {
                let took = self.run_prefill()?;
                // work-conserving: a prefill tick that seated nothing
                // (head blocked, nothing evictable) must not stall the
                // active sessions — run the decode step it displaced
                if took != Action::Prefill && self.active_sessions() > 0 {
                    self.run_decode()?;
                    return Ok(Action::Decode);
                }
                return Ok(took);
            }
            Action::Decode => self.run_decode()?,
            // next_action never returns Preempt or Mixed; Idle is a no-op
            Action::Preempt | Action::Mixed | Action::Idle => {}
        }
        Ok(action)
    }

    /// Run ticks until queue, slots, and the preemption queue drain.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            self.tick()?;
        }
        Ok(())
    }

    /// One chunked-mode tick: (1) seat pending requests into free slots —
    /// same admission/eviction logic as monolithic mode, but seating does
    /// no model work; (2) pack the decode lanes (1 budget token each) and
    /// grant the remaining budget to mid-prefill sessions as chunks of at
    /// most `chunk_tokens`, FIFO by request arrival — decode runs every
    /// tick it has a lane, so a stream of long prompts can never starve an
    /// in-flight decoder; (3) execute the decode step, then the granted
    /// chunks in one backend call. A session whose chunk completes its
    /// prompt samples its first token from that call's logits and becomes
    /// a decode lane next tick.
    ///
    /// Action reporting: a chunked tick that both evicted AND did decode
    /// or chunk work reports the work ([`Action::Mixed`] / `Prefill` /
    /// `Decode`); [`Action::Preempt`] is returned only when eviction was
    /// the tick's sole effect. `EngineMetrics::preemptions` is the
    /// authoritative eviction count either way.
    fn tick_chunked(&mut self) -> Result<Action> {
        let mut admitted = false;
        let mut evicted = false;
        let free = self.slots.len() - self.active_sessions();
        if free > 0 && self.batcher.should_prefill(free, Instant::now()) {
            match self.run_prefill()? {
                Action::Prefill => admitted = true,
                Action::Preempt => evicted = true,
                _ => {}
            }
        }
        let decode_lanes = self
            .slots
            .iter()
            .flatten()
            .filter(|s| s.decode_ready())
            .count();
        // FIFO-fair chunk grants: oldest arrival first, at most one chunk
        // per session per tick, within what the budget has left after the
        // decode lanes. A fully-adopted prompt (prefix-cache hit covering
        // everything) still needs one zero-token grant for its first-token
        // logits; it is charged one budget token.
        let mut pref: Vec<(Instant, u64, usize)> = self
            .slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| {
                s.as_ref().and_then(|sess| {
                    (!sess.decode_ready()).then_some((sess.request.arrival, sess.request.id, i))
                })
            })
            .collect();
        pref.sort();
        let mut budget = self.tick_budget.saturating_sub(decode_lanes);
        let mut grants: Vec<(usize, usize)> = Vec::new();
        for &(_, _, slot) in &pref {
            if budget == 0 {
                break;
            }
            // xtask-allow(no-panic-in-serving): `pref` was built from slots holding mid-prefill sessions two lines up; an empty slot here is engine-state corruption, not bad input
            let sess = self.slots[slot].as_ref().expect("prefilling slot is seated");
            let want = (sess.prompt_len - sess.prefill_cursor)
                .min(self.chunk_tokens)
                .min(budget);
            budget -= want.max(1).min(budget);
            grants.push((slot, want));
        }
        if decode_lanes > 0 {
            self.run_decode()?;
        }
        let chunked_work = !grants.is_empty();
        if chunked_work {
            self.run_prefill_chunks(&grants)?;
        }
        Ok(match (admitted || chunked_work, decode_lanes > 0) {
            (true, true) => Action::Mixed,
            (true, false) => Action::Prefill,
            (false, true) => Action::Decode,
            (false, false) => {
                if evicted {
                    Action::Preempt
                } else {
                    Action::Idle
                }
            }
        })
    }

    /// Execute one tick's granted prefill chunks in a single backend call
    /// and append each chunk's compressed KV (positions `cursor ..
    /// cursor + want` of each granted slot's prompt). Chunk lanes are
    /// indexed by SLOT — unlike monolithic `seat_prefill`, which packs
    /// admitted requests densely — so a batch mixing decode-ready and
    /// mid-prefill sessions addresses the output slabs without remapping.
    fn run_prefill_chunks(&mut self, grants: &[(usize, usize)]) -> Result<()> {
        let tp = self.exec.serve().prefill_len;
        let tmax = self.exec.serve().tmax;
        let b_total = self.slots.len();
        let mut tokens = vec![PAD; b_total * tp];
        let mut lengths = vec![1i32; b_total]; // idle lanes: dummy len 1
        let mut starts = vec![0usize; b_total];
        let mut lens = vec![0usize; b_total];
        for &(slot, want) in grants {
            // xtask-allow(no-panic-in-serving): grants only name slots the budget pass just saw seated; nothing between can vacate them
            let sess = self.slots[slot].as_ref().expect("granted slot is seated");
            let plen = sess.prompt_len;
            tokens[slot * tp..slot * tp + plen].copy_from_slice(&sess.request.prompt[..plen]);
            lengths[slot] = plen as i32;
            starts[slot] = sess.prefill_cursor;
            lens[slot] = want;
        }
        let t_chunk = Instant::now();
        let out = self
            .exec
            .run_prefill_chunk(&tokens, &lengths, &starts, &lens, &self.quant)?;
        let chunk_dur = t_chunk.elapsed();
        self.metrics.prefill_chunks += grants.len() as u64;
        let (h_n, half) = (
            self.exec.profile().n_kv_heads,
            self.exec.profile().d_head / 2,
        );
        let vocab = self.exec.profile().vocab;
        for &(slot, want) in grants {
            let (id, c0, plen) = {
                // xtask-allow(no-panic-in-serving): same grants invariant as above — the HLO ran, but the slot set is unchanged
                let sess = self.slots[slot].as_ref().expect("granted slot is seated");
                (sess.request.id, sess.prefill_cursor, sess.prompt_len)
            };
            self.obs
                .record_span(EventKind::PrefillChunk, id, self.ticks, chunk_dur, want as u64);
            for t in c0..c0 + want {
                self.kv.append_token_strided(
                    id,
                    &out.kr,
                    &out.ki,
                    &out.vr,
                    &out.vi,
                    (slot * h_n * tp + t) * half,
                    b_total * h_n * tp * half,
                    tp * half,
                )?;
                self.kv.commit_token(id)?;
            }
            // chunk landed: progress — the session is now preemptible
            // (resume continues from the cursor, bit-identically)
            self.slot_decoded[slot] = true;
            // xtask-allow(no-panic-in-serving): same grants invariant; the append/commit loop above cannot clear a slot
            let sess = self.slots[slot].as_mut().expect("granted slot is seated");
            sess.prefill_cursor += want;
            if sess.prefill_cursor >= plen && sess.generated.is_empty() {
                // the chunk that completes the prompt carries full-prompt
                // logits (the run_prefill_chunk contract): sample the
                // first token exactly as monolithic prefill would
                let tok = argmax(&out.logits[slot * vocab..(slot + 1) * vocab]);
                sess.push_token(tok, EOS, tmax);
                self.metrics
                    .ttft
                    .record(Instant::now().duration_since(sess.request.arrival));
                self.obs.record(EventKind::FirstToken, id, self.ticks, 0);
                if sess.finished.is_some() {
                    // xtask-allow(no-panic-in-serving): the borrow that set `finished` was taken from this very slot
                    let sess = self.slots[slot].take().expect("granted slot is seated");
                    self.finish_kv(&sess)?;
                    self.retire(sess);
                }
            }
        }
        Ok(())
    }

    fn free_slot_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    /// Restore preempted sessions (FIFO) into free slots while the pool can
    /// re-promise their remaining footprint. The swap-in moves the
    /// compressed stream back verbatim; a full dense refill on the next
    /// decode tick resumes generation bit-identically.
    fn try_readmit(&mut self) -> Result<()> {
        while !self.preempted.is_empty() {
            let Some(slot) = self.slots.iter().position(|s| s.is_none()) else {
                break;
            };
            // xtask-allow(no-panic-in-serving): the `while !self.preempted.is_empty()` guard is two lines up and nothing pops in between
            let sess = self.preempted.front().expect("checked non-empty");
            let remaining = sess
                .request
                .max_new_tokens
                .saturating_sub(sess.generated.len());
            // same bound as admission: cache_len + remaining == prompt +
            // max_new, so the re-reservation never exceeds the original
            let expected = (sess.cache_len() + remaining).min(self.exec.serve().tmax);
            let id = sess.request.id;
            let mut admitted = self.kv.swap_in(id, expected)?;
            if !admitted {
                // pool full: reclaim cache, bounded by the exact deficit —
                // the prefix cache must never starve a preempted session's
                // re-admission, but when live reservations are what blocks
                // the swap-in, the cache is left alone
                let need = self.kv.swap_in_reserve(id, expected).unwrap_or(0);
                let deficit = self.kv.admit_deficit(need);
                // no exclusions: this sequence's own adopted pages are
                // already pinned by the refs it kept through the swap
                if self.reclaim_prefix_cache(deficit, &[])? > 0 {
                    admitted = self.kv.swap_in(id, expected)?;
                }
            }
            if !admitted {
                break; // FIFO: don't let younger preemptees jump the queue
            }
            // xtask-allow(no-panic-in-serving): same loop guard — the queue is non-empty or we'd have exited above
            let sess = self.preempted.pop_front().expect("checked non-empty");
            self.metrics.swap_ins += 1;
            self.obs.record(
                EventKind::SwapIn,
                sess.request.id,
                self.ticks,
                sess.cache_len() as u64,
            );
            self.slot_filled[slot] = 0; // restored stream: full refill
            self.slot_decoded[slot] = false; // must decode before re-eviction
            self.slots[slot] = Some(sess);
        }
        Ok(())
    }

    /// The eviction candidate: among sessions that have decoded at least
    /// once since being (re)seated (the anti-thrash gate), the one with
    /// the latest request arrival. Sustained overload therefore cycles
    /// admissions at a bounded rate — every victim generated a token
    /// first — instead of thrashing prefill-only sessions through the
    /// swap pool.
    fn youngest_active_slot(&self) -> Option<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(i, _)| self.slot_decoded[*i])
            .filter_map(|(i, s)| s.as_ref().map(|sess| (i, sess.request.arrival)))
            .max_by_key(|&(i, arrival)| (arrival, i))
            .map(|(i, _)| i)
    }

    /// Reclaim up to `deficit` unreferenced cached prefix pages, LRU-first
    /// (no-op with the cache off or a zero deficit). Returns how many pool
    /// pages were freed. Dropping cache is strictly cheaper than
    /// preempting live work, so both blocked admission and blocked swap-in
    /// re-admission try this before anything heavier; refcount-0 only, so
    /// pages referenced by live or swapped sequences always survive.
    /// `exclude` pins extra pages — the blocked request's own matched
    /// prefix: evicting those shrinks its discount by exactly as much as
    /// it frees, so it can never reduce the deficit, only destroy cache.
    fn reclaim_prefix_cache(&mut self, deficit: usize, exclude: &[PageId]) -> Result<usize> {
        if deficit == 0 {
            return Ok(0);
        }
        if self.kv.store_is_node_scoped() {
            // node-store pages are charged to the NODE store's own budget,
            // not this replica's pool — evicting them frees no pool pages,
            // so admission pressure falls through to session eviction
            return Ok(0);
        }
        let Some(p) = self.prefix.as_mut() else {
            return Ok(0);
        };
        let kv = &self.kv;
        let freed = p.evict_lru(deficit, &|pid| {
            if exclude.contains(&pid) {
                return 1; // pinned: a net-zero eviction for the caller
            }
            kv.shared_page_refs(pid).unwrap_or(0)
        });
        for pid in &freed {
            self.kv.free_shared_page(*pid)?;
        }
        self.metrics.prefix_evictions += freed.len() as u64;
        Ok(freed.len())
    }

    /// Evict one active session: compressed cache → swap pool, session →
    /// preemption queue. No dequantization happens; the page pool gets the
    /// session's pages AND its admission reservation back.
    fn evict_slot(&mut self, slot: usize) -> Result<()> {
        // xtask-allow(no-panic-in-serving): every caller selects `slot` from the occupied set; evicting an empty slot is a scheduler bug that must fail loudly
        let mut sess = self.slots[slot].take().expect("evicting an empty slot");
        self.kv.swap_out(sess.request.id)?;
        sess.preemptions += 1;
        self.metrics.preemptions += 1;
        self.obs.record(
            EventKind::Preempt,
            sess.request.id,
            self.ticks,
            sess.cache_len() as u64,
        );
        self.preempted.push_back(sess);
        Ok(())
    }

    /// A prefill tick. Forms a batch; requests that can never fit the pool
    /// are finished immediately with `CacheFull` (no more head-of-line
    /// starvation). With prefix caching on, each candidate's longest
    /// cached prefix is matched here and its reservation charged only for
    /// the unshared remainder. When the queue head is blocked only by
    /// *current* memory pressure, unreferenced cached pages are reclaimed
    /// LRU-first (dropping cache is strictly cheaper than preempting live
    /// work), then active sessions are evicted youngest-first until it
    /// fits — every loop iteration seats new work, frees a cached page, or
    /// shrinks the active set, so this terminates.
    fn run_prefill(&mut self) -> Result<Action> {
        let mut evicted = false;
        loop {
            let free = self.free_slot_indices();
            if free.is_empty() {
                return Ok(if evicted { Action::Preempt } else { Action::Idle });
            }
            let tp = self.exec.serve().prefill_len;
            let tmax = self.exec.serve().tmax;
            let kv = &self.kv;
            let mut prefix = self.prefix.as_mut();
            // pages promised to requests admitted earlier in THIS pass —
            // the pool won't see their reservations until seat_prefill, so
            // the check must accumulate them or a jointly-over-capacity
            // batch would pass admission and fail its reservation later
            let mut batch_pages = 0usize;
            // longest cached prefix per examined request, matched ONCE here
            // and reused at seating so the admission discount and the
            // actual adoption can never disagree
            let mut matches: HashMap<u64, Vec<PageId>> = HashMap::new();
            let taken = self.batcher.take_batch(free.len(), |r| {
                let expected = expected_tokens(r.prompt.len(), r.max_new_tokens, tp, tmax);
                // capacity verdict deliberately ignores sharing: identical
                // accept/reject outcomes with the prefix cache on or off
                if !kv.fits_capacity(expected) {
                    return Admission::Reject;
                }
                let mut pages = kv.pages_for_tokens(expected);
                if let Some(p) = prefix.as_deref_mut() {
                    let plen = r.prompt.len().min(tp);
                    let mut shared = p.match_prefix(&r.prompt[..plen]);
                    // never adopt past the sequence bound (degenerate
                    // tmax < prefill_len configs clamp `expected` below
                    // the prompt) — keeps the discount subtraction and
                    // `new_seq_with_prefix`'s prefix<=expected check sound
                    shared.truncate(expected / kv.page_tokens());
                    pages -= shared.len(); // adopted pages are already charged
                    matches.insert(r.id, shared);
                }
                if kv.can_admit_pages(batch_pages + pages) {
                    batch_pages += pages;
                    Admission::Admit
                } else {
                    Admission::Defer
                }
            });
            // submit() already rejects capacity-impossible requests, but
            // keep the take_batch Reject arm as belt-and-braces (e.g. for
            // requests enqueued through a raw DynamicBatcher)
            for req in taken.rejected {
                self.reject_cache_full(req);
            }
            if !taken.admitted.is_empty() {
                self.seat_prefill(taken.admitted, &free, &mut matches)?;
                return Ok(Action::Prefill);
            }
            if self.batcher.pending() == 0 {
                // nothing admissible and nothing deferred: only rejects ran
                return Ok(if evicted { Action::Preempt } else { Action::Idle });
            }
            // head deferred on memory pressure: reclaim cache, then evict
            // eligible victims until its pages fit, THEN retry the batch
            // pass — a single deferral count per blocked tick
            let (head_id, head_pages) = {
                // xtask-allow(no-panic-in-serving): guarded by the `pending > 0` branch this block sits in
                let head = self.batcher.peek().expect("pending > 0");
                let full = self.kv.pages_for_tokens(expected_tokens(
                    head.prompt.len(),
                    head.max_new_tokens,
                    tp,
                    tmax,
                ));
                // the head's own matched pages stay charged to the store
                let matched = matches.get(&head.id).map_or(0, Vec::len);
                (head.id, full.saturating_sub(matched))
            };
            let head_matched = matches.get(&head_id).map(Vec::as_slice).unwrap_or(&[]);
            let deficit = self.kv.admit_deficit(head_pages);
            if self.reclaim_prefix_cache(deficit, head_matched)? > 0 {
                continue; // retry the pass with the reclaimed room
            }
            while !self.kv.can_admit_pages(head_pages) {
                match self.youngest_active_slot() {
                    Some(victim) => {
                        self.evict_slot(victim)?;
                        evicted = true;
                    }
                    None => {
                        // nothing (more) evictable; the head waits for
                        // running sessions to finish or decode first
                        return Ok(if evicted { Action::Preempt } else { Action::Idle });
                    }
                }
            }
        }
    }

    /// Seat an admitted batch. Monolithic mode runs the prefill HLO and
    /// seats sessions with their first token sampled; chunked mode only
    /// creates the kv sequences and seats the sessions mid-prefill — the
    /// tick planner feeds them their prompt in chunks. `matches` carries
    /// each request's longest cached prefix from the admission pass
    /// (always empty with prefix caching off): matched pages are adopted —
    /// refcounts bumped, zero bytes copied — and only the suffix tokens
    /// are prefilled and appended.
    fn seat_prefill(
        &mut self,
        reqs: Vec<Request>,
        free: &[usize],
        matches: &mut HashMap<u64, Vec<PageId>>,
    ) -> Result<()> {
        if self.chunked {
            return self.seat_chunked(reqs, free, matches);
        }
        let tp = self.exec.serve().prefill_len;
        let tmax = self.exec.serve().tmax;
        let b_total = self.slots.len();
        // Admission FIRST, model work second: every kv sequence is created
        // (adopting what the shared store can actually lease NOW) before a
        // single token runs through the backend, so the per-lane prefix
        // lengths below reflect the ACTUAL adoption. Against a node-scoped
        // store the admission pass's match is only a quote — another
        // replica may have evicted matched pages since — and feeding the
        // stale count to `run_prefill_suffix` would skip KV emission for
        // positions nothing adopted, a silent hole in the cache. A
        // truncated adoption whose re-priced reservation no longer fits
        // requeues its request at the queue head instead.
        let mut seated: Vec<(Request, usize)> = Vec::with_capacity(reqs.len());
        let mut requeue: Vec<Request> = Vec::new();
        for req in reqs {
            let expected = expected_tokens(req.prompt.len(), req.max_new_tokens, tp, tmax);
            let shared = matches.remove(&req.id).unwrap_or_default();
            match self.admit_seq(req.id, expected, &shared)? {
                Some(shared_tokens) => seated.push((req, shared_tokens)),
                None => requeue.push(req),
            }
        }
        for req in requeue.into_iter().rev() {
            self.metrics.prefix_adopt_requeues += 1;
            self.batcher.requeue_front(req);
        }
        if seated.is_empty() {
            return Ok(());
        }
        let mut tokens = vec![PAD; b_total * tp];
        let mut lengths = vec![1i32; b_total]; // dummy lanes: len 1
        let mut prefix_lens = vec![0usize; b_total];
        for (lane, (req, shared_tokens)) in seated.iter().enumerate() {
            let plen = req.prompt.len().min(tp);
            tokens[lane * tp..lane * tp + plen].copy_from_slice(&req.prompt[..plen]);
            lengths[lane] = plen as i32;
            prefix_lens[lane] = *shared_tokens;
        }
        let out = if self.prefix.is_some() {
            // cached positions skip KV emission in the backend
            self.exec
                .run_prefill_suffix(&tokens, &lengths, &prefix_lens, &self.quant)?
        } else {
            self.exec.run_prefill(&tokens, &lengths, &self.quant)?
        };
        self.metrics.prefill_batches += 1;

        let (b_n, h_n, half) = (
            b_total,
            self.exec.profile().n_kv_heads,
            self.exec.profile().d_head / 2,
        );
        let vocab = self.exec.profile().vocab;
        for (lane, (req, shared_tokens)) in seated.into_iter().enumerate() {
            let plen = req.prompt.len().min(tp);
            // pack the SUFFIX tokens' compressed entries: positions below
            // `shared_tokens` are already resident in the adopted pages.
            // One strided append per token covers every (layer, head) at
            // once (kv_manager fans layers out across rayon when
            // worthwhile).
            for t in shared_tokens..plen {
                self.kv.append_token_strided(
                    req.id,
                    &out.kr,
                    &out.ki,
                    &out.vr,
                    &out.vi,
                    (lane * h_n * tp + t) * half,
                    b_n * h_n * tp * half,
                    tp * half,
                )?;
                self.kv.commit_token(req.id)?;
            }
            // first generated token from the prefill logits
            let logits = &out.logits[lane * vocab..(lane + 1) * vocab];
            let tok = argmax(logits);
            let mut sess = Session::new(req, plen);
            sess.push_token(tok, EOS, tmax);
            self.metrics
                .ttft
                .record(Instant::now().duration_since(sess.request.arrival));
            self.obs
                .record(EventKind::FirstToken, sess.request.id, self.ticks, 0);
            if sess.finished.is_some() {
                // finished on its very first token (EOS, or max_new_tokens
                // == 1): retire now instead of burning a decode step
                self.finish_kv(&sess)?;
                self.retire(sess);
                continue;
            }
            let slot = free[lane];
            self.slot_filled[slot] = 0; // new sequence: full refill needed
            self.slot_decoded[slot] = false; // evictable only after a decode
            self.slots[slot] = Some(sess);
        }
        Ok(())
    }

    /// Chunked-mode seating: create each request's kv sequence (adopting
    /// its matched prefix pages, which advances the cursor past them) and
    /// seat the session mid-prefill. No model work happens here — the
    /// same tick's planner grants the first chunk.
    fn seat_chunked(
        &mut self,
        reqs: Vec<Request>,
        free: &[usize],
        matches: &mut HashMap<u64, Vec<PageId>>,
    ) -> Result<()> {
        let tp = self.exec.serve().prefill_len;
        let tmax = self.exec.serve().tmax;
        let mut lane = 0usize;
        let mut requeue: Vec<Request> = Vec::new();
        for req in reqs {
            let plen = req.prompt.len().min(tp);
            let expected = expected_tokens(req.prompt.len(), req.max_new_tokens, tp, tmax);
            let shared = matches.remove(&req.id).unwrap_or_default();
            // same node-store race as monolithic seating: adoption can
            // truncate, and a reservation the truncation re-priced past
            // the pool requeues the request instead of seating it
            let Some(shared_tokens) = self.admit_seq(req.id, expected, &shared)? else {
                requeue.push(req);
                continue;
            };
            let sess = Session::new_prefilling(req, plen, shared_tokens.min(plen));
            let slot = free[lane];
            lane += 1;
            self.slot_filled[slot] = 0; // new sequence: full refill needed
            self.slot_decoded[slot] = false; // evictable once it progresses
            self.slots[slot] = Some(sess);
        }
        for req in requeue.into_iter().rev() {
            self.metrics.prefix_adopt_requeues += 1;
            self.batcher.requeue_front(req);
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        let b_total = self.slots.len();
        let mut token = vec![0i32; b_total];
        let mut pos = vec![0i32; b_total];
        let mut any = false;
        let t_coord = Instant::now();
        for (b, slot) in self.slots.iter().enumerate() {
            if let Some(sess) = slot {
                if !sess.decode_ready() {
                    continue; // mid-prefill (chunked): not a decode lane
                }
                any = true;
                // xtask-allow(no-panic-in-serving): `decode_ready()` requires a sampled token (prefill seeds one before any decode step)
                token[b] = *sess.generated.last().expect("decode-ready session has a token");
                pos[b] = (sess.cache_len() - 1) as i32;
                // fused path: no dense buffers to keep warm — the backend
                // reads compressed pages directly during the decode call
                if !self.fused {
                    let filled = self.kv.fill_dense_range(
                        sess.request.id,
                        b,
                        b_total,
                        self.slot_filled[b],
                        &mut self.kr,
                        &mut self.ki,
                        &mut self.vr,
                        &mut self.vi,
                    )?;
                    self.slot_filled[b] = filled;
                }
            }
        }
        if !any {
            return Ok(());
        }
        let coord_prep = t_coord.elapsed();
        let t0 = Instant::now();
        let out = if self.fused {
            // mid-prefill sessions are not decode lanes: mask them out so
            // the fused reader skips their (partial) caches entirely
            let lanes: Vec<Option<u64>> = self
                .slots
                .iter()
                .map(|s| {
                    s.as_ref()
                        .filter(|sess| sess.decode_ready())
                        .map(|sess| sess.request.id)
                })
                .collect();
            let mut reader = BatchTileReader {
                kv: &self.kv,
                lanes: &lanes,
                scratch: &mut self.tile_scratch,
            };
            self.exec
                .run_decode_fused(&token, &pos, &self.quant, &mut reader)?
        } else {
            self.exec.run_decode(
                &token, &pos, &self.quant, &self.kr, &self.ki, &self.vr, &self.vi,
            )?
        };
        let step_dur = t0.elapsed();
        self.metrics.decode_step_latency.record(step_dur);
        self.metrics.decode_steps += 1;
        self.metrics.decode_slot_steps += b_total as u64;

        let t_post = Instant::now();
        let (h_n, half) = (
            self.exec.profile().n_kv_heads,
            self.exec.profile().d_head / 2,
        );
        let vocab = self.exec.profile().vocab;
        let tmax = self.exec.serve().tmax;
        for b in 0..b_total {
            let Some(sess) = self.slots[b].as_mut() else {
                continue;
            };
            if !sess.decode_ready() {
                continue; // mid-prefill lane: the step never touched it
            }
            self.slot_decoded[b] = true;
            self.obs.record_span(
                EventKind::DecodeStep,
                sess.request.id,
                self.ticks,
                step_dur,
                sess.generated.len() as u64,
            );
            // append the *processed* token's compressed KV across all
            // (layer, head) pairs in one batched call
            self.kv.append_token_strided(
                sess.request.id,
                &out.kr,
                &out.ki,
                &out.vr,
                &out.vi,
                b * h_n * half,
                b_total * h_n * half,
                half,
            )?;
            self.kv.commit_token(sess.request.id)?;
            let tok = argmax(&out.logits[b * vocab..(b + 1) * vocab]);
            let prev_token_at = sess.last_token_at;
            sess.push_token(tok, EOS, tmax);
            if let Some(prev) = prev_token_at {
                self.metrics
                    .itl
                    .record(Instant::now().duration_since(prev));
            }
            self.metrics.tokens_generated += 1;
            if sess.finished.is_some() {
                // xtask-allow(no-panic-in-serving): `sess` above is a borrow of this slot's contents, so the slot is occupied
                let sess = self.slots[b].take().expect("finished session occupies its slot");
                self.finish_kv(&sess)?;
                self.retire(sess);
            }
        }
        self.metrics
            .coordinator_overhead
            .record(coord_prep + t_post.elapsed());
        Ok(())
    }
}

impl<B: ModelBackend> EngineCore for Engine<B> {
    fn submit(&mut self, req: Request) {
        Engine::submit(self, req)
    }

    fn tick(&mut self) -> Result<Action> {
        Engine::tick(self)
    }

    fn take_finished(&mut self) -> Vec<Session> {
        Engine::take_finished(self)
    }

    fn memory_stats(&self) -> MemoryStats {
        Engine::memory_stats(self)
    }

    fn page_tokens(&self) -> usize {
        self.kv.page_tokens()
    }

    fn load(&self) -> usize {
        self.batcher.pending() + self.active_sessions() + self.preempted.len()
    }

    fn has_work(&self) -> bool {
        Engine::has_work(self)
    }

    fn metrics(&self) -> EngineMetrics {
        self.metrics.clone()
    }

    fn obs_snapshot(&self) -> ObsSnapshot {
        Engine::obs_snapshot(self)
    }
}

/// Worst-case cache tokens a request can consume: prompt truncated to the
/// prefill window, plus its full generation budget, capped at tmax. The
/// SINGLE formula behind admission verdicts and page reservations — they
/// must never disagree, or admission re-opens the over-admission hole.
fn expected_tokens(prompt_len: usize, max_new: usize, prefill_len: usize, tmax: usize) -> usize {
    (prompt_len.min(prefill_len) + max_new).min(tmax)
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
