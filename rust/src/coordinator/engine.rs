//! The serving engine: continuous batching over a compressed KV cache.
//!
//! One tick = one scheduler action:
//!   * Prefill — batcher-formed prompt batch → prefill HLO → compressed
//!     entries packed into the kv_manager, sessions seated in slots.
//!   * Decode — active slots' caches reinflated (norm dequant + angle
//!     unpack) into the dense HLO inputs, one fused decode step, new
//!     tokens sampled greedily, new compressed entries appended.
//!
//! Python is never involved; the HLOs were lowered at build time.

use super::batcher::{BatchPolicy, DynamicBatcher};
use super::kv_manager::{MemoryStats, PagedKvCache};
use super::metrics::EngineMetrics;
use super::scheduler::{next_action, Action, SchedulerPolicy};
use super::session::{Request, Session};
use crate::quant::QuantConfig;
use crate::runtime::ModelExecutor;
use anyhow::Result;
use std::time::Instant;

pub const PAD: i32 = 258;
pub const EOS: i32 = 257;

pub struct EngineConfig {
    pub quant: QuantConfig,
    pub batch_policy: BatchPolicy,
    pub scheduler: SchedulerPolicy,
    /// kv pool capacity in pages of `page_tokens`
    pub capacity_pages: usize,
    pub page_tokens: usize,
}

pub struct Engine {
    pub exec: ModelExecutor,
    pub kv: PagedKvCache,
    pub batcher: DynamicBatcher,
    pub scheduler: SchedulerPolicy,
    pub metrics: EngineMetrics,
    pub quant: QuantConfig,
    slots: Vec<Option<Session>>,
    // reusable dense cache buffers (L,B,H,Tmax,d/2)
    kr: Vec<f32>,
    ki: Vec<f32>,
    vr: Vec<f32>,
    vi: Vec<f32>,
    /// tokens already reinflated into the dense buffers, per slot — the
    /// incremental fill keeps per-step coordinator cost O(1) in seq length
    slot_filled: Vec<usize>,
    finished: Vec<Session>,
}

impl Engine {
    pub fn new(exec: ModelExecutor, cfg: EngineConfig) -> Self {
        let (l, b, h, tmax, half) = exec.cache_dims();
        let n = l * b * h * tmax * half;
        let kv = PagedKvCache::new(
            cfg.quant.clone(),
            l,
            h,
            exec.profile.d_head,
            tmax,
            cfg.capacity_pages,
            cfg.page_tokens,
        );
        Engine {
            exec,
            kv,
            batcher: DynamicBatcher::new(cfg.batch_policy),
            scheduler: cfg.scheduler,
            metrics: EngineMetrics::default(),
            quant: cfg.quant,
            slots: (0..b).map(|_| None).collect(),
            slot_filled: vec![0; b],
            kr: vec![0.0; n],
            ki: vec![0.0; n],
            vr: vec![0.0; n],
            vi: vec![0.0; n],
            finished: Vec::new(),
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.metrics.requests_submitted += 1;
        self.batcher.submit(req);
    }

    pub fn active_sessions(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn has_work(&self) -> bool {
        self.batcher.pending() > 0 || self.active_sessions() > 0
    }

    /// Drain finished sessions accumulated since the last call.
    pub fn take_finished(&mut self) -> Vec<Session> {
        std::mem::take(&mut self.finished)
    }

    pub fn memory_stats(&self) -> MemoryStats {
        self.kv.memory_stats()
    }

    /// One scheduler tick. Returns the action taken.
    pub fn tick(&mut self) -> Result<Action> {
        let action = next_action(
            &self.scheduler,
            &self.batcher,
            self.active_sessions(),
            self.slots.len(),
            Instant::now(),
        );
        match action {
            Action::Prefill => self.run_prefill()?,
            Action::Decode => self.run_decode()?,
            Action::Idle => {}
        }
        Ok(action)
    }

    /// Run ticks until queue and slots drain.
    pub fn run_to_completion(&mut self) -> Result<()> {
        while self.has_work() {
            self.tick()?;
        }
        Ok(())
    }

    fn free_slot_indices(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_none())
            .map(|(i, _)| i)
            .collect()
    }

    fn run_prefill(&mut self) -> Result<()> {
        let free = self.free_slot_indices();
        let tp = self.exec.serve.prefill_len;
        let tmax = self.exec.serve.tmax;
        let kv = &self.kv;
        let reqs = self.batcher.take_batch(free.len(), |r| {
            kv.can_admit(r.prompt.len().min(tp) + r.max_new_tokens)
        });
        if reqs.is_empty() {
            return Ok(());
        }
        let b_total = self.slots.len();
        let mut tokens = vec![PAD; b_total * tp];
        let mut lengths = vec![1i32; b_total]; // dummy lanes: len 1
        for (lane, req) in reqs.iter().enumerate() {
            let plen = req.prompt.len().min(tp);
            tokens[lane * tp..lane * tp + plen].copy_from_slice(&req.prompt[..plen]);
            lengths[lane] = plen as i32;
        }
        let out = self.exec.run_prefill(&tokens, &lengths, &self.quant)?;
        self.metrics.prefill_batches += 1;

        let (b_n, h_n, half) = (
            b_total,
            self.exec.profile.n_kv_heads,
            self.exec.profile.d_head / 2,
        );
        let vocab = self.exec.profile.vocab;
        for (lane, req) in reqs.into_iter().enumerate() {
            let plen = req.prompt.len().min(tp);
            self.kv.new_seq(req.id)?;
            // pack the prompt's compressed entries: only t < plen. One
            // strided append per token covers every (layer, head) at once
            // (kv_manager fans layers out across rayon when worthwhile).
            for t in 0..plen {
                self.kv.append_token_strided(
                    req.id,
                    &out.kr,
                    &out.ki,
                    &out.vr,
                    &out.vi,
                    (lane * h_n * tp + t) * half,
                    b_n * h_n * tp * half,
                    tp * half,
                )?;
                self.kv.commit_token(req.id)?;
            }
            self.metrics.prefill_sequences += 1;
            // first generated token from the prefill logits
            let logits = &out.logits[lane * vocab..(lane + 1) * vocab];
            let tok = argmax(logits);
            let mut sess = Session::new(req, plen);
            sess.push_token(tok, EOS, tmax);
            self.metrics
                .ttft
                .record(Instant::now().duration_since(sess.request.arrival));
            let slot = free[lane];
            self.slot_filled[slot] = 0; // new sequence: full refill needed
            self.slots[slot] = Some(sess);
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        let b_total = self.slots.len();
        let mut token = vec![0i32; b_total];
        let mut pos = vec![0i32; b_total];
        let mut any = false;
        let t_coord = Instant::now();
        for (b, slot) in self.slots.iter().enumerate() {
            if let Some(sess) = slot {
                any = true;
                token[b] = *sess.generated.last().expect("session has a token");
                pos[b] = (sess.cache_len() - 1) as i32;
                let filled = self.kv.fill_dense_range(
                    sess.request.id,
                    b,
                    b_total,
                    self.slot_filled[b],
                    &mut self.kr,
                    &mut self.ki,
                    &mut self.vr,
                    &mut self.vi,
                )?;
                self.slot_filled[b] = filled;
            }
        }
        if !any {
            return Ok(());
        }
        let coord_prep = t_coord.elapsed();
        let t0 = Instant::now();
        let out = self.exec.run_decode(
            &token, &pos, &self.quant, &self.kr, &self.ki, &self.vr, &self.vi,
        )?;
        self.metrics.decode_step_latency.record(t0.elapsed());
        self.metrics.decode_steps += 1;
        self.metrics.decode_slot_steps += b_total as u64;

        let t_post = Instant::now();
        let (h_n, half) = (self.exec.profile.n_kv_heads, self.exec.profile.d_head / 2);
        let vocab = self.exec.profile.vocab;
        let tmax = self.exec.serve.tmax;
        for b in 0..b_total {
            let Some(sess) = self.slots[b].as_mut() else {
                continue;
            };
            // append the *processed* token's compressed KV across all
            // (layer, head) pairs in one batched call
            self.kv.append_token_strided(
                sess.request.id,
                &out.kr,
                &out.ki,
                &out.vr,
                &out.vi,
                b * h_n * half,
                b_total * h_n * half,
                half,
            )?;
            self.kv.commit_token(sess.request.id)?;
            let tok = argmax(&out.logits[b * vocab..(b + 1) * vocab]);
            sess.push_token(tok, EOS, tmax);
            self.metrics.tokens_generated += 1;
            if sess.finished.is_some() {
                let sess = self.slots[b].take().unwrap();
                self.kv.free_seq(sess.request.id);
                self.metrics
                    .e2e
                    .record(Instant::now().duration_since(sess.request.arrival));
                self.metrics.requests_finished += 1;
                self.finished.push(sess);
            }
        }
        self.metrics
            .coordinator_overhead
            .record(coord_prep + t_post.elapsed());
        Ok(())
    }
}

fn argmax(xs: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::argmax;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
