//! Prefill/decode interleaving policy.
//!
//! Decode-priority with prefill admission gates (the Orca/vLLM-style
//! tradeoff): decode ticks keep inter-token latency low; prefills run when
//! the batcher says a worthwhile batch exists or slots idle. Pure function
//! of observable state — trivially testable.

use super::batcher::DynamicBatcher;
use std::time::Instant;

/// What one engine tick did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Prompt work ran: a batch was prefilled and seated (monolithic), or
    /// sessions were seated / prefill chunks appended (chunked mode) with
    /// no decode lanes active.
    Prefill,
    /// A decode step advanced the active sessions.
    Decode,
    /// A prefill tick that evicted active sessions (compressed-cache
    /// swap-out) to make room instead of seating new work. `next_action`
    /// never chooses this directly — the engine reports it when a
    /// `Prefill` tick turned into eviction under memory pressure.
    Preempt,
    /// A chunked-prefill tick that interleaved BOTH decode lanes and
    /// prefill chunks under the token budget. Only the engine's chunked
    /// planner produces this; `next_action` never does.
    Mixed,
    /// Nothing to do (or the batcher is waiting out its batching window).
    Idle,
}

/// Knobs for the monolithic prefill/decode interleave decision. The
/// chunked-prefill planner (`EngineConfig::chunked_prefill`) replaces this
/// whole tradeoff with a per-tick token budget and ignores these knobs.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerPolicy {
    /// prefer decode unless at least this fraction of slots are free
    pub prefill_free_frac: f64,
}

impl Default for SchedulerPolicy {
    fn default() -> Self {
        SchedulerPolicy {
            prefill_free_frac: 0.5,
        }
    }
}

/// Decide the next monolithic tick action from observable state:
/// decode-priority with a prefill admission gate at
/// [`SchedulerPolicy::prefill_free_frac`] free slots.
pub fn next_action(
    policy: &SchedulerPolicy,
    batcher: &DynamicBatcher,
    active_sessions: usize,
    total_slots: usize,
    now: Instant,
) -> Action {
    let free = total_slots - active_sessions;
    let want_prefill = batcher.should_prefill(free, now);
    if want_prefill {
        // run prefill if decode is idle, or enough capacity sits free
        if active_sessions == 0
            || (free as f64) / (total_slots as f64) >= policy.prefill_free_frac
        {
            return Action::Prefill;
        }
    }
    if active_sessions > 0 {
        return Action::Decode;
    }
    if want_prefill {
        return Action::Prefill;
    }
    Action::Idle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::{BatchPolicy, DynamicBatcher};
    use crate::coordinator::session::Request;
    use std::time::Duration;

    fn loaded_batcher(n: usize) -> DynamicBatcher {
        let mut b = DynamicBatcher::new(BatchPolicy {
            min_batch: 1,
            max_wait: Duration::ZERO,
        });
        for i in 0..n {
            b.submit(Request::new(i as u64, vec![1], 4));
        }
        b
    }

    #[test]
    fn idle_when_nothing() {
        let b = loaded_batcher(0);
        assert_eq!(
            next_action(&SchedulerPolicy::default(), &b, 0, 4, Instant::now()),
            Action::Idle
        );
    }

    #[test]
    fn prefill_when_empty_and_pending() {
        let b = loaded_batcher(2);
        assert_eq!(
            next_action(&SchedulerPolicy::default(), &b, 0, 4, Instant::now()),
            Action::Prefill
        );
    }

    #[test]
    fn decode_priority_when_mostly_busy() {
        let b = loaded_batcher(2);
        // 3 of 4 slots busy -> free frac 0.25 < 0.5 -> decode first
        assert_eq!(
            next_action(&SchedulerPolicy::default(), &b, 3, 4, Instant::now()),
            Action::Decode
        );
    }

    #[test]
    fn prefill_when_half_free() {
        let b = loaded_batcher(2);
        assert_eq!(
            next_action(&SchedulerPolicy::default(), &b, 2, 4, Instant::now()),
            Action::Prefill
        );
    }

    #[test]
    fn decode_when_no_pending() {
        let b = loaded_batcher(0);
        assert_eq!(
            next_action(&SchedulerPolicy::default(), &b, 2, 4, Instant::now()),
            Action::Decode
        );
    }
}
