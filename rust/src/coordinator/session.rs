//! Request and generation-session state.

use std::time::Instant;

/// An inference request as submitted to the engine.
#[derive(Clone, Debug)]
pub struct Request {
    /// Engine-side request id (unique per engine; the TCP front-end maps
    /// wire ids onto these).
    pub id: u64,
    /// Prompt token ids (byte-level in the sim/TCP paths).
    pub prompt: Vec<i32>,
    /// Generation budget: the session finishes with
    /// [`FinishReason::Length`] once this many tokens were produced.
    pub max_new_tokens: usize,
    /// Submission timestamp — the zero point of the TTFT and end-to-end
    /// latency histograms.
    pub arrival: Instant,
    /// Router affinity key (multi-turn conversations set it so follow-ups
    /// land on the replica that may still hold their prefix).
    pub session_key: Option<u64>,
}

impl Request {
    /// A request arriving now with no session affinity.
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            arrival: Instant::now(),
            session_key: None,
        }
    }

    /// Attach a router affinity key (builder-style).
    pub fn with_session_key(mut self, key: u64) -> Self {
        self.session_key = Some(key);
        self
    }
}

/// Why a session stopped generating.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit its `max_new_tokens` budget.
    Length,
    /// Produced the end-of-sequence token.
    Eos,
    /// The cache cannot hold it: either rejected at admission (it could
    /// never fit the page pool) or it reached the model's `tmax` bound.
    CacheFull,
}

/// A running generation (occupies one batch slot, or the preemption queue
/// while its compressed cache sits in the swap pool).
#[derive(Debug)]
pub struct Session {
    /// The request this session serves.
    pub request: Request,
    /// Prompt length after truncation to the model's prefill window.
    pub prompt_len: usize,
    /// Greedily decoded tokens so far.
    pub generated: Vec<i32>,
    /// When the first token was produced (TTFT endpoint).
    pub first_token_at: Option<Instant>,
    /// When the most recent token was produced — consecutive values are
    /// one inter-token-latency (ITL) sample apart.
    pub last_token_at: Option<Instant>,
    /// Set once the session stops generating.
    pub finished: Option<FinishReason>,
    /// How many times this session was swapped out under memory pressure.
    pub preemptions: u32,
    /// Prompt tokens whose compressed KV is committed to the cache.
    /// Monolithic prefill commits the whole prompt at seat time; chunked
    /// prefill advances this cursor chunk by chunk (and prefix-cache
    /// adoption starts it past the adopted pages). Survives preemption, so
    /// a half-prefilled session resumes exactly where it left off.
    pub prefill_cursor: usize,
}

impl Session {
    /// A session whose prompt is fully prefilled (the monolithic path —
    /// the engine seats it with its first token already sampled).
    pub fn new(request: Request, prompt_len: usize) -> Self {
        Self::new_prefilling(request, prompt_len, prompt_len)
    }

    /// A session seated with only `prefill_cursor` prompt tokens committed
    /// (adopted prefix pages); the chunked-prefill planner feeds it the
    /// rest of the prompt across subsequent ticks.
    pub fn new_prefilling(request: Request, prompt_len: usize, prefill_cursor: usize) -> Self {
        Session {
            request,
            prompt_len,
            generated: Vec::new(),
            first_token_at: None,
            last_token_at: None,
            finished: None,
            preemptions: 0,
            prefill_cursor,
        }
    }

    /// Total cache length = prompt + generated (the decode `pos`).
    pub fn cache_len(&self) -> usize {
        self.prompt_len + self.generated.len()
    }

    /// Whether the whole prompt's KV is committed to the cache.
    pub fn prefill_done(&self) -> bool {
        self.prefill_cursor >= self.prompt_len
    }

    /// Whether this session is a decode lane: prefill complete AND the
    /// first token sampled (every generated token implies a committed
    /// prompt, so this is the single readiness predicate both the chunked
    /// planner and `run_decode` use).
    pub fn decode_ready(&self) -> bool {
        !self.generated.is_empty()
    }

    /// Record one generated token and update the finish state: `eos` ends
    /// the stream, `max_new_tokens` bounds it, and reaching the model's
    /// `tmax` cache bound finishes it with [`FinishReason::CacheFull`].
    pub fn push_token(&mut self, tok: i32, eos: i32, tmax: usize) {
        let now = Instant::now();
        if self.first_token_at.is_none() {
            self.first_token_at = Some(now);
        }
        self.last_token_at = Some(now);
        self.generated.push(tok);
        if tok == eos {
            self.finished = Some(FinishReason::Eos);
        } else if self.generated.len() >= self.request.max_new_tokens {
            self.finished = Some(FinishReason::Length);
        } else if self.cache_len() >= tmax {
            self.finished = Some(FinishReason::CacheFull);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finishes_on_length() {
        let mut s = Session::new(Request::new(1, vec![1, 2], 3), 2);
        assert!(s.prefill_done());
        assert!(!s.decode_ready());
        for t in 0..3 {
            s.push_token(t, 257, 100);
        }
        assert!(s.decode_ready());
        assert_eq!(s.finished, Some(FinishReason::Length));
        assert_eq!(s.cache_len(), 5);
    }

    #[test]
    fn finishes_on_eos() {
        let mut s = Session::new(Request::new(1, vec![1], 10), 1);
        s.push_token(257, 257, 100);
        assert_eq!(s.finished, Some(FinishReason::Eos));
    }

    #[test]
    fn finishes_on_cache_full() {
        let mut s = Session::new(Request::new(1, vec![1, 2, 3], 10), 3);
        s.push_token(5, 257, 5);
        s.push_token(6, 257, 5);
        assert_eq!(s.finished, Some(FinishReason::CacheFull));
    }

    #[test]
    fn prefilling_session_tracks_cursor() {
        let mut s = Session::new_prefilling(Request::new(1, vec![1; 8], 4), 8, 2);
        assert!(!s.prefill_done());
        assert!(!s.decode_ready());
        s.prefill_cursor = 8;
        assert!(s.prefill_done());
        assert!(!s.decode_ready(), "ready only once the first token lands");
        s.push_token(7, 257, 100);
        assert!(s.decode_ready());
        assert!(s.last_token_at.is_some());
    }
}
