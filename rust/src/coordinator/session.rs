//! Request and generation-session state.

use std::time::Instant;

/// An inference request as submitted to the engine.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub arrival: Instant,
    /// Router affinity key (multi-turn conversations set it so follow-ups
    /// land on the replica that may still hold their prefix).
    pub session_key: Option<u64>,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Self {
        Request {
            id,
            prompt,
            max_new_tokens,
            arrival: Instant::now(),
            session_key: None,
        }
    }

    pub fn with_session_key(mut self, key: u64) -> Self {
        self.session_key = Some(key);
        self
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Length,
    Eos,
    CacheFull,
}

/// A running generation (occupies one batch slot, or the preemption queue
/// while its compressed cache sits in the swap pool).
#[derive(Debug)]
pub struct Session {
    pub request: Request,
    pub prompt_len: usize,
    pub generated: Vec<i32>,
    pub first_token_at: Option<Instant>,
    pub finished: Option<FinishReason>,
    /// How many times this session was swapped out under memory pressure.
    pub preemptions: u32,
}

impl Session {
    pub fn new(request: Request, prompt_len: usize) -> Self {
        Session {
            request,
            prompt_len,
            generated: Vec::new(),
            first_token_at: None,
            finished: None,
            preemptions: 0,
        }
    }

    /// Total cache length = prompt + generated (the decode `pos`).
    pub fn cache_len(&self) -> usize {
        self.prompt_len + self.generated.len()
    }

    pub fn push_token(&mut self, tok: i32, eos: i32, tmax: usize) {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        self.generated.push(tok);
        if tok == eos {
            self.finished = Some(FinishReason::Eos);
        } else if self.generated.len() >= self.request.max_new_tokens {
            self.finished = Some(FinishReason::Length);
        } else if self.cache_len() >= tmax {
            self.finished = Some(FinishReason::CacheFull);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finishes_on_length() {
        let mut s = Session::new(Request::new(1, vec![1, 2], 3), 2);
        for t in 0..3 {
            s.push_token(t, 257, 100);
        }
        assert_eq!(s.finished, Some(FinishReason::Length));
        assert_eq!(s.cache_len(), 5);
    }

    #[test]
    fn finishes_on_eos() {
        let mut s = Session::new(Request::new(1, vec![1], 10), 1);
        s.push_token(257, 257, 100);
        assert_eq!(s.finished, Some(FinishReason::Eos));
    }

    #[test]
    fn finishes_on_cache_full() {
        let mut s = Session::new(Request::new(1, vec![1, 2, 3], 10), 3);
        s.push_token(5, 257, 5);
        s.push_token(6, 257, 5);
        assert_eq!(s.finished, Some(FinishReason::CacheFull));
    }
}
