//! The serving coordinator — the paper-as-a-system: a multi-replica
//! vLLM-router-style serving stack whose resident KV cache is
//! TurboAngle-compressed.
//!
//! * [`kv_manager`] — paged compressed cache (bit-packed angles + quantized
//!   norms) chunked into immutable, content-addressed, refcounted page
//!   blocks; reservation-aware block allocator, swap pool for preempted
//!   sequences, memory accounting, and the fused read path's page-tile
//!   iterator (`visit_seq_tiles` / `decode_tile_into` + `TileScratch`)
//! * [`prefix_cache`] — token-level radix tree mapping prompt prefixes to
//!   runs of shared compressed pages, with refcount-aware LRU eviction
//! * [`batcher`] / [`scheduler`] — dynamic batching and prefill/decode
//!   interleave, with terminal `CacheFull` rejection of impossible requests
//! * [`router`] — replica routing policies (round-robin, least-loaded,
//!   consistent-hash session affinity, and prefix-fingerprint routing
//!   with an imbalance-bounded least-loaded fallback)
//! * [`engine`] — the tick loop gluing slots, cache, and the AOT programs;
//!   [`engine::EngineCore`] is the object-safe replica surface, and the
//!   engine is generic over [`crate::runtime::ModelBackend`]
//! * [`server`] — line-delimited-JSON TCP front-end dispatching through the
//!   router into N replica worker threads
//! * [`metrics`] — latency histograms and counters (incl. preemption/swap)

pub mod batcher;
pub mod engine;
pub mod kv_manager;
pub mod metrics;
pub mod prefix_cache;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;

pub use batcher::{Admission, BatchPolicy, DynamicBatcher, TakenBatch};
pub use engine::{Engine, EngineConfig, EngineCore, ReadPath};
pub use kv_manager::{
    BatchTileReader, MemoryStats, PageId, PagedKvCache, SharedPageStore, TileScratch,
};
pub use metrics::{EngineMetrics, Histogram};
pub use prefix_cache::PrefixCache;
pub use router::{hash_session_key, prefix_fingerprint, RoutePolicy, Router};
pub use scheduler::SchedulerPolicy;
pub use session::{FinishReason, Request, Session};
