//! The serving coordinator — the paper-as-a-system: a vLLM-router-style
//! engine whose resident KV cache is TurboAngle-compressed.
//!
//! * [`kv_manager`] — paged compressed cache (bit-packed angles + quantized
//!   norms), block allocator, memory accounting
//! * [`batcher`] / [`scheduler`] — dynamic batching and prefill/decode
//!   interleave
//! * [`router`] — replica routing policies
//! * [`engine`] — the tick loop gluing slots, cache, and the AOT programs
//! * [`metrics`] — latency histograms and counters

pub mod batcher;
pub mod engine;
pub mod kv_manager;
pub mod metrics;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use engine::{Engine, EngineConfig};
pub use kv_manager::PagedKvCache;
pub use router::{RoutePolicy, Router};
pub use scheduler::SchedulerPolicy;
pub use session::{FinishReason, Request, Session};
