//! Serving metrics: counters + log-bucketed latency histograms.
//!
//! The tail-latency accounting lives here: TTFT (time to first token), ITL
//! (inter-token latency), end-to-end request latency and per-step decode
//! latency are all [`Histogram`]s with p50/p95/p99 quantiles, surfaced
//! through [`EngineMetrics::report`] (human), [`EngineMetrics::to_json`]
//! (the wire `stats` response), and `BENCH_serving_latency.json`.

use std::time::Duration;

/// Log-scale histogram from 1µs to ~17s (doubling buckets).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    buckets: [u64; 25],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Histogram {
    /// Record one sample (clamped to >= 1µs).
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(24);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded samples in microseconds (saturating under merge).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Largest recorded sample in microseconds (zero when empty).
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Arithmetic mean of the recorded samples (zero when empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    /// Approximate quantile from bucket upper edges, clamped to the largest
    /// observed sample. Without the clamp a single 100µs sample reports
    /// `quantile(1.0)` as 128µs (the bucket's upper edge, up to 2× off);
    /// with it the tail quantile can never exceed anything actually seen.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros((1u64 << (i + 1)).min(self.max_us));
            }
        }
        Duration::from_micros((1 << 25u64).min(self.max_us))
    }

    /// Fold `other` into `self` bucket-by-bucket: counts add, `sum_us`
    /// saturates (two near-u64::MAX replicas must not wrap into a tiny
    /// mean), `max_us` takes the larger tail. The bucket layout is shared
    /// by construction, so merged quantiles equal the quantiles of a
    /// histogram fed the concatenated samples (pinned by a proptest).
    pub fn merge(&mut self, other: &Histogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// `{"count": …, "mean_us": …, "p50_us": …, "p95_us": …, "p99_us": …}` —
    /// one histogram of the wire `stats` response (units: microseconds).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"count\": {}, \"mean_us\": {}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}}}",
            self.count,
            self.mean().as_micros(),
            self.quantile(0.5).as_micros(),
            self.quantile(0.95).as_micros(),
            self.quantile(0.99).as_micros(),
        )
    }
}

/// Per-engine serving counters and latency histograms. Snapshot-cloned for
/// `EngineCore::metrics`; the TCP front-end serves it as the `stats` wire
/// response via [`EngineMetrics::to_json`].
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// requests handed to `Engine::submit` (including immediate rejects)
    pub requests_submitted: u64,
    /// sessions retired (finished, rejected, or cache-capped)
    pub requests_finished: u64,
    /// decode tokens produced (prefill first-tokens excluded)
    pub tokens_generated: u64,
    /// monolithic prefill batches executed
    pub prefill_batches: u64,
    /// sessions seated through a prefill (monolithic or chunked)
    pub prefill_sequences: u64,
    /// chunked-prefill slices appended (one per session per granted chunk)
    pub prefill_chunks: u64,
    /// decode steps executed (one backend call each)
    pub decode_steps: u64,
    /// decode steps × slot capacity (the denominator of utilization)
    pub decode_slot_steps: u64,
    /// sessions swapped out under memory pressure (compressed-cache evictions)
    pub preemptions: u64,
    /// preempted sessions restored from the swap pool
    pub swap_ins: u64,
    /// requests that could never fit the pool, finished with `CacheFull`
    pub rejected_cache_full: u64,
    /// admitted requests that adopted >= 1 shared prefix page
    pub prefix_hits: u64,
    /// admitted requests with no cached prefix (prefix caching on only)
    pub prefix_misses: u64,
    /// prompt tokens served from shared pages instead of being prefilled
    pub prefix_tokens_reused: u64,
    /// shared pages adopted by admitted sequences (refcount bumps)
    pub prefix_pages_adopted: u64,
    /// full pages newly sealed into the shared store at sequence finish
    pub prefix_pages_inserted: u64,
    /// unreferenced cached pages reclaimed under pool pressure
    pub prefix_evictions: u64,
    /// admitted requests requeued because the node-scoped shared store
    /// evicted their matched prefix between admission and adoption and the
    /// re-priced reservation no longer fit (always 0 with a replica store)
    pub prefix_adopt_requeues: u64,
    /// time-to-first-token (arrival → first token)
    pub ttft: Histogram,
    /// inter-token latency: the gap between a session's consecutive tokens
    /// — the tail this PR's chunked prefill exists to flatten (a
    /// monolithic long-prompt prefill stalls every decoder for a whole
    /// tick; chunking bounds the stall at one chunk)
    pub itl: Histogram,
    /// per decode step (whole batch)
    pub decode_step_latency: Histogram,
    /// request end-to-end (arrival → retirement)
    pub e2e: Histogram,
    /// engine-side overhead per decode step (pack/unpack/gather)
    pub coordinator_overhead: Histogram,
}

impl EngineMetrics {
    /// Slot utilization of decode steps: generated tokens / slot capacity.
    pub fn decode_utilization(&self) -> f64 {
        if self.decode_slot_steps == 0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.decode_slot_steps as f64
    }

    /// Fraction of admitted sequences that reused a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / total as f64
    }

    /// The wire `stats` response body: every counter plus the ttft / itl /
    /// e2e / decode-step histograms with p50/p95/p99 in microseconds (see
    /// `docs/BENCH_GLOSSARY.md` for the schema; a request line
    /// `{"id": N, "stats": true}` returns it).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"requests_submitted\": {}, \"requests_finished\": {}, \
             \"tokens_generated\": {}, \"prefill_batches\": {}, \
             \"prefill_sequences\": {}, \"prefill_chunks\": {}, \
             \"decode_steps\": {}, \"preemptions\": {}, \"swap_ins\": {}, \
             \"rejected_cache_full\": {}, \"prefix_hits\": {}, \
             \"prefix_misses\": {}, \"prefix_tokens_reused\": {}, \
             \"prefix_adopt_requeues\": {}, \
             \"ttft\": {}, \"itl\": {}, \"e2e\": {}, \"decode_step\": {}}}",
            self.requests_submitted,
            self.requests_finished,
            self.tokens_generated,
            self.prefill_batches,
            self.prefill_sequences,
            self.prefill_chunks,
            self.decode_steps,
            self.preemptions,
            self.swap_ins,
            self.rejected_cache_full,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_tokens_reused,
            self.prefix_adopt_requeues,
            self.ttft.to_json(),
            self.itl.to_json(),
            self.e2e.to_json(),
            self.decode_step_latency.to_json(),
        )
    }

    /// Fold another replica's snapshot into this one: counters add,
    /// histograms merge bucket-wise. The fleet-scope `stats` roll-up —
    /// `{"id":N,"stats":true,"scope":"fleet"}` — is a fold of this over
    /// every replica's `EngineMetrics`.
    pub fn merge(&mut self, other: &EngineMetrics) {
        self.requests_submitted += other.requests_submitted;
        self.requests_finished += other.requests_finished;
        self.tokens_generated += other.tokens_generated;
        self.prefill_batches += other.prefill_batches;
        self.prefill_sequences += other.prefill_sequences;
        self.prefill_chunks += other.prefill_chunks;
        self.decode_steps += other.decode_steps;
        self.decode_slot_steps += other.decode_slot_steps;
        self.preemptions += other.preemptions;
        self.swap_ins += other.swap_ins;
        self.rejected_cache_full += other.rejected_cache_full;
        self.prefix_hits += other.prefix_hits;
        self.prefix_misses += other.prefix_misses;
        self.prefix_tokens_reused += other.prefix_tokens_reused;
        self.prefix_pages_adopted += other.prefix_pages_adopted;
        self.prefix_pages_inserted += other.prefix_pages_inserted;
        self.prefix_evictions += other.prefix_evictions;
        self.prefix_adopt_requeues += other.prefix_adopt_requeues;
        self.ttft.merge(&other.ttft);
        self.itl.merge(&other.itl);
        self.decode_step_latency.merge(&other.decode_step_latency);
        self.e2e.merge(&other.e2e);
        self.coordinator_overhead.merge(&other.coordinator_overhead);
    }

    /// Multi-line human-readable snapshot (CLI `serve`/`listen` epilogue).
    pub fn report(&self) -> String {
        format!(
            "requests: {} submitted, {} finished | tokens: {}\n\
             prefill: {} batches ({} seqs, {} chunks) | decode: {} steps (util {:.2})\n\
             preempt: {} out / {} in | rejected cache_full: {}\n\
             prefix: {} hits / {} misses ({:.0}%) | {} tok reused | pages {} \
             adopted / {} sealed / {} evicted\n\
             ttft   p50 {:?} p95 {:?} p99 {:?} mean {:?}\n\
             itl    p50 {:?} p95 {:?} p99 {:?} mean {:?}\n\
             step   p50 {:?} p95 {:?} p99 {:?} mean {:?}\n\
             e2e    p50 {:?} p95 {:?} p99 {:?} mean {:?}\n\
             coord  p50 {:?} p95 {:?} mean {:?}",
            self.requests_submitted,
            self.requests_finished,
            self.tokens_generated,
            self.prefill_batches,
            self.prefill_sequences,
            self.prefill_chunks,
            self.decode_steps,
            self.decode_utilization(),
            self.preemptions,
            self.swap_ins,
            self.rejected_cache_full,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_hit_rate() * 100.0,
            self.prefix_tokens_reused,
            self.prefix_pages_adopted,
            self.prefix_pages_inserted,
            self.prefix_evictions,
            self.ttft.quantile(0.5),
            self.ttft.quantile(0.95),
            self.ttft.quantile(0.99),
            self.ttft.mean(),
            self.itl.quantile(0.5),
            self.itl.quantile(0.95),
            self.itl.quantile(0.99),
            self.itl.mean(),
            self.decode_step_latency.quantile(0.5),
            self.decode_step_latency.quantile(0.95),
            self.decode_step_latency.quantile(0.99),
            self.decode_step_latency.mean(),
            self.e2e.quantile(0.5),
            self.e2e.quantile(0.95),
            self.e2e.quantile(0.99),
            self.e2e.mean(),
            self.coordinator_overhead.quantile(0.5),
            self.coordinator_overhead.quantile(0.95),
            self.coordinator_overhead.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(0.99));
        assert!(h.quantile(0.99) <= h.quantile(1.0));
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn mean_sane() {
        let mut h = Histogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn single_sample_tail_quantile_is_exact() {
        // Regression: the bucket upper edge used to inflate quantile(1.0)
        // on a lone 100µs sample to 128µs. The max clamp pins it exactly.
        let mut h = Histogram::default();
        h.record(Duration::from_micros(100));
        assert_eq!(h.quantile(1.0), Duration::from_micros(100));
        assert_eq!(h.quantile(0.5), Duration::from_micros(100));
        assert_eq!(h.max_us(), 100);
    }

    #[test]
    fn quantile_never_exceeds_observed_max() {
        let mut h = Histogram::default();
        for us in [3u64, 17, 900, 5000, 65_537] {
            h.record(Duration::from_micros(us));
        }
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert!(h.quantile(q).as_micros() as u64 <= h.max_us());
        }
    }

    #[test]
    fn merge_adds_counts_and_saturates_sum() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        a.record(Duration::from_micros(10));
        a.record(Duration::from_micros(1000));
        b.record(Duration::from_micros(500_000));
        let (ca, cb) = (a.count(), b.count());
        a.merge(&b);
        assert_eq!(a.count(), ca + cb);
        assert_eq!(a.sum_us(), 10 + 1000 + 500_000);
        assert_eq!(a.max_us(), 500_000);
        // the merged tail sees b's large sample
        assert!(a.quantile(1.0) >= Duration::from_micros(262_144));

        // saturation: two huge sums must not wrap
        let mut x = Histogram::default();
        x.record(Duration::from_micros(u64::MAX));
        let y = x.clone();
        x.merge(&y);
        assert_eq!(x.sum_us(), u64::MAX.saturating_add(u64::MAX));
    }

    #[test]
    fn engine_metrics_merge_rolls_up() {
        let mut a = EngineMetrics {
            requests_finished: 2,
            tokens_generated: 10,
            ..Default::default()
        };
        a.ttft.record(Duration::from_micros(100));
        let mut b = EngineMetrics {
            requests_finished: 3,
            tokens_generated: 7,
            ..Default::default()
        };
        b.ttft.record(Duration::from_micros(200));
        b.ttft.record(Duration::from_micros(300));
        a.merge(&b);
        assert_eq!(a.requests_finished, 5);
        assert_eq!(a.tokens_generated, 17);
        assert_eq!(a.ttft.count(), 3);
    }

    #[test]
    fn stats_json_round_trips() {
        use crate::util::json::Json;
        let mut m = EngineMetrics {
            requests_finished: 3,
            ..Default::default()
        };
        m.ttft.record(Duration::from_micros(250));
        m.itl.record(Duration::from_micros(40));
        m.itl.record(Duration::from_micros(90));
        let j = Json::parse(&m.to_json()).expect("stats must be valid JSON");
        assert_eq!(j.get("requests_finished").unwrap().as_usize().unwrap(), 3);
        let itl = j.get("itl").unwrap();
        assert_eq!(itl.get("count").unwrap().as_usize().unwrap(), 2);
        assert!(itl.get("p99_us").unwrap().as_f64().unwrap() >= 64.0);
        assert!(j.get("ttft").unwrap().get("p50_us").unwrap().as_f64().unwrap() >= 250.0);
    }
}
