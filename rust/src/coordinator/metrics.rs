//! Serving metrics: counters + log-bucketed latency histograms.

use std::time::Duration;

/// Log-scale histogram from 1µs to ~17s (doubling buckets).
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    buckets: [u64; 25],
    count: u64,
    sum_us: u64,
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros().max(1) as u64;
        let idx = (63 - us.leading_zeros() as usize).min(24);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        Duration::from_micros(self.sum_us / self.count)
    }

    /// Approximate quantile from bucket upper edges.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1 << 25)
    }
}

#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub tokens_generated: u64,
    pub prefill_batches: u64,
    pub prefill_sequences: u64,
    pub decode_steps: u64,
    pub decode_slot_steps: u64,
    /// sessions swapped out under memory pressure (compressed-cache evictions)
    pub preemptions: u64,
    /// preempted sessions restored from the swap pool
    pub swap_ins: u64,
    /// requests that could never fit the pool, finished with `CacheFull`
    pub rejected_cache_full: u64,
    /// admitted requests that adopted >= 1 shared prefix page
    pub prefix_hits: u64,
    /// admitted requests with no cached prefix (prefix caching on only)
    pub prefix_misses: u64,
    /// prompt tokens served from shared pages instead of being prefilled
    pub prefix_tokens_reused: u64,
    /// shared pages adopted by admitted sequences (refcount bumps)
    pub prefix_pages_adopted: u64,
    /// full pages newly sealed into the shared store at sequence finish
    pub prefix_pages_inserted: u64,
    /// unreferenced cached pages reclaimed under pool pressure
    pub prefix_evictions: u64,
    /// time-to-first-token
    pub ttft: Histogram,
    /// per decode step (whole batch)
    pub decode_step_latency: Histogram,
    /// request end-to-end
    pub e2e: Histogram,
    /// engine-side overhead per decode step (pack/unpack/gather)
    pub coordinator_overhead: Histogram,
}

impl EngineMetrics {
    /// Slot utilization of decode steps: generated tokens / slot capacity.
    pub fn decode_utilization(&self) -> f64 {
        if self.decode_slot_steps == 0 {
            return 0.0;
        }
        self.tokens_generated as f64 / self.decode_slot_steps as f64
    }

    /// Fraction of admitted sequences that reused a cached prefix.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefix_hits + self.prefix_misses;
        if total == 0 {
            return 0.0;
        }
        self.prefix_hits as f64 / total as f64
    }

    pub fn report(&self) -> String {
        format!(
            "requests: {} submitted, {} finished | tokens: {}\n\
             prefill: {} batches ({} seqs) | decode: {} steps (util {:.2})\n\
             preempt: {} out / {} in | rejected cache_full: {}\n\
             prefix: {} hits / {} misses ({:.0}%) | {} tok reused | pages {} \
             adopted / {} sealed / {} evicted\n\
             ttft   p50 {:?} p95 {:?} mean {:?}\n\
             step   p50 {:?} p95 {:?} mean {:?}\n\
             e2e    p50 {:?} p95 {:?} mean {:?}\n\
             coord  p50 {:?} p95 {:?} mean {:?}",
            self.requests_submitted,
            self.requests_finished,
            self.tokens_generated,
            self.prefill_batches,
            self.prefill_sequences,
            self.decode_steps,
            self.decode_utilization(),
            self.preemptions,
            self.swap_ins,
            self.rejected_cache_full,
            self.prefix_hits,
            self.prefix_misses,
            self.prefix_hit_rate() * 100.0,
            self.prefix_tokens_reused,
            self.prefix_pages_adopted,
            self.prefix_pages_inserted,
            self.prefix_evictions,
            self.ttft.quantile(0.5),
            self.ttft.quantile(0.95),
            self.ttft.mean(),
            self.decode_step_latency.quantile(0.5),
            self.decode_step_latency.quantile(0.95),
            self.decode_step_latency.mean(),
            self.e2e.quantile(0.5),
            self.e2e.quantile(0.95),
            self.e2e.mean(),
            self.coordinator_overhead.quantile(0.5),
            self.coordinator_overhead.quantile(0.95),
            self.coordinator_overhead.mean(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = Histogram::default();
        for i in 1..=1000u64 {
            h.record(Duration::from_micros(i * 10));
        }
        assert!(h.quantile(0.5) <= h.quantile(0.95));
        assert!(h.quantile(0.95) <= h.quantile(1.0));
        assert_eq!(h.count(), 1000);
    }

    #[test]
    fn mean_sane() {
        let mut h = Histogram::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }
}
