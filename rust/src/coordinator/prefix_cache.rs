//! Prefix cache: a token-level radix tree mapping prompt-token prefixes to
//! runs of full compressed pages in the kv_manager's shared store.
//!
//! Granularity is one page (`page_tokens` tokens): every tree node covers
//! exactly one full page and holds the [`PageId`] of the immutable,
//! content-addressed block carrying that window's compressed KV. A path
//! from the root spells out a token prefix page by page, so the longest
//! cached prefix of a prompt is a straight walk ([`PrefixCache::match_prefix`]).
//!
//! The tree itself holds NO refcounts — the kv_manager's shared store
//! counts live/swapped sequence references. Eviction
//! ([`PrefixCache::evict_lru`]) removes least-recently-used *leaf* nodes
//! whose pages have refcount 0, so:
//!   * a page referenced by any live or swapped sequence is never evicted,
//!   * interior nodes are never orphaned (leaves go first; evicting a leaf
//!     may expose its parent as the next candidate),
//!   * matching keeps working for every prefix still in the tree.
//!
//! Recency is bumped along the matched path on every lookup, so hot system
//! prompts stay resident while one-off conversation tails age out.

use super::kv_manager::PageId;
use std::collections::HashMap;

struct Node {
    /// the page carrying this window's compressed KV (refcounted in the
    /// kv_manager's shared store, not here)
    page: PageId,
    /// the exact `page_tokens`-token window this node covers — kept so the
    /// node can unlink itself from its parent's child map on eviction
    key: Vec<i32>,
    parent: Option<usize>,
    children: HashMap<Vec<i32>, usize>,
    /// logical clock of the last match/insert touching this node
    last_used: u64,
}

/// See the module docs. All operations are O(depth) except eviction's
/// LRU scan, which is O(nodes) per evicted page — fine at page counts the
/// pool can hold.
pub struct PrefixCache {
    page_tokens: usize,
    /// slab arena; `None` slots are freed nodes awaiting reuse
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    roots: HashMap<Vec<i32>, usize>,
    clock: u64,
    cached_tokens: usize,
}

impl PrefixCache {
    /// An empty tree at `page_tokens` tokens per node (must match the
    /// kv_manager's page size).
    pub fn new(page_tokens: usize) -> Self {
        assert!(page_tokens > 0, "page_tokens must be positive");
        PrefixCache {
            page_tokens,
            nodes: Vec::new(),
            free: Vec::new(),
            roots: HashMap::new(),
            clock: 0,
            cached_tokens: 0,
        }
    }

    /// Tokens per tree node (the kv page size).
    pub fn page_tokens(&self) -> usize {
        self.page_tokens
    }

    /// Live nodes == cached pages.
    pub fn pages(&self) -> usize {
        self.nodes.len() - self.free.len()
    }

    /// Tokens covered by the cached pages (always `pages() * page_tokens` —
    /// the invariant the proptests pin).
    pub fn cached_tokens(&self) -> usize {
        self.cached_tokens
    }

    /// Whether the tree caches nothing.
    pub fn is_empty(&self) -> bool {
        self.pages() == 0
    }

    fn node(&self, i: usize) -> &Node {
        self.nodes[i].as_ref().expect("live node")
    }

    fn node_mut(&mut self, i: usize) -> &mut Node {
        self.nodes[i].as_mut().expect("live node")
    }

    fn alloc(&mut self, n: Node) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = Some(n);
                i
            }
            None => {
                self.nodes.push(Some(n));
                self.nodes.len() - 1
            }
        }
    }

    /// The longest cached prefix of `tokens`, in whole pages: the page ids
    /// whose concatenated windows equal `tokens[..k*page_tokens]` for the
    /// largest matchable `k`. Bumps recency along the matched path.
    ///
    /// ```
    /// use turboangle::coordinator::PrefixCache;
    /// let mut tree = PrefixCache::new(2); // 2 tokens per page
    /// tree.insert(&[1, 2, 3, 4], &[10, 11]);
    /// assert_eq!(tree.match_prefix(&[1, 2, 3, 4, 5, 6]), vec![10, 11]);
    /// assert_eq!(tree.match_prefix(&[1, 2, 9, 9]), vec![10]);
    /// assert!(tree.match_prefix(&[7, 7]).is_empty());
    /// ```
    pub fn match_prefix(&mut self, tokens: &[i32]) -> Vec<PageId> {
        self.clock += 1;
        let clock = self.clock;
        let pt = self.page_tokens;
        let mut out = Vec::new();
        let mut cur: Option<usize> = None;
        let mut off = 0usize;
        while off + pt <= tokens.len() {
            let window = &tokens[off..off + pt];
            let next = match cur {
                None => self.roots.get(window).copied(),
                Some(i) => self.node(i).children.get(window).copied(),
            };
            match next {
                Some(j) => {
                    let n = self.node_mut(j);
                    n.last_used = clock;
                    out.push(n.page);
                    cur = Some(j);
                    off += pt;
                }
                None => break,
            }
        }
        out
    }

    /// Index a finished sequence's full-page chain: `pages[i]` carries
    /// tokens `[i*page_tokens, (i+1)*page_tokens)` of `tokens`. Windows
    /// already present keep their existing node (the kv_manager's content
    /// addressing makes the ids agree); new windows extend the tree.
    ///
    /// Returns the chain ids that could NOT be linked because the existing
    /// node at their position holds a DIFFERENT page — possible only via
    /// the kv store's hash-collision dedup fallback. Such pages are
    /// indexed nowhere, so eviction would never find them: the caller must
    /// free the unreferenced ones or they leak their pool charge.
    pub fn insert(&mut self, tokens: &[i32], pages: &[PageId]) -> Vec<PageId> {
        self.insert_with(tokens, pages, &|_| true)
    }

    /// [`Self::insert`] made node-store aware: when the existing node at a
    /// position holds a DIFFERENT page that is no longer `present` in the
    /// shared store (a node-scoped store's LRU ran on another replica, or
    /// under this one's own seal pressure), the node is REPOINTED at the
    /// chain's page — the old id is dead and matching must follow the live
    /// one — instead of orphaning the fresh copy while the tree keeps
    /// offering a page that can never be adopted again. A still-present
    /// conflicting page keeps its node and the chain id is returned as an
    /// orphan, exactly as [`Self::insert`] does.
    pub fn insert_with(
        &mut self,
        tokens: &[i32],
        pages: &[PageId],
        present: &dyn Fn(PageId) -> bool,
    ) -> Vec<PageId> {
        self.clock += 1;
        let clock = self.clock;
        let pt = self.page_tokens;
        let mut orphans = Vec::new();
        let mut cur: Option<usize> = None;
        for (i, &pid) in pages.iter().enumerate() {
            let off = i * pt;
            if off + pt > tokens.len() {
                break;
            }
            let window = tokens[off..off + pt].to_vec();
            let existing = match cur {
                None => self.roots.get(&window).copied(),
                Some(p) => self.node(p).children.get(&window).copied(),
            };
            let j = match existing {
                Some(j) => {
                    let n = self.node_mut(j);
                    n.last_used = clock;
                    if n.page != pid {
                        if present(n.page) {
                            orphans.push(pid);
                        } else {
                            n.page = pid;
                        }
                    }
                    j
                }
                None => {
                    let j = self.alloc(Node {
                        page: pid,
                        key: window.clone(),
                        parent: cur,
                        children: HashMap::new(),
                        last_used: clock,
                    });
                    match cur {
                        None => self.roots.insert(window, j),
                        Some(p) => self.node_mut(p).children.insert(window, j),
                    };
                    self.cached_tokens += pt;
                    j
                }
            };
            cur = Some(j);
        }
        orphans
    }

    /// Drop every node whose page is no longer `present` in the shared
    /// store — node-scoped stores LRU-evict refs==0 pages under seal
    /// pressure, concurrently with every replica — together with its whole
    /// subtree: a chain cannot be adopted past a missing parent, so the
    /// descendants are unreachable for matching even when their own pages
    /// survive (the store reclaims those itself once unreferenced). Returns
    /// the number of nodes removed. A no-op under a replica-scoped store,
    /// whose pages only leave through [`Self::evict_lru`].
    pub fn prune_missing(&mut self, present: &dyn Fn(PageId) -> bool) -> usize {
        let dead: Vec<usize> = self
            .nodes
            .iter()
            .enumerate()
            .filter_map(|(i, slot)| {
                slot.as_ref().and_then(|n| (!present(n.page)).then_some(i))
            })
            .collect();
        let mut removed = 0usize;
        for i in dead {
            // an earlier subtree removal may have already taken this node
            if self.nodes[i].is_some() {
                removed += self.remove_subtree(i);
            }
        }
        removed
    }

    /// Remove node `i` and its whole subtree, unlinking from a (possibly
    /// already-removed) parent. Returns the number of nodes removed.
    fn remove_subtree(&mut self, i: usize) -> usize {
        let n = self.nodes[i].take().expect("live node");
        match n.parent {
            None => {
                self.roots.remove(&n.key);
            }
            Some(p) => {
                if let Some(pn) = self.nodes[p].as_mut() {
                    pn.children.remove(&n.key);
                }
            }
        }
        self.free.push(i);
        self.cached_tokens -= self.page_tokens;
        let mut removed = 1usize;
        for (_, c) in n.children {
            removed += self.remove_subtree(c);
        }
        removed
    }

    /// Evict up to `want` least-recently-used LEAF pages whose refcount
    /// (per `refs`, normally the kv_manager's `shared_page_refs`) is zero.
    /// Returns the evicted page ids — the caller frees them in the shared
    /// store. Pages referenced by live or swapped sequences are never
    /// returned; interior nodes are only reachable after their whole
    /// subtree has drained.
    ///
    /// One arena scan collects every currently-eligible leaf (oldest
    /// first); a cascade — a parent exposed by evicting its last child —
    /// costs at most one more scan per drained tree level, so the whole
    /// call is O(nodes · levels-drained), not O(nodes · want).
    pub fn evict_lru(&mut self, want: usize, refs: &dyn Fn(PageId) -> usize) -> Vec<PageId> {
        let mut evicted = Vec::new();
        while evicted.len() < want {
            let mut candidates: Vec<(u64, usize)> = self
                .nodes
                .iter()
                .enumerate()
                .filter_map(|(i, slot)| {
                    slot.as_ref().and_then(|n| {
                        (n.children.is_empty() && refs(n.page) == 0)
                            .then_some((n.last_used, i))
                    })
                })
                .collect();
            if candidates.is_empty() {
                break;
            }
            candidates.sort_unstable();
            for (_, i) in candidates.into_iter().take(want - evicted.len()) {
                let n = self.nodes[i].take().expect("candidate is live");
                match n.parent {
                    None => {
                        self.roots.remove(&n.key);
                    }
                    Some(p) => {
                        self.node_mut(p).children.remove(&n.key);
                    }
                }
                self.free.push(i);
                self.cached_tokens -= self.page_tokens;
                evicted.push(n.page);
            }
        }
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_refs(_: PageId) -> usize {
        0
    }

    #[test]
    fn match_walks_longest_prefix_at_page_granularity() {
        let mut t = PrefixCache::new(2);
        t.insert(&[1, 2, 3, 4, 5, 6], &[10, 11, 12]);
        assert_eq!(t.pages(), 3);
        assert_eq!(t.cached_tokens(), 6);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4, 5, 6]), vec![10, 11, 12]);
        // partial-page tails never match
        assert_eq!(t.match_prefix(&[1, 2, 3, 4, 5]), vec![10, 11]);
        assert_eq!(t.match_prefix(&[1, 2, 3, 9, 5, 6]), vec![10]);
        assert_eq!(t.match_prefix(&[9, 2, 3, 4]), Vec::<PageId>::new());
        assert_eq!(t.match_prefix(&[1]), Vec::<PageId>::new());
    }

    #[test]
    fn insert_is_idempotent_and_branches() {
        let mut t = PrefixCache::new(2);
        assert!(t.insert(&[1, 2, 3, 4], &[10, 11]).is_empty());
        assert_eq!(t.pages(), 2);
        assert!(t.insert(&[1, 2, 3, 4], &[10, 11]).is_empty());
        assert_eq!(t.pages(), 2, "re-insert creates nothing");
        // branch at the second page
        assert!(t.insert(&[1, 2, 7, 8], &[10, 21]).is_empty());
        assert_eq!(t.pages(), 3);
        assert_eq!(t.match_prefix(&[1, 2, 7, 8]), vec![10, 21]);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), vec![10, 11]);
        // ragged tail tokens are ignored (only full pages insert)
        let before = t.pages();
        t.insert(&[5, 6, 7], &[30, 31]);
        assert_eq!(t.pages(), before + 1, "second page had no full window");
        // a different id at an existing position is reported as an orphan
        // (the caller frees it); the resident node keeps its page
        assert_eq!(t.insert(&[1, 2, 3, 4], &[10, 99]), vec![99]);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), vec![10, 11], "existing node kept");
    }

    #[test]
    fn eviction_takes_lru_leaves_first_and_respects_refs() {
        let mut t = PrefixCache::new(1);
        t.insert(&[1, 2, 3], &[10, 11, 12]);
        t.insert(&[4], &[40]);
        // touch the deep chain so the lone [4] root is LRU
        t.match_prefix(&[1, 2, 3]);
        let got = t.evict_lru(1, &no_refs);
        assert_eq!(got, vec![40], "LRU leaf goes first");
        // leaves only: evicting the chain must go 12, then 11, then 10
        assert_eq!(t.evict_lru(10, &no_refs), vec![12, 11, 10]);
        assert!(t.is_empty());
        assert_eq!(t.cached_tokens(), 0);
        // referenced pages are skipped entirely
        t.insert(&[1, 2], &[10, 11]);
        let pinned = |p: PageId| usize::from(p == 11);
        let none = t.evict_lru(10, &pinned);
        assert_eq!(none, Vec::<PageId>::new(), "leaf pinned, parent not a leaf");
        assert_eq!(t.pages(), 2);
        // after the pin clears, both go
        assert_eq!(t.evict_lru(10, &no_refs), vec![11, 10]);
    }

    #[test]
    fn repoint_and_prune_follow_remote_eviction() {
        let mut t = PrefixCache::new(2);
        t.insert(&[1, 2, 3, 4], &[10, 11]);
        // a remote replica's node store evicted page 10; a fresh harvest
        // re-sealed the same window as page 50 — the node repoints
        let ten_gone = |p: PageId| p != 10;
        assert_eq!(
            t.insert_with(&[1, 2, 3, 4], &[50, 11], &ten_gone),
            Vec::<PageId>::new()
        );
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), vec![50, 11]);
        assert_eq!(t.pages(), 2, "repoint creates no node");
        // a still-present conflicting page keeps its node: the chain id is
        // orphaned exactly as insert() would
        assert_eq!(t.insert_with(&[1, 2, 3, 4], &[50, 77], &|_| true), vec![77]);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4]), vec![50, 11]);
        // pruning a missing interior page drops its whole subtree — the
        // child is unreachable for adoption even though its page survives
        t.insert(&[1, 2, 3, 4, 5, 6], &[50, 11, 12]);
        assert_eq!(t.pages(), 3);
        assert_eq!(t.prune_missing(&|p| p != 11), 2);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4, 5, 6]), vec![50]);
        assert_eq!(t.pages(), 1);
        assert_eq!(t.cached_tokens(), 2);
        // pruning with everything present is a no-op
        assert_eq!(t.prune_missing(&|_| true), 0);
    }

    #[test]
    fn matching_after_partial_eviction_still_works() {
        let mut t = PrefixCache::new(2);
        t.insert(&[1, 2, 3, 4, 5, 6], &[10, 11, 12]);
        assert_eq!(t.evict_lru(1, &no_refs), vec![12]);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4, 5, 6]), vec![10, 11]);
        // arena slot reuse keeps counts consistent
        t.insert(&[1, 2, 3, 4, 9, 9], &[10, 11, 33]);
        assert_eq!(t.pages(), 3);
        assert_eq!(t.cached_tokens(), 6);
        assert_eq!(t.match_prefix(&[1, 2, 3, 4, 9, 9]), vec![10, 11, 33]);
    }
}
