//! `turboangle` CLI — serving engine, table regeneration, config search.
//!
//! Every `tableN` subcommand regenerates the corresponding paper table on
//! the simulated profiles (DESIGN.md §4). `serve` runs the end-to-end
//! engine on a synthetic workload. `selfcheck` cross-validates the native
//! quantizer against python golden vectors AND the AOT kernel artifacts.

use anyhow::{bail, Result};
use std::sync::Arc;
use turboangle::coordinator::{
    Engine, EngineConfig, EngineCore, EngineMetrics, ReadPath, RoutePolicy, SharedPageStore,
};
use turboangle::eval::{search, sensitivity, sweep, PplHarness};
use turboangle::obs::{export, ObsSnapshot};
use turboangle::quant::{angle, fwht, norm, spec, NormMode, QuantConfig, QuantSpec};
use turboangle::report;
use turboangle::runtime::{
    tensorfile, Entry, Manifest, ModelBackend, ModelExecutor, Runtime, SimExecutor,
};
use turboangle::util::cli::Args;
use turboangle::workload::{self, WorkloadSpec};

const ALL_MODELS: [&str; 7] = [
    "tinyllama-sim",
    "mistral-sim",
    "smollm2-sim",
    "phi15-sim",
    "stablelm2-sim",
    "starcoder2-sim",
    "olmo-sim",
];

const USAGE: &str = "\
turboangle — TurboAngle KV-cache compression system

USAGE: turboangle [--artifacts DIR] <subcommand> [flags]

GLOBAL FLAGS
  --artifacts DIR       AOT artifact directory (default: artifacts)

SUBCOMMANDS
  table1     [--models a,b] [--fine] [--centered]   angular vs scalar (Table 1)
  table2     [--models ...]                         per-layer early-boost (Tables 2+3)
  table4     [--model M] [--group-size N] [--sim]   layer-group sensitivity (Table 4)
  table5     [--models ...]                         norm quantization (Table 5)
  table6     [--model M]                            vs calibration baselines (Table 6)
  kv-sens    [--model M] [--n-early N]              K vs V sensitivity (§4.5)
  search     [--model M] [--budget N]               §3.2 few-eval config search
  uniformity [--d D] [--rows N]                     angle-uniformity evidence (§2)
  bits       [--layers L] [--d D]                   Eq.1/Eq.3 rate calculator
  serve      single-engine serve over a synthetic workload ([--sim] or artifacts)
  listen     multi-replica TCP JSON-lines server (docs/ARCHITECTURE.md)
  seed-sweep [--model M] [--seeds N] [--sim]        dPPL spread over random D (paper limitation)
  allocate   [--model M] [--budget B] [--group G]   greedy per-layer bit allocation (extension)
  selfcheck                                         golden + HLO cross-validation
  eval       [--model M | --sim] + QUANT FLAGS      one PPL measurement for one config

QUANT FLAGS (shared by serve, listen, eval — one parser, one validation story)
  --nk N / --nv N         base K / V codebook sizes (default: 128 / 64)
  --n-early E             boost the first E layers (paper §4.2 early-boost)
  --boost-layers SET      boost an explicit layer set: 0,1,5 or 0-7,16-23
                          (exclusive with --n-early)
  --nk-hi N / --nv-hi N   boosted-layer codebooks (default: 256 / 128)
  --norms P               norm preset: fp32 | norm8 | k8v4log
                          (default: k8v4log when serving, fp32 for eval)
  --k-norm M / --v-norm M per-side norm modes: fp32|linear4|linear8|log4|log8
                          (exclusive with --norms)
  --no-quant              fp reference: Mode::None + fp32 norms

SERVE FLAGS (turboangle serve ...)
  --model M               profile to serve (default: smollm2-sim)
  --sim                   deterministic simulated backend — no artifacts needed
  --sim-layers L          sim model depth (default: 8; room for boost schedules)
  --requests N            synthetic requests to run (default: 12)
  --gen-max N             max generated tokens per request (default: 8)
  --read-path P           auto|fused|reinflate (default: auto). fused needs a
                          fused-capable backend (--sim) — rejected on the PJRT
                          executor
  --prefix-cache M        on|off (default: on) — share compressed pages across
                          common prompt prefixes; token streams are identical
  --chunked-prefill M     on|off (default: off) — split prompt ingestion into
                          chunks so decode interleaves with long prefills;
                          token streams are identical, only tail latency
                          changes. Needs a chunk-aware backend — rejected on
                          the PJRT executor (it would re-run the full prefill
                          per chunk)
  --chunk-tokens N        tokens per prefill chunk per tick (default: 16, >= 1)
  --tick-token-budget N   per-tick token budget: decode lanes cost 1 each, the
                          rest goes to prefill chunks (default: 64, >= 1)
  --trace M               on|off (default: off) — record request-lifecycle
                          spans + sampled gauges (docs/OBSERVABILITY.md);
                          token streams are bit-identical either way
  --trace-out FILE        write a Chrome trace-event JSON file at exit
                          (chrome://tracing, Perfetto); implies --trace on
  --sample-every N        tick stride between gauge/stage samples
                          (default: 32, >= 1; 1 = every tick)

LISTEN FLAGS (turboangle listen ...)
  --addr A                bind address (default: 127.0.0.1:7777)
  --max-requests N        serve N generation responses then exit; 0 = forever
                          (default: 0; stats responses do not count)
  --replicas N            engine replica worker threads (default: 1, >= 1)
  --route-policy P        rr|least-loaded|affinity|prefix (default: affinity;
                          affinity keys on the wire \"session_key\"; prefix
                          keys on the prompt's first-page fingerprint so
                          requests sharing a cacheable prefix collocate)
  --imbalance-bound N     prefix routing only: max in-flight jobs the home
                          replica may sit above the least-loaded one before
                          a request spills there instead (default: 4)
  --shared-store S        node|replica (default: replica) — node shares ONE
                          content-addressed immutable-page store across all
                          replicas on this node, so a prefix sealed by any
                          replica is adoptable by every other
  --sim                   deterministic simulated backend — no artifacts needed
  --sim-layers L          sim model depth (default: 2, the protocol-smoke geometry)
  --model M               profile when not --sim (default: smollm2-sim)
  --read-path P           auto|fused|reinflate (default: auto); fused requires
                          --sim (the PJRT backend has no fused decode path)
  --prefix-cache M        on|off (default: on)
  --chunked-prefill M     on|off (default: off); requires a chunk-aware
                          backend (--sim) — rejected on the PJRT executor
  --chunk-tokens N        tokens per prefill chunk per tick (default: 16, >= 1)
  --tick-token-budget N   per-tick token budget (default: 64, >= 1)
  --trace M               on|off (default: off) — per-replica span rings +
                          sampled gauges (docs/OBSERVABILITY.md)
  --trace-out FILE        merged Chrome trace across all replicas at exit
                          (one pid per replica); implies --trace on
  --sample-every N        tick stride between gauge/stage samples
                          (default: 32, >= 1)

  wire protocol: one JSON object per line —
    {\"id\": 1, \"prompt\": \"...\", \"max_new_tokens\": 8, \"session_key\": \"u1\"}
    {\"id\": 2, \"stats\": true}   -> one replica's latency/counter snapshot
    {\"id\": 3, \"stats\": true, \"scope\": \"fleet\"}
                                -> histogram-merged view across all replicas
    {\"id\": 4, \"metrics\": true} -> Prometheus text exposition (one replica)

BENCH ENTRY POINTS (cargo bench --bench <name> [-- --smoke])
  quant_hot_path | serving_throughput | fused_attention | prefix_caching |
  serving_latency | quality_sweep | obs_overhead — each writes
  BENCH_<name>.json; every field is documented in docs/BENCH_GLOSSARY.md
";

fn parse_route_policy(s: &str, imbalance_bound: usize) -> Result<RoutePolicy> {
    Ok(match s {
        "rr" | "round-robin" => RoutePolicy::RoundRobin,
        "least-loaded" => RoutePolicy::LeastLoaded,
        "affinity" | "session-affinity" => RoutePolicy::SessionAffinity,
        "prefix" => RoutePolicy::Prefix { imbalance_bound },
        other => bail!("unknown route policy '{other}' (rr|least-loaded|affinity|prefix)"),
    })
}

fn parse_read_path(s: &str) -> Result<ReadPath> {
    Ok(match s {
        "auto" => ReadPath::Auto,
        "fused" => ReadPath::Fused,
        "reinflate" | "dense" => ReadPath::Reinflate,
        other => bail!("unknown read path '{other}' (auto|fused|reinflate)"),
    })
}

fn parse_on_off(flag: &str, s: &str) -> Result<bool> {
    Ok(match s {
        "on" => true,
        "off" => false,
        other => bail!("--{flag} takes on|off (got '{other}')"),
    })
}

/// Parse the tracing flags shared by `serve` and `listen`: `--trace
/// on|off`, `--trace-out FILE` (implies `--trace on`), and
/// `--sample-every N` (tick stride between gauge/stage samples).
fn parse_trace_flags(args: &Args) -> Result<(bool, Option<String>, usize)> {
    let trace_out = args.flag("trace-out").map(String::from);
    let trace =
        parse_on_off("trace", &args.get_str("trace", "off"))? || trace_out.is_some();
    let sample_every = args.get_usize("sample-every", 32)?;
    if sample_every == 0 {
        bail!(
            "--sample-every must be >= 1 (got 0): it is the tick stride between \
             gauge/stage samples — use 1 to sample every tick, or larger values \
             to cut overhead"
        );
    }
    Ok((trace, trace_out, sample_every))
}

/// Reject `--chunked-prefill on` on a backend without native chunk
/// support. Chunked mode is CORRECT on any backend (the trait default
/// falls back to a full prefill per chunk) but on such a backend it makes
/// latency strictly WORSE than monolithic mode, so the CLI refuses
/// instead of silently degrading.
fn ensure_chunked_support(exec: &ModelExecutor, chunked: bool) -> Result<()> {
    if chunked && !turboangle::runtime::ModelBackend::supports_chunked_prefill(exec) {
        bail!(
            "--chunked-prefill on requires a backend with native chunked prefill \
             (the PJRT executor recomputes the full prefill per chunk, making \
             latency worse, not better); use the --sim backend or --chunked-prefill off"
        );
    }
    Ok(())
}

/// Parse + validate the chunked-prefill flag family. `--chunk-tokens 0`
/// and `--tick-token-budget 0` are rejected here with actionable errors
/// instead of panicking inside engine construction.
fn parse_chunk_flags(args: &Args) -> Result<(bool, usize, usize)> {
    let chunked = parse_on_off("chunked-prefill", &args.get_str("chunked-prefill", "off"))?;
    let chunk_tokens = args.get_usize("chunk-tokens", 16)?;
    let tick_budget = args.get_usize("tick-token-budget", 64)?;
    if chunk_tokens == 0 {
        bail!(
            "--chunk-tokens must be >= 1 (got 0): it is the number of prompt \
             tokens one session prefills per engine tick"
        );
    }
    if tick_budget == 0 {
        bail!(
            "--tick-token-budget must be >= 1 (got 0): it caps decode lanes + \
             prefill chunk tokens per engine tick"
        );
    }
    Ok((chunked, chunk_tokens, tick_budget))
}

fn harness(artifacts: &str, model: &str) -> Result<PplHarness> {
    let manifest = Manifest::load(artifacts)?;
    let rt = Runtime::cpu()?;
    let exec = ModelExecutor::load(&rt, &manifest, model, Entry::Eval)?;
    PplHarness::new(&manifest, exec)
}

/// The artifact-free deterministic backend at a chosen depth (`--sim`
/// everywhere uses seed 1 so serve/eval/benches agree on the "model").
fn sim_exec(layers: usize) -> SimExecutor {
    SimExecutor::with_dims(1, layers, 2, 8, 4, 32, 64)
}

/// PPL harness for an eval-style subcommand: the PJRT executor for
/// `--model`, or the synthetic sim stream under `--sim [--sim-layers L]` —
/// no artifacts touched on that path.
fn eval_harness(args: &Args, artifacts: &str, model: &str) -> Result<PplHarness> {
    if args.get_bool("sim") {
        PplHarness::sim(sim_exec(args.get_usize("sim-layers", 8)?))
    } else {
        harness(artifacts, model)
    }
}

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let artifacts = args.get_str("artifacts", "artifacts");
    match args.subcommand.as_str() {
        "table1" => {
            for m in args.get_list("models", &["mistral-sim", "tinyllama-sim"]) {
                let h = harness(&artifacts, &m)?;
                let rows = sweep::table1(&h, args.get_bool("fine"), args.get_bool("centered"))?;
                println!("{}", report::table1(&m, &rows));
            }
        }
        "table2" => {
            let models = args.get_list("models", &ALL_MODELS);
            let mut results = Vec::new();
            for m in &models {
                eprintln!("sweeping {m} ...");
                let h = harness(&artifacts, m)?;
                let r = sweep::early_boost_sweep(&h, m)?;
                for (tag, d) in &r.sweep_log {
                    eprintln!("   {tag:32} {d:+.4}");
                }
                results.push(r);
            }
            println!("{}", report::table2(&results));
            println!("{}", report::table3(&results));
        }
        "table4" => {
            let h = eval_harness(&args, &artifacts, &args.get_str("model", "phi15-sim"))?;
            let rep = sensitivity::layer_group_sweep(&h, args.get_usize("group-size", 4)?)?;
            println!("{}", report::table4(&rep));
        }
        "table5" => {
            let models = args.get_list("models", &ALL_MODELS);
            let mut rows = Vec::new();
            for m in &models {
                eprintln!("sweeping {m} ...");
                let h = harness(&artifacts, m)?;
                let best = sweep::early_boost_sweep(&h, m)?.best_cfg;
                rows.push(sweep::table5(&h, m, &best)?);
            }
            println!("{}", report::table5(&rows));
        }
        "table6" => {
            let model = args.get_str("model", "mistral-sim");
            let h = harness(&artifacts, &model)?;
            let best = sweep::early_boost_sweep(&h, &model)?.best_cfg;
            let rows = sweep::table6(&h, &best)?;
            println!("{}", report::table6(&rows));
            println!(
                "(paper-cited context: CQ-2c8b 4.0b +0.03, KVQuant-4b-1% 4.32b +0.01,\n\
                 AQUA-KV ~3.0b +0.03 — foreign models/datasets, indicative only)"
            );
        }
        "kv-sens" => {
            let model = args.get_str("model", "tinyllama-sim");
            let h = harness(&artifacts, &model)?;
            let rows = sweep::kv_sensitivity(&h, args.get_usize("n-early", 4)?)?;
            println!("{}", report::kv_sens(&model, &rows));
        }
        "search" => {
            let model = args.get_str("model", "smollm2-sim");
            let budget = args.get_usize("budget", 6)?;
            let h = harness(&artifacts, &model)?;
            let res = search::heuristic_search(&h, budget)?;
            println!("heuristic search on {model} (§3.2, budget {budget} evals):");
            for s in &res.steps {
                println!("  {:32} {:+.4}", s.tag, s.delta_ppl);
            }
            println!(
                "best: {} dPPL {:+.4} ({} evals, {:.2} angle bits)",
                res.best.tag(),
                res.best_delta,
                res.evals_used,
                res.best.angle_bits_per_element()
            );
        }
        "uniformity" => uniformity(args.get_usize("d", 64)?, args.get_usize("rows", 8192)?),
        "bits" => bits_calculator(args.get_usize("layers", 32)?, args.get_usize("d", 128)?),
        "serve" => {
            let mut known = vec![
                "artifacts",
                "model",
                "sim",
                "sim-layers",
                "requests",
                "gen-max",
                "read-path",
                "prefix-cache",
                "chunked-prefill",
                "chunk-tokens",
                "tick-token-budget",
                "trace",
                "trace-out",
                "sample-every",
            ];
            known.extend_from_slice(spec::FLAGS);
            args.check_known(&known)?;
            let quant_spec = QuantSpec::from_args(&args, "k8v4log")?;
            let (chunked, chunk_tokens, tick_budget) = parse_chunk_flags(&args)?;
            let (trace, trace_out, sample_every) = parse_trace_flags(&args)?;
            let read_path = parse_read_path(&args.get_str("read-path", "auto"))?;
            let prefix_cache = parse_on_off("prefix-cache", &args.get_str("prefix-cache", "on"))?;
            let requests = args.get_usize("requests", 12)?;
            let gen_max = args.get_usize("gen-max", 8)?;
            let mk_cfg = |quant: QuantConfig| {
                let mut cfg = EngineConfig::new(quant);
                cfg.read_path = read_path;
                cfg.prefix_cache = prefix_cache;
                cfg.chunked_prefill = chunked;
                cfg.chunk_tokens = chunk_tokens;
                cfg.tick_token_budget = tick_budget;
                cfg.trace = trace;
                cfg.sample_every = sample_every;
                cfg
            };
            let trace_out = trace_out.as_deref();
            if args.get_bool("sim") {
                let sim = sim_exec(args.get_usize("sim-layers", 8)?);
                let l = ModelBackend::profile(&sim).n_layers;
                run_serve("sim", sim, mk_cfg(quant_spec.build(l)?), requests, gen_max, trace_out)?;
            } else {
                if read_path == ReadPath::Fused {
                    bail!(
                        "--read-path fused requires a fused-capable backend (the PJRT \
                         executor has none; use --sim, auto, or reinflate)"
                    );
                }
                let model = args.get_str("model", "smollm2-sim");
                let manifest = Manifest::load(&artifacts)?;
                let rt = Runtime::cpu()?;
                eprintln!("compiling prefill+decode for {model} ...");
                let exec = ModelExecutor::load(&rt, &manifest, &model, Entry::Serve)?;
                ensure_chunked_support(&exec, chunked)?;
                let quant = quant_spec.build(exec.profile.n_layers)?;
                run_serve(&model, exec, mk_cfg(quant), requests, gen_max, trace_out)?;
            }
        }
        "seed-sweep" => {
            let model = args.get_str("model", "smollm2-sim");
            let seeds = args.get_usize("seeds", 5)?;
            let mut h = eval_harness(&args, &artifacts, &model)?;
            let label = if args.get_bool("sim") { "sim" } else { model.as_str() };
            println!("D-seed sensitivity on {label} ({seeds} diagonals; seed 0 = build-time D):");
            for (tag, sweep) in turboangle::eval::seeds::run_with(&mut h, seeds)? {
                println!(
                    "  {tag:28} dPPL mean {:+.4} ± {:.4}  [{:+.4}, {:+.4}]  {:?}",
                    sweep.mean,
                    sweep.std,
                    sweep.min,
                    sweep.max,
                    sweep.deltas.iter().map(|d| (d * 1e4).round() / 1e4).collect::<Vec<_>>()
                );
            }
            println!("(paper limitation addressed: differences below the spread above\n are seed noise, not signal)");
        }
        "allocate" => {
            let model = args.get_str("model", "smollm2-sim");
            let budget = args
                .flag("budget")
                .map(|v| v.parse::<f64>())
                .transpose()?
                .unwrap_or(3.5);
            let group = args.get_usize("group", 4)?;
            let h = harness(&artifacts, &model)?;
            let res = turboangle::eval::allocate::greedy_allocate(&h, budget, group, 512)?;
            println!("greedy bit allocation on {model} (budget {budget} angle bits, groups of {group}):");
            for s in &res.steps {
                println!(
                    "  +{}{}->{:<4}  dPPL {:+.4}  @ {:.3} bits",
                    s.side, s.layer, s.new_bins, s.delta_ppl, s.bits
                );
            }
            println!(
                "result: {} dPPL {:+.4} at {:.3} bits ({} evals)",
                res.best.tag(),
                res.best_delta,
                res.best.angle_bits_per_element(),
                res.evals_used
            );
        }
        "listen" => {
            let mut known = vec![
                "artifacts",
                "model",
                "addr",
                "max-requests",
                "replicas",
                "route-policy",
                "imbalance-bound",
                "shared-store",
                "sim",
                "sim-layers",
                "read-path",
                "prefix-cache",
                "chunked-prefill",
                "chunk-tokens",
                "tick-token-budget",
                "trace",
                "trace-out",
                "sample-every",
            ];
            known.extend_from_slice(spec::FLAGS);
            args.check_known(&known)?;
            let quant_spec = QuantSpec::from_args(&args, "k8v4log")?;
            let model = args.get_str("model", "smollm2-sim");
            let addr = args.get_str("addr", "127.0.0.1:7777");
            let max_requests = args.get_usize("max-requests", 0)?;
            let replicas = args.get_usize("replicas", 1)?;
            if replicas == 0 {
                bail!("--replicas must be >= 1 (got 0): each replica is one engine worker thread");
            }
            let imbalance_bound = args.get_usize("imbalance-bound", 4)?;
            let policy =
                parse_route_policy(&args.get_str("route-policy", "affinity"), imbalance_bound)?;
            let shared_node = match args.get_str("shared-store", "replica").as_str() {
                "node" => true,
                "replica" => false,
                other => bail!("--shared-store takes node|replica (got '{other}')"),
            };
            let read_path = parse_read_path(&args.get_str("read-path", "auto"))?;
            let prefix_cache = parse_on_off("prefix-cache", &args.get_str("prefix-cache", "on"))?;
            let (chunked, chunk_tokens, tick_budget) = parse_chunk_flags(&args)?;
            let (trace, trace_out, sample_every) = parse_trace_flags(&args)?;
            if read_path == ReadPath::Fused && !args.get_bool("sim") {
                // fail with a flag error, not an assert mid-construction:
                // the PJRT executor consumes dense HLO inputs only
                bail!("--read-path fused requires --sim (the PJRT backend has no fused decode path; use auto or reinflate)");
            }
            let engine_cfg = |l: usize| -> Result<EngineConfig> {
                let mut cfg = EngineConfig::new(quant_spec.build(l)?);
                cfg.read_path = read_path;
                cfg.prefix_cache = prefix_cache;
                cfg.chunked_prefill = chunked;
                cfg.chunk_tokens = chunk_tokens;
                cfg.tick_token_budget = tick_budget;
                cfg.trace = trace;
                cfg.sample_every = sample_every;
                Ok(cfg)
            };
            // `--shared-store node`: ONE content-addressed store, built on
            // first use (its capacity scales with the fleet) and cloned
            // into every replica's config
            let mut node_store: Option<Arc<SharedPageStore>> = None;
            let mut attach_store = |cfg: &mut EngineConfig| {
                if !shared_node {
                    return;
                }
                let cap = cfg.capacity_pages * replicas;
                let store = node_store.get_or_insert_with(|| SharedPageStore::node(cap));
                cfg.shared_store = Some(Arc::clone(store));
            };
            let mut engines: Vec<Box<dyn EngineCore>> = Vec::with_capacity(replicas);
            if args.get_bool("sim") {
                // identical seeds: the replicas serve the same "model"
                for _ in 0..replicas {
                    let sim = sim_exec(args.get_usize("sim-layers", 2)?);
                    let l = ModelBackend::profile(&sim).n_layers;
                    let mut cfg = engine_cfg(l)?;
                    attach_store(&mut cfg);
                    engines.push(Box::new(Engine::new(sim, cfg)));
                }
            } else {
                let manifest = Manifest::load(&artifacts)?;
                let rt = Runtime::cpu()?;
                for _ in 0..replicas {
                    let exec = ModelExecutor::load(&rt, &manifest, &model, Entry::Serve)?;
                    ensure_chunked_support(&exec, chunked)?;
                    let l = exec.profile.n_layers;
                    let mut cfg = engine_cfg(l)?;
                    attach_store(&mut cfg);
                    engines.push(Box::new(Engine::new(exec, cfg)));
                }
            }
            let summary =
                turboangle::coordinator::server::serve(engines, &addr, policy, max_requests)?;
            println!("served {} requests across {replicas} replicas", summary.served);
            for (i, m) in summary.replicas.iter().enumerate() {
                println!("-- replica {i} --\n{}", m.report());
            }
            if replicas > 1 {
                let mut fleet = EngineMetrics::default();
                for m in &summary.replicas {
                    fleet.merge(m);
                }
                println!("-- fleet (histogram-merged across {replicas} replicas) --");
                println!("{}", fleet.report());
            }
            if let Some(path) = &trace_out {
                write_trace(path, &summary.traces)?;
            }
        }
        "selfcheck" => selfcheck(&artifacts)?,
        "eval" => {
            let mut known = vec!["artifacts", "model", "sim", "sim-layers"];
            known.extend_from_slice(spec::FLAGS);
            args.check_known(&known)?;
            let quant_spec = QuantSpec::from_args(&args, "fp32")?;
            let model = args.get_str("model", "smollm2-sim");
            let h = eval_harness(&args, &artifacts, &model)?;
            let cfg = quant_spec.build(h.n_layers())?;
            let label = if args.get_bool("sim") { "sim" } else { model.as_str() };
            let base = h.baseline_ppl()?;
            let ppl = h.ppl(&cfg)?;
            println!(
                "{label}: PPL {ppl:.4} (ref {base:.4}) dPPL {:+.4} | {} | {:.2} angle bits, {:.2} total bits",
                ppl - base,
                cfg.tag(),
                cfg.angle_bits_per_element(),
                cfg.total_bits_per_element(h.d_head())
            );
        }
        "" | "help" | "--help" => println!("{USAGE}"),
        other => bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
    Ok(())
}

/// Native uniformity evidence: chi² + max-deviation on hostile
/// heteroscedastic rows, rotated vs raw.
fn uniformity(d: usize, rows: usize) {
    let mut rng = workload::Rng::new(99);
    let sign = fwht::test_sign_diag(d, 7);
    let gauss = |s: &mut workload::Rng| {
        let u1 = s.uniform().max(1e-12);
        let u2 = s.uniform();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    };
    let scales: Vec<f32> = (0..d).map(|_| (0.6 * gauss(&mut rng)).exp()).collect();
    let bins = 32usize;
    let mut hist_rot = vec![0u64; bins];
    let mut hist_raw = vec![0u64; bins];
    let mut x = vec![0.0f32; d];
    for _ in 0..rows {
        let common = gauss(&mut rng);
        for i in 0..d {
            x[i] = (gauss(&mut rng) + 0.3 * common) * scales[i];
        }
        let collect = |v: &[f32], hist: &mut [u64]| {
            for p in 0..d / 2 {
                let theta = v[2 * p + 1].atan2(v[2 * p]);
                let t = if theta < 0.0 { theta + angle::TWO_PI } else { theta };
                let b = ((t / angle::TWO_PI * bins as f32) as usize).min(bins - 1);
                hist[b] += 1;
            }
        };
        collect(&x, &mut hist_raw);
        let mut y = x.clone();
        fwht::rotate(&mut y, &sign);
        collect(&y, &mut hist_rot);
    }
    let expected = (rows * d / 2) as f64 / bins as f64;
    let stats = |hist: &[u64]| -> (f64, f64) {
        let chi2 = hist
            .iter()
            .map(|&c| (c as f64 - expected).powi(2) / expected)
            .sum();
        let maxdev = hist
            .iter()
            .map(|&c| (c as f64 / expected - 1.0).abs())
            .fold(0.0, f64::max);
        (chi2, maxdev)
    };
    let (c_rot, d_rot) = stats(&hist_rot);
    let (c_raw, d_raw) = stats(&hist_raw);
    println!("angle uniformity, d={d}, {rows} hostile heteroscedastic rows, 32 bins");
    println!("  rotated (H·D): chi2 {c_rot:10.1}  max-dev {:5.1}%", d_rot * 100.0);
    println!("  raw          : chi2 {c_raw:10.1}  max-dev {:5.1}%", d_raw * 100.0);
    println!("  histogram (rotated): {hist_rot:?}");
    println!("  histogram (raw)    : {hist_raw:?}");
}

fn bits_calculator(layers: usize, d: usize) {
    println!("rate accounting (Eq. 1 / Eq. 3), L={layers}, d={d}");
    let rows: Vec<(&str, QuantConfig)> = vec![
        ("uniform K128V64 (fp32 norms)", QuantConfig::paper_uniform(layers)),
        (
            "E4 (256,128) (fp32 norms)",
            QuantConfig::early_boost(layers, 4, 256, 128),
        ),
        (
            "uniform + norm8",
            QuantConfig::paper_uniform(layers).with_norm8(),
        ),
        (
            "uniform + K8V4-log",
            QuantConfig::paper_uniform(layers).with_k8v4_log(),
        ),
        (
            "E4 (256,128) + K8V4-log",
            QuantConfig::early_boost(layers, 4, 256, 128).with_k8v4_log(),
        ),
    ];
    for (name, cfg) in rows {
        println!(
            "  {name:32} angle {:.4} b/elem, total {:.4} b/elem",
            cfg.angle_bits_per_element(),
            cfg.total_bits_per_element(d)
        );
    }
}

/// One synthetic-workload serve run over any backend — the `serve`
/// subcommand routes both the PJRT executor and `--sim` here, so a boost
/// schedule proven in the sim sweep serves identically on either.
fn run_serve<B: ModelBackend>(
    model: &str,
    exec: B,
    cfg: EngineConfig,
    requests: usize,
    gen_max: usize,
    trace_out: Option<&str>,
) -> Result<()> {
    let mut engine = Engine::new(exec, cfg);
    let spec = WorkloadSpec {
        n_requests: requests,
        gen_max,
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    for req in workload::generate(&spec) {
        engine.submit(req);
    }
    engine.run_to_completion()?;
    let wall = t0.elapsed();
    let mem = engine.memory_stats();
    println!("== serve run: {model}, {requests} requests, wall {wall:?}");
    println!("{}", engine.metrics.report());
    println!(
        "throughput: {:.1} tok/s (decode), {:.2} req/s",
        engine.metrics.tokens_generated as f64 / wall.as_secs_f64(),
        engine.metrics.requests_finished as f64 / wall.as_secs_f64()
    );
    println!("{}", mem.report());
    for s in engine.take_finished().iter().take(3) {
        let text: String = s
            .generated
            .iter()
            .map(|&t| {
                if (32..127).contains(&t) {
                    (t as u8) as char
                } else {
                    '·'
                }
            })
            .collect();
        println!("  req {} ({} prompt tok) -> {:?}", s.request.id, s.prompt_len, text);
    }
    if let Some(path) = trace_out {
        write_trace(path, &[engine.obs_snapshot()])?;
    }
    Ok(())
}

/// Write the merged Chrome trace for one or more replica snapshots and
/// print a one-line summary (span/gauge counts, ring drops).
fn write_trace(path: &str, traces: &[ObsSnapshot]) -> Result<()> {
    let events: usize = traces.iter().map(|t| t.events.len()).sum();
    let gauges: usize = traces.iter().map(|t| t.gauges.len()).sum();
    let dropped: u64 = traces.iter().map(|t| t.dropped_events).sum();
    std::fs::write(path, export::chrome_trace(traces))?;
    println!(
        "trace: {events} spans + {gauges} gauge samples from {} replica(s) -> {path} \
         ({dropped} ring-dropped)",
        traces.len()
    );
    Ok(())
}

/// Golden + HLO cross-validation of the quantizer stack.
fn selfcheck(artifacts: &str) -> Result<()> {
    let manifest = Manifest::load(artifacts)?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());
    let mut failures = 0;
    for d in [64usize, 128] {
        let g = tensorfile::read(manifest.path(&format!("golden/golden_d{d}.tang")))?;
        let x = g["x"].as_f32()?;
        let sign = g["sign"].as_f32()?;
        let rows = g["x"].shape[0];
        // native rotate vs python
        let rot = g["rotated"].as_f32()?;
        let mut max_err = 0.0f32;
        for r in 0..rows {
            let mut y = x[r * d..(r + 1) * d].to_vec();
            fwht::rotate(&mut y, &sign[..d]);
            for (a, b) in y.iter().zip(&rot[r * d..(r + 1) * d]) {
                max_err = max_err.max((a - b).abs());
            }
        }
        println!("d={d} rotate vs oracle: max err {max_err:.2e}");
        failures += (max_err > 1e-4) as u32;
        // native encode/decode vs python for each n
        for n in [48u32, 64, 128, 256] {
            let rk = g[&format!("r_n{n}")].as_f32()?;
            let kk = g[&format!("k_n{n}")].as_f32()?;
            let dec = g[&format!("dec_n{n}")].as_f32()?;
            let half = d / 2;
            let (mut er, mut ek, mut ed) = (0.0f32, 0usize, 0.0f32);
            for r in 0..rows {
                let e = angle::encode(&x[r * d..(r + 1) * d], &sign[..d], n);
                for i in 0..half {
                    er = er.max((e.r[i] - rk[r * half + i]).abs());
                    ek += (e.k[i] as f32 != kk[r * half + i]) as usize;
                }
                let xh = angle::decode(&e.r, &e.k, &sign[..d], n, false);
                for (a, b) in xh.iter().zip(&dec[r * d..(r + 1) * d]) {
                    ed = ed.max((a - b).abs());
                }
            }
            println!("d={d} n={n}: r err {er:.2e}, bin mismatches {ek}, decode err {ed:.2e}");
            failures += (er > 1e-3 || ek > rows * half / 100 || ed > 1e-2) as u32;
        }
        // norm quant vs python
        let r64 = g["r_n64"].as_f32()?;
        let half = d / 2;
        for (name, mode) in [
            ("normq_b8_log0", NormMode::LINEAR8),
            ("normq_b4_log1", NormMode::LOG4),
            ("normq_b4_log0", NormMode { bits: 4, log_space: false }),
        ] {
            let want = g[name].as_f32()?;
            let mut err = 0.0f32;
            for row in 0..rows {
                let rq = norm::quant_dequant(&r64[row * half..(row + 1) * half], mode);
                for (a, b) in rq.iter().zip(&want[row * half..(row + 1) * half]) {
                    err = err.max((a - b).abs() / b.abs().max(1e-3));
                }
            }
            println!("d={d} {name}: max rel err {err:.2e}");
            failures += (err > 1e-2) as u32;
        }
        // HLO kernel artifact vs native
        let enc_prog = rt.load(manifest.path(&format!("kernels.encode.d{d}.hlo.txt")))?;
        let rows_k = 1024usize;
        let mut xk = vec![0.0f32; rows_k * d];
        let mut s = 12345u64;
        for v in xk.iter_mut() {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            *v = ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32) * 4.0
                - 2.0;
        }
        let args = [
            turboangle::runtime::pjrt::lit_f32(&[rows_k, d], &xk)?,
            turboangle::runtime::pjrt::lit_f32(&[d], &sign[..d])?,
            turboangle::runtime::pjrt::lit_scalar_f32(64.0),
        ];
        let out = enc_prog.run(&args.iter().collect::<Vec<_>>())?;
        let hr = turboangle::runtime::pjrt::to_f32(&out[0])?;
        let hk = turboangle::runtime::pjrt::to_f32(&out[1])?;
        let half = d / 2;
        let (mut er, mut ek) = (0.0f32, 0usize);
        for row in 0..rows_k {
            let e = angle::encode(&xk[row * d..(row + 1) * d], &sign[..d], 64);
            for i in 0..half {
                er = er.max((e.r[i] - hr[row * half + i]).abs());
                ek += (e.k[i] as f32 != hk[row * half + i]) as usize;
            }
        }
        println!(
            "d={d} HLO encode vs native: r err {er:.2e}, bin mismatches {ek}/{}",
            rows_k * half
        );
        failures += (er > 1e-3 || ek > rows_k * half / 1000) as u32;
    }
    if failures > 0 {
        anyhow::bail!("selfcheck FAILED ({failures} checks)");
    }
    println!("selfcheck OK");
    Ok(())
}
