//! Synthetic serving workloads (the testbed stand-in for production
//! request traces — DESIGN.md §2).

use crate::coordinator::session::Request;

/// Deterministic xorshift RNG so workloads are reproducible.
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 ^= self.0 >> 12;
        self.0 ^= self.0 << 25;
        self.0 ^= self.0 >> 27;
        self.0.wrapping_mul(0x2545F4914F6CDD1D)
    }

    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Exponential inter-arrival (Poisson process), seconds.
    pub fn exp(&mut self, rate_per_s: f64) -> f64 {
        -self.uniform().max(1e-12).ln() / rate_per_s
    }

    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        lo + (self.next_u64() as usize) % (hi - lo)
    }
}

/// Prompt-length / generation-length mix.
///
/// With `n_prefixes > 0`, requests draw round-robin from a pool of
/// `n_prefixes` shared system prompts of `prefix_len` tokens each, and
/// `prompt_min..=prompt_max` bounds the PRIVATE tail appended after the
/// shared prefix — the shape real deployments have (common system prompt +
/// per-user remainder), and the workload the prefix cache is measured on.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadSpec {
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub gen_min: usize,
    pub gen_max: usize,
    pub n_requests: usize,
    pub seed: u64,
    /// Number of distinct multi-turn sessions to spread requests over
    /// (0 = no session keys). Exercises session-affinity routing.
    pub sessions: usize,
    /// Shared system prompts requests draw from (0 = every prompt private).
    pub n_prefixes: usize,
    /// Tokens per shared prefix (ignored when `n_prefixes == 0`).
    pub prefix_len: usize,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec {
            prompt_min: 16,
            prompt_max: 64,
            gen_min: 8,
            gen_max: 32,
            n_requests: 16,
            seed: 42,
            sessions: 0,
            n_prefixes: 0,
            prefix_len: 0,
        }
    }
}

/// One corpus-alphabet token (lowercase + space).
fn corpus_token(rng: &mut Rng) -> i32 {
    let r = rng.range(0, 27);
    if r == 26 {
        32
    } else {
        97 + r as i32
    }
}

/// Byte-level prompts drawn from the corpus alphabet (lowercase + space).
pub fn generate(spec: &WorkloadSpec) -> Vec<Request> {
    let mut rng = Rng::new(spec.seed);
    // clamp inverted bounds (e.g. a CLI --prompt-max below the default
    // min) on BOTH the generation and prompt ranges — `Rng::range`
    // already guards hi <= lo, but only the clamp keeps the drawn values
    // inside the [lo.min(hi), hi] interval the caller meant
    let gen_min = spec.gen_min.min(spec.gen_max);
    let prompt_min = spec.prompt_min.min(spec.prompt_max);
    // the pool of shared system prompts requests draw from, round-robin
    let prefixes: Vec<Vec<i32>> = (0..spec.n_prefixes)
        .map(|_| (0..spec.prefix_len).map(|_| corpus_token(&mut rng)).collect())
        .collect();
    (0..spec.n_requests)
        .map(|i| {
            let plen = rng.range(prompt_min, spec.prompt_max + 1);
            let glen = rng.range(gen_min, spec.gen_max + 1);
            let mut prompt: Vec<i32> = if prefixes.is_empty() {
                Vec::with_capacity(plen)
            } else {
                prefixes[i % prefixes.len()].clone()
            };
            prompt.extend((0..plen).map(|_| corpus_token(&mut rng)));
            let req = Request::new(i as u64, prompt, glen);
            if spec.sessions > 0 {
                req.with_session_key((i % spec.sessions) as u64)
            } else {
                req
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(&WorkloadSpec::default());
        let b = generate(&WorkloadSpec::default());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
        }
    }

    #[test]
    fn inverted_bounds_clamp() {
        let spec = WorkloadSpec {
            gen_min: 8,
            gen_max: 3,
            n_requests: 20,
            ..Default::default()
        };
        for r in generate(&spec) {
            assert!(r.max_new_tokens <= 3, "{}", r.max_new_tokens);
        }
    }

    #[test]
    fn inverted_prompt_bounds_clamp() {
        // regression: the prompt range gets the same clamp as gen — an
        // inverted --prompt-min/--prompt-max from the CLI (easy to hit
        // when tuning prefix_len) must behave as [max, max], never panic
        // or draw outside the intended interval
        let spec = WorkloadSpec {
            prompt_min: 40,
            prompt_max: 6,
            n_requests: 30,
            ..Default::default()
        };
        for r in generate(&spec) {
            assert!(r.prompt.len() <= 6, "prompt len {}", r.prompt.len());
        }
        // and with a shared prefix, the clamp applies to the private tail
        let spec = WorkloadSpec {
            prompt_min: 40,
            prompt_max: 6,
            n_requests: 12,
            n_prefixes: 2,
            prefix_len: 5,
            ..Default::default()
        };
        for r in generate(&spec) {
            assert!(r.prompt.len() <= 5 + 6, "prompt len {}", r.prompt.len());
            assert!(r.prompt.len() >= 5);
        }
    }

    #[test]
    fn shared_prefixes_are_drawn_round_robin() {
        let spec = WorkloadSpec {
            prompt_min: 2,
            prompt_max: 6,
            n_requests: 9,
            n_prefixes: 3,
            prefix_len: 8,
            ..Default::default()
        };
        let reqs = generate(&spec);
        // request i shares its first prefix_len tokens with request i+3
        for i in 0..6 {
            assert_eq!(
                &reqs[i].prompt[..8],
                &reqs[i + 3].prompt[..8],
                "requests {i} and {} must share a prefix",
                i + 3
            );
        }
        // the three prefixes are pairwise distinct
        assert_ne!(&reqs[0].prompt[..8], &reqs[1].prompt[..8]);
        assert_ne!(&reqs[1].prompt[..8], &reqs[2].prompt[..8]);
        // tails are private: lengths bounded by prefix_len + prompt_max
        for r in &reqs {
            assert!(r.prompt.len() >= 8 + 2 && r.prompt.len() <= 8 + 6);
            assert!(r.prompt.iter().all(|&t| t == 32 || (97..123).contains(&t)));
        }
        // deterministic across calls
        let again = generate(&spec);
        for (a, b) in reqs.iter().zip(&again) {
            assert_eq!(a.prompt, b.prompt);
        }
    }

    #[test]
    fn respects_bounds() {
        let spec = WorkloadSpec {
            prompt_min: 4,
            prompt_max: 8,
            gen_min: 2,
            gen_max: 3,
            n_requests: 50,
            seed: 7,
            sessions: 0,
            ..Default::default()
        };
        for r in generate(&spec) {
            assert!(r.prompt.len() >= 4 && r.prompt.len() <= 8);
            assert!(r.max_new_tokens >= 2 && r.max_new_tokens <= 3);
            assert!(r.prompt.iter().all(|&t| t == 32 || (97..123).contains(&t)));
        }
    }

    #[test]
    fn session_keys_assigned_round_robin() {
        let spec = WorkloadSpec {
            n_requests: 8,
            sessions: 3,
            ..Default::default()
        };
        let reqs = generate(&spec);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.session_key, Some((i % 3) as u64));
        }
        // default: no keys
        assert!(generate(&WorkloadSpec::default())
            .iter()
            .all(|r| r.session_key.is_none()));
    }

    #[test]
    fn poisson_interarrivals_positive() {
        let mut rng = Rng::new(3);
        let mean: f64 = (0..1000).map(|_| rng.exp(10.0)).sum::<f64>() / 1000.0;
        assert!(mean > 0.05 && mean < 0.2, "mean {mean}");
    }
}
