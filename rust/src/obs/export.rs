//! Trace exporters: Chrome trace-event JSON (Perfetto-loadable) and a
//! Prometheus-style text exposition.
//!
//! Both are hand-rolled string builders (the repo has no serde): the
//! Chrome format is the `{"traceEvents":[...]}` array-of-objects schema
//! with `"ph":"X"` complete spans (pid = replica, tid = request id) and
//! `"ph":"C"` counter tracks for the sampled gauges; the Prometheus
//! format is the plain `# TYPE`/`name{labels} value` text exposition,
//! shipped over the line-oriented wire protocol as a JSON-escaped string
//! (`{"id":N,"metrics":true}` → `{"id":N,"replica":i,"metrics":"..."}`).
//! Field-by-field schema docs live in `docs/OBSERVABILITY.md`.

use super::{ObsSnapshot, StageStats};
use crate::coordinator::{EngineMetrics, MemoryStats};
use std::fmt::Write as _;

/// Render per-replica observability snapshots as one Chrome trace-event
/// JSON document. Load the result in Perfetto / `chrome://tracing`:
/// each replica is a process, each request id a track, and the gauges
/// appear as counter tracks on the same microsecond timeline.
pub fn chrome_trace(replicas: &[ObsSnapshot]) -> String {
    let mut out = String::from("{\"displayTimeUnit\": \"ms\", \"traceEvents\": [");
    let mut first = true;
    for (pid, snap) in replicas.iter().enumerate() {
        for ev in &snap.events {
            if !first {
                out.push(',');
            }
            first = false;
            let _ = write!(
                out,
                "\n{{\"name\": \"{}\", \"cat\": \"request\", \"ph\": \"X\", \
                 \"ts\": {}, \"dur\": {}, \"pid\": {}, \"tid\": {}, \
                 \"args\": {{\"tick\": {}, \"arg\": {}}}}}",
                ev.kind.name(),
                ev.at_us,
                ev.dur_us,
                pid,
                ev.request_id,
                ev.tick,
                ev.arg,
            );
        }
        for g in &snap.gauges {
            for (name, body) in [
                (
                    "pool_pages",
                    format!(
                        "\"used\": {}, \"reserved\": {}, \"capacity\": {}",
                        g.pages_used, g.pages_reserved, g.pages_capacity
                    ),
                ),
                (
                    "shared_store",
                    format!("\"pages\": {}, \"refs\": {}", g.shared_pages, g.shared_refs),
                ),
                ("swap_pool", format!("\"bytes\": {}", g.swap_bytes)),
                ("queue_depth", format!("\"requests\": {}", g.queue_depth)),
            ] {
                if !first {
                    out.push(',');
                }
                first = false;
                let _ = write!(
                    out,
                    "\n{{\"name\": \"{name}\", \"ph\": \"C\", \"ts\": {}, \
                     \"pid\": {pid}, \"args\": {{{body}}}}}",
                    g.at_us,
                );
            }
            if !g.layer_bits_per_element.is_empty() {
                if !first {
                    out.push(',');
                }
                first = false;
                let mut body = String::new();
                for (l, bpe) in g.layer_bits_per_element.iter().enumerate() {
                    if l > 0 {
                        body.push_str(", ");
                    }
                    let _ = write!(body, "\"L{l}\": {bpe:.4}");
                }
                let _ = write!(
                    out,
                    "\n{{\"name\": \"bits_per_element\", \"ph\": \"C\", \
                     \"ts\": {}, \"pid\": {pid}, \"args\": {{{body}}}}}",
                    g.at_us,
                );
            }
        }
    }
    let total_dropped: u64 = replicas.iter().map(|s| s.dropped_events).sum();
    let _ = write!(
        out,
        "\n], \"otherData\": {{\"dropped_events\": {total_dropped}, \"replicas\": {}}}}}",
        replicas.len()
    );
    out
}

/// Escape a string for embedding inside a JSON string literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 8);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render one replica's metrics as Prometheus text exposition (the body
/// of the `{"id":N,"metrics":true}` wire response). Counters are
/// `_total`-suffixed, histograms expose `quantile`-labelled gauges plus
/// `_count`/`_sum_us`, and the fused-path stage timers appear as
/// `turboangle_stage_ns_total{stage=...}`.
pub fn prometheus(
    replica: usize,
    m: &EngineMetrics,
    mem: &MemoryStats,
    queue_depth: usize,
    stage: &StageStats,
) -> String {
    let r = replica;
    let mut o = String::with_capacity(4096);
    let mut counter = |o: &mut String, name: &str, help: &str, v: u64| {
        let _ = write!(
            o,
            "# HELP turboangle_{name} {help}\n# TYPE turboangle_{name} counter\nturboangle_{name}{{replica=\"{r}\"}} {v}\n",
        );
    };
    counter(&mut o, "requests_submitted_total", "Requests handed to submit.", m.requests_submitted);
    counter(&mut o, "requests_finished_total", "Sessions retired.", m.requests_finished);
    counter(&mut o, "tokens_generated_total", "Decode tokens produced.", m.tokens_generated);
    counter(&mut o, "prefill_chunks_total", "Chunked-prefill slices run.", m.prefill_chunks);
    counter(&mut o, "decode_steps_total", "Decode steps executed.", m.decode_steps);
    counter(&mut o, "preemptions_total", "Sessions swapped out under pressure.", m.preemptions);
    counter(&mut o, "swap_ins_total", "Preempted sessions restored.", m.swap_ins);
    counter(&mut o, "rejected_cache_full_total", "Requests rejected as unfittable.", m.rejected_cache_full);
    counter(&mut o, "prefix_hits_total", "Admissions that adopted shared prefix pages.", m.prefix_hits);
    counter(&mut o, "prefix_misses_total", "Admissions with no cached prefix.", m.prefix_misses);
    counter(&mut o, "prefix_adopt_requeues_total", "Seatings requeued after a concurrent replica evicted matched pages.", m.prefix_adopt_requeues);

    for (name, help, h) in [
        ("ttft_us", "Time to first token.", &m.ttft),
        ("itl_us", "Inter-token latency.", &m.itl),
        ("e2e_us", "Request end-to-end latency.", &m.e2e),
        ("decode_step_us", "Per decode step latency.", &m.decode_step_latency),
    ] {
        let _ = write!(o, "# HELP turboangle_{name} {help}\n# TYPE turboangle_{name} summary\n");
        for (q, d) in [(0.5, h.quantile(0.5)), (0.95, h.quantile(0.95)), (0.99, h.quantile(0.99))] {
            let _ = write!(
                o,
                "turboangle_{name}{{replica=\"{r}\",quantile=\"{q}\"}} {}\n",
                d.as_micros()
            );
        }
        let _ = write!(o, "turboangle_{name}_count{{replica=\"{r}\"}} {}\n", h.count());
        let _ = write!(o, "turboangle_{name}_sum{{replica=\"{r}\"}} {}\n", h.sum_us());
    }

    let mut gauge = |o: &mut String, name: &str, help: &str, v: u64| {
        let _ = write!(
            o,
            "# HELP turboangle_{name} {help}\n# TYPE turboangle_{name} gauge\nturboangle_{name}{{replica=\"{r}\"}} {v}\n",
        );
    };
    gauge(&mut o, "pool_pages_used", "Pool pages physically held.", mem.pages_allocated as u64);
    gauge(&mut o, "pool_pages_reserved", "Pool pages promised at admission.", mem.pages_reserved as u64);
    gauge(&mut o, "pool_pages_capacity", "Pool capacity in pages.", mem.pages_capacity as u64);
    gauge(&mut o, "shared_pages", "Shared prefix-store pages.", mem.shared_pages as u64);
    gauge(&mut o, "shared_refs", "References onto shared pages.", mem.shared_refs as u64);
    gauge(
        &mut o,
        "shared_store_id",
        "Process-unique shared-store identity; node-scoped replicas report the same id, so fleet roll-ups count each store once.",
        mem.shared_store_id,
    );
    gauge(&mut o, "swap_bytes", "Swapped compressed stream bytes.", mem.swapped_bytes as u64);
    gauge(&mut o, "queue_depth", "Requests queued, seated, or preempted.", queue_depth as u64);

    let _ = write!(
        o,
        "# HELP turboangle_stage_ns_total Fused read-path time on sampled ticks.\n\
         # TYPE turboangle_stage_ns_total counter\n"
    );
    for (s, ns) in [
        ("unpack", stage.unpack_ns),
        ("gather", stage.gather_ns),
        ("score", stage.score_ns),
    ] {
        let _ = write!(o, "turboangle_stage_ns_total{{replica=\"{r}\",stage=\"{s}\"}} {ns}\n");
    }
    let _ = write!(
        o,
        "# HELP turboangle_stage_sampled_ticks Ticks that contributed stage samples.\n\
         # TYPE turboangle_stage_sampled_ticks counter\n\
         turboangle_stage_sampled_ticks{{replica=\"{r}\"}} {}\n",
        stage.sampled_ticks
    );
    o
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{EventKind, GaugeSample, TraceEvent};
    use crate::util::json::Json;

    fn snap() -> ObsSnapshot {
        ObsSnapshot {
            events: vec![
                TraceEvent {
                    kind: EventKind::Queued,
                    request_id: 1,
                    tick: 0,
                    at_us: 10,
                    dur_us: 0,
                    arg: 4,
                },
                TraceEvent {
                    kind: EventKind::Finish,
                    request_id: 1,
                    tick: 9,
                    at_us: 10,
                    dur_us: 900,
                    arg: 6,
                },
            ],
            gauges: vec![GaugeSample {
                tick: 8,
                at_us: 500,
                pages_used: 3,
                pages_reserved: 4,
                pages_capacity: 64,
                layer_bits_per_element: vec![2.25, 4.5],
                ..Default::default()
            }],
            dropped_events: 0,
            stage: StageStats::default(),
        }
    }

    #[test]
    fn chrome_trace_is_valid_json_with_spans_and_counters() {
        let doc = chrome_trace(&[snap(), ObsSnapshot::default()]);
        let j = Json::parse(&doc).expect("exported trace must parse");
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        let spans: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "X")
            .collect();
        assert_eq!(spans.len(), 2);
        assert!(spans.iter().any(|e| e.get("name").unwrap().as_str().unwrap() == "finish"
            && e.get("dur").unwrap().as_u64().unwrap() == 900));
        let counters: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() == "C")
            .collect();
        assert_eq!(counters.len(), 5, "4 fixed tracks + per-layer bpe");
        assert!(counters
            .iter()
            .any(|e| e.get("name").unwrap().as_str().unwrap() == "bits_per_element"));
    }

    #[test]
    fn json_escape_handles_quotes_and_newlines() {
        let escaped = json_escape("a\"b\\c\nd");
        let wrapped = format!("{{\"s\": \"{escaped}\"}}");
        let j = Json::parse(&wrapped).unwrap();
        assert_eq!(j.get("s").unwrap().as_str().unwrap(), "a\"b\\c\nd");
    }

    #[test]
    fn prometheus_exposition_has_counters_gauges_and_quantiles() {
        let mut m = EngineMetrics::default();
        m.requests_finished = 2;
        m.ttft.record(std::time::Duration::from_micros(150));
        let mem = MemoryStats { pages_allocated: 7, ..Default::default() };
        let stage = StageStats { unpack_ns: 10, gather_ns: 20, score_ns: 30, sampled_ticks: 1 };
        let text = prometheus(1, &m, &mem, 3, &stage);
        assert!(text.contains("turboangle_requests_finished_total{replica=\"1\"} 2"));
        assert!(text.contains("turboangle_ttft_us{replica=\"1\",quantile=\"0.5\"} 150"));
        assert!(text.contains("turboangle_pool_pages_used{replica=\"1\"} 7"));
        assert!(text.contains("turboangle_stage_ns_total{replica=\"1\",stage=\"gather\"} 20"));
        // every non-comment line is `name{labels} value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert!(line.contains("{replica=\"1\""), "bad exposition line: {line}");
        }
    }
}
