//! Observability: request-lifecycle tracing, sampled gauges, fused-path
//! stage timers, and exporters.
//!
//! The serving counters in [`crate::coordinator::EngineMetrics`] answer
//! "how much"; this module answers "when and why". Each replica engine
//! owns a [`Recorder`] — a bounded, allocation-free-on-the-hot-path trace
//! ring of [`TraceEvent`]s with span semantics (queued → admitted /
//! rejected → prefill-chunk×N → first-token → decode-step×N → preempt /
//! swap-in → prefix-adopt → finish) — plus a tick-sampled [`GaugeSeries`]
//! (pool pages, shared-store pressure, swap bytes, queue depth, per-layer
//! achieved bits-per-element) and thread-local [`stage`] timers over the
//! fused read path (unpack / trig-gather / score).
//!
//! Everything drains through [`ObsSnapshot`] (`EngineCore::obs_snapshot`)
//! into two exporters in [`export`]: Chrome trace-event JSON
//! (`--trace-out FILE`, Perfetto-loadable) and Prometheus text exposition
//! (wire query `{"id":N,"metrics":true}`). Tracing is off by default and
//! costs one branch per record site; `--sample-every N` sets the gauge /
//! stage sampling stride. Schema and overhead model:
//! `docs/OBSERVABILITY.md`; overhead numbers: `BENCH_obs_overhead.json`.

pub mod export;
pub mod gauges;
pub mod stage;
pub mod trace;

pub use gauges::{GaugeSample, GaugeSeries};
pub use stage::{Stage, StageStats};
pub use trace::{EventKind, Recorder, TraceEvent, TraceRing};

/// Everything one replica has observed: drained trace events, the gauge
/// series, the ring's drop counter, and accumulated stage timers. This is
/// what `EngineCore::obs_snapshot` returns and what the exporters consume.
#[derive(Clone, Debug, Default)]
pub struct ObsSnapshot {
    /// Trace events in recording order (oldest first).
    pub events: Vec<TraceEvent>,
    /// Sampled gauge series, oldest first.
    pub gauges: Vec<GaugeSample>,
    /// Events lost to ring wrap-around (0 = the trace is complete).
    pub dropped_events: u64,
    /// Fused read-path stage timers accumulated over sampled ticks.
    pub stage: StageStats,
}
