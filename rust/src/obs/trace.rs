//! Bounded request-lifecycle trace ring.
//!
//! Every replica owns one [`Recorder`]: a preallocated ring of
//! [`TraceEvent`]s plus a monotonic epoch. Recording is designed for the
//! serving hot path — when tracing is disabled it is a single branch, and
//! when enabled a `push` is one clock read plus an indexed store into the
//! preallocated ring (no allocation, ever). When the ring wraps, the
//! oldest events are overwritten and a drop counter advances so exporters
//! can report truncation instead of silently lying.
//!
//! The recording functions (`TraceRing::push`, `Recorder::record`,
//! `Recorder::record_span`) are covered by the `no-alloc-in-hot-path` and
//! `no-nondeterminism-in-identity-paths` lints in `cargo xtask analyze`:
//! they must stay allocation-free and their clock reads must never feed
//! content hashes or scoring state.

use std::time::{Duration, Instant};

/// What happened to a request. One variant per lifecycle edge from the
/// span diagram in `docs/OBSERVABILITY.md`:
/// queued → admitted/rejected → prefill-chunk×N → first-token →
/// decode-step×N (interleaved with preempt/swap-in) → finish.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the waiting queue (`arg` = prompt tokens).
    Queued,
    /// Request was seated in a decode slot (`arg` = expected cache tokens).
    Admitted,
    /// Request was rejected because the pool cannot ever hold it.
    Rejected,
    /// One chunked-prefill slice ran (`arg` = tokens in the chunk). Span.
    PrefillChunk,
    /// The first generated token was emitted.
    FirstToken,
    /// One decode step advanced this request (`arg` = tokens generated so
    /// far). Span covering the batched step latency.
    DecodeStep,
    /// Request was preempted and its cache swapped out (`arg` = cached
    /// tokens at eviction).
    Preempt,
    /// Request was re-admitted from the swap pool.
    SwapIn,
    /// Admission adopted shared prefix pages (`arg` = pages adopted).
    PrefixAdopt,
    /// Request finished (`arg` = tokens generated). Span covering the
    /// whole arrival→retire lifetime; every other event for the same
    /// request nests inside it.
    Finish,
}

impl EventKind {
    /// Stable lower-snake name used by the exporters.
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Queued => "queued",
            EventKind::Admitted => "admitted",
            EventKind::Rejected => "rejected",
            EventKind::PrefillChunk => "prefill_chunk",
            EventKind::FirstToken => "first_token",
            EventKind::DecodeStep => "decode_step",
            EventKind::Preempt => "preempt",
            EventKind::SwapIn => "swap_in",
            EventKind::PrefixAdopt => "prefix_adopt",
            EventKind::Finish => "finish",
        }
    }
}

/// One recorded event. `Copy` and fixed-size so the ring is a flat
/// preallocated buffer.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Which lifecycle edge this is.
    pub kind: EventKind,
    /// The wire/request id the event belongs to.
    pub request_id: u64,
    /// Engine tick at which the event was recorded.
    pub tick: u64,
    /// Microseconds since the recorder's epoch at the START of the event
    /// (for instant events this is the moment of recording).
    pub at_us: u64,
    /// Span duration in microseconds; 0 for instant events.
    pub dur_us: u64,
    /// Kind-specific payload (see [`EventKind`] docs).
    pub arg: u64,
}

/// Fixed-capacity ring of [`TraceEvent`]s. Pushing never allocates; once
/// full, the oldest event is overwritten and `dropped` advances.
#[derive(Debug)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    /// Next write position (wraps at capacity).
    head: usize,
    /// Total events overwritten because the ring was full.
    dropped: u64,
    capacity: usize,
}

impl TraceRing {
    /// Preallocate a ring holding up to `capacity` events (min 1).
    pub fn new(capacity: usize) -> TraceRing {
        let capacity = capacity.max(1);
        TraceRing {
            buf: Vec::with_capacity(capacity),
            head: 0,
            dropped: 0,
            capacity,
        }
    }

    /// Append one event. Allocation-free: the buffer was sized at
    /// construction, so this is at most an indexed overwrite.
    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.capacity {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.dropped += 1;
        }
        self.head += 1;
        if self.head == self.capacity {
            self.head = 0;
        }
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True when no events have been recorded.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Events overwritten because the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Copy the held events out in recording order (oldest first).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        if self.buf.len() < self.capacity {
            self.buf.clone()
        } else {
            // Ring has wrapped: oldest event sits at `head`.
            let mut out = Vec::with_capacity(self.buf.len());
            out.extend_from_slice(&self.buf[self.head..]);
            out.extend_from_slice(&self.buf[..self.head]);
            out
        }
    }
}

/// Per-replica trace recorder: an enable flag, a monotonic epoch all
/// timestamps are relative to, and the bounded ring.
#[derive(Debug)]
pub struct Recorder {
    enabled: bool,
    epoch: Instant,
    ring: TraceRing,
}

impl Recorder {
    /// Build a recorder; when `enabled` is false every record call is a
    /// single branch and the ring stays empty.
    pub fn new(enabled: bool, capacity: usize) -> Recorder {
        Recorder {
            enabled,
            epoch: Instant::now(),
            ring: TraceRing::new(capacity),
        }
    }

    /// Whether recording is active.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Microseconds since the recorder epoch (shared clock for gauges so
    /// counter tracks line up with spans in the exported trace).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record an instant event (duration 0).
    #[inline]
    pub fn record(&mut self, kind: EventKind, request_id: u64, tick: u64, arg: u64) {
        if !self.enabled {
            return;
        }
        let at_us = self.epoch.elapsed().as_micros() as u64;
        self.ring.push(TraceEvent {
            kind,
            request_id,
            tick,
            at_us,
            dur_us: 0,
            arg,
        });
    }

    /// Record a span that ENDS now and lasted `dur`; `at_us` is
    /// back-dated so the exported span covers `[now - dur, now]`.
    #[inline]
    pub fn record_span(&mut self, kind: EventKind, request_id: u64, tick: u64, dur: Duration, arg: u64) {
        if !self.enabled {
            return;
        }
        let end_us = self.epoch.elapsed().as_micros() as u64;
        let dur_us = dur.as_micros() as u64;
        self.ring.push(TraceEvent {
            kind,
            request_id,
            tick,
            at_us: end_us.saturating_sub(dur_us),
            dur_us,
            arg,
        });
    }

    /// Events overwritten because the ring wrapped.
    pub fn dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// Number of events currently held.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Copy held events out in recording order.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        self.ring.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_holds_events_in_order_and_wraps() {
        let mut r = TraceRing::new(4);
        for i in 0..6u64 {
            r.push(TraceEvent {
                kind: EventKind::DecodeStep,
                request_id: i,
                tick: i,
                at_us: i,
                dur_us: 0,
                arg: 0,
            });
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 2);
        let ids: Vec<u64> = r.snapshot().iter().map(|e| e.request_id).collect();
        assert_eq!(ids, vec![2, 3, 4, 5], "oldest two overwritten, order kept");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let mut rec = Recorder::new(false, 16);
        rec.record(EventKind::Queued, 1, 0, 0);
        rec.record_span(EventKind::Finish, 1, 0, Duration::from_micros(5), 0);
        assert!(rec.is_empty());
    }

    #[test]
    fn span_is_backdated_to_cover_duration() {
        let mut rec = Recorder::new(true, 16);
        std::thread::sleep(Duration::from_millis(2));
        rec.record_span(EventKind::Finish, 7, 3, Duration::from_micros(1500), 9);
        let evs = rec.snapshot();
        assert_eq!(evs.len(), 1);
        let e = evs[0];
        assert_eq!(e.kind, EventKind::Finish);
        assert_eq!(e.dur_us, 1500);
        assert!(e.at_us + e.dur_us <= rec.now_us());
    }
}
