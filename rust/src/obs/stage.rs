//! Thread-local per-stage timing for the fused decode read path.
//!
//! The fused path's identity-critical files (`runtime/sim.rs`,
//! `coordinator/kv_manager.rs` decode helpers) ban the `Instant`
//! identifier outright via `cargo xtask analyze`, so they cannot read a
//! clock themselves. Instead they wrap their stages in [`time`], and the
//! clock read lives here — in one audited module — behind a thread-local
//! enable flag. When timing is disabled (the default, and every
//! non-sampled tick), [`time`] is one thread-local branch around the
//! closure; the engine flips the flag on only for ticks selected by the
//! `--sample-every` stride.
//!
//! Thread-locality is safe because the fused read path runs on the single
//! engine thread of each replica (the rayon-parallel dense fill paths are
//! deliberately not instrumented).

use std::cell::Cell;
use std::time::Instant;

/// The three stages of the fused dequant-attend read path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// Code/norm unpacking: `decode_side_range` over packed tiles.
    Unpack,
    /// Trig-table gather (`gather_trig`) feeding the polar reconstruction.
    Gather,
    /// Score accumulation: polar terms, fold, and row reduction.
    Score,
}

/// Accumulated per-stage wall time, plus how many engine ticks
/// contributed samples. Nanosecond sums so short stages don't vanish.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StageStats {
    /// Total nanoseconds spent unpacking codes/norms on sampled ticks.
    pub unpack_ns: u64,
    /// Total nanoseconds in trig-table gathers on sampled ticks.
    pub gather_ns: u64,
    /// Total nanoseconds in score accumulation on sampled ticks.
    pub score_ns: u64,
    /// Number of sampled ticks that contributed to the sums.
    pub sampled_ticks: u64,
}

impl StageStats {
    /// Fold one sampled tick's counters in (adds the sums, counts the
    /// tick).
    pub fn add_sample(&mut self, s: StageStats) {
        self.unpack_ns += s.unpack_ns;
        self.gather_ns += s.gather_ns;
        self.score_ns += s.score_ns;
        self.sampled_ticks += 1;
    }

    /// Fleet roll-up: add another replica's accumulated stats wholesale.
    pub fn merge(&mut self, o: &StageStats) {
        self.unpack_ns += o.unpack_ns;
        self.gather_ns += o.gather_ns;
        self.score_ns += o.score_ns;
        self.sampled_ticks += o.sampled_ticks;
    }
}

thread_local! {
    static ENABLED: Cell<bool> = const { Cell::new(false) };
    static UNPACK_NS: Cell<u64> = const { Cell::new(0) };
    static GATHER_NS: Cell<u64> = const { Cell::new(0) };
    static SCORE_NS: Cell<u64> = const { Cell::new(0) };
}

/// Turn stage timing on/off for the current thread. The engine enables
/// it only for sampled ticks, so untimed ticks pay one branch per stage.
pub fn set_enabled(on: bool) {
    ENABLED.with(|e| e.set(on));
}

/// Whether stage timing is currently enabled on this thread.
#[inline]
pub fn enabled() -> bool {
    ENABLED.with(|e| e.get())
}

/// Run `f`, attributing its wall time to `stage` when timing is enabled.
/// Disabled: one thread-local branch, then the closure runs untouched.
#[inline]
pub fn time<R>(stage: Stage, f: impl FnOnce() -> R) -> R {
    if !enabled() {
        return f();
    }
    let t0 = Instant::now();
    let r = f();
    let ns = t0.elapsed().as_nanos() as u64;
    let cell = match stage {
        Stage::Unpack => &UNPACK_NS,
        Stage::Gather => &GATHER_NS,
        Stage::Score => &SCORE_NS,
    };
    cell.with(|c| c.set(c.get() + ns));
    r
}

/// Drain the current thread's counters, resetting them to zero. Returns
/// sums with `sampled_ticks = 0`; callers fold via
/// [`StageStats::add_sample`] which counts the tick.
pub fn take() -> StageStats {
    StageStats {
        unpack_ns: UNPACK_NS.with(|c| c.replace(0)),
        gather_ns: GATHER_NS.with(|c| c.replace(0)),
        score_ns: SCORE_NS.with(|c| c.replace(0)),
        sampled_ticks: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timing_records_nothing() {
        set_enabled(false);
        let v = time(Stage::Gather, || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(take(), StageStats::default());
    }

    #[test]
    fn enabled_timing_attributes_to_the_right_stage() {
        set_enabled(true);
        let _ = take(); // reset any prior state on this test thread
        time(Stage::Unpack, || std::thread::sleep(std::time::Duration::from_micros(200)));
        time(Stage::Score, || std::thread::sleep(std::time::Duration::from_micros(200)));
        set_enabled(false);
        let s = take();
        assert!(s.unpack_ns > 0 && s.score_ns > 0);
        assert_eq!(s.gather_ns, 0);
        // take() drained the counters
        assert_eq!(take(), StageStats::default());
    }

    #[test]
    fn add_sample_counts_ticks_and_merge_adds_them() {
        let mut a = StageStats::default();
        a.add_sample(StageStats { unpack_ns: 5, gather_ns: 1, score_ns: 2, sampled_ticks: 0 });
        a.add_sample(StageStats { unpack_ns: 5, gather_ns: 1, score_ns: 2, sampled_ticks: 0 });
        assert_eq!(a.sampled_ticks, 2);
        let mut b = a;
        b.merge(&a);
        assert_eq!(b.unpack_ns, 20);
        assert_eq!(b.sampled_ticks, 4);
    }
}
