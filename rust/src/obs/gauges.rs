//! Tick-sampled gauge series: pool occupancy, shared-store pressure,
//! swap-pool size, queue depth, and per-layer achieved bits-per-element.
//!
//! The engine samples one [`GaugeSample`] every `--sample-every` ticks
//! (stride 1 = every tick) into a bounded [`GaugeSeries`]; exporters turn
//! the series into Chrome trace counter tracks so occupancy lines up with
//! request spans on the same timeline.

/// One sampled snapshot of replica-level gauges at a given tick.
#[derive(Clone, Debug, Default)]
pub struct GaugeSample {
    /// Engine tick at which the sample was taken.
    pub tick: u64,
    /// Microseconds since the replica's trace epoch (shared clock with
    /// [`crate::obs::TraceEvent::at_us`]).
    pub at_us: u64,
    /// Pool pages physically held (private + shared).
    pub pages_used: u64,
    /// Pool pages promised at admission (>= used).
    pub pages_reserved: u64,
    /// Pool capacity in pages (constant, kept per-sample so exported
    /// traces are self-describing).
    pub pages_capacity: u64,
    /// Immutable pages in the content-addressed shared prefix store.
    pub shared_pages: u64,
    /// Total sequence references onto shared pages.
    pub shared_refs: u64,
    /// Heap bytes of swapped-out compressed streams.
    pub swap_bytes: u64,
    /// Requests waiting or running: queued + seated + preempted.
    pub queue_depth: u64,
    /// Achieved total (angle + norm) bits per original fp16 element, per
    /// layer, across resident + shared + swapped streams. Empty when the
    /// cache is empty.
    pub layer_bits_per_element: Vec<f64>,
}

/// Bounded FIFO of gauge samples. When full, the oldest sample is
/// discarded and the drop counter advances.
#[derive(Clone, Debug)]
pub struct GaugeSeries {
    samples: std::collections::VecDeque<GaugeSample>,
    capacity: usize,
    dropped: u64,
}

impl GaugeSeries {
    /// Series bounded at `capacity` samples (min 1).
    pub fn new(capacity: usize) -> GaugeSeries {
        let capacity = capacity.max(1);
        GaugeSeries {
            samples: std::collections::VecDeque::with_capacity(capacity),
            capacity,
            dropped: 0,
        }
    }

    /// Append one sample, discarding the oldest when full.
    pub fn push(&mut self, s: GaugeSample) {
        if self.samples.len() == self.capacity {
            self.samples.pop_front();
            self.dropped += 1;
        }
        self.samples.push_back(s);
    }

    /// Number of samples currently held.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True when no samples have been taken.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Samples discarded because the series was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Copy the held samples out, oldest first.
    pub fn snapshot(&self) -> Vec<GaugeSample> {
        self.samples.iter().cloned().collect()
    }
}

impl Default for GaugeSeries {
    fn default() -> GaugeSeries {
        GaugeSeries::new(4096)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_bounds_and_drops_oldest() {
        let mut s = GaugeSeries::new(3);
        for tick in 0..5u64 {
            s.push(GaugeSample { tick, ..Default::default() });
        }
        assert_eq!(s.len(), 3);
        assert_eq!(s.dropped(), 2);
        let ticks: Vec<u64> = s.snapshot().iter().map(|g| g.tick).collect();
        assert_eq!(ticks, vec![2, 3, 4]);
    }
}
