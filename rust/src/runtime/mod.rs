//! Runtime layer: PJRT client, AOT artifact loading, weights, and the
//! model executor. Python never runs here — artifacts are self-contained.

pub mod backend;
pub mod executor;
pub mod manifest;
pub mod pjrt;
pub mod sim;
pub mod tensorfile;

pub use backend::{KvTileReader, KvTileView, ModelBackend};
pub use executor::{DecodeOut, Entry, ModelExecutor, PrefillOut};
pub use manifest::{Manifest, Profile};
pub use pjrt::{Program, Runtime};
pub use sim::SimExecutor;
