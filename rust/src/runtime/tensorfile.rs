//! Reader/writer for the TANG tensor container (see
//! `python/compile/tensorfile.py` for the format spec). Build-time python
//! writes weights/golden vectors; this side loads them at runtime.

use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"TANG";
const VERSION: u32 = 1;

/// Element type codes (must match python `_CODES`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    U8 = 2,
}

/// One named tensor: shape + raw little-endian payload.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

impl Tensor {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == DType::F32, "tensor is not f32");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn as_i32(&self) -> Result<Vec<i32>> {
        ensure!(self.dtype == DType::I32, "tensor is not i32");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    pub fn from_f32(shape: &[usize], values: &[f32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor {
            dtype: DType::F32,
            shape: shape.to_vec(),
            data: values.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }

    pub fn from_i32(shape: &[usize], values: &[i32]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor {
            dtype: DType::I32,
            shape: shape.to_vec(),
            data: values.iter().flat_map(|v| v.to_le_bytes()).collect(),
        }
    }
}

/// Load every tensor in a TANG file (order-preserving by name).
pub fn read<P: AsRef<Path>>(path: P) -> Result<BTreeMap<String, Tensor>> {
    let mut data = Vec::new();
    std::fs::File::open(path.as_ref())?.read_to_end(&mut data)?;
    parse(&data)
}

pub fn parse(data: &[u8]) -> Result<BTreeMap<String, Tensor>> {
    ensure!(data.len() >= 12 && &data[..4] == MAGIC, "bad magic");
    let version = u32::from_le_bytes(data[4..8].try_into()?);
    ensure!(version == VERSION, "unsupported version {version}");
    let count = u32::from_le_bytes(data[8..12].try_into()?) as usize;
    let mut off = 12;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
            ensure!(*off + n <= data.len(), "truncated tensorfile");
            let s = &data[*off..*off + n];
            *off += n;
            Ok(s)
        };
        let nlen = u16::from_le_bytes(take(&mut off, 2)?.try_into()?) as usize;
        let name = String::from_utf8(take(&mut off, nlen)?.to_vec())?;
        let code = take(&mut off, 1)?[0];
        let ndim = take(&mut off, 1)?[0] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(u32::from_le_bytes(take(&mut off, 4)?.try_into()?) as usize);
        }
        let plen = u64::from_le_bytes(take(&mut off, 8)?.try_into()?) as usize;
        let payload = take(&mut off, plen)?.to_vec();
        let dtype = match code {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U8,
            c => bail!("unknown dtype code {c}"),
        };
        out.insert(
            name,
            Tensor {
                dtype,
                shape,
                data: payload,
            },
        );
    }
    Ok(out)
}

/// Write tensors (used by tests to round-trip against the python reader).
pub fn write<P: AsRef<Path>>(path: P, tensors: &BTreeMap<String, Tensor>) -> Result<()> {
    let mut f = std::fs::File::create(path.as_ref())?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.dtype as u8, t.shape.len() as u8])?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        f.write_all(&(t.data.len() as u64).to_le_bytes())?;
        f.write_all(&t.data)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = BTreeMap::new();
        m.insert("a".into(), Tensor::from_f32(&[2, 3], &[1., 2., 3., 4., 5., 6.]));
        m.insert("b".into(), Tensor::from_i32(&[4], &[7, -8, 9, 0]));
        let dir = std::env::temp_dir().join("tang_test.tang");
        write(&dir, &m).unwrap();
        let back = read(&dir).unwrap();
        assert_eq!(back["a"].as_f32().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back["a"].shape, vec![2, 3]);
        assert_eq!(back["b"].as_i32().unwrap(), vec![7, -8, 9, 0]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let mut m = BTreeMap::new();
        m.insert("x".into(), Tensor::from_f32(&[8], &[0.0; 8]));
        let p = std::env::temp_dir().join("tang_trunc.tang");
        write(&p, &m).unwrap();
        let data = std::fs::read(&p).unwrap();
        assert!(parse(&data[..data.len() - 4]).is_err());
    }
}
