//! Deterministic simulated model backend — the serving stack's test
//! double when no compiled artifacts / PJRT runtime exist.
//!
//! [`SimExecutor`] implements [`super::ModelBackend`] with closed-form
//! hashing instead of a transformer. Two properties make it useful beyond
//! a stub:
//!
//! * **Deterministic**: the same prompt always generates the same tokens,
//!   so end-to-end tests can compare runs exactly.
//! * **Cache-sensitive**: each decode step folds a checksum of the lane's
//!   *reinflated dense cache* (every kr/ki/vr/vi element up to `pos`) into
//!   the next token. Any corruption anywhere in the compressed store —
//!   a bad bit-unpack, a lossy swap-out/swap-in, a stale dense refill —
//!   changes the generated text. That is exactly the property preemption
//!   tests need: swap a sequence out and back in, and bit-identical
//!   restoration is *observable from the tokens*.
//!
//! The emitted "compressed" entries respect the [`QuantConfig`] the engine
//! passes (angle codes < n_bins, positive raw norms), so the kv_manager
//! packs them at the exact widths production uses.

use super::backend::ModelBackend;
use super::executor::{DecodeOut, PrefillOut};
use super::manifest::{Profile, ServeProtocol};
use crate::quant::QuantConfig;
use crate::util::hash::splitmix64 as mix;
use anyhow::{ensure, Result};

pub struct SimExecutor {
    profile: Profile,
    serve: ServeProtocol,
    seed: u64,
}

impl SimExecutor {
    /// Small default geometry: 2 layers, 2 KV heads, d_head 8, batch 4,
    /// prefill 32, tmax 64 — big enough to exercise paging and batching,
    /// small enough that a full serve run is microseconds.
    pub fn new(seed: u64) -> Self {
        Self::with_dims(seed, 2, 2, 8, 4, 32, 64)
    }

    pub fn with_dims(
        seed: u64,
        n_layers: usize,
        n_kv_heads: usize,
        d_head: usize,
        batch: usize,
        prefill_len: usize,
        tmax: usize,
    ) -> Self {
        assert!(d_head % 2 == 0, "d_head must be even (polar pairs)");
        SimExecutor {
            profile: Profile {
                name: "sim".to_string(),
                mirrors: "none (deterministic hash model)".to_string(),
                n_layers,
                d_head,
                n_q_heads: n_kv_heads,
                n_kv_heads,
                d_model: n_kv_heads * d_head,
                d_ff: 4 * n_kv_heads * d_head,
                vocab: 259,
                gqa_ratio: 1,
                param_count: 0,
                weights: String::new(),
                eval_hlo: String::new(),
                prefill_hlo: String::new(),
                decode_hlo: String::new(),
                eval_inputs: Vec::new(),
                prefill_inputs: Vec::new(),
                decode_inputs: Vec::new(),
            },
            serve: ServeProtocol {
                batch,
                prefill_len,
                tmax,
            },
            seed,
        }
    }

    /// Fold one prompt prefix into a rolling state.
    fn prompt_state(&self, tokens: &[i32]) -> u64 {
        let mut h = mix(self.seed ^ 0x5EED);
        for &t in tokens {
            h = mix(h ^ t as u64);
        }
        h
    }

    /// Derive a (raw norm, angle code) pair for one element.
    fn entry(h: u64, bins: u32) -> (f32, f32) {
        let r = 0.1 + (h % 1009) as f32 / 252.0; // positive, spread
        let k = (mix(h) % bins as u64) as f32; // valid code for this layer
        (r, k)
    }

    fn next_token(state: u64) -> i32 {
        // rare EOS keeps most runs length-bounded but exercises both paths
        if state % 97 == 0 {
            257 // EOS (engine::EOS)
        } else {
            (state % 250) as i32
        }
    }

    /// One-hot logits for `tok`, with low state bits folded into the peak
    /// value: argmax is unchanged, but distinct states produce distinct
    /// logit vectors even when they pick the same token (tests compare
    /// whole vectors).
    fn set_logits(logits: &mut [f32], lane: usize, vocab: usize, tok: i32, state: u64) {
        let idx = lane * vocab + tok.rem_euclid(vocab as i32) as usize;
        logits[idx] = 1.0 + (state % 65536) as f32 / 1.0e6;
    }
}

impl ModelBackend for SimExecutor {
    fn profile(&self) -> &Profile {
        &self.profile
    }

    fn serve(&self) -> &ServeProtocol {
        &self.serve
    }

    fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        let (b_n, tp) = (self.serve.batch, self.serve.prefill_len);
        let (l_n, h_n, half) = (
            self.profile.n_layers,
            self.profile.n_kv_heads,
            self.profile.d_head / 2,
        );
        ensure!(tokens.len() == b_n * tp && lengths.len() == b_n);
        ensure!(cfg.layers.len() == l_n, "config/profile layer mismatch");
        let vocab = self.profile.vocab;
        let n = l_n * b_n * h_n * tp * half;
        let mut out = PrefillOut {
            logits: vec![0.0; b_n * vocab],
            kr: vec![0.0; n],
            ki: vec![0.0; n],
            vr: vec![0.0; n],
            vi: vec![0.0; n],
        };
        for lane in 0..b_n {
            let plen = (lengths[lane] as usize).min(tp);
            let prompt = &tokens[lane * tp..lane * tp + plen];
            // per-position states: fold of the prompt prefix up to t
            let mut h = mix(self.seed ^ 0x5EED);
            for (t, &tok) in prompt.iter().enumerate() {
                h = mix(h ^ tok as u64);
                for l in 0..l_n {
                    let bins = cfg.layers[l];
                    for hd in 0..h_n {
                        let base = (((l * b_n + lane) * h_n + hd) * tp + t) * half;
                        for i in 0..half {
                            let tag = ((l as u64) << 40) | ((hd as u64) << 32) | (i as u64);
                            let e = mix(h ^ tag);
                            let (r, k) = Self::entry(e, bins.n_k);
                            out.kr[base + i] = r;
                            out.ki[base + i] = k;
                            let (r, k) = Self::entry(mix(e ^ 0x56), bins.n_v);
                            out.vr[base + i] = r;
                            out.vi[base + i] = k;
                        }
                    }
                }
            }
            let state = self.prompt_state(prompt);
            Self::set_logits(&mut out.logits, lane, vocab, Self::next_token(state), state);
        }
        Ok(out)
    }

    fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut> {
        let (l_n, b_n, h_n, tmax, half) = self.cache_dims();
        ensure!(token.len() == b_n && pos.len() == b_n);
        ensure!(kr.len() == l_n * b_n * h_n * tmax * half, "cache shape");
        ensure!(cfg.layers.len() == l_n, "config/profile layer mismatch");
        let vocab = self.profile.vocab;
        let mut out = DecodeOut {
            logits: vec![0.0; b_n * vocab],
            kr: vec![0.0; l_n * b_n * h_n * half],
            ki: vec![0.0; l_n * b_n * h_n * half],
            vr: vec![0.0; l_n * b_n * h_n * half],
            vi: vec![0.0; l_n * b_n * h_n * half],
        };
        for lane in 0..b_n {
            // rows [0, pos) are the KV-resident prefix — exactly what the
            // real decode HLO reads from the dense cache (the current
            // token's KV is computed in-graph, and the engine only refills
            // rows below the committed kv length, which equals `pos`)
            let len = (pos[lane].max(0) as usize).min(tmax);
            // checksum over every reinflated element of this lane's cache:
            // the "attention" — any single-bit change in the compressed
            // store flips the generated token stream
            let mut acc: u64 = 0;
            for l in 0..l_n {
                for hd in 0..h_n {
                    for t in 0..len {
                        let base = (((l * b_n + lane) * h_n + hd) * tmax + t) * half;
                        for i in 0..half {
                            acc = mix(
                                acc ^ (kr[base + i].to_bits() as u64)
                                    ^ ((ki[base + i].to_bits() as u64) << 16)
                                    ^ ((vr[base + i].to_bits() as u64) << 32)
                                    ^ ((vi[base + i].to_bits() as u64) << 8),
                            );
                        }
                    }
                }
            }
            let state = mix(acc ^ (token[lane] as u64) ^ ((pos[lane] as u64) << 48));
            let tok = Self::next_token(state);
            Self::set_logits(&mut out.logits, lane, vocab, tok, state);
            // this step's compressed KV entries
            for l in 0..l_n {
                let bins = cfg.layers[l];
                for hd in 0..h_n {
                    let base = ((l * b_n + lane) * h_n + hd) * half;
                    for i in 0..half {
                        let tag = ((l as u64) << 40) | ((hd as u64) << 32) | (i as u64);
                        let e = mix(state ^ tag);
                        let (r, k) = Self::entry(e, bins.n_k);
                        out.kr[base + i] = r;
                        out.ki[base + i] = k;
                        let (r, k) = Self::entry(mix(e ^ 0x56), bins.n_v);
                        out.vr[base + i] = r;
                        out.vi[base + i] = k;
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> QuantConfig {
        QuantConfig::paper_uniform(2).with_k8v4_log()
    }

    #[test]
    fn prefill_is_deterministic_and_code_bounded() {
        let sim = SimExecutor::new(7);
        let (b, tp) = (sim.serve().batch, sim.serve().prefill_len);
        let mut tokens = vec![0i32; b * tp];
        tokens[..3].copy_from_slice(&[10, 20, 30]);
        let mut lengths = vec![1i32; b];
        lengths[0] = 3;
        let a = sim.run_prefill(&tokens, &lengths, &cfg()).unwrap();
        let b2 = sim.run_prefill(&tokens, &lengths, &cfg()).unwrap();
        assert_eq!(a.logits, b2.logits);
        assert_eq!(a.ki, b2.ki);
        for &k in &a.ki {
            assert!(k >= 0.0 && k < 128.0, "K code {k} out of range");
        }
        for &k in &a.vi {
            assert!(k >= 0.0 && k < 64.0, "V code {k} out of range");
        }
        for &r in &a.kr {
            assert!(r >= 0.0, "norms must be non-negative");
        }
    }

    #[test]
    fn decode_depends_on_cache_contents() {
        let sim = SimExecutor::new(7);
        let (l, b, h, tmax, half) = sim.cache_dims();
        let n = l * b * h * tmax * half;
        let kr = vec![0.5; n];
        let token = vec![42i32; b];
        let pos = vec![2i32; b];
        let out1 = sim
            .run_decode(&token, &pos, &cfg(), &kr, &kr, &kr, &kr)
            .unwrap();
        let mut kr2 = kr.clone();
        kr2[half] = 0.75; // one element inside lane 0's attended range
        let out2 = sim
            .run_decode(&token, &pos, &cfg(), &kr2, &kr, &kr, &kr)
            .unwrap();
        assert_ne!(
            out1.logits[..sim.profile().vocab],
            out2.logits[..sim.profile().vocab],
            "lane 0's token must see the cache change"
        );
        // other lanes unaffected (their cache region is unchanged)
        assert_eq!(
            out1.logits[sim.profile().vocab..],
            out2.logits[sim.profile().vocab..]
        );
    }
}
