//! Deterministic simulated model backend — the serving stack's test
//! double when no compiled artifacts / PJRT runtime exist.
//!
//! [`SimExecutor`] implements [`super::ModelBackend`] with closed-form
//! hashing instead of a transformer. Two properties make it useful beyond
//! a stub:
//!
//! * **Deterministic**: the same prompt always generates the same tokens,
//!   so end-to-end tests can compare runs exactly.
//! * **Cache-sensitive**: each decode step folds a checksum *and* a
//!   streaming-softmax score of the lane's cache (every kr/ki/vr/vi
//!   element up to `pos`) into the next token. Any corruption anywhere in
//!   the compressed store — a bad bit-unpack, a lossy swap-out/swap-in, a
//!   stale dense refill — changes the generated text. That is exactly the
//!   property preemption tests need: swap a sequence out and back in, and
//!   bit-identical restoration is *observable from the tokens*.
//!
//! The scorer ([`LaneScore`]) is shared between `run_decode` (dense
//! reinflated slabs) and `run_decode_fused` (compressed page tiles via
//! [`KvTileReader`]), so the engine's two read paths emit bit-identical
//! tokens by construction.
//!
//! The emitted "compressed" entries respect the [`QuantConfig`] the engine
//! passes (angle codes < n_bins, positive raw norms), so the kv_manager
//! packs them at the exact widths production uses.

use super::backend::{KvTileReader, KvTileView, ModelBackend};
use super::executor::{DecodeOut, PrefillOut};
use super::manifest::{EvalProtocol, Profile, ServeProtocol};
use crate::obs::stage::{self, Stage};
use crate::quant::angle::TrigLut;
use crate::quant::kernels::{self, KernelKind, TrigScratch};
use crate::quant::{LayerBins, Mode, NormMode, QuantConfig};
use crate::util::hash::splitmix64 as mix;
use anyhow::{ensure, Result};
use std::cell::{Ref, RefCell};
// xtask-allow(no-nondeterminism-in-identity-paths): HashMap here is keyed lookup only (LutCache interning); nothing ever iterates it, so hash order cannot reach a checksum
use std::collections::HashMap;
use std::sync::Arc;

/// Streaming per-lane attention state shared by the dense-reinflate and
/// fused read paths — ONE implementation, so the two paths cannot drift.
/// The engine's fused-vs-reinflate bit-identity rests on both calling
/// these methods in the same (layer, head, token, element) order.
///
/// Two components fold into the generated token:
/// * a checksum over the raw slab values (`acc`) — any single-bit change
///   anywhere in the compressed store flips the token stream, which is
///   what the preemption/swap tests observe;
/// * a streaming-softmax accumulator over per-token scores computed from
///   the *dequantized* polar pairs (`TrigLut` trig × reconstructed pair
///   norms). Scores live in rotated space on purpose: H·D is orthonormal,
///   so dot products match x-space and the inverse FWHT never needs to
///   run on the decode hot path.
struct LaneScore {
    acc: u64,
    /// online-softmax running (max, normalizer, weighted value)
    m: f32,
    l: f32,
    o: f32,
    /// current row (one token's d/2 pairs) partial score and value
    s_row: f32,
    v_row: f32,
}

impl LaneScore {
    fn new() -> Self {
        LaneScore {
            acc: 0,
            m: f32::NEG_INFINITY,
            l: 0.0,
            o: 0.0,
            s_row: 0.0,
            v_row: 0.0,
        }
    }

    /// Fold one element's raw bits into the cache checksum. The chain is
    /// inherently sequential (each step hashes the previous), so every
    /// kernel runs it in the same element order.
    #[inline]
    fn fold_acc(&mut self, kr: f32, ki: f32, vr: f32, vi: f32) {
        self.acc = mix(
            self.acc
                ^ (kr.to_bits() as u64)
                ^ ((ki.to_bits() as u64) << 16)
                ^ ((vr.to_bits() as u64) << 32)
                ^ ((vi.to_bits() as u64) << 8),
        );
    }

    #[inline]
    fn element(&mut self, lutk: &TrigLut, lutv: &TrigLut, kr: f32, ki: f32, vr: f32, vi: f32) {
        self.fold_acc(kr, ki, vr, vi);
        // reconstructed polar pair: the trig the real decode would apply
        let (kc, ks) = lutk.cos_sin(ki as u16);
        let (vc, vs) = lutv.cos_sin(vi as u16);
        self.s_row += kr * (kc - 0.25 * ks);
        self.v_row += vr * (vc + 0.5 * vs);
    }

    /// Score `tokens` whole rows of `half` pairs each — the cache-blocked
    /// slab update both read paths call. `KernelKind::Scalar` is the
    /// original one-element-at-a-time loop; `KernelKind::Simd` restages
    /// the same math as batched passes over the slab (checksum sweep, LUT
    /// gather into `scratch`, vectorized weighted-term map, then the
    /// sequential per-row reduction). Per-element expressions and every
    /// accumulation order are unchanged, so the two kernels are
    /// bit-identical — `scalar_and_simd_kernels_decode_bit_identically`
    /// and the engine integration tests pin it.
    #[allow(clippy::too_many_arguments)]
    fn slab(
        &mut self,
        kind: KernelKind,
        lutk: &TrigLut,
        lutv: &TrigLut,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
        tokens: usize,
        half: usize,
        scratch: &mut TrigScratch,
    ) {
        let elems = tokens * half;
        debug_assert!(
            kr.len() >= elems && ki.len() >= elems && vr.len() >= elems && vi.len() >= elems
        );
        match kind {
            KernelKind::Scalar => stage::time(Stage::Score, || {
                let rows = kr[..elems]
                    .chunks_exact(half)
                    .zip(ki[..elems].chunks_exact(half))
                    .zip(vr[..elems].chunks_exact(half))
                    .zip(vi[..elems].chunks_exact(half));
                for (((kr, ki), vr), vi) in rows {
                    for (((&a, &b), &c), &d) in kr.iter().zip(ki).zip(vr).zip(vi) {
                        self.element(lutk, lutv, a, b, c, d);
                    }
                    self.end_row();
                }
            }),
            KernelKind::Simd => {
                // pass 1: checksum chain, sequential in element order
                stage::time(Stage::Score, || {
                    for (((&a, &b), &c), &d) in kr[..elems]
                        .iter()
                        .zip(&ki[..elems])
                        .zip(&vr[..elems])
                        .zip(&vi[..elems])
                    {
                        self.fold_acc(a, b, c, d);
                    }
                });
                // pass 2: gather trig table entries for the whole slab
                scratch.ensure(elems);
                stage::time(Stage::Gather, || {
                    kernels::gather_trig(lutk, &ki[..elems], &mut scratch.kc, &mut scratch.ks);
                    kernels::gather_trig(lutv, &vi[..elems], &mut scratch.vc, &mut scratch.vs);
                });
                stage::time(Stage::Score, || {
                    // pass 3: elementwise weighted polar terms (vectorizable;
                    // `kc + (-0.25)*ks` == `kc - 0.25*ks` exactly in IEEE-754)
                    kernels::weighted_polar_terms(
                        &kr[..elems],
                        &scratch.kc,
                        &scratch.ks,
                        -0.25,
                        &mut scratch.st,
                    );
                    kernels::weighted_polar_terms(
                        &vr[..elems],
                        &scratch.vc,
                        &scratch.vs,
                        0.5,
                        &mut scratch.vt,
                    );
                    // pass 4: per-row reduction in original element order,
                    // then the streaming-softmax row close — both sequential
                    for (st, vt) in scratch.st[..elems]
                        .chunks_exact(half)
                        .zip(scratch.vt[..elems].chunks_exact(half))
                    {
                        for (&s, &v) in st.iter().zip(vt) {
                            self.s_row += s;
                            self.v_row += v;
                        }
                        self.end_row();
                    }
                });
            }
        }
    }

    /// Close one token row: classic streaming-softmax update (rescale the
    /// accumulator when a new max arrives, otherwise weight-and-add).
    #[inline]
    fn end_row(&mut self) {
        let (s, v) = (self.s_row, self.v_row);
        self.s_row = 0.0;
        self.v_row = 0.0;
        if s > self.m {
            let r = (self.m - s).exp(); // first row: exp(-inf) == 0
            self.l = self.l * r + 1.0;
            self.o = self.o * r + v;
            self.m = s;
        } else {
            let w = (s - self.m).exp();
            self.l += w;
            self.o += w * v;
        }
    }

    /// Fold everything into the lane's decode state.
    fn state(self, token: i32, pos: i32) -> u64 {
        let mut h = self.acc;
        if self.l > 0.0 {
            h = mix(h ^ ((self.o / self.l).to_bits() as u64) ^ ((self.m.to_bits() as u64) << 32));
        }
        mix(h ^ (token as u64) ^ ((pos as u64) << 48))
    }
}

/// Per-layer (K, V) trig tables memoized on the executor — the config is
/// fixed per engine, so the tables are built once, not once per token.
/// Tables are interned in `pool` by bin count: a 32-layer model whose
/// boost schedule uses three distinct codebook sizes builds exactly three
/// tables, and layers with equal bins share one allocation. `builds`
/// counts actual [`TrigLut::new`] calls so tests can pin that decode
/// never rebuilds per tick. `.max(2)` guards degenerate scalar-baseline
/// configs whose arrays carry bit counts.
#[derive(Default)]
struct LutCache {
    key: Vec<LayerBins>,
    per_layer: Vec<(Arc<TrigLut>, Arc<TrigLut>)>,
    // xtask-allow(no-nondeterminism-in-identity-paths): per-bin-count LUT pool, accessed only via get/insert by key — never iterated
    pool: HashMap<u32, Arc<TrigLut>>,
    builds: usize,
}

impl LutCache {
    // xtask-allow(no-nondeterminism-in-identity-paths): keyed get/insert on the pool above; iteration-order-free by construction
    fn intern(pool: &mut HashMap<u32, Arc<TrigLut>>, builds: &mut usize, n: u32) -> Arc<TrigLut> {
        let n = n.max(2);
        if let Some(t) = pool.get(&n) {
            return t.clone();
        }
        *builds += 1;
        let t = Arc::new(TrigLut::new(n, false));
        pool.insert(n, t.clone());
        t
    }
}

pub struct SimExecutor {
    profile: Profile,
    serve: ServeProtocol,
    eval: EvalProtocol,
    seed: u64,
    /// ±1 rotation diagonal (swappable for D-seed sweeps)
    sign: Vec<f32>,
    luts: RefCell<LutCache>,
    /// which scoring kernel decode runs (see [`LaneScore::slab`])
    kernel: KernelKind,
    /// slab-sized trig staging buffers, grown once and reused every tick
    scratch: RefCell<TrigScratch>,
}

impl SimExecutor {
    /// Small default geometry: 2 layers, 2 KV heads, d_head 8, batch 4,
    /// prefill 32, tmax 64 — big enough to exercise paging and batching,
    /// small enough that a full serve run is microseconds.
    pub fn new(seed: u64) -> Self {
        Self::with_dims(seed, 2, 2, 8, 4, 32, 64)
    }

    pub fn with_dims(
        seed: u64,
        n_layers: usize,
        n_kv_heads: usize,
        d_head: usize,
        batch: usize,
        prefill_len: usize,
        tmax: usize,
    ) -> Self {
        assert!(d_head % 2 == 0, "d_head must be even (polar pairs)");
        SimExecutor {
            profile: Profile {
                name: "sim".to_string(),
                mirrors: "none (deterministic hash model)".to_string(),
                n_layers,
                d_head,
                n_q_heads: n_kv_heads,
                n_kv_heads,
                d_model: n_kv_heads * d_head,
                d_ff: 4 * n_kv_heads * d_head,
                vocab: 259,
                gqa_ratio: 1,
                param_count: 0,
                weights: String::new(),
                eval_hlo: String::new(),
                prefill_hlo: String::new(),
                decode_hlo: String::new(),
                eval_inputs: Vec::new(),
                prefill_inputs: Vec::new(),
                decode_inputs: Vec::new(),
            },
            serve: ServeProtocol {
                batch,
                prefill_len,
                tmax,
            },
            // held-out chunk geometry for the teacher-forced eval surface;
            // chunk count is a multiple of the batch so the harness's
            // batched sweep tiles it exactly
            eval: EvalProtocol {
                chunks: 2 * batch,
                chunk_len: 64,
                batch,
                paper_protocol: "sim-synthetic (deterministic hash model)".to_string(),
            },
            seed,
            sign: vec![1.0; d_head],
            luts: RefCell::new(LutCache::default()),
            kernel: KernelKind::auto(),
            scratch: RefCell::new(TrigScratch::new()),
        }
    }

    /// Which scoring kernel decode currently dispatches to.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Override the scoring kernel — defaults to [`KernelKind::auto`];
    /// tests and benches set this for in-process scalar-vs-simd
    /// comparisons.
    pub fn set_kernel(&mut self, kernel: KernelKind) {
        self.kernel = kernel;
    }

    /// Closed-form per-predicted-token NLL penalty for `cfg` — the sim's
    /// stand-in for real quantization error, shaped to reproduce the
    /// paper's qualitative structure so the sensitivity loop has something
    /// faithful to optimize: error falls off as 1/n² in the codebook size,
    /// early layers are the most sensitive (a decaying layer weight plus a
    /// deterministic per-seed wiggle), the K side matters more than V,
    /// scalar baselines pay more at equal bit budgets, and quantized norms
    /// add a small extra term (log-space cheaper than linear, the §3.3
    /// asymmetry). The rotation diagonal modulates the total by ±5% so
    /// D-seed sweeps observe spread.
    fn quant_penalty(&self, cfg: &QuantConfig) -> f64 {
        if cfg.mode == Mode::None {
            return 0.0;
        }
        let l_n = self.profile.n_layers;
        let angle_err = |n: u32| 1.0 / (n as f64 * n as f64);
        let scalar_err = |bits: u32| 8.0 / 4f64.powi(bits as i32);
        let mut pen = 0.0;
        for (l, b) in cfg.layers.iter().enumerate() {
            let wiggle = (mix(self.seed ^ 0x5E45 ^ l as u64) % 1000) as f64 / 1000.0;
            let w = 0.25 + 2.0 * (-3.0 * l as f64 / l_n as f64).exp() + 0.35 * wiggle;
            let (ek, ev) = match cfg.mode {
                Mode::Angle => (angle_err(b.n_k), angle_err(b.n_v)),
                Mode::AngleCentered => (1.3 * angle_err(b.n_k), 1.3 * angle_err(b.n_v)),
                _ => (scalar_err(b.n_k), scalar_err(b.n_v)),
            };
            pen += w * (ek + 0.45 * ev);
        }
        pen = 60.0 * pen / l_n as f64;
        let norm_pen = |m: NormMode, weight: f64| {
            if m.bits == 0 {
                0.0
            } else {
                weight * 0.002 * (if m.log_space { 0.55 } else { 1.0 })
                    / 2f64.powi(i32::from(m.bits))
            }
        };
        pen += norm_pen(cfg.k_norm, 1.0) + norm_pen(cfg.v_norm, 0.5);
        let mut sh = mix(self.seed ^ 0xD1A6);
        for &s in &self.sign {
            sh = mix(sh ^ s.to_bits() as u64);
        }
        pen * (1.0 + ((sh % 401) as f64 - 200.0) / 4000.0)
    }

    /// Borrow the memoized per-layer trig tables, (re)building them only
    /// when the config's layer bins changed since the last decode.
    fn luts(&self, cfg: &QuantConfig) -> Ref<'_, LutCache> {
        {
            let mut g = self.luts.borrow_mut();
            if g.key != cfg.layers {
                g.key = cfg.layers.clone();
                let LutCache { key, per_layer, pool, builds } = &mut *g;
                per_layer.clear();
                for b in key.iter() {
                    let k = LutCache::intern(pool, builds, b.n_k);
                    let v = LutCache::intern(pool, builds, b.n_v);
                    per_layer.push((k, v));
                }
            }
        }
        self.luts.borrow()
    }

    /// Fold one prompt prefix into a rolling state.
    fn prompt_state(&self, tokens: &[i32]) -> u64 {
        let mut h = mix(self.seed ^ 0x5EED);
        for &t in tokens {
            h = mix(h ^ t as u64);
        }
        h
    }

    /// Derive a (raw norm, angle code) pair for one element.
    fn entry(h: u64, bins: u32) -> (f32, f32) {
        let r = 0.1 + (h % 1009) as f32 / 252.0; // positive, spread
        let k = (mix(h) % bins as u64) as f32; // valid code for this layer
        (r, k)
    }

    fn next_token(state: u64) -> i32 {
        // rare EOS keeps most runs length-bounded but exercises both paths
        if state % 97 == 0 {
            257 // EOS (engine::EOS)
        } else {
            (state % 250) as i32
        }
    }

    /// One-hot logits for `tok`, with low state bits folded into the peak
    /// value: argmax is unchanged, but distinct states produce distinct
    /// logit vectors even when they pick the same token (tests compare
    /// whole vectors).
    fn set_logits(logits: &mut [f32], lane: usize, vocab: usize, tok: i32, state: u64) {
        let idx = lane * vocab + tok.rem_euclid(vocab as i32) as usize;
        logits[idx] = 1.0 + (state % 65536) as f32 / 1.0e6;
    }

    fn empty_decode_out(&self) -> DecodeOut {
        let (l_n, b_n, h_n, _tmax, half) = self.cache_dims();
        let step = l_n * b_n * h_n * half;
        DecodeOut {
            logits: vec![0.0; b_n * self.profile.vocab],
            kr: vec![0.0; step],
            ki: vec![0.0; step],
            vr: vec![0.0; step],
            vi: vec![0.0; step],
        }
    }

    /// Shared prefill body. `emit(lane, plen)` returns the half-open
    /// position range whose KV this call must emit — `(0, plen)` for a
    /// full prefill, `(skip, plen)` for a suffix prefill over adopted
    /// shared pages, `(start, start + chunk)` for one chunked-prefill
    /// slice. Positions outside the range only fold the rolling prompt
    /// hash (O(1) per token), so emitted entries and logits match a full
    /// prefill bit for bit no matter how the prompt is sliced.
    fn prefill_impl<F>(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        emit: F,
        cfg: &QuantConfig,
    ) -> Result<PrefillOut>
    where
        F: Fn(usize, usize) -> (usize, usize),
    {
        let (b_n, tp) = (self.serve.batch, self.serve.prefill_len);
        let (l_n, h_n, half) = (
            self.profile.n_layers,
            self.profile.n_kv_heads,
            self.profile.d_head / 2,
        );
        ensure!(tokens.len() == b_n * tp && lengths.len() == b_n);
        ensure!(cfg.layers.len() == l_n, "config/profile layer mismatch");
        let vocab = self.profile.vocab;
        let n = l_n * b_n * h_n * tp * half;
        let mut out = PrefillOut {
            logits: vec![0.0; b_n * vocab],
            kr: vec![0.0; n],
            ki: vec![0.0; n],
            vr: vec![0.0; n],
            vi: vec![0.0; n],
        };
        for lane in 0..b_n {
            let plen = (lengths[lane] as usize).min(tp);
            let (from, to) = emit(lane, plen);
            let prompt = &tokens[lane * tp..lane * tp + plen];
            // per-position states: fold of the prompt prefix up to t
            let mut h = mix(self.seed ^ 0x5EED);
            for (t, &tok) in prompt.iter().enumerate() {
                h = mix(h ^ tok as u64);
                if t < from || t >= to {
                    continue; // outside this call's emission range
                }
                for l in 0..l_n {
                    let bins = cfg.layers[l];
                    for hd in 0..h_n {
                        let base = (((l * b_n + lane) * h_n + hd) * tp + t) * half;
                        for i in 0..half {
                            let tag = ((l as u64) << 40) | ((hd as u64) << 32) | (i as u64);
                            let e = mix(h ^ tag);
                            let (r, k) = Self::entry(e, bins.n_k);
                            out.kr[base + i] = r;
                            out.ki[base + i] = k;
                            let (r, k) = Self::entry(mix(e ^ 0x56), bins.n_v);
                            out.vr[base + i] = r;
                            out.vi[base + i] = k;
                        }
                    }
                }
            }
            let state = self.prompt_state(prompt);
            Self::set_logits(&mut out.logits, lane, vocab, Self::next_token(state), state);
        }
        Ok(out)
    }

    /// Write one lane's outputs for decode `state`: the logits row plus
    /// this step's compressed KV entries — shared by both read paths.
    fn emit_lane(&self, out: &mut DecodeOut, lane: usize, state: u64, cfg: &QuantConfig) {
        let (l_n, b_n, h_n, _tmax, half) = self.cache_dims();
        let vocab = self.profile.vocab;
        let tok = Self::next_token(state);
        Self::set_logits(&mut out.logits, lane, vocab, tok, state);
        for l in 0..l_n {
            let bins = cfg.layers[l];
            for hd in 0..h_n {
                let base = ((l * b_n + lane) * h_n + hd) * half;
                for i in 0..half {
                    let tag = ((l as u64) << 40) | ((hd as u64) << 32) | (i as u64);
                    let e = mix(state ^ tag);
                    let (r, k) = Self::entry(e, bins.n_k);
                    out.kr[base + i] = r;
                    out.ki[base + i] = k;
                    let (r, k) = Self::entry(mix(e ^ 0x56), bins.n_v);
                    out.vr[base + i] = r;
                    out.vi[base + i] = k;
                }
            }
        }
    }
}

impl ModelBackend for SimExecutor {
    fn profile(&self) -> &Profile {
        &self.profile
    }

    fn serve(&self) -> &ServeProtocol {
        &self.serve
    }

    fn eval_protocol(&self) -> &EvalProtocol {
        &self.eval
    }

    /// Teacher-forced eval: per-row NLL is a deterministic base stream
    /// (a rolling hash of the row's tokens) plus the closed-form
    /// `quant_penalty` for `cfg`. Position 0 has no prediction, so
    /// each row counts `chunk_len - 1` tokens — matching the real eval
    /// HLO's shifted-target convention.
    fn eval_nll(&self, tokens: &[i32], cfg: &QuantConfig) -> Result<(Vec<f32>, Vec<f32>)> {
        let (b, cl) = (self.eval.batch, self.eval.chunk_len);
        ensure!(
            tokens.len() == b * cl,
            "eval tokens must be batch×chunk_len = {}x{}",
            b,
            cl
        );
        ensure!(
            cfg.layers.len() == self.profile.n_layers,
            "config/profile layer mismatch"
        );
        let pen = self.quant_penalty(cfg);
        let (mut nll, mut cnt) = (vec![0.0f32; b], vec![0.0f32; b]);
        for row in 0..b {
            let mut h = mix(self.seed ^ 0xE7A1);
            let (mut s, mut c) = (0.0f64, 0.0f64);
            for (j, &t) in tokens[row * cl..(row + 1) * cl].iter().enumerate() {
                h = mix(h ^ t as u64);
                if j == 0 {
                    continue;
                }
                s += 1.8 + (h % 2048) as f64 / 4096.0 + pen;
                c += 1.0;
            }
            nll[row] = s as f32;
            cnt[row] = c as f32;
        }
        Ok((nll, cnt))
    }

    fn sign(&self) -> &[f32] {
        &self.sign
    }

    fn set_sign(&mut self, sign: &[f32]) -> Result<()> {
        ensure!(
            sign.len() == self.profile.d_head,
            "sign diagonal length {} != d_head {}",
            sign.len(),
            self.profile.d_head
        );
        ensure!(
            sign.iter().all(|v| *v == 1.0 || *v == -1.0),
            "sign diagonal entries must be ±1"
        );
        self.sign = sign.to_vec();
        Ok(())
    }

    fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        self.prefill_impl(tokens, lengths, |_, plen| (0, plen), cfg)
    }

    /// Suffix prefill: positions below the lane's prefix length only fold
    /// the prompt hash (O(1) per token) — the per-(layer, head, element)
    /// KV emission, which dominates prefill cost, runs for the suffix
    /// alone. Emitted suffix entries and logits are bit-identical to a
    /// full [`Self::run_prefill`] because each position's state depends
    /// only on the prompt prefix up to it.
    fn run_prefill_suffix(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        prefix_lens: &[usize],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        ensure!(prefix_lens.len() == self.serve.batch, "prefix_lens length != batch");
        self.prefill_impl(
            tokens,
            lengths,
            |lane, plen| (prefix_lens[lane].min(plen), plen),
            cfg,
        )
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    /// Chunked prefill: emission runs for `starts[lane] .. starts[lane] +
    /// chunk_lens[lane]` alone, so the per-tick cost is proportional to
    /// the chunk, not the whole prompt — the saving the engine's chunked
    /// scheduler banks on. The rolling prompt hash still folds every
    /// position, so chunk entries and the full-prompt logits are
    /// bit-identical to one-shot prefill regardless of how the prompt is
    /// sliced (the chunked-on/off integration tests pin this).
    fn run_prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        starts: &[usize],
        chunk_lens: &[usize],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        let b_n = self.serve.batch;
        ensure!(
            starts.len() == b_n && chunk_lens.len() == b_n,
            "starts/chunk_lens length != batch"
        );
        for lane in 0..b_n {
            let plen = (lengths[lane] as usize).min(self.serve.prefill_len);
            ensure!(
                chunk_lens[lane] == 0 || starts[lane] + chunk_lens[lane] <= plen,
                "lane {lane}: chunk {}..{} beyond prompt length {plen}",
                starts[lane],
                starts[lane] + chunk_lens[lane]
            );
        }
        self.prefill_impl(
            tokens,
            lengths,
            |lane, plen| {
                let from = starts[lane].min(plen);
                (from, (from + chunk_lens[lane]).min(plen))
            },
            cfg,
        )
    }

    fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut> {
        let (l_n, b_n, h_n, tmax, half) = self.cache_dims();
        ensure!(token.len() == b_n && pos.len() == b_n);
        ensure!(kr.len() == l_n * b_n * h_n * tmax * half, "cache shape");
        ensure!(cfg.layers.len() == l_n, "config/profile layer mismatch");
        let luts = self.luts(cfg);
        let mut scratch = self.scratch.borrow_mut();
        let mut out = self.empty_decode_out();
        for lane in 0..b_n {
            // rows [0, pos) are the KV-resident prefix — exactly what the
            // real decode HLO reads from the dense cache (the current
            // token's KV is computed in-graph, and the engine only refills
            // rows below the committed kv length, which equals `pos`)
            let len = (pos[lane].max(0) as usize).min(tmax);
            // the "attention": checksum + streaming softmax over every
            // reinflated element of this lane's cache (see [`LaneScore`]).
            // Rows 0..len of one (layer, head) are contiguous in the dense
            // layout, so each slab call covers the whole attended range.
            let mut sc = LaneScore::new();
            for (l, (lutk, lutv)) in luts.per_layer.iter().enumerate() {
                for hd in 0..h_n {
                    let s = ((l * b_n + lane) * h_n + hd) * tmax * half;
                    let e = s + len * half;
                    sc.slab(
                        self.kernel,
                        lutk,
                        lutv,
                        &kr[s..e],
                        &ki[s..e],
                        &vr[s..e],
                        &vi[s..e],
                        len,
                        half,
                        &mut scratch,
                    );
                }
            }
            let state = sc.state(token[lane], pos[lane]);
            self.emit_lane(&mut out, lane, state, cfg);
        }
        Ok(out)
    }

    fn supports_fused_decode(&self) -> bool {
        true
    }

    /// The fused read path: identical scoring to [`Self::run_decode`], but
    /// the rows arrive as dequantized page tiles straight from the
    /// compressed store — the dense (L,B,H,Tmax,d/2) tensors never exist.
    /// Tile order (heads ascending, token ranges ascending) matches the
    /// dense loop's (head, token) nesting, and both paths share
    /// [`LaneScore`], so the emitted tokens are bit-identical.
    fn run_decode_fused(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        cache: &mut dyn KvTileReader,
    ) -> Result<DecodeOut> {
        let (l_n, b_n, _, tmax, half) = self.cache_dims();
        ensure!(token.len() == b_n && pos.len() == b_n);
        ensure!(cfg.layers.len() == l_n, "config/profile layer mismatch");
        let luts = self.luts(cfg);
        let mut scratch = self.scratch.borrow_mut();
        let kernel = self.kernel;
        let mut out = self.empty_decode_out();
        for lane in 0..b_n {
            let len = (pos[lane].max(0) as usize).min(tmax);
            let mut sc = LaneScore::new();
            for (l, (lutk, lutv)) in luts.per_layer.iter().enumerate() {
                cache.visit(lane, l, len, &mut |tile: &KvTileView<'_>| {
                    debug_assert_eq!(tile.half, half, "tile geometry mismatch");
                    sc.slab(
                        kernel,
                        lutk,
                        lutv,
                        tile.kr,
                        tile.ki,
                        tile.vr,
                        tile.vi,
                        tile.tokens,
                        tile.half,
                        &mut scratch,
                    );
                })?;
            }
            let state = sc.state(token[lane], pos[lane]);
            self.emit_lane(&mut out, lane, state, cfg);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> QuantConfig {
        QuantConfig::paper_uniform(2).with_k8v4_log()
    }

    #[test]
    fn prefill_is_deterministic_and_code_bounded() {
        let sim = SimExecutor::new(7);
        let (b, tp) = (sim.serve().batch, sim.serve().prefill_len);
        let mut tokens = vec![0i32; b * tp];
        tokens[..3].copy_from_slice(&[10, 20, 30]);
        let mut lengths = vec![1i32; b];
        lengths[0] = 3;
        let a = sim.run_prefill(&tokens, &lengths, &cfg()).unwrap();
        let b2 = sim.run_prefill(&tokens, &lengths, &cfg()).unwrap();
        assert_eq!(a.logits, b2.logits);
        assert_eq!(a.ki, b2.ki);
        for &k in &a.ki {
            assert!(k >= 0.0 && k < 128.0, "K code {k} out of range");
        }
        for &k in &a.vi {
            assert!(k >= 0.0 && k < 64.0, "V code {k} out of range");
        }
        for &r in &a.kr {
            assert!(r >= 0.0, "norms must be non-negative");
        }
    }

    #[test]
    fn suffix_prefill_matches_full_prefill_on_the_suffix() {
        let sim = SimExecutor::new(9);
        let (b, tp) = (sim.serve().batch, sim.serve().prefill_len);
        let (l_n, h_n, half) = (
            sim.profile().n_layers,
            sim.profile().n_kv_heads,
            sim.profile().d_head / 2,
        );
        let mut tokens = vec![0i32; b * tp];
        let mut lengths = vec![1i32; b];
        for lane in 0..b {
            for t in 0..8 {
                tokens[lane * tp + t] = (lane * 31 + t * 7) as i32 + 1;
            }
            lengths[lane] = 8;
        }
        let full = sim.run_prefill(&tokens, &lengths, &cfg()).unwrap();
        // per-lane skip depths, including 0 (no prefix) and plen (all cached)
        let skips = vec![0usize, 3, 8, 5];
        let suf = sim
            .run_prefill_suffix(&tokens, &lengths, &skips[..b], &cfg())
            .unwrap();
        assert_eq!(full.logits, suf.logits, "logits reflect the full prompt");
        for lane in 0..b {
            for t in skips[lane].min(8)..8 {
                for l in 0..l_n {
                    for hd in 0..h_n {
                        let base = (((l * b + lane) * h_n + hd) * tp + t) * half;
                        assert_eq!(
                            &full.kr[base..base + half],
                            &suf.kr[base..base + half],
                            "lane={lane} t={t}"
                        );
                        assert_eq!(&full.ki[base..base + half], &suf.ki[base..base + half]);
                        assert_eq!(&full.vr[base..base + half], &suf.vr[base..base + half]);
                        assert_eq!(&full.vi[base..base + half], &suf.vi[base..base + half]);
                    }
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_matches_full_prefill_slice_by_slice() {
        let sim = SimExecutor::new(15);
        let (b, tp) = (sim.serve().batch, sim.serve().prefill_len);
        let (l_n, h_n, half) = (
            sim.profile().n_layers,
            sim.profile().n_kv_heads,
            sim.profile().d_head / 2,
        );
        let mut tokens = vec![0i32; b * tp];
        let mut lengths = vec![1i32; b];
        let plen = 11usize;
        for lane in 0..b {
            for t in 0..plen {
                tokens[lane * tp + t] = (lane * 17 + t * 3) as i32 + 1;
            }
            lengths[lane] = plen as i32;
        }
        let full = sim.run_prefill(&tokens, &lengths, &cfg()).unwrap();
        // walk every lane through ragged chunk sizes; the final chunk's
        // logits must equal the full-prefill logits
        for chunk in [1usize, 3, 4, 11] {
            let mut starts = vec![0usize; b];
            let mut done = vec![false; b];
            let mut last = None;
            while !done.iter().all(|&d| d) {
                let lens: Vec<usize> = starts.iter().map(|&s| chunk.min(plen - s)).collect();
                let out = sim
                    .run_prefill_chunk(&tokens, &lengths, &starts, &lens, &cfg())
                    .unwrap();
                for lane in 0..b {
                    for t in starts[lane]..starts[lane] + lens[lane] {
                        for l in 0..l_n {
                            for hd in 0..h_n {
                                let base = (((l * b + lane) * h_n + hd) * tp + t) * half;
                                assert_eq!(
                                    &full.kr[base..base + half],
                                    &out.kr[base..base + half],
                                    "chunk={chunk} lane={lane} t={t}"
                                );
                                assert_eq!(&full.ki[base..base + half], &out.ki[base..base + half]);
                                assert_eq!(&full.vr[base..base + half], &out.vr[base..base + half]);
                                assert_eq!(&full.vi[base..base + half], &out.vi[base..base + half]);
                            }
                        }
                    }
                    starts[lane] += lens[lane];
                    done[lane] = starts[lane] >= plen;
                }
                last = Some(out);
            }
            assert_eq!(
                full.logits,
                last.unwrap().logits,
                "final chunk logits must reflect the full prompt (chunk={chunk})"
            );
        }
    }

    /// Tile reader over plain dense slabs — lets the unit test compare the
    /// fused scorer against the dense one on the exact same values without
    /// standing up a PagedKvCache.
    struct SliceTiles<'a> {
        b_n: usize,
        h_n: usize,
        tmax: usize,
        half: usize,
        tile: usize,
        kr: &'a [f32],
        ki: &'a [f32],
        vr: &'a [f32],
        vi: &'a [f32],
        buf: Vec<f32>,
    }

    impl KvTileReader for SliceTiles<'_> {
        fn visit(
            &mut self,
            lane: usize,
            layer: usize,
            upto: usize,
            f: &mut dyn FnMut(&KvTileView<'_>),
        ) -> Result<()> {
            let (half, tile) = (self.half, self.tile);
            for hd in 0..self.h_n {
                let mut t0 = 0usize;
                while t0 < upto {
                    let tokens = tile.min(upto - t0);
                    let elems = tokens * half;
                    for (s, slab) in [self.kr, self.ki, self.vr, self.vi].into_iter().enumerate() {
                        let src =
                            (((layer * self.b_n + lane) * self.h_n + hd) * self.tmax + t0) * half;
                        self.buf[s * elems..(s + 1) * elems]
                            .copy_from_slice(&slab[src..src + elems]);
                    }
                    f(&KvTileView {
                        layer,
                        head: hd,
                        t0,
                        tokens,
                        half,
                        kr: &self.buf[..elems],
                        ki: &self.buf[elems..2 * elems],
                        vr: &self.buf[2 * elems..3 * elems],
                        vi: &self.buf[3 * elems..4 * elems],
                    });
                    t0 += tokens;
                }
            }
            Ok(())
        }
    }

    #[test]
    fn fused_decode_bit_identical_to_dense() {
        let sim = SimExecutor::new(11);
        let (l, b, h, tmax, half) = sim.cache_dims();
        let n = l * b * h * tmax * half;
        // valid codes: ki < 128, vi < 64, positive norms
        let kr: Vec<f32> = (0..n).map(|i| 0.1 + (i % 97) as f32 / 31.0).collect();
        let ki: Vec<f32> = (0..n).map(|i| (i * 7 % 128) as f32).collect();
        let vr: Vec<f32> = (0..n).map(|i| 0.2 + (i % 53) as f32 / 17.0).collect();
        let vi: Vec<f32> = (0..n).map(|i| (i * 11 % 64) as f32).collect();
        let token: Vec<i32> = (0..b as i32).map(|i| 40 + i).collect();
        let pos: Vec<i32> = (0..b as i32).map(|i| (i * 5) % tmax as i32).collect();
        let dense = sim.run_decode(&token, &pos, &cfg(), &kr, &ki, &vr, &vi).unwrap();
        for tile in [1usize, 3, 4, 64] {
            let mut tiles = SliceTiles {
                b_n: b,
                h_n: h,
                tmax,
                half,
                tile,
                kr: &kr,
                ki: &ki,
                vr: &vr,
                vi: &vi,
                buf: vec![0.0; 4 * tile.min(tmax) * half],
            };
            let fused = sim.run_decode_fused(&token, &pos, &cfg(), &mut tiles).unwrap();
            assert_eq!(dense.logits, fused.logits, "tile={tile}");
            assert_eq!(dense.kr, fused.kr, "tile={tile}");
            assert_eq!(dense.ki, fused.ki, "tile={tile}");
            assert_eq!(dense.vr, fused.vr, "tile={tile}");
            assert_eq!(dense.vi, fused.vi, "tile={tile}");
        }
    }

    #[test]
    fn decode_reuses_cached_luts_across_ticks() {
        let sim = SimExecutor::new(7);
        let (l, b, h, tmax, half) = sim.cache_dims();
        let n = l * b * h * tmax * half;
        let kr = vec![0.5; n];
        let token = vec![1i32; b];
        let pos = vec![3i32; b];
        sim.run_decode(&token, &pos, &cfg(), &kr, &kr, &kr, &kr).unwrap();
        let after_first = sim.luts.borrow().builds;
        // paper_uniform: every layer is (128, 64) → exactly two tables
        assert_eq!(after_first, 2, "one build per distinct bin count");
        for _ in 0..5 {
            sim.run_decode(&token, &pos, &cfg(), &kr, &kr, &kr, &kr).unwrap();
        }
        assert_eq!(
            sim.luts.borrow().builds,
            after_first,
            "steady-state decode must not rebuild trig LUTs per tick"
        );
        let g = sim.luts.borrow();
        assert!(
            Arc::ptr_eq(&g.per_layer[0].0, &g.per_layer[1].0),
            "layers with equal bin counts must share one table"
        );
        drop(g);
        // a boosted schedule adds only the NEW bin counts to the pool
        let boosted = QuantConfig::selective_boost(l, &[0], 256, 64).with_k8v4_log();
        sim.run_decode(&token, &pos, &boosted, &kr, &kr, &kr, &kr).unwrap();
        assert_eq!(sim.luts.borrow().builds, after_first + 1, "only 256 is new");
    }

    #[test]
    fn scalar_and_simd_kernels_decode_bit_identically() {
        let mut scalar = SimExecutor::new(11);
        scalar.set_kernel(KernelKind::Scalar);
        let mut simd = SimExecutor::new(11);
        simd.set_kernel(KernelKind::Simd);
        let (l, b, h, tmax, half) = scalar.cache_dims();
        let n = l * b * h * tmax * half;
        let kr: Vec<f32> = (0..n).map(|i| 0.1 + (i % 97) as f32 / 31.0).collect();
        let ki: Vec<f32> = (0..n).map(|i| (i * 7 % 128) as f32).collect();
        let vr: Vec<f32> = (0..n).map(|i| 0.2 + (i % 53) as f32 / 17.0).collect();
        let vi: Vec<f32> = (0..n).map(|i| (i * 11 % 64) as f32).collect();
        let token: Vec<i32> = (0..b as i32).map(|i| 40 + i).collect();
        let pos: Vec<i32> = (0..b as i32).map(|i| (i * 9) % tmax as i32).collect();
        let a = scalar.run_decode(&token, &pos, &cfg(), &kr, &ki, &vr, &vi).unwrap();
        let s = simd.run_decode(&token, &pos, &cfg(), &kr, &ki, &vr, &vi).unwrap();
        assert_eq!(a.logits, s.logits, "kernels must agree bit-for-bit");
        assert_eq!(a.kr, s.kr);
        assert_eq!(a.ki, s.ki);
        assert_eq!(a.vr, s.vr);
        assert_eq!(a.vi, s.vi);
    }

    #[test]
    fn eval_nll_orders_configs_like_the_paper() {
        let sim = SimExecutor::with_dims(3, 8, 2, 8, 4, 32, 64);
        let proto = ModelBackend::eval_protocol(&sim).clone();
        let tokens: Vec<i32> = (0..proto.batch * proto.chunk_len)
            .map(|i| (i * 13 % 250) as i32 + 1)
            .collect();
        let total = |cfg: &QuantConfig| {
            let (nll, cnt) = sim.eval_nll(&tokens, cfg).unwrap();
            nll.iter().sum::<f32>() as f64 / cnt.iter().sum::<f32>() as f64
        };
        let base = total(&QuantConfig::none(8));
        let uniform = total(&QuantConfig::paper_uniform(8));
        let boosted = total(&QuantConfig::early_boost(8, 4, 256, 128));
        let scalar = total(&QuantConfig::scalar_baseline(8, Mode::Kivi, 3));
        // fp reference pays nothing; quantization costs something; boosting
        // the sensitive early layers recovers part of it; a ~3-bit scalar
        // baseline is worse than the ~3.25-bit angle quantizer
        assert!(base < uniform, "{base} vs {uniform}");
        assert!(boosted < uniform, "{boosted} vs {uniform}");
        assert!(base < boosted);
        assert!(uniform < scalar, "{uniform} vs {scalar}");
        // norms: K8V4-log is nearly free on top of uniform
        let k8v4 = total(&QuantConfig::paper_uniform(8).with_k8v4_log());
        assert!(k8v4 - uniform < 0.01 * (uniform - base), "{k8v4} vs {uniform}");
        // determinism
        assert_eq!(total(&QuantConfig::paper_uniform(8)), uniform);
    }

    #[test]
    fn sign_swaps_perturb_eval_but_not_baseline() {
        let mut sim = SimExecutor::new(5);
        let proto = ModelBackend::eval_protocol(&sim).clone();
        let tokens: Vec<i32> = (0..proto.batch * proto.chunk_len)
            .map(|i| (i * 7 % 250) as i32 + 1)
            .collect();
        let cfg = QuantConfig::paper_uniform(2);
        let (a, _) = sim.eval_nll(&tokens, &cfg).unwrap();
        let base0 = sim.eval_nll(&tokens, &QuantConfig::none(2)).unwrap();
        let d = ModelBackend::profile(&sim).d_head;
        let mut flipped = vec![1.0f32; d];
        flipped[0] = -1.0;
        assert!(ModelBackend::set_sign(&mut sim, &flipped).is_ok());
        let (b, _) = sim.eval_nll(&tokens, &cfg).unwrap();
        assert_ne!(a, b, "D-seed swap must move quantized eval");
        // the unquantized reference is rotation-invariant
        assert_eq!(base0.0, sim.eval_nll(&tokens, &QuantConfig::none(2)).unwrap().0);
        // bad diagonals rejected
        assert!(ModelBackend::set_sign(&mut sim, &[1.0; 3]).is_err());
        assert!(ModelBackend::set_sign(&mut sim, &vec![0.5; d]).is_err());
    }

    #[test]
    fn decode_depends_on_cache_contents() {
        let sim = SimExecutor::new(7);
        let (l, b, h, tmax, half) = sim.cache_dims();
        let n = l * b * h * tmax * half;
        let kr = vec![0.5; n];
        let token = vec![42i32; b];
        let pos = vec![2i32; b];
        let out1 = sim
            .run_decode(&token, &pos, &cfg(), &kr, &kr, &kr, &kr)
            .unwrap();
        let mut kr2 = kr.clone();
        kr2[half] = 0.75; // one element inside lane 0's attended range
        let out2 = sim
            .run_decode(&token, &pos, &cfg(), &kr2, &kr, &kr, &kr)
            .unwrap();
        assert_ne!(
            out1.logits[..sim.profile().vocab],
            out2.logits[..sim.profile().vocab],
            "lane 0's token must see the cache change"
        );
        // other lanes unaffected (their cache region is unchanged)
        assert_eq!(
            out1.logits[sim.profile().vocab..],
            out2.logits[sim.profile().vocab..]
        );
    }
}
