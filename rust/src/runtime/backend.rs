//! The model-execution contract the serving engine programs against.
//!
//! [`ModelBackend`] is the seam between the coordinator and whatever runs
//! the transformer math: the PJRT-backed [`ModelExecutor`] in production,
//! or [`super::sim::SimExecutor`] — a closed-form deterministic stand-in —
//! in tests, benches, and environments without compiled artifacts. The
//! engine only ever sees this trait, so every replica of the serving stack
//! is backend-agnostic.

use super::executor::{DecodeOut, ModelExecutor, PrefillOut};
use super::manifest::{EvalProtocol, Profile, ServeProtocol};
use crate::quant::QuantConfig;
use anyhow::{bail, Result};

/// One dequantized tile of a lane's compressed cache: `tokens` consecutive
/// token rows for one (layer, head), decoded straight from the bit-packed
/// pages into a small reused scratch. Slabs are token-major `tokens×half`:
/// `kr`/`vr` carry dequantized pair norms, `ki`/`vi` angle bin indices as
/// exact f32 codes — the same values a dense reinflation would have put in
/// the `(L,B,H,Tmax,d/2)` tensors, bit for bit.
pub struct KvTileView<'a> {
    pub layer: usize,
    pub head: usize,
    /// absolute token index of the tile's first row
    pub t0: usize,
    pub tokens: usize,
    pub half: usize,
    pub kr: &'a [f32],
    pub ki: &'a [f32],
    pub vr: &'a [f32],
    pub vi: &'a [f32],
}

/// Tile-granular read access to a decode batch's compressed caches — the
/// seam the fused read path crosses between the coordinator (which owns
/// the pages) and a backend (which consumes dequantized tiles). For one
/// `(lane, layer)` the visitor yields tiles heads-ascending, then token
/// ranges ascending, covering exactly tokens `0..upto`; empty lanes yield
/// nothing. Implemented by `coordinator::kv_manager::BatchTileReader`.
pub trait KvTileReader {
    fn visit(
        &mut self,
        lane: usize,
        layer: usize,
        upto: usize,
        f: &mut dyn FnMut(&KvTileView<'_>),
    ) -> Result<()>;
}

/// Everything the engine needs from a model: static shape info plus the
/// two serving entry points. `Send` because replicas run on dedicated
/// worker threads (each backend instance is owned by exactly one thread).
pub trait ModelBackend: Send {
    fn profile(&self) -> &Profile;
    fn serve(&self) -> &ServeProtocol;

    /// (L, B, H, Tmax, d/2) for the dense serving-cache tensors.
    fn cache_dims(&self) -> (usize, usize, usize, usize, usize) {
        let p = self.profile();
        let s = self.serve();
        (p.n_layers, s.batch, p.n_kv_heads, s.tmax, p.d_head / 2)
    }

    /// Prompt prefill over (serve.batch × serve.prefill_len) PAD-padded
    /// tokens. Output slabs are (L, B, H, Tp, d/2) row-major: raw pair
    /// norms + angle bin indices (as f32 codes), plus last-token logits.
    fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut>;

    /// Prefill that skips KV emission for the first `prefix_lens[lane]`
    /// positions of each lane — their compressed KV is already resident
    /// (adopted shared prefix pages), so only the suffix needs computing.
    /// Output layout matches [`Self::run_prefill`]; slab contents at
    /// skipped positions are unspecified (the engine never appends them),
    /// and the logits must still reflect the FULL prompt. The default
    /// ignores the hint and runs a full prefill — correct everywhere, no
    /// savings; backends override to make prefix-cache hits actually skip
    /// work.
    fn run_prefill_suffix(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        _prefix_lens: &[usize],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        self.run_prefill(tokens, lengths, cfg)
    }

    /// Whether [`Self::run_prefill_chunk`] computes only the requested
    /// positions. The default implementation below is correct everywhere
    /// but recomputes a full prefill per chunk, so the engine's chunked
    /// mode (`--chunked-prefill on`) works on any backend — it just only
    /// *saves* prefill compute on backends that return true here.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Prefill one CHUNK of each lane's prompt: emit compressed KV only
    /// for positions `starts[lane] .. starts[lane] + chunk_lens[lane]` (a
    /// lane with `chunk_lens[lane] == 0` emits nothing). Two contracts the
    /// engine's chunked-vs-monolithic bit-identity tests pin:
    ///
    /// * emitted entries must be bit-identical to the same positions of a
    ///   one-shot [`Self::run_prefill`] over the full prompt, and
    /// * `logits` must reflect the FULL `lengths[lane]`-token prompt — the
    ///   engine samples the first generated token from the chunk that
    ///   completes the prompt.
    ///
    /// Output layout matches [`Self::run_prefill`]; slab contents outside
    /// the chunk ranges are unspecified (the engine never appends them).
    /// The default runs a full prefill, which satisfies both contracts, so
    /// every backend supports chunked serving out of the box.
    fn run_prefill_chunk(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        _starts: &[usize],
        _chunk_lens: &[usize],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        self.run_prefill(tokens, lengths, cfg)
    }

    /// One decode step over the dense reinflated cache; cache slices are
    /// (L, B, H, Tmax, d/2) row-major f32.
    #[allow(clippy::too_many_arguments)]
    fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut>;

    /// Whether [`Self::run_decode_fused`] is implemented. The engine's
    /// `ReadPath::Auto` resolves on this: backends that can consume
    /// compressed pages directly skip the dense reinflation entirely.
    fn supports_fused_decode(&self) -> bool {
        false
    }

    /// One decode step consuming compressed pages tile-by-tile through a
    /// [`KvTileReader`] instead of pre-reinflated dense tensors. Must emit
    /// output bit-identical to [`Self::run_decode`] over the dense
    /// reinflation of the same cache (the sim integration tests pin this).
    fn run_decode_fused(
        &self,
        _token: &[i32],
        _pos: &[i32],
        _cfg: &QuantConfig,
        _cache: &mut dyn KvTileReader,
    ) -> Result<DecodeOut> {
        bail!("this backend has no fused decode path (supports_fused_decode() is false)")
    }

    // --- teacher-forced eval surface (the ppl/sensitivity harness) -------

    /// The teacher-forced eval protocol geometry: held-out chunk count,
    /// chunk length, and the eval batch size.
    fn eval_protocol(&self) -> &EvalProtocol;

    /// Teacher-forced NLL over one `eval.batch × eval.chunk_len` block of
    /// held-out tokens under `cfg`: per-row (nll_sum, predicted_count).
    /// Backends without an eval entry point keep the default error — the
    /// harness surfaces it at construction, not mid-sweep.
    fn eval_nll(&self, _tokens: &[i32], _cfg: &QuantConfig) -> Result<(Vec<f32>, Vec<f32>)> {
        bail!("this backend has no teacher-forced eval entry point")
    }

    /// The ±1 rotation diagonal D currently in effect (length `d_head`).
    fn sign(&self) -> &[f32];

    /// Swap the rotation diagonal (the §4.3 D-seed robustness sweeps).
    /// Entries must be ±1 and the length must match `d_head`.
    fn set_sign(&mut self, _sign: &[f32]) -> Result<()> {
        bail!("this backend has a fixed rotation diagonal")
    }
}

impl ModelBackend for ModelExecutor {
    fn profile(&self) -> &Profile {
        &self.profile
    }

    fn serve(&self) -> &ServeProtocol {
        &self.serve
    }

    fn cache_dims(&self) -> (usize, usize, usize, usize, usize) {
        ModelExecutor::cache_dims(self)
    }

    fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        ModelExecutor::run_prefill(self, tokens, lengths, cfg)
    }

    fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut> {
        ModelExecutor::run_decode(self, token, pos, cfg, kr, ki, vr, vi)
    }

    fn eval_protocol(&self) -> &EvalProtocol {
        &self.eval_proto
    }

    fn eval_nll(&self, tokens: &[i32], cfg: &QuantConfig) -> Result<(Vec<f32>, Vec<f32>)> {
        ModelExecutor::eval_nll(self, tokens, cfg)
    }

    fn sign(&self) -> &[f32] {
        &self.sign
    }

    fn set_sign(&mut self, sign: &[f32]) -> Result<()> {
        ModelExecutor::set_sign(self, sign)
    }
}
