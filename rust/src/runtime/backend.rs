//! The model-execution contract the serving engine programs against.
//!
//! [`ModelBackend`] is the seam between the coordinator and whatever runs
//! the transformer math: the PJRT-backed [`ModelExecutor`] in production,
//! or [`super::sim::SimExecutor`] — a closed-form deterministic stand-in —
//! in tests, benches, and environments without compiled artifacts. The
//! engine only ever sees this trait, so every replica of the serving stack
//! is backend-agnostic.

use super::executor::{DecodeOut, ModelExecutor, PrefillOut};
use super::manifest::{Profile, ServeProtocol};
use crate::quant::QuantConfig;
use anyhow::Result;

/// Everything the engine needs from a model: static shape info plus the
/// two serving entry points. `Send` because replicas run on dedicated
/// worker threads (each backend instance is owned by exactly one thread).
pub trait ModelBackend: Send {
    fn profile(&self) -> &Profile;
    fn serve(&self) -> &ServeProtocol;

    /// (L, B, H, Tmax, d/2) for the dense serving-cache tensors.
    fn cache_dims(&self) -> (usize, usize, usize, usize, usize) {
        let p = self.profile();
        let s = self.serve();
        (p.n_layers, s.batch, p.n_kv_heads, s.tmax, p.d_head / 2)
    }

    /// Prompt prefill over (serve.batch × serve.prefill_len) PAD-padded
    /// tokens. Output slabs are (L, B, H, Tp, d/2) row-major: raw pair
    /// norms + angle bin indices (as f32 codes), plus last-token logits.
    fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut>;

    /// One decode step over the dense reinflated cache; cache slices are
    /// (L, B, H, Tmax, d/2) row-major f32.
    #[allow(clippy::too_many_arguments)]
    fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut>;
}

impl ModelBackend for ModelExecutor {
    fn profile(&self) -> &Profile {
        &self.profile
    }

    fn serve(&self) -> &ServeProtocol {
        &self.serve
    }

    fn cache_dims(&self) -> (usize, usize, usize, usize, usize) {
        ModelExecutor::cache_dims(self)
    }

    fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        ModelExecutor::run_prefill(self, tokens, lengths, cfg)
    }

    fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut> {
        ModelExecutor::run_decode(self, token, pos, cfg, kr, ki, vr, vi)
    }
}
