//! `artifacts/manifest.json` — the build→runtime contract emitted by
//! `python/compile/aot.py`: profile hyperparameters, artifact paths,
//! input orderings, and the eval/serve protocol shapes.
//!
//! Parsed with the in-tree JSON parser (util::json) — serde is unavailable
//! in this offline environment.

use crate::util::json::Json;
use anyhow::{anyhow, ensure, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: u32,
    pub sign_seed: u64,
    pub eval: EvalProtocol,
    pub serve: ServeProtocol,
    pub modes: BTreeMap<String, i32>,
    pub profiles: BTreeMap<String, Profile>,
    pub kernels: BTreeMap<String, String>,
    pub root: PathBuf,
}

#[derive(Clone, Debug)]
pub struct EvalProtocol {
    pub chunks: usize,
    pub chunk_len: usize,
    pub batch: usize,
    pub paper_protocol: String,
}

#[derive(Clone, Debug)]
pub struct ServeProtocol {
    pub batch: usize,
    pub prefill_len: usize,
    pub tmax: usize,
}

#[derive(Clone, Debug)]
pub struct Profile {
    pub name: String,
    pub mirrors: String,
    pub n_layers: usize,
    pub d_head: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub gqa_ratio: usize,
    pub param_count: u64,
    pub weights: String,
    pub eval_hlo: String,
    pub prefill_hlo: String,
    pub decode_hlo: String,
    pub eval_inputs: Vec<String>,
    pub prefill_inputs: Vec<String>,
    pub decode_inputs: Vec<String>,
}

fn profile_from_json(j: &Json) -> Result<Profile> {
    Ok(Profile {
        name: j.get("name")?.as_str()?.to_string(),
        mirrors: j.get("mirrors")?.as_str()?.to_string(),
        n_layers: j.get("n_layers")?.as_usize()?,
        d_head: j.get("d_head")?.as_usize()?,
        n_q_heads: j.get("n_q_heads")?.as_usize()?,
        n_kv_heads: j.get("n_kv_heads")?.as_usize()?,
        d_model: j.get("d_model")?.as_usize()?,
        d_ff: j.get("d_ff")?.as_usize()?,
        vocab: j.get("vocab")?.as_usize()?,
        gqa_ratio: j.get("gqa_ratio")?.as_usize()?,
        param_count: j.get("param_count")?.as_u64()?,
        weights: j.get("weights")?.as_str()?.to_string(),
        eval_hlo: j.get("eval_hlo")?.as_str()?.to_string(),
        prefill_hlo: j.get("prefill_hlo")?.as_str()?.to_string(),
        decode_hlo: j.get("decode_hlo")?.as_str()?.to_string(),
        eval_inputs: j.get("eval_inputs")?.str_vec()?,
        prefill_inputs: j.get("prefill_inputs")?.str_vec()?,
        decode_inputs: j.get("decode_inputs")?.str_vec()?,
    })
}

impl Manifest {
    pub fn from_json_text(text: &str, root: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()? as u32;
        ensure!(version == 1, "unsupported manifest version {version}");
        let ev = j.get("eval")?;
        let sv = j.get("serve")?;
        let mut modes = BTreeMap::new();
        for (k, v) in j.get("modes")?.as_obj()? {
            modes.insert(k.clone(), v.as_usize()? as i32);
        }
        let mut profiles = BTreeMap::new();
        for (k, v) in j.get("profiles")?.as_obj()? {
            profiles.insert(k.clone(), profile_from_json(v)?);
        }
        let mut kernels = BTreeMap::new();
        if let Some(ks) = j.opt("kernels") {
            for (k, v) in ks.as_obj()? {
                kernels.insert(k.clone(), v.as_str()?.to_string());
            }
        }
        Ok(Manifest {
            version,
            sign_seed: j.get("sign_seed")?.as_u64()?,
            eval: EvalProtocol {
                chunks: ev.get("chunks")?.as_usize()?,
                chunk_len: ev.get("chunk_len")?.as_usize()?,
                batch: ev.get("batch")?.as_usize()?,
                paper_protocol: ev
                    .opt("paper_protocol")
                    .and_then(|v| v.as_str().ok())
                    .unwrap_or("")
                    .to_string(),
            },
            serve: ServeProtocol {
                batch: sv.get("batch")?.as_usize()?,
                prefill_len: sv.get("prefill_len")?.as_usize()?,
                tmax: sv.get("tmax")?.as_usize()?,
            },
            modes,
            profiles,
            kernels,
            root,
        })
    }

    pub fn load<P: AsRef<Path>>(artifacts_dir: P) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow!("read {path:?}: {e} (run `make artifacts`?)"))?;
        Self::from_json_text(&text, root)
    }

    /// Locate the artifacts dir: $TURBOANGLE_ARTIFACTS or ./artifacts.
    pub fn discover() -> Result<Manifest> {
        let dir = std::env::var("TURBOANGLE_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn profile(&self, name: &str) -> Result<&Profile> {
        self.profiles.get(name).ok_or_else(|| {
            anyhow!(
                "unknown profile '{name}' (have: {:?})",
                self.profiles.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_manifest() {
        let json = r#"{
            "version": 1, "sign_seed": 1,
            "eval": {"chunks": 2, "chunk_len": 3, "batch": 1},
            "serve": {"batch": 1, "prefill_len": 4, "tmax": 8},
            "modes": {"none": 0, "angle": 1},
            "profiles": {}
        }"#;
        let m = Manifest::from_json_text(json, PathBuf::from(".")).unwrap();
        assert_eq!(m.eval.chunks, 2);
        assert_eq!(m.modes["angle"], 1);
        assert!(m.profile("nope").is_err());
    }
}
