//! PJRT plumbing: HLO text → compile → execute (the
//! /opt/xla-example/load_hlo pattern, wrapped for reuse).
//!
//! All artifacts are lowered by `python/compile/aot.py` with
//! `return_tuple=True`, so every execution returns one tuple literal that
//! we decompose. HLO *text* is the interchange format (see aot.py docstring).

use anyhow::{anyhow, Result};
use std::path::Path;

/// Shared PJRT CPU client (one per process).
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        Ok(Runtime {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text artifact.
    pub fn load<P: AsRef<Path>>(&self, path: P) -> Result<Program> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Program {
            exe,
            name: path.file_name().unwrap().to_string_lossy().into_owned(),
        })
    }
}

/// One compiled executable.
pub struct Program {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Program {
    /// Execute with literal inputs; returns the decomposed output tuple.
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<&xla::Literal>(args)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", self.name))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {}: {e:?}", self.name))
    }
}

/// f32 literal with shape.
pub fn lit_f32(shape: &[usize], values: &[f32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), values.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(values)
        .reshape(&dims)
        .map_err(|e| anyhow!("{e:?}"))
}

/// i32 literal with shape.
pub fn lit_i32(shape: &[usize], values: &[i32]) -> Result<xla::Literal> {
    debug_assert_eq!(shape.iter().product::<usize>(), values.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(values)
        .reshape(&dims)
        .map_err(|e| anyhow!("{e:?}"))
}

/// scalar i32 literal.
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// scalar f32 literal.
pub fn lit_scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Fetch a literal's f32 payload.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
}
