//! Model executor: weights + compiled entry points for one profile.
//!
//! Owns the three AOT programs (eval / prefill / decode) and the weight
//! literals, and translates [`QuantConfig`] into the runtime input arrays.
//! Everything above this layer (coordinator, eval harness) is PJRT-free.

use super::manifest::{Manifest, Profile};
use super::pjrt::{lit_f32, lit_i32, lit_scalar_i32, to_f32, Program, Runtime};
use super::tensorfile;
use crate::quant::QuantConfig;
use anyhow::{anyhow, ensure, Result};

/// Which entry points to compile (eval-only is much faster to start).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Entry {
    Eval,
    Serve,
    All,
}

pub struct ModelExecutor {
    pub profile: Profile,
    pub serve: super::manifest::ServeProtocol,
    pub eval_proto: super::manifest::EvalProtocol,
    weights: Vec<xla::Literal>,
    pub sign: Vec<f32>,
    sign_lit: xla::Literal,
    eval: Option<Program>,
    prefill: Option<Program>,
    decode: Option<Program>,
}

/// Outputs of a prefill call: last-token logits + the compressed cache
/// (pair norms are RAW f32 here; the kv_manager owns norm quantization).
pub struct PrefillOut {
    pub logits: Vec<f32>,       // (B, V)
    pub kr: Vec<f32>,           // (L, B, H, Tp, d/2)
    pub ki: Vec<f32>,
    pub vr: Vec<f32>,
    pub vi: Vec<f32>,
}

/// Outputs of a decode step: next-token logits + this token's compressed KV.
pub struct DecodeOut {
    pub logits: Vec<f32>,       // (B, V)
    pub kr: Vec<f32>,           // (L, B, H, d/2)
    pub ki: Vec<f32>,
    pub vr: Vec<f32>,
    pub vi: Vec<f32>,
}

impl ModelExecutor {
    pub fn load(rt: &Runtime, manifest: &Manifest, name: &str, entry: Entry) -> Result<Self> {
        let profile = manifest.profile(name)?.clone();
        let tensors = tensorfile::read(manifest.path(&profile.weights))?;
        let mut weights = Vec::new();
        // weight order == the leading names of eval_inputs (the param list)
        let n_params = profile.eval_inputs.len() - 5; // tokens,sign,nk,nv,norm_cfg,mode... see below
        // eval_inputs = PARAM_ORDER + [tokens, sign, nk, nv, norm_cfg, mode]
        let param_names = &profile.eval_inputs[..profile.eval_inputs.len() - 6];
        ensure!(n_params - 1 == param_names.len(), "manifest param arity");
        for pname in param_names {
            let t = tensors
                .get(pname)
                .ok_or_else(|| anyhow!("weights missing tensor '{pname}'"))?;
            weights.push(lit_f32(&t.shape, &t.as_f32()?)?);
        }
        let sign_t = tensors
            .get("sign")
            .ok_or_else(|| anyhow!("weights missing 'sign'"))?;
        let sign = sign_t.as_f32()?;
        let sign_lit = lit_f32(&[profile.d_head], &sign)?;

        let load = |rel: &str| rt.load(manifest.path(rel));
        let (eval, prefill, decode) = match entry {
            Entry::Eval => (Some(load(&profile.eval_hlo)?), None, None),
            Entry::Serve => (
                None,
                Some(load(&profile.prefill_hlo)?),
                Some(load(&profile.decode_hlo)?),
            ),
            Entry::All => (
                Some(load(&profile.eval_hlo)?),
                Some(load(&profile.prefill_hlo)?),
                Some(load(&profile.decode_hlo)?),
            ),
        };
        Ok(ModelExecutor {
            profile,
            serve: manifest.serve.clone(),
            eval_proto: manifest.eval.clone(),
            weights,
            sign,
            sign_lit,
            eval,
            prefill,
            decode,
        })
    }

    fn cfg_literals(&self, cfg: &QuantConfig) -> Result<[xla::Literal; 4]> {
        let l = self.profile.n_layers;
        ensure!(cfg.layers.len() == l, "config has {} layers, model has {l}",
                cfg.layers.len());
        let (nk, nv) = cfg.to_bin_arrays();
        Ok([
            lit_f32(&[l], &nk)?,
            lit_f32(&[l], &nv)?,
            lit_f32(&[4], &cfg.to_norm_cfg())?,
            lit_scalar_i32(cfg.mode as i32),
        ])
    }

    /// Teacher-forced NLL over one chunk batch. `tokens` is
    /// (eval.batch, eval.chunk_len) row-major. Returns (nll_sum, count) per row.
    pub fn eval_nll(&self, tokens: &[i32], cfg: &QuantConfig) -> Result<(Vec<f32>, Vec<f32>)> {
        let prog = self.eval.as_ref().ok_or_else(|| anyhow!("eval not loaded"))?;
        let b = self.eval_proto.batch;
        let cl = self.eval_proto.chunk_len;
        ensure!(tokens.len() == b * cl, "tokens must be {b}x{cl}");
        let tokens_lit = lit_i32(&[b, cl], tokens)?;
        let [nk, nv, ncfg, mode] = self.cfg_literals(cfg)?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&tokens_lit);
        args.push(&self.sign_lit);
        args.push(&nk);
        args.push(&nv);
        args.push(&ncfg);
        args.push(&mode);
        let out = prog.run(&args)?;
        ensure!(out.len() == 2, "eval returns 2 outputs");
        Ok((to_f32(&out[0])?, to_f32(&out[1])?))
    }

    /// Prompt prefill (serve.batch × serve.prefill_len, PAD-padded).
    pub fn run_prefill(
        &self,
        tokens: &[i32],
        lengths: &[i32],
        cfg: &QuantConfig,
    ) -> Result<PrefillOut> {
        let prog = self
            .prefill
            .as_ref()
            .ok_or_else(|| anyhow!("prefill not loaded"))?;
        let b = self.serve.batch;
        let tp = self.serve.prefill_len;
        ensure!(tokens.len() == b * tp && lengths.len() == b);
        let tokens_lit = lit_i32(&[b, tp], tokens)?;
        let len_lit = lit_i32(&[b], lengths)?;
        let [nk, nv, ncfg, mode] = self.cfg_literals(cfg)?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&tokens_lit);
        args.push(&len_lit);
        args.push(&self.sign_lit);
        args.push(&nk);
        args.push(&nv);
        args.push(&ncfg);
        args.push(&mode);
        let out = prog.run(&args)?;
        ensure!(out.len() == 5, "prefill returns 5 outputs");
        Ok(PrefillOut {
            logits: to_f32(&out[0])?,
            kr: to_f32(&out[1])?,
            ki: to_f32(&out[2])?,
            vr: to_f32(&out[3])?,
            vi: to_f32(&out[4])?,
        })
    }

    /// One decode step over the dense (norm-dequantized) compressed cache.
    /// Cache slices are (L, B, H, Tmax, d/2) row-major f32.
    #[allow(clippy::too_many_arguments)]
    pub fn run_decode(
        &self,
        token: &[i32],
        pos: &[i32],
        cfg: &QuantConfig,
        kr: &[f32],
        ki: &[f32],
        vr: &[f32],
        vi: &[f32],
    ) -> Result<DecodeOut> {
        let prog = self
            .decode
            .as_ref()
            .ok_or_else(|| anyhow!("decode not loaded"))?;
        let (l, b, h, tmax, half) = self.cache_dims();
        let cshape = [l, b, h, tmax, half];
        ensure!(token.len() == b && pos.len() == b);
        ensure!(kr.len() == l * b * h * tmax * half, "cache shape mismatch");
        let token_lit = lit_i32(&[b], token)?;
        let pos_lit = lit_i32(&[b], pos)?;
        let [nk, nv, ncfg, mode] = self.cfg_literals(cfg)?;
        let kr_l = lit_f32(&cshape, kr)?;
        let ki_l = lit_f32(&cshape, ki)?;
        let vr_l = lit_f32(&cshape, vr)?;
        let vi_l = lit_f32(&cshape, vi)?;
        let mut args: Vec<&xla::Literal> = self.weights.iter().collect();
        args.push(&token_lit);
        args.push(&pos_lit);
        args.push(&self.sign_lit);
        args.push(&nk);
        args.push(&nv);
        args.push(&ncfg);
        args.push(&mode);
        args.push(&kr_l);
        args.push(&ki_l);
        args.push(&vr_l);
        args.push(&vi_l);
        let out = prog.run(&args)?;
        ensure!(out.len() == 5, "decode returns 5 outputs");
        Ok(DecodeOut {
            logits: to_f32(&out[0])?,
            kr: to_f32(&out[1])?,
            ki: to_f32(&out[2])?,
            vr: to_f32(&out[3])?,
            vi: to_f32(&out[4])?,
        })
    }

    /// Swap the ±1 diagonal used by every entry point (D-seed sweeps —
    /// the diagonal is a runtime input, so no recompilation happens).
    pub fn set_sign(&mut self, sign: &[f32]) -> Result<()> {
        ensure!(sign.len() == self.profile.d_head, "sign length");
        ensure!(sign.iter().all(|v| *v == 1.0 || *v == -1.0), "sign must be ±1");
        self.sign = sign.to_vec();
        self.sign_lit = lit_f32(&[self.profile.d_head], sign)?;
        Ok(())
    }

    /// (L, B, H, Tmax, d/2) for the serving cache tensors.
    pub fn cache_dims(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.profile.n_layers,
            self.serve.batch,
            self.profile.n_kv_heads,
            self.serve.tmax,
            self.profile.d_head / 2,
        )
    }
}
