//! Batched TurboAngle encode/decode over `[rows × d]` slabs — the
//! throughput form of the per-vector hot path in [`super::angle`].
//!
//! The serving engine and benches process thousands of head-dim vectors per
//! step; doing that one `encode_into` call at a time leaves every core but
//! one idle. This module fans rows out across rayon with per-thread scratch
//! buffers (the `encode_into` pattern, amortized per worker instead of per
//! row) and falls back to a single-thread loop below [`PAR_ROW_THRESHOLD`]
//! rows, where fork/join overhead would dominate.
//!
//! Bit-exactness contract: every variant produces output BIT-IDENTICAL to
//! row-by-row [`super::angle::encode_into`] / [`super::angle::decode_into`]
//! — the per-row kernel is the same code, and the decode LUT is the proven
//! bit-identical [`TrigLut`] path — so golden agreement with the JAX oracle
//! is inherited, not re-established.

use super::angle::{decode_into_lut, encode_into, TrigLut};
use rayon::prelude::*;

/// Below this many rows the serial loop wins: a fork/join dispatch costs
/// more than encoding the rows outright (measured in
/// `benches/quant_hot_path.rs`).
pub const PAR_ROW_THRESHOLD: usize = 128;

fn batch_dims(x_len: usize, d: usize, r_len: usize, k_len: usize) -> (usize, usize) {
    assert!(d.is_power_of_two() && d >= 2, "d must be a power of two >= 2");
    assert_eq!(x_len % d, 0, "slab length must be a multiple of d");
    let rows = x_len / d;
    let half = d / 2;
    assert_eq!(r_len, rows * half, "r buffer must be rows*d/2");
    assert_eq!(k_len, rows * half, "k buffer must be rows*d/2");
    (rows, half)
}

/// Encode a `[rows × d]` slab; picks serial or parallel by row count.
///
/// Roundtrip with [`decode_batch`] — the reconstruction error is bounded
/// by the bin width (paper Alg. 1):
///
/// ```
/// use turboangle::quant::{decode_batch, encode_batch};
/// use turboangle::quant::fwht::test_sign_diag;
/// let (rows, d, n) = (4usize, 16usize, 256u32);
/// let sign = test_sign_diag(d, 1);
/// let x: Vec<f32> = (0..rows * d).map(|i| (i as f32 * 0.37).sin()).collect();
/// let (mut r, mut k) = (vec![0.0f32; rows * d / 2], vec![0u16; rows * d / 2]);
/// encode_batch(&x, &sign, n, &mut r, &mut k);
/// let mut xh = vec![0.0f32; rows * d];
/// decode_batch(&r, &k, &sign, n, false, &mut xh);
/// let mse: f32 =
///     x.iter().zip(&xh).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / (rows * d) as f32;
/// assert!(mse < 1e-3, "mse {mse}");
/// ```
pub fn encode_batch(x: &[f32], sign: &[f32], n: u32, r_out: &mut [f32], k_out: &mut [u16]) {
    let d = sign.len();
    let (rows, _) = batch_dims(x.len(), d, r_out.len(), k_out.len());
    if rows >= PAR_ROW_THRESHOLD {
        encode_batch_parallel(x, sign, n, r_out, k_out);
    } else {
        encode_batch_serial(x, sign, n, r_out, k_out);
    }
}

/// Single-thread slab encode with one reused scratch buffer.
pub fn encode_batch_serial(x: &[f32], sign: &[f32], n: u32, r_out: &mut [f32], k_out: &mut [u16]) {
    let d = sign.len();
    let (_, half) = batch_dims(x.len(), d, r_out.len(), k_out.len());
    let mut scratch = vec![0.0f32; d];
    for ((row, r), k) in x
        .chunks_exact(d)
        .zip(r_out.chunks_exact_mut(half))
        .zip(k_out.chunks_exact_mut(half))
    {
        encode_into(row, sign, n, &mut scratch, r, k);
    }
}

/// Rayon slab encode: rows fan out across the pool, each worker keeps its
/// own scratch buffer alive across the rows it processes.
pub fn encode_batch_parallel(
    x: &[f32],
    sign: &[f32],
    n: u32,
    r_out: &mut [f32],
    k_out: &mut [u16],
) {
    let d = sign.len();
    let (_, half) = batch_dims(x.len(), d, r_out.len(), k_out.len());
    x.par_chunks_exact(d)
        .zip(r_out.par_chunks_exact_mut(half))
        .zip(k_out.par_chunks_exact_mut(half))
        .for_each_init(
            || vec![0.0f32; d],
            |scratch, ((row, r), k)| encode_into(row, sign, n, scratch, r, k),
        );
}

/// Decode a `[rows × d/2]` pair of (norm, bin) slabs back into `[rows × d]`;
/// picks serial or parallel by row count. Builds the `n`-entry trig LUT
/// once for the whole slab.
pub fn decode_batch(r: &[f32], k: &[u16], sign: &[f32], n: u32, centered: bool, out: &mut [f32]) {
    let d = sign.len();
    let (rows, _) = batch_dims(out.len(), d, r.len(), k.len());
    let lut = TrigLut::new(n, centered);
    if rows >= PAR_ROW_THRESHOLD {
        decode_batch_parallel(r, k, sign, &lut, out);
    } else {
        decode_batch_serial(r, k, sign, &lut, out);
    }
}

/// Single-thread slab decode through a prebuilt LUT.
pub fn decode_batch_serial(r: &[f32], k: &[u16], sign: &[f32], lut: &TrigLut, out: &mut [f32]) {
    let d = sign.len();
    let (_, half) = batch_dims(out.len(), d, r.len(), k.len());
    for ((r_row, k_row), out_row) in r
        .chunks_exact(half)
        .zip(k.chunks_exact(half))
        .zip(out.chunks_exact_mut(d))
    {
        decode_into_lut(r_row, k_row, sign, lut, out_row);
    }
}

/// Rayon slab decode through a shared prebuilt LUT.
pub fn decode_batch_parallel(r: &[f32], k: &[u16], sign: &[f32], lut: &TrigLut, out: &mut [f32]) {
    let d = sign.len();
    let (_, half) = batch_dims(out.len(), d, r.len(), k.len());
    r.par_chunks_exact(half)
        .zip(k.par_chunks_exact(half))
        .zip(out.par_chunks_exact_mut(d))
        .for_each(|((r_row, k_row), out_row)| decode_into_lut(r_row, k_row, sign, lut, out_row));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::angle::{decode_into, encode};
    use crate::quant::fwht::test_sign_diag;
    use crate::util::prop::Gen;

    fn slab(rows: usize, d: usize, seed: u64) -> Vec<f32> {
        Gen::new(seed).f32_vec(rows * d, -4.0, 4.0)
    }

    #[test]
    fn encode_batch_bit_identical_to_rowwise() {
        for (rows, d, n) in [(1usize, 8usize, 48u32), (7, 64, 128), (300, 32, 64)] {
            let sign = test_sign_diag(d, 5);
            let x = slab(rows, d, 9 + rows as u64);
            let half = d / 2;
            let (mut r, mut k) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
            encode_batch(&x, &sign, n, &mut r, &mut k);
            for row in 0..rows {
                let e = encode(&x[row * d..(row + 1) * d], &sign, n);
                assert_eq!(&r[row * half..(row + 1) * half], &e.r[..], "rows={rows} d={d}");
                assert_eq!(&k[row * half..(row + 1) * half], &e.k[..], "rows={rows} d={d}");
            }
        }
    }

    #[test]
    fn decode_batch_bit_identical_to_rowwise() {
        for (rows, d, n, centered) in
            [(1usize, 8usize, 48u32, false), (7, 64, 128, true), (300, 32, 64, false)]
        {
            let sign = test_sign_diag(d, 6);
            let x = slab(rows, d, 11 + rows as u64);
            let half = d / 2;
            let (mut r, mut k) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
            encode_batch_serial(&x, &sign, n, &mut r, &mut k);
            let mut out = vec![0.0f32; rows * d];
            decode_batch(&r, &k, &sign, n, centered, &mut out);
            let mut want = vec![0.0f32; d];
            for row in 0..rows {
                decode_into(
                    &r[row * half..(row + 1) * half],
                    &k[row * half..(row + 1) * half],
                    &sign,
                    n,
                    centered,
                    &mut want,
                );
                assert_eq!(&out[row * d..(row + 1) * d], &want[..], "rows={rows} d={d}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial_exactly() {
        let (rows, d, n) = (513usize, 64usize, 128u32);
        let sign = test_sign_diag(d, 7);
        let x = slab(rows, d, 21);
        let half = d / 2;
        let (mut rs, mut ks) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
        let (mut rp, mut kp) = (vec![0.0f32; rows * half], vec![0u16; rows * half]);
        encode_batch_serial(&x, &sign, n, &mut rs, &mut ks);
        encode_batch_parallel(&x, &sign, n, &mut rp, &mut kp);
        assert_eq!(rs, rp);
        assert_eq!(ks, kp);
        let lut = TrigLut::new(n, false);
        let (mut os, mut op) = (vec![0.0f32; rows * d], vec![0.0f32; rows * d]);
        decode_batch_serial(&rs, &ks, &sign, &lut, &mut os);
        decode_batch_parallel(&rp, &kp, &sign, &lut, &mut op);
        assert_eq!(os, op);
    }

    #[test]
    #[should_panic(expected = "multiple of d")]
    fn rejects_ragged_slab() {
        let sign = test_sign_diag(8, 1);
        let x = vec![0.0f32; 13];
        let (mut r, mut k) = (vec![0.0f32; 4], vec![0u16; 4]);
        encode_batch(&x, &sign, 64, &mut r, &mut k);
    }
}
