//! Bit-packed storage for angle indices and norm codes.
//!
//! The kv_manager stores angle bins at exactly `ceil(log2(n))` bits each in
//! a little-endian u64 bitstream — this is where the paper's `log2(n)/2`
//! bits-per-element rate physically lives in RAM.

/// Bits needed for a bin index in `0..n`.
#[inline]
pub fn bits_for(n: u32) -> u32 {
    debug_assert!(n >= 2);
    32 - (n - 1).leading_zeros()
}

/// A little-endian bitstream of fixed-width codes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BitVec {
    words: Vec<u64>,
    len_bits: usize,
}

impl BitVec {
    pub fn with_capacity(codes: usize, width: u32) -> Self {
        BitVec {
            words: Vec::with_capacity((codes * width as usize).div_ceil(64)),
            len_bits: 0,
        }
    }

    #[inline]
    pub fn push(&mut self, code: u32, width: u32) {
        debug_assert!((1..=32).contains(&width));
        debug_assert!(code < (1u64 << width) as u32 || width == 32);
        let bit = self.len_bits;
        let word = bit / 64;
        let off = (bit % 64) as u32;
        if word >= self.words.len() {
            self.words.push(0);
        }
        self.words[word] |= (code as u64) << off;
        if off + width > 64 {
            self.words.push((code as u64) >> (64 - off));
        }
        self.len_bits += width as usize;
    }

    #[inline]
    pub fn get(&self, idx: usize, width: u32) -> u32 {
        let bit = idx * width as usize;
        let word = bit / 64;
        let off = (bit % 64) as u32;
        let mask = if width == 32 { u32::MAX as u64 } else { (1u64 << width) - 1 };
        let mut v = self.words[word] >> off;
        if off + width > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    pub fn len_codes(&self, width: u32) -> usize {
        self.len_bits / width as usize
    }

    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    /// Heap bytes actually used for storage (memory accounting).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    pub fn clear(&mut self) {
        self.words.clear();
        self.len_bits = 0;
    }
}

/// Pack a slice of codes at fixed width.
pub fn pack(codes: &[u16], width: u32) -> BitVec {
    let mut bv = BitVec::with_capacity(codes.len(), width);
    for &c in codes {
        bv.push(c as u32, width);
    }
    bv
}

/// Unpack `count` codes.
pub fn unpack(bv: &BitVec, count: usize, width: u32) -> Vec<u16> {
    (0..count).map(|i| bv.get(i, width) as u16).collect()
}

/// Unpack straight into an f32 buffer (what the HLO decode input wants).
pub fn unpack_f32_into(bv: &BitVec, width: u32, out: &mut [f32]) {
    for (i, o) in out.iter_mut().enumerate() {
        *o = bv.get(i, width) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_for_known() {
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(48), 6);
        assert_eq!(bits_for(56), 6);
        assert_eq!(bits_for(64), 6);
        assert_eq!(bits_for(65), 7);
        assert_eq!(bits_for(128), 7);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(512), 9);
    }

    #[test]
    fn roundtrip_all_widths() {
        for width in 1..=16u32 {
            let max = ((1u32 << width) - 1) as u16;
            let codes: Vec<u16> = (0..257u32)
                .map(|i| ((i * 2654435761u32.wrapping_mul(i + 1)) as u16) & max)
                .collect();
            let bv = pack(&codes, width);
            assert_eq!(unpack(&bv, codes.len(), width), codes, "w={width}");
        }
    }

    #[test]
    fn storage_is_tight() {
        let codes = vec![0u16; 1024];
        let bv = pack(&codes, 7);
        // 1024 codes * 7 bits = 7168 bits = 112 u64 words
        assert_eq!(bv.storage_bytes(), 112 * 8);
    }

    #[test]
    fn word_boundary_crossing() {
        // width 7 crosses a 64-bit boundary at code 9 (63 -> 70 bits)
        let codes: Vec<u16> = (0..20).map(|i| (i * 11 % 128) as u16).collect();
        let bv = pack(&codes, 7);
        assert_eq!(unpack(&bv, 20, 7), codes);
    }

    #[test]
    fn unpack_f32_matches() {
        let codes: Vec<u16> = (0..100).map(|i| (i % 64) as u16).collect();
        let bv = pack(&codes, 6);
        let mut out = vec![0.0f32; 100];
        unpack_f32_into(&bv, 6, &mut out);
        for (c, o) in codes.iter().zip(&out) {
            assert_eq!(*c as f32, *o);
        }
    }
}
