//! Bit-packed storage for angle indices and norm codes.
//!
//! The kv_manager stores angle bins at exactly `ceil(log2(n))` bits each in
//! a little-endian u64 bitstream — this is where the paper's `log2(n)/2`
//! bits-per-element rate physically lives in RAM.

/// Bits needed for a bin index in `0..n`.
#[inline]
pub fn bits_for(n: u32) -> u32 {
    debug_assert!(n >= 2);
    32 - (n - 1).leading_zeros()
}

/// Low-`width` mask for `width` in 1..=32 (fits u64 without overflow).
#[inline]
fn width_mask(width: u32) -> u64 {
    (1u64 << width) - 1
}

/// A little-endian bitstream of fixed-width codes.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BitVec {
    words: Vec<u64>,
    len_bits: usize,
}

impl BitVec {
    /// An empty stream preallocated for `codes` codes of `width` bits.
    pub fn with_capacity(codes: usize, width: u32) -> Self {
        BitVec {
            words: Vec::with_capacity((codes * width as usize).div_ceil(64)),
            len_bits: 0,
        }
    }

    /// Append one `width`-bit code. Out-of-range codes are truncated to
    /// their low `width` bits: before the mask, a stray high bit would OR
    /// into the *neighboring* codes of the stream and silently corrupt the
    /// whole cache page in release builds.
    #[inline]
    pub fn push(&mut self, code: u32, width: u32) {
        debug_assert!((1..=32).contains(&width));
        let code = (code as u64) & width_mask(width);
        let bit = self.len_bits;
        let word = bit / 64;
        let off = (bit % 64) as u32;
        if word >= self.words.len() {
            self.words.push(0);
        }
        self.words[word] |= code << off;
        if off + width > 64 {
            self.words.push(code >> (64 - off));
        }
        self.len_bits += width as usize;
    }

    /// Random-access read of code `idx` in a `width`-bit stream.
    #[inline]
    pub fn get(&self, idx: usize, width: u32) -> u32 {
        let bit = idx * width as usize;
        let word = bit / 64;
        let off = (bit % 64) as u32;
        let mask = width_mask(width);
        let mut v = self.words[word] >> off;
        if off + width > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    /// Stored code count at `width` bits each.
    pub fn len_codes(&self, width: u32) -> usize {
        self.len_bits / width as usize
    }

    /// Stored length in bits.
    pub fn len_bits(&self) -> usize {
        self.len_bits
    }

    /// Heap bytes actually used for storage (memory accounting).
    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// The raw little-endian word stream (content addressing of sealed
    /// cache pages hashes these directly instead of re-unpacking codes).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Reset to an empty stream, keeping the word allocation.
    pub fn clear(&mut self) {
        self.words.clear();
        self.len_bits = 0;
    }
}

/// Sequential fixed-width reader over a [`BitVec`] — the fused read path's
/// hot loop. [`BitVec::get`] recomputes word index and offset from scratch
/// per code; the cursor streams through the words with a rolling bit
/// buffer, which is what lets page-tile decode keep pace with a dense f32
/// scan. Yields exactly the bits `get` would.
pub struct BitCursor<'a> {
    words: &'a [u64],
    next_word: usize,
    buf: u128,
    avail: u32,
}

impl<'a> BitCursor<'a> {
    /// Cursor positioned at code index `start` of a `width`-bit stream.
    pub fn new(bv: &'a BitVec, start: usize, width: u32) -> Self {
        debug_assert!((1..=32).contains(&width));
        let bit = start * width as usize;
        debug_assert!(bit <= bv.len_bits);
        let word = bit / 64;
        let off = (bit % 64) as u32;
        let (buf, avail, next_word) = if word < bv.words.len() {
            ((bv.words[word] >> off) as u128, 64 - off, word + 1)
        } else {
            (0, 0, word)
        };
        BitCursor {
            words: &bv.words,
            next_word,
            buf,
            avail,
        }
    }

    /// Read the next code. The caller must not read past the packed length.
    #[inline]
    pub fn next(&mut self, width: u32) -> u32 {
        if self.avail < width {
            self.buf |= (self.words[self.next_word] as u128) << self.avail;
            self.next_word += 1;
            self.avail += 64;
        }
        let code = (self.buf as u64 & width_mask(width)) as u32;
        self.buf >>= width;
        self.avail -= width;
        code
    }
}

/// Pack a slice of codes at fixed width.
pub fn pack(codes: &[u16], width: u32) -> BitVec {
    let mut bv = BitVec::with_capacity(codes.len(), width);
    for &c in codes {
        bv.push(c as u32, width);
    }
    bv
}

/// Bulk-unpack the dispatch macro shared by the u16 and f32 sinks: loads a
/// two-word 128-bit window once, then shatters every code it fully covers
/// with independent shifts (no per-code branch, no rolling-buffer
/// dependency chain — the form LLVM unrolls and schedules wide). A window
/// always covers at least `(128 - 63) / 32 = 2` codes, so the outer loop
/// advances every iteration.
macro_rules! bulk_unpack {
    ($bv:expr, $start:expr, $width:expr, $out:expr, $code:ident => $emit:expr) => {{
        let (bv, width, out) = ($bv, $width, $out);
        debug_assert!((1..=32).contains(&width));
        debug_assert!(($start + out.len()) * width as usize <= bv.len_bits);
        let words = &bv.words;
        let mask = width_mask(width);
        let mut bit = $start * width as usize;
        let mut i = 0usize;
        let n = out.len();
        while i < n {
            let word = bit / 64;
            let off = (bit % 64) as u32;
            let lo = words[word] as u128;
            let hi = if word + 1 < words.len() {
                words[word + 1] as u128
            } else {
                0
            };
            let win = (lo | (hi << 64)) >> off;
            let avail = 128 - off;
            let m = ((avail / width) as usize).min(n - i);
            for (j, o) in out[i..i + m].iter_mut().enumerate() {
                let $code = ((win >> (j as u32 * width)) as u64 & mask) as u16;
                *o = $emit;
            }
            bit += m * width;
            i += m;
        }
    }};
}

/// Bulk-unpack `out.len()` codes starting at code index `start` of a
/// `width`-bit stream into a caller buffer — the vectorizable replacement
/// for a [`BitCursor`] loop on tile-decode hot paths (bit-identical to
/// sequential `next` reads; proptested across widths 1..=16 including
/// word-straddling codes). [`BitCursor`] remains the right tool for
/// sequential/validation reads that interleave with other work.
pub fn unpack_codes_range_into(bv: &BitVec, start: usize, width: u32, out: &mut [u16]) {
    bulk_unpack!(bv, start, width, out, code => code);
}

/// [`unpack_codes_range_into`] with an f32 sink: codes land as exact
/// integers (f32 represents every integer below 2^24 exactly; packed
/// codebooks cap at 16-bit codes), ready for dequant arithmetic without an
/// intermediate u16 buffer.
pub fn unpack_f32_range_into(bv: &BitVec, start: usize, width: u32, out: &mut [f32]) {
    bulk_unpack!(bv, start, width, out, code => code as f32);
}

/// Unpack codes into a caller buffer — the scratch-reusing variant of
/// [`unpack`] for hot paths that would otherwise allocate per call.
pub fn unpack_into(bv: &BitVec, width: u32, out: &mut [u16]) {
    unpack_codes_range_into(bv, 0, width, out);
}

/// Unpack `count` codes (allocating convenience over [`unpack_into`]).
pub fn unpack(bv: &BitVec, count: usize, width: u32) -> Vec<u16> {
    let mut out = vec![0u16; count];
    unpack_into(bv, width, &mut out);
    out
}

/// Unpack straight into an f32 buffer (what the HLO decode input wants).
pub fn unpack_f32_into(bv: &BitVec, width: u32, out: &mut [f32]) {
    unpack_f32_range_into(bv, 0, width, out);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_for_known() {
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(48), 6);
        assert_eq!(bits_for(56), 6);
        assert_eq!(bits_for(64), 6);
        assert_eq!(bits_for(65), 7);
        assert_eq!(bits_for(128), 7);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(512), 9);
    }

    #[test]
    fn roundtrip_all_widths() {
        for width in 1..=16u32 {
            let max = ((1u32 << width) - 1) as u16;
            let codes: Vec<u16> = (0..257u32)
                .map(|i| ((i * 2654435761u32.wrapping_mul(i + 1)) as u16) & max)
                .collect();
            let bv = pack(&codes, width);
            assert_eq!(unpack(&bv, codes.len(), width), codes, "w={width}");
        }
    }

    #[test]
    fn storage_is_tight() {
        let codes = vec![0u16; 1024];
        let bv = pack(&codes, 7);
        // 1024 codes * 7 bits = 7168 bits = 112 u64 words
        assert_eq!(bv.storage_bytes(), 112 * 8);
    }

    #[test]
    fn word_boundary_crossing() {
        // width 7 crosses a 64-bit boundary at code 9 (63 -> 70 bits)
        let codes: Vec<u16> = (0..20).map(|i| (i * 11 % 128) as u16).collect();
        let bv = pack(&codes, 7);
        assert_eq!(unpack(&bv, 20, 7), codes);
    }

    #[test]
    fn oversized_code_is_masked_not_smeared() {
        // regression: push() used to OR the full 32-bit value into the
        // stream, so an out-of-range code corrupted its *neighbors* in
        // release builds. The low `width` bits must land, nothing else.
        let mut bv = BitVec::with_capacity(3, 4);
        bv.push(0x5, 4);
        bv.push(0xFFF3, 4); // oversized: pre-fix this smears bits 8..20
        bv.push(0xA, 4);
        assert_eq!(bv.get(0, 4), 0x5, "left neighbor");
        assert_eq!(bv.get(1, 4), 0x3, "oversized code keeps its low bits");
        assert_eq!(bv.get(2, 4), 0xA, "right neighbor");
        // and across a word boundary (width 7, code 9 spans bits 63..70)
        let mut bv = BitVec::with_capacity(12, 7);
        for i in 0..9 {
            bv.push(i, 7);
        }
        bv.push(u32::MAX, 7);
        bv.push(0x55, 7);
        for i in 0..9 {
            assert_eq!(bv.get(i as usize, 7), i);
        }
        assert_eq!(bv.get(9, 7), 0x7F);
        assert_eq!(bv.get(10, 7), 0x55);
    }

    #[test]
    fn cursor_matches_get_at_any_start() {
        for width in [1u32, 3, 7, 11, 16] {
            let max = ((1u64 << width) - 1) as u32;
            let codes: Vec<u16> = (0..300u32)
                .map(|i| (i.wrapping_mul(2654435761) & max) as u16)
                .collect();
            let bv = pack(&codes, width);
            for start in [0usize, 1, 8, 9, 63, 64, 150] {
                let mut cur = BitCursor::new(&bv, start, width);
                for idx in start..codes.len() {
                    assert_eq!(
                        cur.next(width),
                        bv.get(idx, width),
                        "w={width} start={start} idx={idx}"
                    );
                }
            }
        }
    }

    #[test]
    fn unpack_f32_matches() {
        let codes: Vec<u16> = (0..100).map(|i| (i % 64) as u16).collect();
        let bv = pack(&codes, 6);
        let mut out = vec![0.0f32; 100];
        unpack_f32_into(&bv, 6, &mut out);
        for (c, o) in codes.iter().zip(&out) {
            assert_eq!(*c as f32, *o);
        }
    }

    #[test]
    fn bulk_unpack_matches_cursor_at_every_width_and_start() {
        // the bulk word-window unpacker must yield exactly the bits a
        // sequential BitCursor yields, at every width, from starts that
        // land mid-word and on codes straddling a 64-bit boundary
        for width in 1..=16u32 {
            let max = ((1u64 << width) - 1) as u32;
            let codes: Vec<u16> = (0..517u32)
                .map(|i| (i.wrapping_mul(2654435761) & max) as u16)
                .collect();
            let bv = pack(&codes, width);
            for start in [0usize, 1, 7, 63, 64, 65, 130, 511] {
                let n = codes.len() - start;
                let mut cur = BitCursor::new(&bv, start, width);
                let want: Vec<u16> = (0..n).map(|_| cur.next(width) as u16).collect();
                let mut got = vec![0u16; n];
                unpack_codes_range_into(&bv, start, width, &mut got);
                assert_eq!(got, want, "w={width} start={start}");
                let mut got_f = vec![0.0f32; n];
                unpack_f32_range_into(&bv, start, width, &mut got_f);
                for (g, w) in got_f.iter().zip(&want) {
                    assert_eq!(*g, *w as f32, "w={width} start={start}");
                }
            }
        }
    }

    #[test]
    fn bulk_unpack_partial_and_empty_ranges() {
        let codes: Vec<u16> = (0..40).map(|i| (i * 13 % 128) as u16).collect();
        let bv = pack(&codes, 7);
        let mut out = [0u16; 0];
        unpack_codes_range_into(&bv, 5, 7, &mut out); // empty range: no-op
        let mut out = vec![0u16; 3];
        unpack_codes_range_into(&bv, 9, 7, &mut out); // crosses word 0/1 seam
        assert_eq!(out, &codes[9..12]);
        // exact end-of-stream read (last code ends on the packed length)
        let mut out = vec![0u16; 1];
        unpack_codes_range_into(&bv, 39, 7, &mut out);
        assert_eq!(out[0], codes[39]);
    }

    #[test]
    fn unpack_into_reuses_scratch() {
        let codes: Vec<u16> = (0..300).map(|i| (i % 512) as u16).collect();
        let bv = pack(&codes, 9);
        let mut scratch = vec![0u16; 300];
        unpack_into(&bv, 9, &mut scratch);
        assert_eq!(scratch, codes);
        assert_eq!(unpack(&bv, 300, 9), codes);
    }
}
