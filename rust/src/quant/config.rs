//! Per-layer MixedKV configuration and bit-rate accounting (Eq. 1 / Eq. 3).
//!
//! A [`QuantConfig`] is the single object every harness, bench and the
//! serving engine share: per-layer (n_K, n_V) codebook sizes, the norm
//! quantization modes, and the quantizer mode. Constructors express the
//! paper's schedules: uniform, contiguous early-boost (§3.2), and selective
//! boosts (phi-1.5's 0–7 + 16–23).

use super::angle::MAX_BINS;
use super::norm::NormMode;
use crate::util::hash::splitmix64 as mix;
use anyhow::{ensure, Result};

/// Quantizer mode — must match `manifest.json: modes` (L2 lax.switch order).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(i32)]
pub enum Mode {
    /// fp reference: no quantization anywhere.
    None = 0,
    /// TurboAngle uniform angle quantization, left-edge decode (Alg. 1).
    Angle = 1,
    /// TurboAngle with bin-center decode (the §4.4 ablation).
    AngleCentered = 2,
    /// TurboQuant sym-g4 baseline; per-layer arrays carry BITS not bins.
    TqSymG4 = 3,
    /// KIVI-style per-channel asymmetric baseline (bits in arrays).
    Kivi = 4,
    /// KVQuant-style per-vector + 1% outliers baseline (bits in arrays).
    KvQuant = 5,
}

/// Per-layer codebook sizes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerBins {
    /// K-side angle bins (scalar baselines: bit width instead)
    pub n_k: u32,
    /// V-side angle bins (scalar baselines: bit width instead)
    pub n_v: u32,
}

/// Full quantizer configuration for one model.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantConfig {
    /// Which quantizer runs (angle modes vs scalar baselines vs off).
    pub mode: Mode,
    /// Per-layer (n_K, n_V) codebook sizes, index = layer.
    pub layers: Vec<LayerBins>,
    /// Norm quantization for the K side (§3.3).
    pub k_norm: NormMode,
    /// Norm quantization for the V side (§3.3).
    pub v_norm: NormMode,
}

/// The paper's uniform K-side codebook: 128 bins (§4.1; with
/// [`UNIFORM_NV`] this is the 3.25-angle-bit baseline).
pub const UNIFORM_NK: u32 = 128;
/// The paper's uniform V-side codebook: 64 bins (§4.1).
pub const UNIFORM_NV: u32 = 64;

/// The single checked construction path behind every [`QuantConfig`]
/// constructor: a base codebook for all layers, an optional boosted layer
/// set with its own codebook, the quantizer mode and the norm modes.
///
/// [`build`](Self::build) applies the bin-cap (u16 codebook limit) and
/// layer-count checks uniformly and returns actionable errors instead of
/// panicking, which makes it the right entry point for untrusted input
/// (CLI flags, wire requests). The named constructors
/// ([`QuantConfig::uniform`], [`QuantConfig::early_boost`], …) are thin
/// forwarding wrappers that keep their historical panicking behavior.
#[derive(Clone, Debug)]
pub struct QuantConfigBuilder {
    n_layers: usize,
    mode: Mode,
    base: LayerBins,
    boosted: Vec<usize>,
    hi: LayerBins,
    k_norm: NormMode,
    v_norm: NormMode,
}

impl QuantConfigBuilder {
    /// Set the quantizer mode (default [`Mode::Angle`]).
    pub fn mode(mut self, mode: Mode) -> Self {
        self.mode = mode;
        self
    }

    /// Base (n_k, n_v) codebook applied to every non-boosted layer
    /// (default the paper's K128V64). Scalar-baseline modes carry bit
    /// widths here instead of bin counts.
    pub fn base_bins(mut self, n_k: u32, n_v: u32) -> Self {
        self.base = LayerBins { n_k, n_v };
        self
    }

    /// Contiguous early-boost (§3.2): boost layers `0..n_early`, clamped
    /// to the layer count like [`QuantConfig::early_boost`] always did.
    pub fn boost_first(mut self, n_early: usize) -> Self {
        self.boosted = (0..n_early.min(self.n_layers)).collect();
        self
    }

    /// Boost an arbitrary layer set. Unlike
    /// [`QuantConfig::selective_boost`], out-of-range indices are an
    /// error at [`build`](Self::build) time, not silently dropped.
    pub fn boost_layers(mut self, layers: &[usize]) -> Self {
        self.boosted = layers.to_vec();
        self
    }

    /// Codebook for the boosted layers (default the paper's 256/128).
    pub fn boost_bins(mut self, nk_hi: u32, nv_hi: u32) -> Self {
        self.hi = LayerBins { n_k: nk_hi, n_v: nv_hi };
        self
    }

    /// Norm quantization modes for the K and V sides (default fp32).
    pub fn norms(mut self, k: NormMode, v: NormMode) -> Self {
        self.k_norm = k;
        self.v_norm = v;
        self
    }

    /// Materialize the config, enforcing every invariant in one place:
    /// boosted layer indices must exist, and in angle modes every codebook
    /// (base and boost) must stay inside the u16-representable range —
    /// `n > 2^16` would truncate through the packed `u16` bin indices and
    /// decode garbage.
    pub fn build(self) -> Result<QuantConfig> {
        for &l in &self.boosted {
            ensure!(
                l < self.n_layers,
                "boost layer {l} out of range for a {}-layer model \
                 (valid layer indices: 0..{})",
                self.n_layers,
                self.n_layers
            );
        }
        if matches!(self.mode, Mode::None | Mode::Angle | Mode::AngleCentered) {
            for (n, side) in [(self.hi.n_k, "K boost"), (self.hi.n_v, "V boost")] {
                ensure!(
                    (2..=MAX_BINS).contains(&n),
                    "{side} bin count {n} outside 2..=65536 (u16 codebook limit)"
                );
            }
        }
        let mut layers = vec![self.base; self.n_layers];
        for &l in &self.boosted {
            layers[l] = self.hi;
        }
        let cfg = QuantConfig {
            mode: self.mode,
            layers,
            k_norm: self.k_norm,
            v_norm: self.v_norm,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

impl QuantConfig {
    /// Start a checked builder for an `n_layers`-deep model (paper
    /// defaults: angle mode, K128V64 base, 256/128 boost bins, fp32
    /// norms, no boosted layers).
    pub fn builder(n_layers: usize) -> QuantConfigBuilder {
        QuantConfigBuilder {
            n_layers,
            mode: Mode::Angle,
            base: LayerBins {
                n_k: UNIFORM_NK,
                n_v: UNIFORM_NV,
            },
            boosted: Vec::new(),
            hi: LayerBins { n_k: 256, n_v: 128 },
            k_norm: NormMode::FP32,
            v_norm: NormMode::FP32,
        }
    }

    /// Uniform baseline at (n_k, n_v) for all layers, fp32 norms.
    pub fn uniform(n_layers: usize, n_k: u32, n_v: u32) -> Self {
        Self::builder(n_layers)
            .base_bins(n_k, n_v)
            .build()
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// The K128V64 paper baseline: 3.25 angle bits per element (Eq. 1)
    /// at every layer, fp32 norms.
    ///
    /// ```
    /// use turboangle::quant::QuantConfig;
    /// let cfg = QuantConfig::paper_uniform(32);
    /// assert_eq!(cfg.layers.len(), 32);
    /// assert!((cfg.angle_bits_per_element() - 3.25).abs() < 1e-12);
    /// // §3.3 worked example: uniform + K8V4-log at d=128 is 6.75 b/elem
    /// let deploy = cfg.with_k8v4_log();
    /// assert!((deploy.total_bits_per_element(128) - 6.75).abs() < 1e-9);
    /// ```
    pub fn paper_uniform(n_layers: usize) -> Self {
        Self::uniform(n_layers, UNIFORM_NK, UNIFORM_NV)
    }

    /// Contiguous early-boost: layers `0..n_early` at (nk_hi, nv_hi), the
    /// rest at the uniform baseline (§3.2).
    pub fn early_boost(n_layers: usize, n_early: usize, nk_hi: u32, nv_hi: u32) -> Self {
        Self::builder(n_layers)
            .boost_first(n_early)
            .boost_bins(nk_hi, nv_hi)
            .build()
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Selective boost of an arbitrary layer set (phi-1.5's 0–7 ∪ 16–23).
    /// Out-of-range indices are ignored (historical behavior); use
    /// [`QuantConfig::builder`] directly to make them an error.
    pub fn selective_boost(
        n_layers: usize,
        boosted: &[usize],
        nk_hi: u32,
        nv_hi: u32,
    ) -> Self {
        let in_range: Vec<usize> = boosted.iter().copied().filter(|&l| l < n_layers).collect();
        Self::builder(n_layers)
            .boost_layers(&in_range)
            .boost_bins(nk_hi, nv_hi)
            .build()
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Disable quantization (the fp16-reference run).
    pub fn none(n_layers: usize) -> Self {
        Self::builder(n_layers)
            .mode(Mode::None)
            .build()
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Scalar-baseline configs: per-layer arrays carry bits.
    pub fn scalar_baseline(n_layers: usize, mode: Mode, bits: u32) -> Self {
        Self::builder(n_layers)
            .mode(mode)
            .base_bins(bits, bits)
            .build()
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Non-panicking variant of the constructor bound, for configs built
    /// from untrusted input (CLI flags, wire requests, direct `layers`
    /// mutation): every angle-mode layer must keep its bin counts inside
    /// the u16-representable range.
    pub fn validate(&self) -> Result<()> {
        if matches!(self.mode, Mode::None | Mode::Angle | Mode::AngleCentered) {
            for (l, b) in self.layers.iter().enumerate() {
                ensure!(
                    (2..=MAX_BINS).contains(&b.n_k) && (2..=MAX_BINS).contains(&b.n_v),
                    "layer {l} bins (K{}, V{}) outside 2..=65536 (u16 codebook limit)",
                    b.n_k,
                    b.n_v
                );
            }
        }
        Ok(())
    }

    /// Set both sides' norm quantization modes (builder-style).
    pub fn with_norms(mut self, k: NormMode, v: NormMode) -> Self {
        self.k_norm = k;
        self.v_norm = v;
        self
    }

    /// K8V4-log (§3.3): 8-bit linear K norms, 4-bit log V norms.
    pub fn with_k8v4_log(self) -> Self {
        self.with_norms(NormMode::LINEAR8, NormMode::LOG4)
    }

    /// norm8: 8-bit linear norms on both sides.
    pub fn with_norm8(self) -> Self {
        self.with_norms(NormMode::LINEAR8, NormMode::LINEAR8)
    }

    // --- rate accounting -------------------------------------------------

    /// Eq. 1: average angle bits per element across layers,
    /// (log2 n_K + log2 n_V) / 4 summed over layers / L.
    pub fn angle_bits_per_element(&self) -> f64 {
        let l = self.layers.len() as f64;
        self.layers
            .iter()
            .map(|b| ((b.n_k as f64).log2() + (b.n_v as f64).log2()) / 4.0)
            .sum::<f64>()
            / l
    }

    /// Eq. 3 for one side: b_angle + b_norm/2 + 64/d (fp32 norms charge the
    /// paper's reference 16 bits/element, i.e. 32/2, with no minmax term).
    fn side_bits(bins: u32, norm: NormMode, d: usize) -> f64 {
        let angle = (bins as f64).log2() / 2.0;
        if norm.bits == 0 {
            angle + 16.0
        } else {
            angle + norm.bits as f64 / 2.0 + 64.0 / d as f64
        }
    }

    /// Eq. 3, K/V- and layer-averaged total bits per element.
    pub fn total_bits_per_element(&self, d: usize) -> f64 {
        let l = self.layers.len() as f64;
        self.layers
            .iter()
            .map(|b| {
                (Self::side_bits(b.n_k, self.k_norm, d)
                    + Self::side_bits(b.n_v, self.v_norm, d))
                    / 2.0
            })
            .sum::<f64>()
            / l
    }

    /// Eq. 3 under its serving-facing name: the rate the engine's
    /// `MemoryStats::total_bits_per_element()` must reproduce within 1%
    /// (asserted by the quality_sweep bench).
    pub fn bits_per_element(&self, d: usize) -> f64 {
        self.total_bits_per_element(d)
    }

    /// Angle-bits-only variant of Eq. 3 (Tables 1/2 count only angle bits).
    pub fn angle_bits_only(&self) -> f64 {
        self.angle_bits_per_element()
    }

    /// Order-sensitive 64-bit digest of everything that changes the packed
    /// page byte stream: mode, per-layer codebook sizes, and both norm
    /// modes. The shared prefix store folds this into every page content
    /// hash so mixed-precision pages holding identical tokens never dedup
    /// across configs (two configs can pack the same codes at the same
    /// widths — e.g. 48 vs 64 bins — so byte-stream equality alone is not
    /// enough).
    pub fn content_fingerprint(&self) -> u64 {
        let mut h = mix(0x7A5C_0F1E ^ self.mode as i32 as u64);
        for b in &self.layers {
            h = mix(h ^ b.n_k as u64 ^ ((b.n_v as u64) << 32));
        }
        mix(h
            ^ self.k_norm.bits as u64
            ^ ((self.k_norm.log_space as u64) << 8)
            ^ ((self.v_norm.bits as u64) << 16)
            ^ ((self.v_norm.log_space as u64) << 24))
    }

    /// Physical compressed bytes per token per layer (what kv_manager
    /// actually stores): packed angle bits + norm codes + minmax pairs.
    pub fn stored_bytes_per_token_layer(&self, layer: usize, d: usize, n_kv_heads: usize) -> usize {
        use super::packing::bits_for;
        let b = &self.layers[layer];
        let half = d / 2;
        let angle_bits = (bits_for(b.n_k) as usize + bits_for(b.n_v) as usize) * half;
        let norm_bits = |m: NormMode| {
            if m.bits == 0 {
                32 * half
            } else {
                m.bits as usize * half + 64
            }
        };
        n_kv_heads * (angle_bits + norm_bits(self.k_norm) + norm_bits(self.v_norm) + 7) / 8
    }

    // --- serialization to the HLO runtime inputs -------------------------

    /// Per-layer f32 arrays (nk, nv) as the eval/prefill/decode HLOs expect.
    pub fn to_bin_arrays(&self) -> (Vec<f32>, Vec<f32>) {
        (
            self.layers.iter().map(|b| b.n_k as f32).collect(),
            self.layers.iter().map(|b| b.n_v as f32).collect(),
        )
    }

    /// norm_cfg = [k_bits, k_log, v_bits, v_log].
    pub fn to_norm_cfg(&self) -> [f32; 4] {
        [
            self.k_norm.bits as f32,
            self.k_norm.log_space as u8 as f32,
            self.v_norm.bits as f32,
            self.v_norm.log_space as u8 as f32,
        ]
    }

    /// The baseline (majority) per-layer bins — boosted layers are the
    /// minority that differ from this.
    pub fn majority_bins(&self) -> LayerBins {
        let mut counts: Vec<(LayerBins, usize)> = Vec::new();
        for b in &self.layers {
            match counts.iter_mut().find(|(k, _)| k == b) {
                Some((_, c)) => *c += 1,
                None => counts.push((*b, 1)),
            }
        }
        counts.into_iter().max_by_key(|&(_, c)| c).unwrap().0
    }

    /// Short human tag for reports, e.g. "E4(256,128)+K8V4log".
    pub fn tag(&self) -> String {
        let base = &self.majority_bins();
        let boosted: Vec<usize> = self
            .layers
            .iter()
            .enumerate()
            .filter(|(_, b)| **b != *base)
            .map(|(i, _)| i)
            .collect();
        let head = match self.mode {
            Mode::None => return "fp-ref".into(),
            Mode::Angle => String::new(),
            Mode::AngleCentered => "c:".into(),
            Mode::TqSymG4 => format!("TQ-sym{}-g4", base.n_k),
            Mode::Kivi => format!("KIVI-{}b", base.n_k),
            Mode::KvQuant => format!("KVQ-{}b", base.n_k),
        };
        if matches!(self.mode, Mode::TqSymG4 | Mode::Kivi | Mode::KvQuant) {
            return head;
        }
        let norms = match (self.k_norm, self.v_norm) {
            (NormMode::FP32, NormMode::FP32) => String::new(),
            (k, v) => format!(
                "+K{}{}V{}{}",
                k.bits,
                if k.log_space { "log" } else { "" },
                v.bits,
                if v.log_space { "log" } else { "" }
            ),
        };
        if boosted.is_empty() {
            format!("{head}U(K{},V{}){norms}", base.n_k, base.n_v)
        } else {
            let hi = self.layers[boosted[0]];
            format!(
                "{head}B[{}](K{},V{}){norms}",
                compact_ranges(&boosted),
                hi.n_k,
                hi.n_v
            )
        }
    }
}

/// "0-3,16-23" style range formatting for layer sets.
pub fn compact_ranges(sorted: &[usize]) -> String {
    let mut out = String::new();
    let mut i = 0;
    while i < sorted.len() {
        let start = sorted[i];
        let mut end = start;
        while i + 1 < sorted.len() && sorted[i + 1] == end + 1 {
            i += 1;
            end = sorted[i];
        }
        if !out.is_empty() {
            out.push(',');
        }
        if start == end {
            out.push_str(&start.to_string());
        } else {
            out.push_str(&format!("{start}-{end}"));
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq1_uniform_baseline_is_3_25() {
        let cfg = QuantConfig::paper_uniform(32);
        assert!((cfg.angle_bits_per_element() - 3.25).abs() < 1e-12);
    }

    #[test]
    fn eq1_early_boost_matches_paper() {
        // E4 with (256,128) on L=32: 4 layers at (8+7)/4=3.75, 28 at 3.25
        let cfg = QuantConfig::early_boost(32, 4, 256, 128);
        let expect = (4.0 * 3.75 + 28.0 * 3.25) / 32.0;
        assert!((cfg.angle_bits_per_element() - expect).abs() < 1e-12);
        // paper Table 2 Mistral-7B best per-layer = 3.31 bits
        assert!((cfg.angle_bits_per_element() - 3.3125).abs() < 1e-12);
    }

    #[test]
    fn eq3_worked_example_from_paper() {
        // §3.3: K8V4-log, b_angle=3.25, d=128 -> K side 7.75, V side 5.75,
        // average 6.75
        let cfg = QuantConfig::paper_uniform(32).with_k8v4_log();
        let total = cfg.total_bits_per_element(128);
        assert!((total - 6.75).abs() < 1e-9, "{total}");
        // d=64 overhead: 64/d = 1.0 -> 7.25
        let total64 = cfg.total_bits_per_element(64);
        assert!((total64 - 7.25).abs() < 1e-9, "{total64}");
    }

    #[test]
    fn eq3_norm8() {
        // norm8 at d=128: 3.25 + 8/2 + 0.5 = 7.75 on both sides
        let cfg = QuantConfig::paper_uniform(32).with_norm8();
        assert!((cfg.total_bits_per_element(128) - 7.75).abs() < 1e-9);
    }

    #[test]
    fn fp32_norms_charge_16_bits() {
        let cfg = QuantConfig::paper_uniform(8);
        assert!((cfg.total_bits_per_element(128) - (3.25 + 16.0)).abs() < 1e-9);
    }

    #[test]
    fn selective_matches_manual() {
        let boosted: Vec<usize> = (0..8).chain(16..24).collect();
        let sel = QuantConfig::selective_boost(24, &boosted, 256, 128);
        // phi-1.5 optimal: 16 of 24 layers boosted -> paper says 3.58 bits
        let bits = sel.angle_bits_per_element();
        assert!((bits - (16.0 * 3.75 + 8.0 * 3.25) / 24.0).abs() < 1e-12);
        assert!((bits - 3.5833).abs() < 1e-3);
    }

    #[test]
    fn stored_bytes_accounting() {
        let cfg = QuantConfig::paper_uniform(2).with_k8v4_log();
        // d=128: angle bits = (7+6)*64 = 832; K norms 8*64+64=576;
        // V norms 4*64+64=320; total 1728 bits = 216 bytes
        assert_eq!(cfg.stored_bytes_per_token_layer(0, 128, 1), 216);
    }

    #[test]
    fn compact_ranges_format() {
        assert_eq!(compact_ranges(&[0, 1, 2, 3]), "0-3");
        assert_eq!(
            compact_ranges(&[0, 1, 2, 3, 4, 5, 6, 7, 16, 17, 18, 19, 20, 21, 22, 23]),
            "0-7,16-23"
        );
        assert_eq!(compact_ranges(&[5]), "5");
    }

    #[test]
    fn tags_are_stable() {
        assert_eq!(QuantConfig::paper_uniform(4).tag(), "U(K128,V64)");
        assert_eq!(
            QuantConfig::early_boost(8, 4, 256, 128).tag(),
            "B[0-3](K256,V128)"
        );
        assert_eq!(QuantConfig::none(4).tag(), "fp-ref");
    }

    #[test]
    #[should_panic(expected = "u16 codebook limit")]
    fn rejects_bins_beyond_u16() {
        // n > 2^16 used to truncate through `as u16` and decode garbage
        let _ = QuantConfig::uniform(2, (1 << 16) + 1, 64);
    }

    #[test]
    #[should_panic(expected = "u16 codebook limit")]
    fn rejects_oversized_boost_bins() {
        let _ = QuantConfig::early_boost(8, 4, 1 << 17, 128);
    }

    #[test]
    fn validate_checks_mutated_layers() {
        assert!(QuantConfig::uniform(2, 1 << 16, 2).validate().is_ok());
        let mut cfg = QuantConfig::paper_uniform(2);
        cfg.layers[1].n_v = (1 << 16) + 4; // bypasses the constructor
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("layer 1"), "{err}");
        // scalar baselines carry BITS in the arrays — not bin-bounded
        assert!(QuantConfig::scalar_baseline(2, Mode::Kivi, 2).validate().is_ok());
    }

    #[test]
    fn builder_rejects_out_of_range_boost_layer() {
        let err = QuantConfig::builder(4)
            .boost_layers(&[0, 7])
            .build()
            .unwrap_err()
            .to_string();
        assert!(err.contains("boost layer 7"), "{err}");
        assert!(err.contains("4-layer"), "{err}");
        // the wrapper keeps the historical silently-ignore behavior
        let cfg = QuantConfig::selective_boost(4, &[0, 7], 256, 128);
        assert_eq!(cfg.tag(), "B[0](K256,V128)");
    }

    #[test]
    fn builder_matches_wrapper_constructors() {
        assert_eq!(
            QuantConfig::builder(8)
                .boost_first(4)
                .boost_bins(256, 128)
                .build()
                .unwrap(),
            QuantConfig::early_boost(8, 4, 256, 128)
        );
        assert_eq!(
            QuantConfig::builder(8)
                .mode(Mode::None)
                .build()
                .unwrap(),
            QuantConfig::none(8)
        );
    }

    #[test]
    fn builder_caps_bins_uniformly() {
        // base and boost codebooks hit the same u16 cap through build()
        let base = QuantConfig::builder(2).base_bins(1 << 17, 64).build();
        assert!(base.unwrap_err().to_string().contains("u16 codebook limit"));
        let hi = QuantConfig::builder(2)
            .boost_first(1)
            .boost_bins(256, (1 << 16) + 1)
            .build();
        assert!(hi.unwrap_err().to_string().contains("u16 codebook limit"));
    }

    #[test]
    fn fingerprint_separates_configs() {
        let a = QuantConfig::paper_uniform(4);
        // same packed widths are NOT the same fingerprint: 48 and 64 bins
        // both pack at 6 bits
        let b = QuantConfig::uniform(4, 128, 48);
        assert_ne!(a.content_fingerprint(), b.content_fingerprint());
        // norm-mode-only differences separate too
        assert_ne!(
            a.content_fingerprint(),
            a.clone().with_k8v4_log().content_fingerprint()
        );
        // per-layer placement matters, not just the multiset
        let c = QuantConfig::selective_boost(4, &[0], 256, 128);
        let d = QuantConfig::selective_boost(4, &[3], 256, 128);
        assert_ne!(c.content_fingerprint(), d.content_fingerprint());
        // and it is a pure function of the config
        assert_eq!(a.content_fingerprint(), QuantConfig::paper_uniform(4).content_fingerprint());
    }

    #[test]
    fn majority_base_handles_suffix_boost() {
        // boosting a suffix set must not invert the tag
        let cfg7 = QuantConfig::selective_boost(7, &[0, 5, 6], 256, 128);
        assert_eq!(cfg7.majority_bins(), LayerBins { n_k: 128, n_v: 64 });
        assert_eq!(cfg7.tag(), "B[0,5-6](K256,V128)");
    }
}
