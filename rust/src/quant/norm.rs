//! Per-vector min-max norm quantization (paper §3.3, Eq. 2).
//!
//! Linear or log-space codes at `bits` ∈ {1..16}; the per-vector fp32
//! (min, max) pair is the 64/d overhead term of Eq. 3. The K8V4-log
//! configuration is 8-bit linear for K norms, 4-bit log for V norms.

/// Norm quantization mode for one cache side (K or V).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct NormMode {
    /// 0 = fp32 passthrough.
    pub bits: u8,
    /// quantize in log space (better for right-skewed norm distributions)
    pub log_space: bool,
}

impl NormMode {
    /// fp32 passthrough: norms stored uncompressed.
    pub const FP32: NormMode = NormMode { bits: 0, log_space: false };
    /// 8-bit linear min-max codes (the paper's K-side choice).
    pub const LINEAR8: NormMode = NormMode { bits: 8, log_space: false };
    /// 4-bit log-space codes (the paper's V-side choice).
    pub const LOG4: NormMode = NormMode { bits: 4, log_space: true };

    /// The top code value, `2^bits - 1`.
    pub fn levels(&self) -> f32 {
        ((1u32 << self.bits) - 1) as f32
    }
}

/// Quantized norms for one vector: codes + the min/max window.
#[derive(Clone, Debug)]
pub struct QuantizedNorms {
    /// one `bits`-wide code per pair norm
    pub codes: Vec<u16>,
    /// window minimum (log-space value in log mode)
    pub vmin: f32,
    /// window maximum (log-space value in log mode)
    pub vmax: f32,
}

#[inline]
fn fwd(v: f32, log_space: bool) -> f32 {
    if log_space {
        v.max(1e-12).ln()
    } else {
        v
    }
}

#[inline]
fn bwd(v: f32, log_space: bool) -> f32 {
    if log_space {
        v.exp()
    } else {
        v
    }
}

/// Quantize one vector of pair norms. `mode.bits == 0` is rejected here —
/// the caller keeps fp32 norms and never materializes codes.
///
/// Non-finite inputs: the pre-round value is clamped into `[0, levels]`
/// and NaN maps to code 0, so codes are always in range — previously a
/// NaN/±inf norm rode the saturating `as u16` cast into nonsense codes.
/// The (vmin, vmax) window still records what the input was (`f32::min`/
/// `max` skip NaN operands, so a lone NaN element cannot poison it; in
/// linear mode a ±inf element makes the window non-finite and
/// dequantization *propagates* that non-finite value rather than hiding
/// it; log mode forwards through `max(1e-12).ln()`, which absorbs NaN and
/// -inf to `ln(1e-12)`).
pub fn quantize(r: &[f32], mode: NormMode) -> QuantizedNorms {
    assert!((1..=16).contains(&mode.bits));
    let mut vmin = f32::INFINITY;
    let mut vmax = f32::NEG_INFINITY;
    for &v in r {
        let t = fwd(v, mode.log_space);
        vmin = vmin.min(t);
        vmax = vmax.max(t);
    }
    let scale = if vmax > vmin { vmax - vmin } else { 1.0 };
    let levels = mode.levels();
    let codes = r
        .iter()
        .map(|&v| {
            let t = (fwd(v, mode.log_space) - vmin) / scale * levels;
            // NaN -> 0, out-of-window -> nearest edge; a no-op for finite
            // in-window inputs, so oracle-golden bits are untouched
            let t = if t.is_nan() { 0.0 } else { t.clamp(0.0, levels) };
            // round-half-to-even to match numpy/jax rounding
            t.round_ties_even() as u16
        })
        .collect();
    QuantizedNorms { codes, vmin, vmax }
}

/// Dequantize codes back to norms. `out` must match the code count exactly
/// — a short buffer used to zip silently and drop the tail.
pub fn dequantize_into(q: &QuantizedNorms, mode: NormMode, out: &mut [f32]) {
    assert_eq!(
        out.len(),
        q.codes.len(),
        "dequantize_into: output length must equal the code count"
    );
    let scale = if q.vmax > q.vmin { q.vmax - q.vmin } else { 1.0 };
    let levels = mode.levels().max(1.0);
    for (o, &c) in out.iter_mut().zip(&q.codes) {
        *o = bwd(q.vmin + c as f32 * scale / levels, mode.log_space);
    }
}

/// Allocating convenience wrapper around [`dequantize_into`].
pub fn dequantize(q: &QuantizedNorms, mode: NormMode) -> Vec<f32> {
    let mut out = vec![0.0; q.codes.len()];
    dequantize_into(q, mode, &mut out);
    out
}

/// quant-dequant in one step (eval paths / tests). fp32 mode passes through.
pub fn quant_dequant(r: &[f32], mode: NormMode) -> Vec<f32> {
    if mode.bits == 0 {
        return r.to_vec();
    }
    dequantize(&quantize(r, mode), mode)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                let u = (s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32
                    / (1u64 << 24) as f32;
                // right-skewed, strictly positive (lognormal-ish)
                (3.0 * (u - 0.5)).exp()
            })
            .collect()
    }

    #[test]
    fn codes_in_range() {
        let r = skewed(64, 1);
        for mode in [NormMode::LINEAR8, NormMode::LOG4, NormMode { bits: 2, log_space: false }] {
            let q = quantize(&r, mode);
            let max = (1u32 << mode.bits) - 1;
            assert!(q.codes.iter().all(|&c| (c as u32) <= max));
        }
    }

    #[test]
    fn dequant_within_window() {
        let r = skewed(64, 2);
        let rq = quant_dequant(&r, NormMode::LINEAR8);
        let lo = r.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        for v in rq {
            assert!(v >= lo - 1e-4 && v <= hi + 1e-3);
        }
    }

    #[test]
    fn eight_bit_half_step_bound() {
        let r = skewed(128, 3);
        let rq = quant_dequant(&r, NormMode::LINEAR8);
        let lo = r.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = r.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let step = (hi - lo) / 255.0;
        for (a, b) in r.iter().zip(&rq) {
            assert!((a - b).abs() <= step * 0.51);
        }
    }

    #[test]
    fn log4_beats_linear4_on_skewed() {
        let r = skewed(512, 4);
        let lin = quant_dequant(&r, NormMode { bits: 4, log_space: false });
        let log = quant_dequant(&r, NormMode::LOG4);
        let rel = |q: &[f32]| -> f32 {
            r.iter()
                .zip(q)
                .map(|(a, b)| ((b / a) - 1.0).abs())
                .sum::<f32>()
                / r.len() as f32
        };
        assert!(rel(&log) < rel(&lin));
    }

    #[test]
    fn fp32_passthrough() {
        let r = skewed(32, 5);
        assert_eq!(quant_dequant(&r, NormMode::FP32), r);
    }

    #[test]
    fn non_finite_inputs_yield_in_range_codes() {
        // regression: NaN scale used to push garbage through the `as u16`
        // saturating cast; codes must stay inside the code range and NaN
        // elements must map to code 0
        for mode in [
            NormMode::LINEAR8,
            NormMode::LOG4,
            NormMode { bits: 2, log_space: false },
        ] {
            let max = ((1u32 << mode.bits) - 1) as u16;
            let r = [1.0f32, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 2.5];
            let q = quantize(&r, mode);
            assert!(
                q.codes.iter().all(|&c| c <= max),
                "bits={} codes={:?}",
                mode.bits,
                q.codes
            );
            assert_eq!(q.codes[1], 0, "NaN maps to code 0 (bits={})", mode.bits);
        }
        // all-NaN vector: degenerate window, still deterministic codes
        let q = quantize(&[f32::NAN; 4], NormMode::LINEAR8);
        assert_eq!(q.codes, vec![0u16; 4]);
        // linear ±inf: the window is non-finite and dequant propagates it
        let q = quantize(&[1.0, f32::INFINITY], NormMode::LINEAR8);
        assert!(q.vmax.is_infinite());
        let d = dequantize(&q, NormMode::LINEAR8);
        assert!(!d[1].is_finite(), "non-finite window must stay visible");
    }

    #[test]
    #[should_panic(expected = "output length must equal the code count")]
    fn dequantize_into_rejects_short_buffer() {
        let q = quantize(&[1.0f32, 2.0, 3.0], NormMode::LINEAR8);
        let mut out = vec![0.0f32; 2]; // one short: used to zip silently
        dequantize_into(&q, NormMode::LINEAR8, &mut out);
    }

    #[test]
    fn constant_vector_stable() {
        let r = vec![2.5f32; 16];
        let rq = quant_dequant(&r, NormMode::LINEAR8);
        for v in rq {
            assert!((v - 2.5).abs() < 1e-6);
        }
        let rq = quant_dequant(&r, NormMode::LOG4);
        for v in rq {
            assert!((v - 2.5).abs() < 1e-5);
        }
    }
}
