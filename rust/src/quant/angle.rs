//! TurboAngle encode / decode (paper Alg. 1 + §3.1), native hot path.
//!
//! Encode: y = H·D·x, polar-decompose consecutive pairs, uniform angle
//! bins. Decode: trig lookup at the bin LEFT edge (paper default) or bin
//! center (ablation), inverse transform. Matches the python oracle to f32
//! tolerance (golden-tested).

use super::fwht::{rotate, unrotate};

/// 2π — the full angle circle the codebook divides into `n` bins.
pub const TWO_PI: f32 = core::f32::consts::TAU;

/// Largest supported codebook: bin indices travel as `u16` (`Encoded::k`,
/// the packed streams, `TrigLut`), so `n` beyond 2^16 would silently
/// truncate and decode garbage. Enforced with a hard error at
/// [`super::QuantConfig`] construction and a debug assert at the encode
/// boundary.
pub const MAX_BINS: u32 = 1 << 16;

/// Compressed representation of one head-dim vector: d/2 pair norms and
/// d/2 angle bin indices (bin count `n` stored by the owner).
#[derive(Clone, Debug, PartialEq)]
pub struct Encoded {
    /// d/2 pair norms (raw f32; norm quantization is the owner's job)
    pub r: Vec<f32>,
    /// d/2 angle bin indices in `0..n`
    pub k: Vec<u16>,
}

/// Quantize one angle to a bin index. theta from atan2 (any range).
#[inline]
pub fn angle_to_bin(theta: f32, n: u32) -> u16 {
    debug_assert!(
        (2..=MAX_BINS).contains(&n),
        "bin count {n} outside the u16-representable range 2..=65536"
    );
    let t = if theta < 0.0 { theta + TWO_PI } else { theta };
    // floor(n * t / 2pi) mod n — f32 arithmetic kept IDENTICAL to the
    // jax oracle so bin boundaries agree bit-for-bit on golden inputs.
    let k = (n as f32 * t / TWO_PI).floor();
    (k as i64).rem_euclid(n as i64) as u16
}

/// Bin index back to an angle (left edge by default, matching Alg. 1).
#[inline]
pub fn bin_to_angle(k: u16, n: u32, centered: bool) -> f32 {
    let kk = if centered { k as f32 + 0.5 } else { k as f32 };
    TWO_PI * kk / n as f32
}

/// Encode a single vector (length d, power of two). `scratch` must be d
/// floats; avoids per-call allocation on the hot path.
pub fn encode_into(
    x: &[f32],
    sign: &[f32],
    n: u32,
    scratch: &mut [f32],
    r_out: &mut [f32],
    k_out: &mut [u16],
) {
    let d = x.len();
    debug_assert!(d.is_power_of_two() && d >= 2);
    debug_assert_eq!(scratch.len(), d);
    debug_assert_eq!(r_out.len(), d / 2);
    debug_assert_eq!(k_out.len(), d / 2);
    scratch.copy_from_slice(x);
    rotate(scratch, sign);
    for i in 0..d / 2 {
        let even = scratch[2 * i];
        let odd = scratch[2 * i + 1];
        r_out[i] = (even * even + odd * odd).sqrt();
        k_out[i] = angle_to_bin(odd.atan2(even), n);
    }
}

/// Allocating convenience wrapper around [`encode_into`].
pub fn encode(x: &[f32], sign: &[f32], n: u32) -> Encoded {
    let d = x.len();
    let mut scratch = vec![0.0; d];
    let mut r = vec![0.0; d / 2];
    let mut k = vec![0u16; d / 2];
    encode_into(x, sign, n, &mut scratch, &mut r, &mut k);
    Encoded { r, k }
}

/// Decode into `out` (length d = 2 * r.len()).
pub fn decode_into(
    r: &[f32],
    k: &[u16],
    sign: &[f32],
    n: u32,
    centered: bool,
    out: &mut [f32],
) {
    let half = r.len();
    debug_assert_eq!(k.len(), half);
    debug_assert_eq!(out.len(), 2 * half);
    for i in 0..half {
        let theta = bin_to_angle(k[i], n, centered);
        let (s, c) = theta.sin_cos();
        out[2 * i] = r[i] * c;
        out[2 * i + 1] = r[i] * s;
    }
    unrotate(out, sign);
}

/// Precomputed per-bin trig table — decode's sin/cos is the hot spot, and
/// the codebook has only `n` distinct angles. Values are BIT-IDENTICAL to
/// [`decode_into`] (same `bin_to_angle` + `sin_cos`).
pub struct TrigLut {
    cos: Vec<f32>,
    sin: Vec<f32>,
}

impl TrigLut {
    /// Precompute the `n`-bin table (left-edge or bin-center angles).
    /// Panics outside the `2..=65536` u16 codebook range.
    pub fn new(n: u32, centered: bool) -> Self {
        assert!(
            (2..=MAX_BINS).contains(&n),
            "TrigLut bin count {n} outside 2..=65536 (u16 codebook limit)"
        );
        let mut cos = Vec::with_capacity(n as usize);
        let mut sin = Vec::with_capacity(n as usize);
        for k in 0..n {
            let (s, c) = bin_to_angle(k as u16, n, centered).sin_cos();
            cos.push(c);
            sin.push(s);
        }
        TrigLut { cos, sin }
    }

    /// (cos θ, sin θ) for bin `k`, clamped to the last bin so a corrupted
    /// code stays deterministic instead of panicking mid-decode.
    #[inline]
    pub fn cos_sin(&self, k: u16) -> (f32, f32) {
        let k = (k as usize).min(self.cos.len() - 1);
        (self.cos[k], self.sin[k])
    }

    /// Number of bins in the table.
    pub fn bins(&self) -> usize {
        self.cos.len()
    }

    /// The raw per-bin cosine table (bin index -> cos θ). Exposed for the
    /// batched gather in [`crate::quant::kernels`]; values match
    /// [`Self::cos_sin`] exactly.
    pub fn cos_table(&self) -> &[f32] {
        &self.cos
    }

    /// The raw per-bin sine table (bin index -> sin θ), matching
    /// [`Self::cos_sin`] exactly.
    pub fn sin_table(&self) -> &[f32] {
        &self.sin
    }
}

/// LUT-accelerated decode (EXPERIMENTS.md §Perf): identical output to
/// [`decode_into`], ~3x faster at d=64..128.
pub fn decode_into_lut(
    r: &[f32],
    k: &[u16],
    sign: &[f32],
    lut: &TrigLut,
    out: &mut [f32],
) {
    let half = r.len();
    debug_assert_eq!(k.len(), half);
    debug_assert_eq!(out.len(), 2 * half);
    for i in 0..half {
        let ki = k[i] as usize;
        out[2 * i] = r[i] * lut.cos[ki];
        out[2 * i + 1] = r[i] * lut.sin[ki];
    }
    unrotate(out, sign);
}

/// Allocating convenience wrapper around [`decode_into`].
pub fn decode(r: &[f32], k: &[u16], sign: &[f32], n: u32, centered: bool) -> Vec<f32> {
    let mut out = vec![0.0; 2 * r.len()];
    decode_into(r, k, sign, n, centered, &mut out);
    out
}

/// encode→decode roundtrip (fp32 norms — the Table 1/2 setting).
pub fn quant_dequant(x: &[f32], sign: &[f32], n: u32, centered: bool) -> Vec<f32> {
    let e = encode(x, sign, n);
    decode(&e.r, &e.k, sign, n, centered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fwht::test_sign_diag;

    fn rand_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..d)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32)
                    * 6.0
                    - 3.0
            })
            .collect()
    }

    #[test]
    fn bins_in_range() {
        let sign = test_sign_diag(64, 1);
        for n in [3u32, 48, 56, 64, 128, 512] {
            let e = encode(&rand_vec(64, 9), &sign, n);
            assert!(e.k.iter().all(|&k| (k as u32) < n), "n={n}");
        }
    }

    #[test]
    fn angle_to_bin_boundaries() {
        // theta exactly 0 -> bin 0; theta just below 2pi -> last bin
        assert_eq!(angle_to_bin(0.0, 64), 0);
        // f32: 2pi + (-1e-7) rounds back to 2pi -> bin 0 (mod n), same as jax
        assert_eq!(angle_to_bin(-1e-7, 64), 0);
        assert_eq!(angle_to_bin(-1e-3, 64), 63);
        assert_eq!(angle_to_bin(TWO_PI - 1e-4, 64), 63);
        // quadrants at n=4
        assert_eq!(angle_to_bin(0.1, 4), 0);
        assert_eq!(angle_to_bin(std::f32::consts::FRAC_PI_2 + 0.1, 4), 1);
        assert_eq!(angle_to_bin(std::f32::consts::PI + 0.1, 4), 2);
        assert_eq!(angle_to_bin(-0.1, 4), 3);
    }

    #[test]
    fn roundtrip_error_bound() {
        // ||x - x_hat|| <= ||x|| * 2pi/n (left-edge worst case, orthonormal H)
        let d = 128;
        let sign = test_sign_diag(d, 2);
        for n in [32u32, 64, 256] {
            let x = rand_vec(d, 5);
            let xq = quant_dequant(&x, &sign, n, false);
            let err: f32 = x
                .iter()
                .zip(&xq)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                .sqrt();
            let norm: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
            assert!(err <= norm * TWO_PI / n as f32 + 1e-3, "n={n} err={err}");
        }
    }

    #[test]
    fn error_shrinks_with_bins() {
        let d = 64;
        let sign = test_sign_diag(d, 3);
        let x = rand_vec(d, 8);
        let mut prev = f32::INFINITY;
        for n in [8u32, 32, 128, 512] {
            let xq = quant_dequant(&x, &sign, n, true);
            let mse: f32 = x
                .iter()
                .zip(&xq)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f32>()
                / d as f32;
            assert!(mse < prev, "n={n}");
            prev = mse;
        }
    }

    #[test]
    fn norms_preserved() {
        let d = 64;
        let sign = test_sign_diag(d, 4);
        let x = rand_vec(d, 6);
        let e0 = encode(&x, &sign, 16);
        let xq = quant_dequant(&x, &sign, 16, false);
        let e1 = encode(&xq, &sign, 16);
        for (a, b) in e0.r.iter().zip(&e1.r) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn lut_decode_bit_identical() {
        let d = 128;
        let sign = test_sign_diag(d, 6);
        for n in [48u32, 64, 512] {
            for centered in [false, true] {
                let x = rand_vec(d, 9 + n as u64);
                let e = encode(&x, &sign, n);
                let want = decode(&e.r, &e.k, &sign, n, centered);
                let lut = TrigLut::new(n, centered);
                let mut got = vec![0.0; d];
                decode_into_lut(&e.r, &e.k, &sign, &lut, &mut got);
                assert_eq!(want, got, "n={n} centered={centered}");
            }
        }
    }

    #[test]
    fn into_variants_match_allocating() {
        let d = 64;
        let sign = test_sign_diag(d, 5);
        let x = rand_vec(d, 7);
        let e = encode(&x, &sign, 48);
        let mut scratch = vec![0.0; d];
        let mut r = vec![0.0; d / 2];
        let mut k = vec![0u16; d / 2];
        encode_into(&x, &sign, 48, &mut scratch, &mut r, &mut k);
        assert_eq!(e.r, r);
        assert_eq!(e.k, k);
        let dec = decode(&e.r, &e.k, &sign, 48, false);
        let mut out = vec![0.0; d];
        decode_into(&r, &k, &sign, 48, false, &mut out);
        assert_eq!(dec, out);
    }
}
