//! Vectorized dequant/attention microkernels for the fused read path.
//!
//! The serving hot loop — page-tile decode feeding streaming-softmax
//! attention — used to be per-element scalar work: one [`BitCursor`]
//! `next()` per packed code, one [`TrigLut::cos_sin`] pair per polar pair.
//! This module replaces that with three batched stages sharing one
//! dispatch:
//!
//! 1. **bulk bit-unpack** — whole 64-bit words are loaded once and
//!    shattered into code lanes
//!    ([`crate::quant::packing::unpack_f32_range_into`]), handling every
//!    packed width a per-layer boost schedule produces (48- and 64-bin
//!    layers both pack 6 bits, boosted 256-bin layers pack 8);
//! 2. **batched trig reconstruction** — [`gather_trig`] pulls `TrigLut`
//!    entries for a whole code lane into contiguous cos/sin slabs;
//! 3. **cache-blocked scoring** — elementwise term kernels
//!    ([`weighted_polar_terms`]) feed the streaming-softmax update with
//!    per-row sequential reductions, so accumulation order (and therefore
//!    every bit of the result) matches the scalar loop.
//!
//! **Dispatch.** [`KernelKind`] selects the path at runtime:
//! [`KernelKind::Scalar`] is the original per-element loop, verbatim;
//! [`KernelKind::Simd`] is the batched pipeline above. Both read paths
//! (fused tile decode and dense reinflation) route through ONE
//! [`decode_side_range`], so fused ≡ reinflate bit-identity holds by
//! construction, and simd ≡ scalar is pinned by proptests and the
//! end-to-end token-stream test.
//!
//! **Why bit-identical.** The batched path never reassociates a float
//! reduction and never changes a per-element expression: bit-unpacking is
//! integer-exact, code→f32 conversion is exact below 2^24, the norm affine
//! map keeps the scalar's `vmin + c * scale / levels` shape (the division
//! stays per element — hoisting `scale/levels` shifts results by 1 ulp),
//! and row sums run sequentially in the original element order. Elementwise
//! IEEE arithmetic is deterministic lane-for-lane, so vectorizing the *map*
//! stages cannot change a bit.
//!
//! **The `simd` cargo feature** (nightly, off by default) swaps the inner
//! elementwise loops for explicit `std::simd` lanes. Without it the same
//! kernels compile as batched scalar loops that LLVM autovectorizes; output
//! is identical either way, so the feature is purely a codegen lever.

use super::angle::TrigLut;
use super::norm::NormMode;
use super::packing::{bits_for, unpack_f32_range_into, BitCursor, BitVec};
use anyhow::{ensure, Result};

/// Which implementation of the shared dequant/score kernels runs.
///
/// Carried as a field by `PagedKvCache` and `SimExecutor` (settable, so
/// tests compare both in one process) and resolved once per construction
/// via [`KernelKind::auto`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelKind {
    /// The original per-element loops (BitCursor pops + per-pair LUT
    /// calls). Kept forever as the bit-identity reference and the
    /// validation path.
    Scalar,
    /// Bulk word-window unpack + batched trig gather + blocked scoring.
    /// Bit-identical to [`KernelKind::Scalar`]; faster on every serving
    /// geometry (the fused_attention bench reports the ratio).
    #[default]
    Simd,
}

impl KernelKind {
    /// Runtime dispatch: `TURBOANGLE_KERNEL=scalar|simd` overrides, default
    /// [`KernelKind::Simd`]. Unknown values fall back to the default so a
    /// typo degrades to the fast path, never to a crash.
    pub fn auto() -> Self {
        match std::env::var("TURBOANGLE_KERNEL") {
            Ok(v) if v.eq_ignore_ascii_case("scalar") => KernelKind::Scalar,
            _ => KernelKind::Simd,
        }
    }
}

/// Dequantize tokens `t0..t0+tokens` of one side chunk (`t0` chunk-local)
/// into token-major (norms, codes-as-f32) rows. THE dequant kernel: both
/// read paths (dense reinflation and fused tile decode) call it with their
/// chunk's raw parts, so their outputs cannot drift — and both
/// [`KernelKind`]s produce bit-identical rows (proptested across norm
/// modes and mixed-width boost schedules).
///
/// `angles`/`norm_codes` are the chunk's packed streams, `windows` its
/// per-token (min, max) norm windows, `raw_norms` its fp32 norms (used
/// when `mode.bits == 0`).
///
/// All length preconditions are validated here in EVERY profile, not just
/// debug: the packed streams come from stored cache state, so a truncated
/// bitstream (partially appended layer, corrupted page) must surface as a
/// clean `Err` instead of an out-of-bounds read of stale words in release
/// builds. This is the single public entry for both read paths, so the
/// inner stages ([`BitCursor`], `bulk_unpack!`) may keep their checks as
/// `debug_assert!` — every index they touch is bounded by the checks here.
#[allow(clippy::too_many_arguments)]
pub fn decode_side_range(
    kind: KernelKind,
    angles: &BitVec,
    bins: u32,
    norm_codes: &BitVec,
    windows: &[(f32, f32)],
    raw_norms: &[f32],
    mode: NormMode,
    t0: usize,
    tokens: usize,
    half: usize,
    out_r: &mut [f32],
    out_i: &mut [f32],
) -> Result<()> {
    let elems = tokens * half;
    let end = t0 + tokens;
    let width = bits_for(bins);
    ensure!(
        out_r.len() >= elems && out_i.len() >= elems,
        "decode_side_range: output buffers ({}, {}) hold fewer than tokens*half = {elems} elements",
        out_r.len(),
        out_i.len()
    );
    ensure!(
        angles.len_bits() >= end * half * width as usize,
        "decode_side_range: angle stream truncated ({} bits stored, {} needed for tokens ..{end})",
        angles.len_bits(),
        end * half * width as usize
    );
    if mode.bits == 0 {
        ensure!(
            raw_norms.len() >= end * half,
            "decode_side_range: fp32 norm stream truncated ({} stored, {} needed)",
            raw_norms.len(),
            end * half
        );
    } else {
        ensure!(
            windows.len() >= end,
            "decode_side_range: norm windows truncated ({} stored, {end} needed)",
            windows.len()
        );
        ensure!(
            norm_codes.len_bits() >= end * half * mode.bits as usize,
            "decode_side_range: norm code stream truncated ({} bits stored, {} needed)",
            norm_codes.len_bits(),
            end * half * mode.bits as usize
        );
    }
    match kind {
        KernelKind::Scalar => {
            let mut ang = BitCursor::new(angles, t0 * half, width);
            for o in out_i[..elems].iter_mut() {
                *o = ang.next(width) as f32;
            }
        }
        KernelKind::Simd => {
            unpack_f32_range_into(angles, t0 * half, width, &mut out_i[..elems]);
        }
    }
    if mode.bits == 0 {
        out_r[..elems].copy_from_slice(&raw_norms[t0 * half..t0 * half + elems]);
        return Ok(());
    }
    let bits = mode.bits as u32;
    let levels = mode.levels().max(1.0);
    match kind {
        KernelKind::Scalar => {
            let mut codes = BitCursor::new(norm_codes, t0 * half, bits);
            for (t, row) in out_r[..elems].chunks_exact_mut(half).enumerate() {
                let (vmin, vmax) = windows[t0 + t];
                let scale = if vmax > vmin { vmax - vmin } else { 1.0 };
                // `(c*scale)/levels` — the exact expression of
                // `norm::dequantize_into`; do NOT hoist `scale/levels` (it
                // shifts the result by 1 ulp and breaks bit-parity with the
                // norm module / oracle)
                if mode.log_space {
                    for o in row.iter_mut() {
                        *o = (vmin + codes.next(bits) as f32 * scale / levels).exp();
                    }
                } else {
                    for o in row.iter_mut() {
                        *o = vmin + codes.next(bits) as f32 * scale / levels;
                    }
                }
            }
        }
        KernelKind::Simd => {
            // no scratch: codes land in `out_r` as exact f32 integers, the
            // per-row affine map then runs in place — the unhoistable
            // division vectorizes across the row instead of serializing
            // behind a bit-cursor pop
            unpack_f32_range_into(norm_codes, t0 * half, bits, &mut out_r[..elems]);
            for (t, row) in out_r[..elems].chunks_exact_mut(half).enumerate() {
                let (vmin, vmax) = windows[t0 + t];
                let scale = if vmax > vmin { vmax - vmin } else { 1.0 };
                affine_in_place(row, vmin, scale, levels);
                if mode.log_space {
                    for o in row.iter_mut() {
                        *o = o.exp();
                    }
                }
            }
        }
    }
    Ok(())
}

/// Gather `(cos θ, sin θ)` for a whole lane of codes-as-f32 into
/// contiguous slabs. Per element this is exactly [`TrigLut::cos_sin`] on
/// `code as u16` — same saturating cast, same last-bin clamp for corrupted
/// codes — so the gathered slabs are bit-identical to per-pair lookups.
///
/// The length check is a release-mode `assert!`: this is a public kernel
/// entry, and a short output slab is a caller bug that must not degrade to
/// a silent partial gather in release builds. One branch per lane call is
/// noise next to the gather itself.
pub fn gather_trig(lut: &TrigLut, codes: &[f32], cos_out: &mut [f32], sin_out: &mut [f32]) {
    let n = codes.len();
    assert!(
        cos_out.len() >= n && sin_out.len() >= n,
        "gather_trig: output slabs ({}, {}) shorter than the {n} input codes",
        cos_out.len(),
        sin_out.len()
    );
    let (cos, sin) = (lut.cos_table(), lut.sin_table());
    let last = cos.len() - 1;
    for ((c, co), so) in codes.iter().zip(&mut cos_out[..n]).zip(&mut sin_out[..n]) {
        let k = (*c as u16 as usize).min(last);
        *co = cos[k];
        *so = sin[k];
    }
}

/// `out[i] = r[i] * (c[i] + coef * s[i])` — the reconstructed-polar-pair
/// term of the sim's attention score, batched over a lane. With `coef`
/// negative this is bit-identical to the scalar `c - |coef| * s` form
/// (IEEE: `a - b == a + (-b)` and `(-x)*y == -(x*y)` exactly).
pub fn weighted_polar_terms(r: &[f32], c: &[f32], s: &[f32], coef: f32, out: &mut [f32]) {
    let n = r.len();
    // Release-mode check for the same reason as `gather_trig`: public
    // kernel entry, caller bug must fail loudly in every profile.
    assert!(
        c.len() >= n && s.len() >= n && out.len() >= n,
        "weighted_polar_terms: lanes ({}, {}, {}) shorter than the {n} radii",
        c.len(),
        s.len(),
        out.len()
    );
    #[cfg(feature = "simd")]
    {
        use std::simd::Simd;
        const L: usize = 8;
        let coefv = Simd::<f32, L>::splat(coef);
        let chunks = n / L * L;
        for i in (0..chunks).step_by(L) {
            let rv = Simd::<f32, L>::from_slice(&r[i..i + L]);
            let cv = Simd::<f32, L>::from_slice(&c[i..i + L]);
            let sv = Simd::<f32, L>::from_slice(&s[i..i + L]);
            out[i..i + L].copy_from_slice(&(rv * (cv + coefv * sv)).to_array());
        }
        for i in chunks..n {
            out[i] = r[i] * (c[i] + coef * s[i]);
        }
    }
    #[cfg(not(feature = "simd"))]
    for (((o, &ri), &ci), &si) in out[..n].iter_mut().zip(r).zip(c).zip(s) {
        *o = ri * (ci + coef * si);
    }
}

/// In-place `v = vmin + v * scale / levels` over one token row — the norm
/// dequant affine map with the division kept per element (see
/// [`decode_side_range`] on why it must not be hoisted). The batched form
/// lets the divisions issue as vector ops; per-lane IEEE arithmetic keeps
/// every element bit-identical to the scalar expression.
fn affine_in_place(row: &mut [f32], vmin: f32, scale: f32, levels: f32) {
    #[cfg(feature = "simd")]
    {
        use std::simd::Simd;
        const L: usize = 8;
        let (vm, sc, lv) = (
            Simd::<f32, L>::splat(vmin),
            Simd::<f32, L>::splat(scale),
            Simd::<f32, L>::splat(levels),
        );
        let n = row.len();
        let chunks = n / L * L;
        for i in (0..chunks).step_by(L) {
            let v = Simd::<f32, L>::from_slice(&row[i..i + L]);
            row[i..i + L].copy_from_slice(&(vm + v * sc / lv).to_array());
        }
        for o in row[chunks..].iter_mut() {
            *o = vmin + *o * scale / levels;
        }
    }
    #[cfg(not(feature = "simd"))]
    for o in row.iter_mut() {
        *o = vmin + *o * scale / levels;
    }
}

/// Reused slabs for the batched scoring pipeline: gathered K/V trig lanes
/// and the per-element score/value terms. Grows once to the largest tile
/// seen and stays there — the same bounded-scratch contract as
/// `TileScratch`.
#[derive(Debug, Default)]
pub struct TrigScratch {
    /// gathered cos θ for the K-side codes of one tile
    pub kc: Vec<f32>,
    /// gathered sin θ for the K-side codes
    pub ks: Vec<f32>,
    /// gathered cos θ for the V-side codes
    pub vc: Vec<f32>,
    /// gathered sin θ for the V-side codes
    pub vs: Vec<f32>,
    /// per-element score terms `kr·(kcos - 0.25·ksin)`
    pub st: Vec<f32>,
    /// per-element value terms `vr·(vcos + 0.5·vsin)`
    pub vt: Vec<f32>,
}

impl TrigScratch {
    /// Empty scratch; grows to the tile size on first use.
    pub fn new() -> Self {
        Self::default()
    }

    /// Make every slab hold at least `elems` floats.
    pub fn ensure(&mut self, elems: usize) {
        if self.kc.len() < elems {
            self.kc.resize(elems, 0.0);
            self.ks.resize(elems, 0.0);
            self.vc.resize(elems, 0.0);
            self.vs.resize(elems, 0.0);
            self.st.resize(elems, 0.0);
            self.vt.resize(elems, 0.0);
        }
    }

    /// Bytes held across all six slabs (bench observability).
    pub fn bytes(&self) -> usize {
        (self.kc.len()
            + self.ks.len()
            + self.vc.len()
            + self.vs.len()
            + self.st.len()
            + self.vt.len())
            * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::packing::pack;
    use crate::util::prop::{run_cases, Gen};

    fn pack_f32_codes(codes: &[f32], width: u32) -> BitVec {
        pack(&codes.iter().map(|&c| c as u16).collect::<Vec<_>>(), width)
    }

    /// Random side chunks across norm modes (fp32 / linear / log) and the
    /// widths boost schedules produce: both kernels must emit identical
    /// bits, including from a nonzero chunk-local t0.
    #[test]
    fn prop_simd_decode_matches_scalar_all_modes() {
        run_cases(200, |g| {
            let half = *g.choice(&[1usize, 2, 4, 16, 32]);
            let tokens = g.usize_in(1, 24);
            let bins = *g.choice(&[48u32, 56, 64, 128, 200, 256, 1024]);
            let mode = *g.choice(&[
                NormMode::FP32,
                NormMode::LINEAR8,
                NormMode::LOG4,
                NormMode { bits: 5, log_space: false },
            ]);
            let width = bits_for(bins);
            let total = tokens * half;
            let acodes: Vec<f32> = (0..total).map(|_| (g.u64() % bins as u64) as f32).collect();
            let angles = pack_f32_codes(&acodes, width);
            let mut windows = Vec::new();
            let mut raw_norms = Vec::new();
            let mut norm_codes = BitVec::default();
            if mode.bits == 0 {
                raw_norms = g.f32_vec(total, 0.01, 8.0);
            } else {
                let ncodes: Vec<f32> = (0..total)
                    .map(|_| (g.u64() % (1u64 << mode.bits)) as f32)
                    .collect();
                norm_codes = pack_f32_codes(&ncodes, mode.bits as u32);
                for _ in 0..tokens {
                    let a = g.f32_in(-2.0, 2.0);
                    let b = a + g.f32_in(0.0, 3.0);
                    windows.push((a, b));
                }
            }
            let t0 = g.usize_in(0, tokens - 1);
            let span = tokens - t0;
            let n = span * half;
            let (mut sr, mut si) = (vec![0.0f32; n], vec![0.0f32; n]);
            let (mut vr, mut vi) = (vec![1.0f32; n], vec![1.0f32; n]);
            decode_side_range(
                KernelKind::Scalar,
                &angles,
                bins,
                &norm_codes,
                &windows,
                &raw_norms,
                mode,
                t0,
                span,
                half,
                &mut sr,
                &mut si,
            )
            .unwrap();
            decode_side_range(
                KernelKind::Simd,
                &angles,
                bins,
                &norm_codes,
                &windows,
                &raw_norms,
                mode,
                t0,
                span,
                half,
                &mut vr,
                &mut vi,
            )
            .unwrap();
            assert_eq!(sr, vr, "norms diverged: bins={bins} mode={mode:?} t0={t0}");
            assert_eq!(si, vi, "angles diverged: bins={bins} mode={mode:?} t0={t0}");
        });
    }

    /// A truncated packed stream must surface as `Err` from the public
    /// entry in EVERY build profile — these checks are `ensure!`, not
    /// `debug_assert!`, so this test pins release behavior too (CI runs
    /// the lib suite under `--release` as well).
    #[test]
    fn truncated_streams_error_cleanly() {
        let (half, tokens, bins) = (4usize, 6usize, 64u32);
        let width = bits_for(bins);
        let total = tokens * half;
        let full: Vec<f32> = (0..total).map(|i| (i as u32 % bins) as f32).collect();
        let angles = pack_f32_codes(&full, width);
        // Angle stream one token short of what t0..t0+tokens needs.
        let short_angles = pack_f32_codes(&full[..total - half], width);
        let mode = NormMode::LINEAR8;
        let ncodes: Vec<f32> = (0..total).map(|i| (i % 256) as f32).collect();
        let norm_codes = pack_f32_codes(&ncodes, mode.bits as u32);
        let short_norms = pack_f32_codes(&ncodes[..total - half], mode.bits as u32);
        let windows: Vec<(f32, f32)> = (0..tokens).map(|t| (t as f32, t as f32 + 1.0)).collect();
        let (mut r, mut i) = (vec![0.0f32; total], vec![0.0f32; total]);
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            let run = |ang: &BitVec, nc: &BitVec, win: &[(f32, f32)], r: &mut [f32], i: &mut [f32]| {
                decode_side_range(
                    kind, ang, bins, nc, win, &[], mode, 0, tokens, half, r, i,
                )
            };
            assert!(run(&angles, &norm_codes, &windows, &mut r, &mut i).is_ok());
            let e = run(&short_angles, &norm_codes, &windows, &mut r, &mut i).unwrap_err();
            assert!(e.to_string().contains("angle stream truncated"), "{e}");
            let e = run(&angles, &short_norms, &windows, &mut r, &mut i).unwrap_err();
            assert!(e.to_string().contains("norm code stream truncated"), "{e}");
            let e = run(&angles, &norm_codes, &windows[..tokens - 1], &mut r, &mut i).unwrap_err();
            assert!(e.to_string().contains("norm windows truncated"), "{e}");
            let e = run(&angles, &norm_codes, &windows, &mut r[..total - 1], &mut i).unwrap_err();
            assert!(e.to_string().contains("output buffers"), "{e}");
            // fp32 norms: raw stream shorter than the decode span
            let e = decode_side_range(
                kind,
                &angles,
                bins,
                &BitVec::default(),
                &[],
                &full[..total - 1],
                NormMode::FP32,
                0,
                tokens,
                half,
                &mut r,
                &mut i,
            )
            .unwrap_err();
            assert!(e.to_string().contains("fp32 norm stream truncated"), "{e}");
        }
    }

    /// Nonzero `t0` counts against the stored stream too: a chunk holding
    /// only `t0` tokens must reject a read past its end even when the
    /// requested span alone would fit.
    #[test]
    fn truncation_accounts_for_chunk_local_offset() {
        let (half, bins) = (2usize, 48u32);
        let width = bits_for(bins);
        let codes: Vec<f32> = (0..4 * half).map(|i| (i as u32 % bins) as f32).collect();
        let angles = pack_f32_codes(&codes, width);
        let norms: Vec<f32> = (0..8 * half).map(|i| i as f32).collect();
        let (mut r, mut i) = (vec![0.0f32; 4 * half], vec![0.0f32; 4 * half]);
        for kind in [KernelKind::Scalar, KernelKind::Simd] {
            // 4 tokens stored: t0=2, span=2 fits; t0=3, span=2 does not.
            for (t0, span, ok) in [(2usize, 2usize, true), (3, 2, false)] {
                let got = decode_side_range(
                    kind,
                    &angles,
                    bins,
                    &BitVec::default(),
                    &[],
                    &norms,
                    NormMode::FP32,
                    t0,
                    span,
                    half,
                    &mut r,
                    &mut i,
                );
                assert_eq!(got.is_ok(), ok, "kind={kind:?} t0={t0}");
            }
        }
    }

    #[test]
    fn gather_matches_per_pair_lookup_with_clamping() {
        let lut = TrigLut::new(48, false);
        // valid codes plus out-of-range ones (clamped to the last bin) and
        // a huge f32 (saturating u16 cast)
        let codes: Vec<f32> = vec![0.0, 1.0, 47.0, 48.0, 200.0, 70000.0, 13.0];
        let mut c = vec![0.0; codes.len()];
        let mut s = vec![0.0; codes.len()];
        gather_trig(&lut, &codes, &mut c, &mut s);
        for (i, &k) in codes.iter().enumerate() {
            let (wc, ws) = lut.cos_sin(k as u16);
            assert_eq!((c[i], s[i]), (wc, ws), "code {k}");
        }
    }

    #[test]
    fn weighted_terms_match_scalar_expression() {
        let mut g = Gen::new(41);
        let n = 67; // odd length exercises the vector tail
        let r = g.f32_vec(n, 0.01, 5.0);
        let c = g.f32_vec(n, -1.0, 1.0);
        let s = g.f32_vec(n, -1.0, 1.0);
        let mut out = vec![0.0f32; n];
        weighted_polar_terms(&r, &c, &s, -0.25, &mut out);
        for i in 0..n {
            assert_eq!(out[i], r[i] * (c[i] - 0.25 * s[i]), "i={i}");
        }
        weighted_polar_terms(&r, &c, &s, 0.5, &mut out);
        for i in 0..n {
            assert_eq!(out[i], r[i] * (c[i] + 0.5 * s[i]), "i={i}");
        }
    }

    #[test]
    fn kernel_env_dispatch() {
        // can't mutate the process env safely under the parallel test
        // runner; pin the parsing contract instead
        assert_eq!(KernelKind::default(), KernelKind::Simd);
        let parse = |v: Option<&str>| match v {
            Some(s) if s.eq_ignore_ascii_case("scalar") => KernelKind::Scalar,
            _ => KernelKind::Simd,
        };
        assert_eq!(parse(Some("scalar")), KernelKind::Scalar);
        assert_eq!(parse(Some("SCALAR")), KernelKind::Scalar);
        assert_eq!(parse(Some("simd")), KernelKind::Simd);
        assert_eq!(parse(Some("wat")), KernelKind::Simd);
        assert_eq!(parse(None), KernelKind::Simd);
    }

    #[test]
    fn trig_scratch_grows_once() {
        let mut s = TrigScratch::new();
        s.ensure(64);
        let b = s.bytes();
        s.ensure(32);
        assert_eq!(s.bytes(), b, "smaller tiles must not shrink or grow scratch");
        s.ensure(128);
        assert_eq!(s.bytes(), 2 * b);
    }
}
