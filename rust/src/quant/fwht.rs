//! Normalized Fast Walsh-Hadamard transform over the head dimension.
//!
//! Native mirror of `python/compile/kernels/fwht.py` for the L3 hot path
//! (kv_manager pack/unpack and native quant benches). Self-inverse and
//! orthonormal; validated against the python oracle via golden vectors.

/// In-place unnormalized FWHT butterfly. `x.len()` must be a power of two.
#[inline]
pub fn fwht_raw(x: &mut [f32]) {
    let d = x.len();
    debug_assert!(d.is_power_of_two());
    let mut h = 1;
    while h < d {
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

/// In-place normalized FWHT (orthonormal, self-inverse).
#[inline]
pub fn fwht(x: &mut [f32]) {
    fwht_raw(x);
    let scale = 1.0 / (x.len() as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// y = H·D·x : multiply by the ±1 diagonal, then normalized FWHT.
#[inline]
pub fn rotate(x: &mut [f32], sign: &[f32]) {
    debug_assert_eq!(x.len(), sign.len());
    for (v, s) in x.iter_mut().zip(sign) {
        *v *= s;
    }
    fwht(x);
}

/// x = D·H·y : normalized FWHT then the ±1 diagonal (both self-inverse).
#[inline]
pub fn unrotate(y: &mut [f32], sign: &[f32]) {
    fwht(y);
    for (v, s) in y.iter_mut().zip(sign) {
        *v *= s;
    }
}

/// The shared random ±1 diagonal D. Mirrors
/// `ref.make_sign_diag(d, seed)` = numpy `default_rng(seed)` — we do NOT
/// reimplement PCG64 here; runtime code loads the actual diagonal from the
/// weights tensorfile. This helper exists for self-contained tests/benches.
pub fn test_sign_diag(d: usize, seed: u64) -> Vec<f32> {
    // xorshift* — deterministic test-only source, NOT numpy-compatible.
    let mut s = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..d)
        .map(|_| {
            s ^= s >> 12;
            s ^= s << 25;
            s ^= s >> 27;
            if (s.wrapping_mul(0x2545F4914F6CDD1D) >> 63) & 1 == 1 {
                1.0
            } else {
                -1.0
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rand_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..d)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32)
                    * 4.0
                    - 2.0
            })
            .collect()
    }

    #[test]
    fn self_inverse() {
        for d in [2usize, 8, 64, 128] {
            let x = rand_vec(d, 3);
            let mut y = x.clone();
            fwht(&mut y);
            fwht(&mut y);
            for (a, b) in x.iter().zip(&y) {
                assert!((a - b).abs() < 1e-5, "d={d}");
            }
        }
    }

    #[test]
    fn preserves_norm() {
        for d in [4usize, 32, 128] {
            let x = rand_vec(d, 9);
            let n0: f32 = x.iter().map(|v| v * v).sum();
            let mut y = x.clone();
            fwht(&mut y);
            let n1: f32 = y.iter().map(|v| v * v).sum();
            assert!((n0 - n1).abs() < 1e-3 * n0.max(1.0));
        }
    }

    #[test]
    fn matches_dense_hadamard() {
        let d = 8;
        // Sylvester construction
        let mut h = vec![vec![1.0f32]];
        while h.len() < d {
            let n = h.len();
            let mut nh = vec![vec![0.0; 2 * n]; 2 * n];
            for i in 0..n {
                for j in 0..n {
                    nh[i][j] = h[i][j];
                    nh[i][j + n] = h[i][j];
                    nh[i + n][j] = h[i][j];
                    nh[i + n][j + n] = -h[i][j];
                }
            }
            h = nh;
        }
        let x = rand_vec(d, 5);
        let scale = 1.0 / (d as f32).sqrt();
        let expect: Vec<f32> = (0..d)
            .map(|i| (0..d).map(|j| h[i][j] * x[j]).sum::<f32>() * scale)
            .collect();
        let mut y = x.clone();
        fwht(&mut y);
        for (a, b) in expect.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn rotate_unrotate_roundtrip() {
        let d = 64;
        let sign = test_sign_diag(d, 11);
        let x = rand_vec(d, 7);
        let mut y = x.clone();
        rotate(&mut y, &sign);
        unrotate(&mut y, &sign);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-5);
        }
    }
}
