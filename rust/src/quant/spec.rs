//! Shared CLI surface for quantizer configuration.
//!
//! Every entry point that accepts quant flags from a user — `serve`,
//! `listen`, `eval`, the sensitivity subcommands, and the benches — parses
//! them through one [`QuantSpec`], so the flag set, the defaults, and the
//! validation story never diverge between subcommands. Unknown values are
//! rejected with actionable messages (historically `--norms bogus` fell
//! through silently to fp32 norms), and the built [`QuantConfig`] passes
//! `QuantConfig::validate()` before it reaches the engine.

use super::config::{Mode, QuantConfig, UNIFORM_NK, UNIFORM_NV};
use super::norm::NormMode;
use crate::util::cli::Args;
use anyhow::{bail, Result};

/// The flags [`QuantSpec::from_args`] understands; splice into each
/// subcommand's `check_known` list.
pub const FLAGS: &[&str] = &[
    "nk",
    "nv",
    "n-early",
    "boost-layers",
    "nk-hi",
    "nv-hi",
    "norms",
    "k-norm",
    "v-norm",
    "no-quant",
];

/// A parsed-but-not-yet-built quant schedule: everything the user said on
/// the command line, independent of the model depth. [`build`](Self::build)
/// binds it to a layer count and runs the full validation chain.
#[derive(Clone, Debug)]
pub struct QuantSpec {
    /// Base K-side codebook size.
    pub nk: u32,
    /// Base V-side codebook size.
    pub nv: u32,
    /// Boost the first `n_early` layers (0 = none; exclusive with
    /// `boost_layers`).
    pub n_early: usize,
    /// Explicit boosted layer set (`--boost-layers 0,1,5` or `0-7,16-23`).
    pub boost_layers: Option<Vec<usize>>,
    /// Boosted-layer K codebook.
    pub nk_hi: u32,
    /// Boosted-layer V codebook.
    pub nv_hi: u32,
    /// K-side norm mode.
    pub k_norm: NormMode,
    /// V-side norm mode.
    pub v_norm: NormMode,
    /// Serve the fp reference instead (forces `Mode::None` + fp32 norms).
    pub no_quant: bool,
}

/// Parse one per-side norm mode: `fp32 | linear4 | linear8 | log4 | log8`.
pub fn parse_norm_mode(flag: &str, s: &str) -> Result<NormMode> {
    Ok(match s {
        "fp32" => NormMode::FP32,
        "linear4" => NormMode {
            bits: 4,
            log_space: false,
        },
        "linear8" => NormMode::LINEAR8,
        "log4" => NormMode::LOG4,
        "log8" => NormMode {
            bits: 8,
            log_space: true,
        },
        other => bail!(
            "--{flag}: unknown norm mode '{other}' \
             (accepted: fp32 | linear4 | linear8 | log4 | log8)"
        ),
    })
}

/// Parse a `--norms` preset into (k_norm, v_norm).
fn parse_norms_preset(s: &str) -> Result<(NormMode, NormMode)> {
    Ok(match s {
        "fp32" => (NormMode::FP32, NormMode::FP32),
        "norm8" => (NormMode::LINEAR8, NormMode::LINEAR8),
        "k8v4log" => (NormMode::LINEAR8, NormMode::LOG4),
        other => bail!(
            "--norms: unknown preset '{other}' (accepted: fp32 | norm8 | k8v4log; \
             for per-side control use --k-norm/--v-norm with \
             fp32|linear4|linear8|log4|log8)"
        ),
    })
}

/// Parse a layer-set expression: comma-separated indices and inclusive
/// ranges, e.g. `0,1,5` or `0-7,16-23`. Returns a sorted, deduplicated set.
pub fn parse_layer_set(flag: &str, s: &str) -> Result<Vec<usize>> {
    let mut out: Vec<usize> = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            bail!("--{flag}: empty entry in layer set '{s}' (example: 0,1,5 or 0-7,16-23)");
        }
        let parse_idx = |t: &str| -> Result<usize> {
            t.parse().map_err(|_| {
                anyhow::anyhow!(
                    "--{flag}: '{t}' is not a layer index \
                     (example: 0,1,5 or 0-7,16-23)"
                )
            })
        };
        match part.split_once('-') {
            Some((a, b)) => {
                let (lo, hi) = (parse_idx(a)?, parse_idx(b)?);
                if lo > hi {
                    bail!("--{flag}: descending range '{part}' (write it as {hi}-{lo})");
                }
                out.extend(lo..=hi);
            }
            None => out.push(parse_idx(part)?),
        }
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

impl QuantSpec {
    /// Parse the shared quant flags out of `args`. `default_norms` is the
    /// subcommand's `--norms` preset default (`"k8v4log"` for serving,
    /// `"fp32"` for the eval tables, matching the paper's reporting).
    pub fn from_args(args: &Args, default_norms: &str) -> Result<QuantSpec> {
        let preset = args.get_str("norms", default_norms);
        let (mut k_norm, mut v_norm) = parse_norms_preset(&preset)?;
        if args.flag("norms").is_some()
            && (args.flag("k-norm").is_some() || args.flag("v-norm").is_some())
        {
            bail!(
                "--norms is a preset for both sides; combining it with \
                 --k-norm/--v-norm is ambiguous — pass either the preset or \
                 the per-side modes"
            );
        }
        if let Some(v) = args.flag("k-norm") {
            k_norm = parse_norm_mode("k-norm", v)?;
        }
        if let Some(v) = args.flag("v-norm") {
            v_norm = parse_norm_mode("v-norm", v)?;
        }
        let boost_layers = match args.flag("boost-layers") {
            Some(v) => Some(parse_layer_set("boost-layers", v)?),
            None => None,
        };
        let n_early = args.get_usize("n-early", 0)?;
        if boost_layers.is_some() && n_early > 0 {
            bail!(
                "--boost-layers and --n-early both select the boosted layer \
                 set; pass one or the other"
            );
        }
        Ok(QuantSpec {
            nk: args.get_u32("nk", UNIFORM_NK)?,
            nv: args.get_u32("nv", UNIFORM_NV)?,
            n_early,
            boost_layers,
            nk_hi: args.get_u32("nk-hi", 256)?,
            nv_hi: args.get_u32("nv-hi", 128)?,
            k_norm,
            v_norm,
            no_quant: args.get_bool("no-quant"),
        })
    }

    /// Bind the spec to a model depth and build the validated config.
    /// Every invariant — bin caps, boost indices inside `0..n_layers` —
    /// errors here with an actionable message; this is the one untrusted
    /// entry point into [`QuantConfig`].
    pub fn build(&self, n_layers: usize) -> Result<QuantConfig> {
        if self.no_quant {
            let cfg = QuantConfig::builder(n_layers).mode(Mode::None).build()?;
            return Ok(cfg.with_norms(NormMode::FP32, NormMode::FP32));
        }
        let mut b = QuantConfig::builder(n_layers)
            .base_bins(self.nk, self.nv)
            .boost_bins(self.nk_hi, self.nv_hi)
            .norms(self.k_norm, self.v_norm);
        if let Some(set) = &self.boost_layers {
            b = b.boost_layers(set);
        } else if self.n_early > 0 {
            b = b.boost_first(self.n_early);
        }
        b.build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn defaults_build_paper_uniform() {
        let spec = QuantSpec::from_args(&args("serve"), "k8v4log").unwrap();
        let cfg = spec.build(8).unwrap();
        assert_eq!(cfg, QuantConfig::paper_uniform(8).with_k8v4_log());
    }

    #[test]
    fn boost_layers_flow_through() {
        let a = args("serve --boost-layers 0-1,5 --nk-hi 512 --nv-hi 256");
        let cfg = QuantSpec::from_args(&a, "fp32").unwrap().build(8).unwrap();
        assert_eq!(cfg, QuantConfig::selective_boost(8, &[0, 1, 5], 512, 256));
    }

    #[test]
    fn bogus_norms_error_not_silent_fp32() {
        // the historical bug: `--norms bogus` silently served fp32 norms
        let err = QuantSpec::from_args(&args("serve --norms bogus"), "fp32")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown preset 'bogus'"), "{err}");
        assert!(err.contains("k8v4log"), "{err}");
    }

    #[test]
    fn per_side_norms_and_conflicts() {
        let a = args("serve --k-norm linear8 --v-norm log8");
        let spec = QuantSpec::from_args(&a, "fp32").unwrap();
        assert_eq!(spec.k_norm, NormMode::LINEAR8);
        assert_eq!(
            spec.v_norm,
            NormMode {
                bits: 8,
                log_space: true
            }
        );
        assert!(QuantSpec::from_args(&args("serve --norms norm8 --k-norm fp32"), "fp32").is_err());
        assert!(QuantSpec::from_args(&args("serve --boost-layers 0 --n-early 2"), "fp32").is_err());
        let err = QuantSpec::from_args(&args("serve --k-norm huge"), "fp32")
            .unwrap_err()
            .to_string();
        assert!(err.contains("--k-norm"), "{err}");
    }

    #[test]
    fn layer_set_syntax() {
        assert_eq!(parse_layer_set("x", "0,1,5").unwrap(), vec![0, 1, 5]);
        assert_eq!(
            parse_layer_set("x", "0-3,16-18").unwrap(),
            vec![0, 1, 2, 3, 16, 17, 18]
        );
        assert_eq!(parse_layer_set("x", "5,5,2").unwrap(), vec![2, 5]);
        assert!(parse_layer_set("x", "3-1").unwrap_err().to_string().contains("1-3"));
        assert!(parse_layer_set("x", "a").is_err());
        assert!(parse_layer_set("x", "1,,2").is_err());
    }

    #[test]
    fn boost_out_of_range_is_actionable() {
        let a = args("serve --boost-layers 0,9");
        let err = QuantSpec::from_args(&a, "fp32")
            .unwrap()
            .build(4)
            .unwrap_err()
            .to_string();
        assert!(err.contains("boost layer 9"), "{err}");
    }

    #[test]
    fn no_quant_forces_fp_reference() {
        let a = args("serve --no-quant --norms k8v4log");
        let cfg = QuantSpec::from_args(&a, "k8v4log").unwrap().build(4).unwrap();
        assert_eq!(cfg.mode, Mode::None);
        assert_eq!(cfg.k_norm, NormMode::FP32);
        assert_eq!(cfg.v_norm, NormMode::FP32);
    }
}
