//! Native TurboQuant sym-b-g4 baseline (paper §4.2 / [13]).
//!
//! FWHT + random-sign rotation, then symmetric scalar quantization with a
//! per-group absmax scale. The comparison point for Table 1.

use super::fwht::{rotate, unrotate};

/// quant-dequant at `bits` with group size `group` along the head dim.
pub fn tq_scalar_g(x: &[f32], sign: &[f32], bits: u32, group: usize) -> Vec<f32> {
    let d = x.len();
    assert_eq!(d % group, 0);
    let mut y = x.to_vec();
    rotate(&mut y, sign);
    let qmax = ((1u32 << (bits.min(16) - 1)) - 1) as f32;
    for g in y.chunks_mut(group) {
        let scale = g.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let scale = if scale > 0.0 { scale } else { 1.0 };
        for v in g.iter_mut() {
            let q = (*v / scale * qmax).round_ties_even().clamp(-qmax, qmax);
            *v = q / qmax * scale;
        }
    }
    unrotate(&mut y, sign);
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::angle::quant_dequant as angle_qd;
    use crate::quant::fwht::test_sign_diag;

    fn rand_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..d)
            .map(|_| {
                s ^= s >> 12;
                s ^= s << 25;
                s ^= s >> 27;
                ((s.wrapping_mul(0x2545F4914F6CDD1D) >> 40) as f32 / (1u64 << 24) as f32)
                    * 6.0
                    - 3.0
            })
            .collect()
    }

    fn mse(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / a.len() as f32
    }

    #[test]
    fn error_shrinks_with_bits() {
        let sign = test_sign_diag(64, 1);
        let x = rand_vec(64, 2);
        let e3 = mse(&x, &tq_scalar_g(&x, &sign, 3, 4));
        let e4 = mse(&x, &tq_scalar_g(&x, &sign, 4, 4));
        let e8 = mse(&x, &tq_scalar_g(&x, &sign, 8, 4));
        assert!(e8 < e4 && e4 < e3);
    }

    #[test]
    fn angular_beats_scalar_at_matched_bits() {
        // Table 1 shape: TurboAngle n=64 (3.0 bits) vs TQ-sym3-g4 (3.0 bits)
        let d = 128;
        let sign = test_sign_diag(d, 3);
        let mut ea = 0.0;
        let mut et = 0.0;
        for seed in 0..32u64 {
            let x = rand_vec(d, 10 + seed);
            ea += mse(&x, &angle_qd(&x, &sign, 64, true));
            et += mse(&x, &tq_scalar_g(&x, &sign, 3, 4));
        }
        assert!(ea < et, "angle {ea} vs tq {et}");
    }

    #[test]
    fn exact_at_high_bits() {
        let sign = test_sign_diag(32, 4);
        let x = rand_vec(32, 5);
        let xq = tq_scalar_g(&x, &sign, 16, 4);
        for (a, b) in x.iter().zip(&xq) {
            assert!((a - b).abs() < 2e-3);
        }
    }
}
