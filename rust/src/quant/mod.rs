//! Native TurboAngle quantizer — the L3 mirror of the Pallas kernels.
//!
//! The serving kv_manager uses this path to pack/unpack the compressed
//! cache without touching PJRT; the eval/bench paths use it for workload
//! generation and ablations. Cross-validated against the python oracle via
//! `rust/tests/golden.rs` (golden vectors emitted by `compile.aot`).

pub mod angle;
pub mod baseline;
pub mod batch;
pub mod config;
pub mod fwht;
pub mod kernels;
pub mod norm;
pub mod packing;
pub mod spec;

pub use angle::{decode, decode_into, encode, encode_into, Encoded};
pub use kernels::{KernelKind, TrigScratch};
pub use batch::{decode_batch, encode_batch};
pub use config::{LayerBins, Mode, QuantConfig, QuantConfigBuilder};
pub use norm::NormMode;
pub use spec::QuantSpec;
