//! `cargo xtask` — repo-specific static and model-based analysis.
//!
//! Dependency-free on purpose: the lint pass is a hand-rolled lexer over
//! the four rules in [`lints`], and the concurrency models in [`models`]
//! are exhaustively explored in-process. `cargo xtask analyze` is the CI
//! gate; `lint` and `loom` run the halves individually.

mod lex;
mod lints;
mod models;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// The workspace root: two levels up from `rust/xtask`.
fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("xtask sits two levels below the repo root")
        .to_path_buf()
}

fn usage() -> ExitCode {
    eprintln!(
        "usage: cargo xtask <command>\n\n\
         commands:\n  \
         analyze        run the lint pass and all concurrency models (the CI gate)\n  \
         lint           run only the source lints\n  \
         loom [--trace] run only the concurrency models; --trace prints\n                 \
         the counterexample schedules of the pinned buggy variants"
    );
    ExitCode::FAILURE
}

/// Run the four source lints; returns the finding count.
fn run_lints(root: &Path) -> usize {
    let findings = match lints::run(root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("xtask lint: {e}");
            return 1;
        }
    };
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("lint: OK — {} rules over the serving and kernel tree", lints::RULE_NAMES.len());
    } else {
        println!(
            "lint: {} finding(s); suppress with `// xtask-allow(<rule>): <reason>` \
             only where the invariant genuinely holds",
            findings.len()
        );
    }
    findings.len()
}

/// Explore the shipped protocol models; returns the violation count.
/// With `trace`, also re-runs the pinned buggy variants and prints the
/// schedules that break them.
fn run_models(trace: bool) -> usize {
    const MAX_STATES: usize = 2_000_000;
    let mut violations = 0usize;

    let server = models::explore(models::server::ServerModel::new(3, false), MAX_STATES);
    println!("{}", models::render(&server));
    violations += server.violation.is_some() as usize;

    let store = models::explore(models::store::StoreModel::new(false, true), MAX_STATES);
    println!("{}", models::render(&store));
    violations += store.violation.is_some() as usize;

    let node = models::explore(models::node_store::NodeStoreModel::new(false), MAX_STATES);
    println!("{}", models::render(&node));
    violations += node.violation.is_some() as usize;

    if trace {
        println!("\npinned counterexamples (buggy variants, expected to fail):");
        for report in [
            models::explore(models::server::ServerModel::new(3, true), MAX_STATES),
            models::explore(models::store::StoreModel::new(true, true), MAX_STATES),
            models::explore(models::store::StoreModel::new(false, false), MAX_STATES),
            models::explore(models::node_store::NodeStoreModel::new(true), MAX_STATES),
        ] {
            println!("{}", models::render(&report));
        }
    }
    violations
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = match args.first().map(String::as_str) {
        Some(c) => c,
        None => return usage(),
    };
    let failures = match cmd {
        "analyze" => run_lints(&repo_root()) + run_models(false),
        "lint" => run_lints(&repo_root()),
        "loom" => run_models(args.iter().any(|a| a == "--trace")),
        _ => return usage(),
    };
    if failures == 0 {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
