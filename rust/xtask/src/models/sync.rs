//! The `#[cfg(loom)]` seam: concrete `Arc<Mutex<_>>` miniatures of the
//! modeled protocols, built against std by default and against
//! `loom::sync` when the crate is compiled with `RUSTFLAGS="--cfg loom"`
//! (after adding the loom dev-dependency — it is not vendored offline,
//! see `docs/ANALYSIS.md`).
//!
//! Under std these run as plain threaded smoke tests — one interleaving
//! per run, a sanity check that the miniature matches the abstract model
//! in [`super::server`] / [`super::store`]. Under loom, `loom::model`
//! replays the SAME closure across every schedule its exploration
//! generates, so the concrete lock-and-channel code gets the exhaustive
//! treatment the abstract models already have.

#[cfg(loom)]
pub use loom::sync::{Arc, Mutex};
#[cfg(loom)]
pub use loom::thread;

#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex};
#[cfg(not(loom))]
pub use std::thread;

/// Shared-page refcount cell: the concrete miniature of
/// [`super::store::StoreModel`]'s page. `None` means evicted.
pub type PageCell = Arc<Mutex<Option<u32>>>;

/// Adopt the page (bump refs). Returns false on a prefix miss.
pub fn adopt(page: &PageCell) -> bool {
    let mut slot = page.lock().unwrap();
    match slot.as_mut() {
        Some(refs) => {
            *refs += 1;
            true
        }
        None => false,
    }
}

/// Release one ref. Panics on underflow — the invariant the models check.
pub fn unref(page: &PageCell) {
    let mut slot = page.lock().unwrap();
    let refs = slot.as_mut().expect("unref of an evicted page");
    assert!(*refs > 0, "refcount underflow");
    *refs -= 1;
}

/// Evict iff refs == 0, revalidated under the same lock acquisition the
/// free happens in — the policy `stale_evict_observation_is_found_unsafe`
/// shows is load-bearing. Returns true if the page was freed.
pub fn try_evict(page: &PageCell) -> bool {
    let mut slot = page.lock().unwrap();
    if matches!(*slot, Some(0)) {
        *slot = None;
        true
    } else {
        false
    }
}

/// One run of the store lifecycle: a swapping sequence and an eviction
/// pass racing on a shared page. Safe for any interleaving because refs
/// are held across the swap window and eviction revalidates under the
/// lock. Called directly by the std smoke test and via `loom::model` by
/// the loom test.
pub fn store_lifecycle_run() {
    let page: PageCell = Arc::new(Mutex::new(Some(0)));

    let seq = {
        let page = Arc::clone(&page);
        thread::spawn(move || {
            if adopt(&page) {
                // swap-out .. swap-in window: refs stay held
                let mut slot = page.lock().unwrap();
                assert!(slot.is_some(), "page evicted under a held ref");
                drop(slot);
                unref(&page);
            }
        })
    };
    let evictor = {
        let page = Arc::clone(&page);
        thread::spawn(move || {
            try_evict(&page);
        })
    };

    seq.join().unwrap();
    evictor.join().unwrap();

    // Whatever the schedule, refs have drained: either the page survived
    // with refs == 0 or it was evicted while unreferenced.
    let slot = page.lock().unwrap();
    assert!(matches!(*slot, None | Some(0)), "leaked refs: {:?}", *slot);
}

#[cfg(all(test, not(loom)))]
mod std_tests {
    /// One arbitrary interleaving per run; the abstract model covers the
    /// rest. Keeps the miniature honest against refactors.
    #[test]
    fn store_lifecycle_smoke() {
        for _ in 0..64 {
            super::store_lifecycle_run();
        }
    }
}

#[cfg(all(test, loom))]
mod loom_tests {
    /// `RUSTFLAGS="--cfg loom" cargo test -p xtask` (with the loom
    /// dev-dependency added) explores every schedule of the miniature.
    #[test]
    fn store_lifecycle_all_schedules() {
        loom::model(super::store_lifecycle_run);
    }
}
