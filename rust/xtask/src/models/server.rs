//! Model of the TCP front-end's dispatcher / router / replica-worker /
//! writer handshake (`coordinator/server.rs`).
//!
//! The protocol being checked, mirroring `serve_on`:
//!
//! * the **dispatcher** routes ingested requests through the shared
//!   `Arc<Mutex<Router>>` (least-loaded) into per-replica mpsc queues,
//!   and exits once the shared `served` counter reaches `max_requests`
//!   (dropping the queues, which tells workers to drain and exit);
//! * each **replica worker** pops jobs, produces one response line per
//!   request, pushes it to the connection's writer queue, and decrements
//!   its router load;
//! * the **writer thread** pops lines and writes them to the socket —
//!   and only THEN bumps `served` (`ConnLine::counts`);
//! * once the dispatcher and workers exit, `serve` returns and the
//!   process exits, killing the (detached) writer thread wherever it is.
//!
//! The property is **no-lost-response**: when the process exits, every
//! request's response has reached the socket. It holds precisely because
//! `served` counts at the socket write. The `count_on_enqueue` knob moves
//! the count to the worker's send — the obvious-looking alternative — and
//! the explorer finds the schedule where the dispatcher sees
//! `served == max` while a line is still queued and the process exit
//! drops it. That pinned counterexample is the regression test guarding
//! the `ConnLine::counts` design.

use super::Model;

/// Number of replica workers in the model.
pub const REPLICAS: usize = 2;

/// Actor indices.
const DISPATCHER: usize = 0;
const WORKER0: usize = 1;
const WRITER: usize = 1 + REPLICAS;
const EXIT: usize = 2 + REPLICAS;

/// State machine for the dispatcher/worker/writer handshake.
#[derive(Clone)]
pub struct ServerModel {
    /// Buggy variant: count `served` when the worker enqueues the line
    /// instead of when the writer delivers it.
    pub count_on_enqueue: bool,
    /// Requests not yet dispatched.
    pending: u8,
    /// Total requests == `max_requests` of the bounded serve.
    max_requests: u8,
    /// Router load per replica (incremented on route, decremented on
    /// completion — both under the one mutex, so one atomic step each).
    loads: [u8; REPLICAS],
    /// Set when `Router::complete` underflowed (refcount-style bug).
    load_underflow: bool,
    /// In-flight jobs per replica queue (job identity doesn't matter for
    /// the property; counts do).
    queued: [u8; REPLICAS],
    /// A popped job the worker is currently executing.
    working: [bool; REPLICAS],
    /// Worker exited (queue disconnected and drained).
    exited: [bool; REPLICAS],
    /// Dispatcher exited (observed served >= max; queues dropped).
    dispatcher_done: bool,
    /// Response lines sitting in the connection writer's queue.
    writer_queue: u8,
    /// Lines that reached the socket.
    delivered: u8,
    /// The bounded-serve counter (`Arc<AtomicUsize>` in the real code).
    served: u8,
    /// Process exited: `serve` returned and detached threads are gone.
    process_exited: bool,
}

impl ServerModel {
    /// A bounded serve of `requests` requests with the real counting
    /// discipline (`count_on_enqueue: false`) or the buggy one.
    pub fn new(requests: u8, count_on_enqueue: bool) -> Self {
        ServerModel {
            count_on_enqueue,
            pending: requests,
            max_requests: requests,
            loads: [0; REPLICAS],
            load_underflow: false,
            queued: [0; REPLICAS],
            working: [false; REPLICAS],
            exited: [false; REPLICAS],
            dispatcher_done: false,
            writer_queue: 0,
            delivered: 0,
            served: 0,
            process_exited: false,
        }
    }

    /// Least-loaded routing with low-index tie-break (`Router`'s
    /// deterministic policy for equal loads).
    fn route(&mut self) -> usize {
        let mut best = 0usize;
        for r in 1..REPLICAS {
            if self.loads[r] < self.loads[best] {
                best = r;
            }
        }
        self.loads[best] += 1;
        best
    }

    fn complete(&mut self, replica: usize) {
        if self.loads[replica] == 0 {
            self.load_underflow = true;
        } else {
            self.loads[replica] -= 1;
        }
    }
}

impl Model for ServerModel {
    fn name(&self) -> &'static str {
        if self.count_on_enqueue {
            "server-dispatch (count-on-enqueue bug)"
        } else {
            "server-dispatch"
        }
    }

    fn actor_label(&self, actor: usize) -> String {
        match actor {
            DISPATCHER => "dispatcher".into(),
            WRITER => "writer".into(),
            EXIT => "process-exit".into(),
            w => format!("worker{}", w - WORKER0),
        }
    }

    fn enabled_actors(&self) -> Vec<usize> {
        let mut out = Vec::new();
        // Dispatcher: has a request to route, or can observe completion.
        if !self.dispatcher_done && (self.pending > 0 || self.served >= self.max_requests) {
            out.push(DISPATCHER);
        }
        for r in 0..REPLICAS {
            if self.exited[r] {
                continue;
            }
            // Worker: finish current job, pop the next, or observe the
            // disconnected empty queue and exit.
            if self.working[r]
                || self.queued[r] > 0
                || (self.dispatcher_done && self.queued[r] == 0)
            {
                out.push(WORKER0 + r);
            }
        }
        if self.writer_queue > 0 && !self.process_exited {
            out.push(WRITER);
        }
        if self.dispatcher_done && self.exited.iter().all(|&e| e) && !self.process_exited {
            out.push(EXIT);
        }
        out
    }

    fn step(&mut self, actor: usize) {
        match actor {
            DISPATCHER => {
                if self.served >= self.max_requests {
                    // `serve_on` breaks out of its loop and drops the
                    // replica queues.
                    self.dispatcher_done = true;
                } else {
                    // route + send, router locked for the route call
                    let r = self.route();
                    self.queued[r] += 1;
                    self.pending -= 1;
                }
            }
            WRITER => {
                // write_all + flush, then count (the ConnLine::counts
                // contract) — or just deliver, in the buggy variant
                self.writer_queue -= 1;
                self.delivered += 1;
                if !self.count_on_enqueue {
                    self.served += 1;
                }
            }
            EXIT => {
                // serve() returned; main exits; detached writer threads
                // die wherever they are, queue contents and all.
                self.process_exited = true;
            }
            w => {
                let r = w - WORKER0;
                if self.working[r] {
                    // engine tick produced the response: enqueue the line
                    // to the writer, complete the router entry
                    self.working[r] = false;
                    self.writer_queue += 1;
                    if self.count_on_enqueue {
                        self.served += 1;
                    }
                    self.complete(r);
                } else if self.queued[r] > 0 {
                    self.queued[r] -= 1;
                    self.working[r] = true;
                } else {
                    // disconnected + drained: worker returns its metrics
                    self.exited[r] = true;
                }
            }
        }
    }

    fn invariant(&self) -> Result<(), String> {
        if self.load_underflow {
            return Err("router load underflow: complete() without a matching route()".into());
        }
        if self.delivered > self.max_requests {
            return Err(format!(
                "delivered {} responses for {} requests",
                self.delivered, self.max_requests
            ));
        }
        if self.process_exited && self.writer_queue > 0 {
            return Err(format!(
                "lost response: process exited with {} line(s) still in a writer queue",
                self.writer_queue
            ));
        }
        Ok(())
    }

    fn terminal(&self) -> Result<(), String> {
        if self.delivered != self.max_requests {
            return Err(format!(
                "lost response: terminated with {}/{} responses on the wire",
                self.delivered, self.max_requests
            ));
        }
        if self.loads.iter().any(|&l| l != 0) {
            return Err(format!("router loads not drained: {:?}", self.loads));
        }
        if !self.process_exited {
            return Err("deadlock: all actors blocked before process exit".into());
        }
        Ok(())
    }

    fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.pending);
        out.extend_from_slice(&self.loads);
        out.extend_from_slice(&self.queued);
        out.push(
            self.working[0] as u8
                | (self.working[1] as u8) << 1
                | (self.exited[0] as u8) << 2
                | (self.exited[1] as u8) << 3
                | (self.dispatcher_done as u8) << 4
                | (self.process_exited as u8) << 5
                | (self.load_underflow as u8) << 6,
        );
        out.push(self.writer_queue);
        out.push(self.delivered);
        out.push(self.served);
    }
}

#[cfg(test)]
mod tests {
    use super::super::explore;
    use super::*;

    /// The shipped counting discipline survives every interleaving.
    #[test]
    fn correct_protocol_is_exhaustively_safe() {
        let r = explore(ServerModel::new(3, false), 2_000_000);
        assert!(r.violation.is_none(), "{}", super::super::render(&r));
        assert!(r.states > 30, "suspiciously small state space: {}", r.states);
    }

    /// Pinned counterexample: counting at enqueue time lets the bounded
    /// serve observe completion while a response is still buffered, and
    /// the process exit drops it. This is WHY `ConnLine::counts` is
    /// counted by the writer after the socket write.
    #[test]
    fn count_on_enqueue_loses_a_response() {
        let r = explore(ServerModel::new(3, true), 2_000_000);
        let v = r.violation.expect("the lost-response schedule must be found");
        assert!(v.message.contains("lost response"), "{}", v.message);
        // The schedule must actually involve an early process exit.
        assert!(v.trace.iter().any(|s| s == "process-exit"), "{:?}", v.trace);
    }
}
