//! Exhaustive interleaving exploration for the serving stack's two
//! nastiest concurrent protocols.
//!
//! The real code runs threads; tests can only sample interleavings. Here
//! the protocols are re-stated as small state machines — every lock
//! region of the real code becomes one atomic `step` — and a depth-first
//! search with state memoization visits EVERY reachable interleaving,
//! checking the safety invariants in every state and the liveness
//! conditions in every terminal state. This is the same state-space
//! semantics `loom` gives `Arc<Mutex<_>>` programs (each step is a
//! critical section; steps of different actors commute only through the
//! shared state), minus weak-memory modeling — which these protocols
//! don't rely on: every shared access is behind a `Mutex` or an mpsc
//! channel.
//!
//! [`sync`] holds the `#[cfg(loom)]` seam: concrete `Arc<Mutex<_>>`
//! miniatures of both protocols whose sync primitives swap to
//! `loom::sync` when the crate is built with `--cfg loom` (and the loom
//! dependency added), so the models stay wired for the real checker
//! without it being vendored offline.

pub mod node_store;
pub mod server;
pub mod store;
pub mod sync;

/// A model: finite actors stepping atomically over shared state.
pub trait Model: Clone {
    /// Display name for reports.
    fn name(&self) -> &'static str;
    /// Human label for actor `i` (trace rendering).
    fn actor_label(&self, actor: usize) -> String;
    /// Indices of actors with an enabled step in this state (ascending).
    fn enabled_actors(&self) -> Vec<usize>;
    /// Perform actor `i`'s one atomic step.
    fn step(&mut self, actor: usize);
    /// Safety invariant, checked in EVERY reachable state.
    fn invariant(&self) -> Result<(), String>;
    /// Terminal condition, checked when no actor is enabled: either a
    /// completed run (Ok) or a deadlock/lost-progress state (Err).
    fn terminal(&self) -> Result<(), String>;
    /// Serialize the state for memoization (must be injective).
    fn encode(&self, out: &mut Vec<u8>);
}

/// A counterexample: the schedule that reaches a violating state.
#[derive(Debug, Clone)]
pub struct Violation {
    pub message: String,
    /// Actor labels in execution order.
    pub trace: Vec<String>,
}

/// Exploration outcome.
#[derive(Debug)]
pub struct Report {
    pub name: &'static str,
    /// Distinct states visited.
    pub states: usize,
    /// Transitions executed.
    pub transitions: usize,
    pub violation: Option<Violation>,
}

/// Exhaustively explore every interleaving of `init` by DFS with visited-
/// state memoization. Sound for safety properties: every reachable state
/// is visited once; pruning only skips states already checked. The first
/// violating state found is returned with its schedule.
pub fn explore<M: Model>(init: M, max_states: usize) -> Report {
    let name = init.name();
    let mut visited = std::collections::HashSet::new();
    let mut states = 0usize;
    let mut transitions = 0usize;
    // Stack frames: (state, its enabled actors, next branch index).
    let mut stack: Vec<(M, Vec<usize>, usize)> = Vec::new();
    let mut path: Vec<String> = Vec::new();

    let violation = 'search: {
        let mut key = Vec::new();
        init.encode(&mut key);
        visited.insert(key);
        states += 1;
        if let Err(message) = init.invariant() {
            break 'search Some(Violation { message, trace: path.clone() });
        }
        let enabled = init.enabled_actors();
        if enabled.is_empty() {
            if let Err(message) = init.terminal() {
                break 'search Some(Violation { message, trace: path.clone() });
            }
        }
        stack.push((init, enabled, 0));
        while let Some((state, enabled, next)) = stack.last_mut() {
            if *next >= enabled.len() {
                stack.pop();
                path.pop();
                continue;
            }
            let actor = enabled[*next];
            *next += 1;
            let mut succ = state.clone();
            succ.step(actor);
            transitions += 1;
            path.push(succ.actor_label(actor));
            let mut key = Vec::new();
            succ.encode(&mut key);
            if !visited.insert(key) {
                path.pop();
                continue; // already checked this state and its successors
            }
            states += 1;
            if states > max_states {
                break 'search Some(Violation {
                    message: format!("state space exceeded {max_states} states"),
                    trace: path.clone(),
                });
            }
            if let Err(message) = succ.invariant() {
                break 'search Some(Violation { message, trace: path.clone() });
            }
            let succ_enabled = succ.enabled_actors();
            if succ_enabled.is_empty() {
                if let Err(message) = succ.terminal() {
                    break 'search Some(Violation { message, trace: path.clone() });
                }
                path.pop();
                continue;
            }
            stack.push((succ, succ_enabled, 0));
        }
        None
    };
    Report { name, states, transitions, violation }
}

/// Render a report for terminal output.
pub fn render(report: &Report) -> String {
    match &report.violation {
        None => format!(
            "model {}: OK — {} states, {} transitions, all interleavings pass",
            report.name, report.states, report.transitions
        ),
        Some(v) => format!(
            "model {}: VIOLATION after {} states — {}\n  schedule: {}",
            report.name,
            report.states,
            v.message,
            v.trace.join(" → ")
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two actors each increment a shared counter twice; invariant bounds
    /// the counter; terminal requires completion.
    #[derive(Clone)]
    struct Counter {
        left: [u8; 2],
        value: u8,
    }

    impl Model for Counter {
        fn name(&self) -> &'static str {
            "counter"
        }
        fn actor_label(&self, actor: usize) -> String {
            format!("inc{actor}")
        }
        fn enabled_actors(&self) -> Vec<usize> {
            (0..2).filter(|&a| self.left[a] > 0).collect()
        }
        fn step(&mut self, actor: usize) {
            self.left[actor] -= 1;
            self.value += 1;
        }
        fn invariant(&self) -> Result<(), String> {
            if self.value <= 4 {
                Ok(())
            } else {
                Err("counter exceeded 4".into())
            }
        }
        fn terminal(&self) -> Result<(), String> {
            if self.value == 4 {
                Ok(())
            } else {
                Err(format!("finished at {}", self.value))
            }
        }
        fn encode(&self, out: &mut Vec<u8>) {
            out.extend_from_slice(&[self.left[0], self.left[1], self.value]);
        }
    }

    #[test]
    fn explorer_visits_full_diamond() {
        let r = explore(Counter { left: [2, 2], value: 0 }, 10_000);
        assert!(r.violation.is_none(), "{:?}", r.violation);
        // States are (left0, left1) pairs: 3*3 = 9 distinct.
        assert_eq!(r.states, 9);
        assert!(r.transitions >= 12);
    }

    #[test]
    fn explorer_reports_violating_schedule() {
        #[derive(Clone)]
        struct Bad(Counter);
        impl Model for Bad {
            fn name(&self) -> &'static str {
                "bad-counter"
            }
            fn actor_label(&self, a: usize) -> String {
                self.0.actor_label(a)
            }
            fn enabled_actors(&self) -> Vec<usize> {
                self.0.enabled_actors()
            }
            fn step(&mut self, a: usize) {
                self.0.step(a);
                if a == 1 {
                    self.0.value += 1; // double-count bug
                }
            }
            fn invariant(&self) -> Result<(), String> {
                self.0.invariant()
            }
            fn terminal(&self) -> Result<(), String> {
                self.0.terminal()
            }
            fn encode(&self, out: &mut Vec<u8>) {
                self.0.encode(out)
            }
        }
        let r = explore(Bad(Counter { left: [2, 2], value: 0 }), 10_000);
        let v = r.violation.expect("double-count must be found");
        assert!(!v.trace.is_empty());
        assert!(v.trace.iter().any(|s| s == "inc1"));
    }
}
