//! Model of the shared-prefix store's adopt / free / evict / swap
//! refcount lifecycle (`coordinator/kv_manager.rs`).
//!
//! One sealed shared page, two sequences, one LRU evictor:
//!
//! * `adopt` — `new_seq_with_prefix` bumps the page's refcount;
//! * `unref` — `free_seq` drops it (never below zero);
//! * `swap_out` / `swap_in` — the real policy KEEPS shared refs while a
//!   sequence is swapped out (the refs pin the prefix against eviction);
//! * the evictor scans for `refs == 0` pages and frees them, revalidating
//!   `refs == 0` at free time (`free_shared_page`'s `ensure!`).
//!
//! Checked properties: **refcount-never-negative**, **no-double-free**
//! (pool release accounting underflows if a page is freed twice), and
//! **no use-after-free** (no sequence ever holds or re-admits a page
//! that was evicted under it).
//!
//! Two knobs re-introduce the two nastiest interleavings as pinned
//! counterexamples:
//!
//! * `drop_refs_on_swap` — the tempting "swapped-out sequences shouldn't
//!   pin memory" policy. The explorer finds: seq A swaps out (refs drop
//!   to 0), the evictor frees the page, A swaps back in → use-after-free.
//!   This is WHY `swap_out` keeps shared refs.
//! * `revalidate_on_evict: false` — the evictor trusts its scan. The
//!   explorer finds: evictor observes `refs == 0`, seq B adopts the page
//!   (swap-in re-admission), evictor frees it under B → an adopted page
//!   evicted. This is WHY `free_shared_page` re-checks under the lock.

use super::Model;

/// Per-sequence lifecycle script: adopt → (swap cycle) → release.
#[derive(Clone, Copy, PartialEq, Eq)]
enum SeqPhase {
    Start,
    /// Holding a ref (counted unless swapped under `drop_refs_on_swap`).
    Adopted,
    /// Swapped out (only the swapping sequence enters this phase).
    Swapped,
    /// Swapped back in.
    Resident,
    Done,
    /// Terminal-with-error marker (the violation text lives in `fault`).
    Faulted,
}

/// Evictor scan state.
#[derive(Clone, Copy, PartialEq, Eq)]
enum EvictPhase {
    /// Looking for a refs == 0 page.
    Scan,
    /// Observed the page evictable; free not yet performed.
    Candidate,
    Done,
}

/// State machine for the shared-page refcount lifecycle.
#[derive(Clone)]
pub struct StoreModel {
    /// Buggy policy: swap-out drops shared refs, swap-in re-adopts.
    pub drop_refs_on_swap: bool,
    /// Real policy: free re-checks refs == 0 under the lock.
    pub revalidate_on_evict: bool,
    /// The sealed shared page: present in the store tree?
    page_present: bool,
    /// Its refcount.
    refs: u8,
    /// Pool pages allocated (the page costs 1; underflow = double free).
    pool_allocated: u8,
    /// Sequence 0 swaps; sequence 1 is a plain adopt/release peer.
    seqs: [SeqPhase; 2],
    /// Evictor two-phase scan (observe, then free) — two lock regions,
    /// exactly like an LRU pass that collects candidates then frees.
    evictor: EvictPhase,
    /// Remaining evictor passes (bounds the state space).
    evict_passes: u8,
    /// First violation observed by a step (checked by `invariant`).
    fault: Option<&'static str>,
}

impl StoreModel {
    /// Model with the real policies (`drop_refs_on_swap: false`,
    /// `revalidate_on_evict: true`) or a buggy variant.
    pub fn new(drop_refs_on_swap: bool, revalidate_on_evict: bool) -> Self {
        StoreModel {
            drop_refs_on_swap,
            revalidate_on_evict,
            // The page was sealed by an earlier sequence and sits in the
            // store cache with no current adopters.
            page_present: true,
            refs: 0,
            pool_allocated: 1,
            seqs: [SeqPhase::Start; 2],
            evictor: EvictPhase::Scan,
            evict_passes: 2,
            fault: None,
        }
    }

    fn adopt(&mut self) -> bool {
        if !self.page_present {
            // Prefix miss: the real code simply doesn't adopt. For the
            // swap-in path this is a use-after-free (handled by caller).
            return false;
        }
        self.refs += 1;
        true
    }

    fn unref(&mut self) {
        if self.refs == 0 {
            self.fault = Some("refcount underflow: unref of a page with refs == 0");
        } else {
            self.refs -= 1;
        }
    }
}

impl Model for StoreModel {
    fn name(&self) -> &'static str {
        match (self.drop_refs_on_swap, self.revalidate_on_evict) {
            (false, true) => "store-refcount",
            (true, _) => "store-refcount (drop-refs-on-swap bug)",
            (false, false) => "store-refcount (no-revalidate-evict bug)",
        }
    }

    fn actor_label(&self, actor: usize) -> String {
        match actor {
            0 => "seqA".into(),
            1 => "seqB".into(),
            _ => "evictor".into(),
        }
    }

    fn enabled_actors(&self) -> Vec<usize> {
        if self.fault.is_some() {
            return Vec::new(); // freeze the violating state for the checker
        }
        let mut out = Vec::new();
        for (i, s) in self.seqs.iter().enumerate() {
            if !matches!(s, SeqPhase::Done | SeqPhase::Faulted) {
                out.push(i);
            }
        }
        if self.evictor != EvictPhase::Done && self.evict_passes > 0 {
            out.push(2);
        }
        out
    }

    fn step(&mut self, actor: usize) {
        match actor {
            // seqA: adopt → swap_out → swap_in → free
            0 => match self.seqs[0] {
                SeqPhase::Start => {
                    self.seqs[0] = if self.adopt() {
                        SeqPhase::Adopted
                    } else {
                        SeqPhase::Done // prefix miss: owned-only sequence
                    };
                }
                SeqPhase::Adopted => {
                    // swap_out: pool pages released; shared refs KEPT by
                    // the real policy, dropped by the buggy one
                    if self.drop_refs_on_swap {
                        self.unref();
                    }
                    self.seqs[0] = SeqPhase::Swapped;
                }
                SeqPhase::Swapped => {
                    // swap_in: the stream returns; under the buggy policy
                    // it must re-adopt the prefix it thinks it still has
                    if self.drop_refs_on_swap {
                        if !self.adopt() {
                            self.fault = Some(
                                "use-after-free: swap-in found its shared prefix page evicted",
                            );
                            self.seqs[0] = SeqPhase::Faulted;
                            return;
                        }
                    } else if !self.page_present {
                        self.fault =
                            Some("use-after-free: page evicted while a swapped sequence held refs");
                        self.seqs[0] = SeqPhase::Faulted;
                        return;
                    }
                    self.seqs[0] = SeqPhase::Resident;
                }
                SeqPhase::Resident => {
                    self.unref();
                    self.seqs[0] = SeqPhase::Done;
                }
                SeqPhase::Done | SeqPhase::Faulted => {}
            },
            // seqB: adopt → free (late admission racing the evictor)
            1 => match self.seqs[1] {
                SeqPhase::Start => {
                    self.seqs[1] = if self.adopt() {
                        SeqPhase::Adopted
                    } else {
                        SeqPhase::Done
                    };
                }
                SeqPhase::Adopted => {
                    if !self.page_present {
                        self.fault =
                            Some("use-after-free: page evicted under a resident adopter");
                        self.seqs[1] = SeqPhase::Faulted;
                        return;
                    }
                    self.unref();
                    self.seqs[1] = SeqPhase::Done;
                }
                _ => {}
            },
            // evictor: observe a refs == 0 page, then free it
            _ => match self.evictor {
                EvictPhase::Scan => {
                    if self.page_present && self.refs == 0 {
                        self.evictor = EvictPhase::Candidate;
                    } else {
                        self.evict_passes -= 1;
                        if self.evict_passes == 0 {
                            self.evictor = EvictPhase::Done;
                        }
                    }
                }
                EvictPhase::Candidate => {
                    let safe = !self.revalidate_on_evict || self.refs == 0;
                    if self.page_present && safe {
                        if self.refs > 0 {
                            // (only reachable without revalidation)
                            self.fault = Some(
                                "adopted page evicted: free ran on a stale refs == 0 observation",
                            );
                        }
                        self.page_present = false;
                        if self.pool_allocated == 0 {
                            self.fault = Some("double free: pool release underflow");
                        } else {
                            self.pool_allocated -= 1;
                        }
                    }
                    self.evict_passes -= 1;
                    self.evictor = if self.evict_passes == 0 {
                        EvictPhase::Done
                    } else {
                        EvictPhase::Scan
                    };
                }
                EvictPhase::Done => {}
            },
        }
    }

    fn invariant(&self) -> Result<(), String> {
        if let Some(f) = self.fault {
            return Err(f.to_string());
        }
        // Pool accounting: the page is the only allocation.
        let expect = self.page_present as u8;
        if self.pool_allocated != expect {
            return Err(format!(
                "pool accounting drift: {} allocated, page_present={}",
                self.pool_allocated, self.page_present
            ));
        }
        // A page absent from the store cannot carry refs.
        if !self.page_present && self.refs > 0 {
            return Err(format!("{} refs on an evicted page", self.refs));
        }
        Ok(())
    }

    fn terminal(&self) -> Result<(), String> {
        if self.seqs.iter().any(|s| *s != SeqPhase::Done) {
            return Err("deadlock: a sequence could not finish its script".into());
        }
        if self.refs != 0 {
            return Err(format!("leaked refs at shutdown: {}", self.refs));
        }
        Ok(())
    }

    fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.page_present as u8);
        out.push(self.refs);
        out.push(self.pool_allocated);
        for s in &self.seqs {
            out.push(*s as u8);
        }
        out.push(self.evictor as u8);
        out.push(self.evict_passes);
        out.push(self.fault.map_or(0, |_| 1));
    }
}

#[cfg(test)]
mod tests {
    use super::super::explore;
    use super::*;

    /// The shipped lifecycle (refs kept across swap, revalidated evict)
    /// survives every interleaving of two sequences and the evictor.
    #[test]
    fn real_policies_are_exhaustively_safe() {
        let r = explore(StoreModel::new(false, true), 2_000_000);
        assert!(r.violation.is_none(), "{}", super::super::render(&r));
        assert!(r.states > 30, "suspiciously small state space: {}", r.states);
    }

    /// Pinned counterexample #1: dropping shared refs on swap-out lets
    /// the evictor free the prefix under a swapped sequence; swap-in then
    /// re-admits a freed page. This is WHY `swap_out` keeps shared refs.
    #[test]
    fn drop_refs_on_swap_is_found_unsafe() {
        let r = explore(StoreModel::new(true, true), 2_000_000);
        let v = r.violation.expect("the swap/evict race must be found");
        assert!(v.message.contains("use-after-free"), "{}", v.message);
        assert!(v.trace.iter().any(|s| s == "evictor"), "{:?}", v.trace);
    }

    /// Pinned counterexample #2: freeing on a stale refs == 0 observation
    /// evicts a page a late-admitted sequence just adopted. This is WHY
    /// `free_shared_page` revalidates refs == 0 under the lock.
    #[test]
    fn stale_evict_observation_is_found_unsafe() {
        let r = explore(StoreModel::new(false, false), 2_000_000);
        let v = r.violation.expect("the adopt/evict race must be found");
        assert!(
            v.message.contains("adopted page evicted")
                || v.message.contains("use-after-free")
                || v.message.contains("refs on an evicted page"),
            "{}",
            v.message
        );
    }
}
