//! Model of the CROSS-REPLICA node-store refcount lifecycle
//! (`coordinator/kv_manager.rs`, `SharedPageStore::node`).
//!
//! One content-addressed page, two replica actors, one LRU evictor:
//!
//! * `harvest` — each replica seals the same token window at finish time;
//!   the node store dedups on content equality, so the second seal lands
//!   on the FIRST replica's physical page instead of inserting a copy;
//! * `adopt` — `new_seq_with_prefix` on either replica bumps the page's
//!   (store-global) refcount; a miss after eviction simply recomputes;
//! * `swap_out` / `swap_in` — a preempted adopter KEEPS its shared refs
//!   while swapped, pinning the page across the replica boundary;
//! * the evictor (an at-capacity `seal_page` on some replica) frees
//!   `refs == 0` pages, revalidating under the store lock.
//!
//! Checked properties: **refcount-never-negative**,
//! **no-evict-under-remote-ref** (a page replica B still references can
//! never be freed by replica A's eviction pass, even while B's adopter is
//! swapped out), and **no-double-free** (freeing an absent page).
//!
//! One knob re-introduces the scoping bug this store exists to prevent:
//!
//! * `local_refs_only` — the evicting replica consults only its OWN
//!   sequences when judging a page idle (the natural design if each
//!   replica kept private refcounts instead of the store counting
//!   globally). The explorer finds: A seals, B dedup-harvests and adopts,
//!   A's evictor sees no LOCAL use and frees the page under B. Even
//!   free-time revalidation cannot save it — it revalidates the wrong
//!   set. This is WHY refcounts live in the store, not the replicas.

use super::Model;

/// Per-replica sequence script over the shared page.
#[derive(Clone, Copy, PartialEq, Eq)]
enum Phase {
    Start,
    /// Sealed (or dedup-harvested) the page into the node store.
    Sealed,
    /// Holding a store-global ref.
    Adopted,
    /// Preempted: pool pages gone, shared refs kept (replica A only).
    Swapped,
    /// Swapped back in.
    Resident,
    Done,
    /// Terminal-with-error marker (the violation text lives in `fault`).
    Faulted,
}

/// Evictor scan state.
#[derive(Clone, Copy, PartialEq, Eq)]
enum EvictPhase {
    /// Looking for an idle page.
    Scan,
    /// Observed the page evictable; free not yet performed.
    Candidate,
    Done,
}

/// State machine for the cross-replica node-store page lifecycle.
#[derive(Clone)]
pub struct NodeStoreModel {
    /// Buggy policy: eviction judges idleness by the evicting replica's
    /// own refs, blind to the peer replica's.
    pub local_refs_only: bool,
    /// The sealed page: resident in the node store?
    page_present: bool,
    /// Per-replica refcounts; the store's global count is their sum. The
    /// evictor runs on replica 0's seal path.
    refs: [u8; 2],
    /// Replica A adopts / swaps; replica B is the remote dedup-adopter.
    seqs: [Phase; 2],
    /// Evictor two-phase pass (observe, then free under the lock).
    evictor: EvictPhase,
    /// Remaining evictor passes (bounds the state space).
    evict_passes: u8,
    /// First violation observed by a step (checked by `invariant`).
    fault: Option<&'static str>,
}

impl NodeStoreModel {
    /// Model with the real policy (`local_refs_only: false`) or the
    /// replica-scoped-refcount bug.
    pub fn new(local_refs_only: bool) -> Self {
        NodeStoreModel {
            local_refs_only,
            // Nothing sealed yet: replica A's first finish publishes it.
            page_present: false,
            refs: [0, 0],
            seqs: [Phase::Start; 2],
            evictor: EvictPhase::Scan,
            evict_passes: 2,
            fault: None,
        }
    }

    fn global_refs(&self) -> u8 {
        self.refs[0] + self.refs[1]
    }

    /// The refs the evictor can SEE under the active policy.
    fn observed_refs(&self) -> u8 {
        if self.local_refs_only {
            self.refs[0]
        } else {
            self.global_refs()
        }
    }

    /// Seal the page's content: insert when absent, dedup onto the
    /// existing physical page when present (never a second copy).
    fn harvest(&mut self) {
        if !self.page_present {
            self.page_present = true;
        }
    }

    fn adopt(&mut self, replica: usize) -> bool {
        if !self.page_present {
            // Prefix miss (evicted since sealing): the real code
            // recomputes the window — the sequence proceeds owned-only.
            return false;
        }
        self.refs[replica] += 1;
        true
    }

    fn unref(&mut self, replica: usize) {
        if self.refs[replica] == 0 {
            self.fault = Some("refcount underflow: unref of a page with refs == 0");
        } else {
            self.refs[replica] -= 1;
        }
    }
}

impl Model for NodeStoreModel {
    fn name(&self) -> &'static str {
        if self.local_refs_only {
            "node-store-refcount (local-refs-only bug)"
        } else {
            "node-store-refcount"
        }
    }

    fn actor_label(&self, actor: usize) -> String {
        match actor {
            0 => "replicaA".into(),
            1 => "replicaB".into(),
            _ => "evictor".into(),
        }
    }

    fn enabled_actors(&self) -> Vec<usize> {
        if self.fault.is_some() {
            return Vec::new(); // freeze the violating state for the checker
        }
        let mut out = Vec::new();
        for (i, s) in self.seqs.iter().enumerate() {
            if !matches!(s, Phase::Done | Phase::Faulted) {
                out.push(i);
            }
        }
        if self.evictor != EvictPhase::Done && self.evict_passes > 0 {
            out.push(2);
        }
        out
    }

    fn step(&mut self, actor: usize) {
        match actor {
            // replica A: harvest → adopt → swap_out → swap_in → free
            0 => match self.seqs[0] {
                Phase::Start => {
                    self.harvest();
                    self.seqs[0] = Phase::Sealed;
                }
                Phase::Sealed => {
                    self.seqs[0] = if self.adopt(0) { Phase::Adopted } else { Phase::Done };
                }
                Phase::Adopted => {
                    // swap_out: pool pages and reservation released; the
                    // shared refs are KEPT — they are the eviction pin
                    self.seqs[0] = Phase::Swapped;
                }
                Phase::Swapped => {
                    if !self.page_present {
                        self.fault = Some(
                            "use-after-free: page evicted while a swapped sequence held refs",
                        );
                        self.seqs[0] = Phase::Faulted;
                        return;
                    }
                    self.seqs[0] = Phase::Resident;
                }
                Phase::Resident => {
                    self.unref(0);
                    self.seqs[0] = Phase::Done;
                }
                Phase::Done | Phase::Faulted => {}
            },
            // replica B: dedup-harvest → adopt → free (the remote peer
            // whose refs replica A's evictor must respect)
            1 => match self.seqs[1] {
                Phase::Start => {
                    self.harvest();
                    self.seqs[1] = Phase::Sealed;
                }
                Phase::Sealed => {
                    self.seqs[1] = if self.adopt(1) { Phase::Adopted } else { Phase::Done };
                }
                Phase::Adopted => {
                    if !self.page_present {
                        self.fault =
                            Some("use-after-free: page evicted under a resident remote adopter");
                        self.seqs[1] = Phase::Faulted;
                        return;
                    }
                    self.unref(1);
                    self.seqs[1] = Phase::Done;
                }
                _ => {}
            },
            // evictor: observe an idle page, then free it under the lock
            _ => match self.evictor {
                EvictPhase::Scan => {
                    if self.page_present && self.observed_refs() == 0 {
                        self.evictor = EvictPhase::Candidate;
                    } else {
                        self.evict_passes -= 1;
                        if self.evict_passes == 0 {
                            self.evictor = EvictPhase::Done;
                        }
                    }
                }
                EvictPhase::Candidate => {
                    // free-time revalidation — against the policy's view;
                    // a replica-scoped view revalidates the WRONG set
                    if self.page_present && self.observed_refs() == 0 {
                        if self.global_refs() > 0 {
                            self.fault = Some(
                                "remote-ref eviction: page freed while the peer replica held refs",
                            );
                        }
                        self.page_present = false;
                    }
                    self.evict_passes -= 1;
                    self.evictor =
                        if self.evict_passes == 0 { EvictPhase::Done } else { EvictPhase::Scan };
                }
                EvictPhase::Done => {}
            },
        }
    }

    fn invariant(&self) -> Result<(), String> {
        if let Some(f) = self.fault {
            return Err(f.to_string());
        }
        // A page absent from the store cannot carry refs on ANY replica.
        if !self.page_present && self.global_refs() > 0 {
            return Err(format!("{} refs on an evicted page", self.global_refs()));
        }
        Ok(())
    }

    fn terminal(&self) -> Result<(), String> {
        if self.seqs.iter().any(|s| *s != Phase::Done) {
            return Err("deadlock: a replica could not finish its script".into());
        }
        if self.global_refs() != 0 {
            return Err(format!("leaked refs at shutdown: {}", self.global_refs()));
        }
        Ok(())
    }

    fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.page_present as u8);
        out.push(self.refs[0]);
        out.push(self.refs[1]);
        for s in &self.seqs {
            out.push(*s as u8);
        }
        out.push(self.evictor as u8);
        out.push(self.evict_passes);
        out.push(self.fault.map_or(0, |_| 1));
    }
}

#[cfg(test)]
mod tests {
    use super::super::explore;
    use super::*;

    /// The shipped store-global refcount survives every interleaving of
    /// two replicas (one swapping) and the evictor: no page is ever freed
    /// under a remote ref, no ref underflows, nothing double-frees.
    #[test]
    fn global_refcounts_are_exhaustively_safe() {
        let r = explore(NodeStoreModel::new(false), 2_000_000);
        assert!(r.violation.is_none(), "{}", super::super::render(&r));
        assert!(r.states > 50, "suspiciously small state space: {}", r.states);
    }

    /// Pinned counterexample: replica-scoped refcounts let replica A's
    /// eviction pass free a page replica B dedup-harvested and adopted —
    /// free-time revalidation included, since it revalidates the wrong
    /// set. This is WHY refcounts live in the node store itself.
    #[test]
    fn local_refs_only_is_found_unsafe() {
        let r = explore(NodeStoreModel::new(true), 2_000_000);
        let v = r.violation.expect("the cross-replica evict race must be found");
        assert!(
            v.message.contains("remote-ref eviction")
                || v.message.contains("use-after-free")
                || v.message.contains("refs on an evicted page"),
            "{}",
            v.message
        );
        assert!(v.trace.iter().any(|s| s == "replicaB"), "{:?}", v.trace);
        assert!(v.trace.iter().any(|s| s == "evictor"), "{:?}", v.trace);
    }
}
