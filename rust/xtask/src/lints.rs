//! Repo-specific source lints for the TurboAngle serving stack.
//!
//! Four rules, each encoding an invariant the ordinary toolchain cannot
//! see (docs/ANALYSIS.md has the full matrix):
//!
//! * `no-alloc-in-hot-path` — the decode-stage kernels, the tile-decode
//!   tick path, and the obs recording functions (trace-ring push, stage
//!   timer) must stay allocation-free (`_into` contract from PR 7).
//! * `no-panic-in-serving` — no `unwrap`/`expect`/`panic!` in the wire
//!   path (`coordinator/server.rs`, `engine.rs`, `util/json.rs`): one bad
//!   connection must never kill a reader/writer/replica thread.
//! * `no-nondeterminism-in-identity-paths` — nothing feeding content
//!   hashes or `LaneScore` checksums may touch `HashMap`/`HashSet`
//!   iteration order, wall clocks, or fused-multiply-add float helpers.
//! * `release-checked-bounds` — kernel-stage slice preconditions must be
//!   validated in release builds at the public entry; a bare
//!   `debug_assert!` on a length is exactly the check that vanishes where
//!   it matters.
//!
//! Escape hatch: `// xtask-allow(<rule>): reason` on the flagged line or
//! the line directly above. The reason is mandatory — an allow without
//! one is itself a finding — so every suppression carries its audit note.

use crate::lex::{self, LexedFile};
use std::path::Path;

/// Names of every rule, for allow-comment validation.
pub const RULE_NAMES: [&str; 4] = [
    "no-alloc-in-hot-path",
    "no-panic-in-serving",
    "no-nondeterminism-in-identity-paths",
    "release-checked-bounds",
];

/// What to look for on a code line.
pub enum Needle {
    /// Exact substring of the blanked code text (operator-adjacent forms
    /// like `.unwrap()` or `Vec::new`).
    Sub(&'static str),
    /// Identifier with word boundaries (`HashMap`, `Instant`).
    Ident(&'static str),
    /// A `debug_assert!`/`debug_assert_eq!` whose argument mentions a
    /// length — a bounds check that vanishes in release builds.
    DebugAssertLen,
}

/// Where a rule applies within one file.
pub enum Scope {
    /// The whole file, minus `#[cfg(test)] mod` blocks.
    WholeFile,
    /// Only inside the named functions' bodies. Every listed name must
    /// exist in the file — a missing one is a finding, so scopes cannot
    /// silently rot when code moves.
    Funcs(&'static [&'static str]),
}

/// One (rule, file, scope) binding.
pub struct Target {
    pub rule: &'static str,
    pub file: &'static str,
    pub scope: Scope,
}

/// The needle set for each rule.
pub fn rule_needles(rule: &str) -> &'static [Needle] {
    match rule {
        "no-alloc-in-hot-path" => &[
            Needle::Sub("Vec::new"),
            Needle::Sub("vec!"),
            Needle::Sub(".to_vec()"),
            Needle::Sub(".collect()"),
            Needle::Sub(".collect::"),
            Needle::Sub("String::new"),
            Needle::Sub(".to_string()"),
            Needle::Sub(".to_owned()"),
            Needle::Sub("Box::new"),
        ],
        "no-panic-in-serving" => &[
            Needle::Sub(".unwrap()"),
            Needle::Sub(".expect("),
            Needle::Sub("panic!("),
            Needle::Sub("unreachable!("),
            Needle::Sub("todo!("),
            Needle::Sub("unimplemented!("),
        ],
        "no-nondeterminism-in-identity-paths" => &[
            Needle::Ident("HashMap"),
            Needle::Ident("HashSet"),
            Needle::Ident("Instant"),
            Needle::Ident("SystemTime"),
            Needle::Sub(".mul_add("),
        ],
        "release-checked-bounds" => &[Needle::DebugAssertLen],
        _ => &[],
    }
}

/// Rationale printed with each finding.
pub fn rule_note(rule: &str) -> &'static str {
    match rule {
        "no-alloc-in-hot-path" => {
            "decode stages run per tile per tick; allocation belongs in grow-once scratch (TileScratch/TrigScratch), not the kernel body"
        }
        "no-panic-in-serving" => {
            "a panic here kills a reader/writer/replica thread and poisons shared locks; return an error line or drop the connection"
        }
        "no-nondeterminism-in-identity-paths" => {
            "content hashes and LaneScore checksums must be reproducible across runs and platforms; no hash-iteration order, clocks, or fused float ops"
        }
        "release-checked-bounds" => {
            "debug_assert! length checks vanish in release; validate at the public kernel entry with ensure!/assert! instead"
        }
        _ => "",
    }
}

/// The repo's lint surface: which rule applies where.
pub fn targets() -> Vec<Target> {
    use Scope::*;
    vec![
        Target {
            rule: "no-alloc-in-hot-path",
            file: "rust/src/quant/kernels.rs",
            scope: Funcs(&[
                "decode_side_range",
                "gather_trig",
                "weighted_polar_terms",
                "affine_in_place",
            ]),
        },
        Target {
            rule: "no-alloc-in-hot-path",
            file: "rust/src/quant/packing.rs",
            scope: Funcs(&[
                "unpack_codes_range_into",
                "unpack_f32_range_into",
                "unpack_into",
                "unpack_f32_into",
            ]),
        },
        Target {
            rule: "no-alloc-in-hot-path",
            file: "rust/src/coordinator/kv_manager.rs",
            scope: Funcs(&[
                "visit_seq_tiles",
                "decode_tile_into",
                "decode_lh_range",
                "decode_side_range",
                "fill_layer",
                "fill_dense_range",
            ]),
        },
        Target {
            rule: "no-alloc-in-hot-path",
            file: "rust/src/runtime/sim.rs",
            scope: Funcs(&["slab", "element", "fold_acc", "end_row"]),
        },
        Target {
            rule: "no-alloc-in-hot-path",
            file: "rust/src/obs/trace.rs",
            scope: Funcs(&["push", "record", "record_span"]),
        },
        Target {
            rule: "no-alloc-in-hot-path",
            file: "rust/src/obs/stage.rs",
            scope: Funcs(&["time"]),
        },
        Target {
            rule: "no-panic-in-serving",
            file: "rust/src/coordinator/server.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-panic-in-serving",
            file: "rust/src/coordinator/engine.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-panic-in-serving",
            file: "rust/src/util/json.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "rust/src/quant/kernels.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "rust/src/quant/packing.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "rust/src/util/hash.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "rust/src/runtime/sim.rs",
            scope: WholeFile,
        },
        Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "rust/src/coordinator/kv_manager.rs",
            scope: Funcs(&["fold_hash", "content_hash"]),
        },
        // Obs recording runs inside the engine tick between identity-
        // critical stages: it must never name a clock type directly
        // (timestamps flow through the Recorder epoch only) so a refactor
        // cannot leak wall-clock state into scoring or hashing code.
        Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "rust/src/obs/trace.rs",
            scope: Funcs(&["push", "record", "record_span"]),
        },
        Target {
            rule: "release-checked-bounds",
            file: "rust/src/quant/kernels.rs",
            scope: WholeFile,
        },
    ]
}

/// One lint hit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: String,
    pub excerpt: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}\n    note: {}",
            self.file,
            self.line,
            self.rule,
            self.excerpt,
            rule_note(&self.rule)
        )
    }
}

/// Run every target against the repo rooted at `root`.
pub fn run(root: &Path) -> Result<Vec<Finding>, String> {
    let mut findings = Vec::new();
    let mut cache: Vec<(String, LexedFile)> = Vec::new();
    for t in targets() {
        let idx = match cache.iter().position(|(f, _)| f == t.file) {
            Some(i) => i,
            None => {
                let src = std::fs::read_to_string(root.join(t.file))
                    .map_err(|e| format!("{}: {e}", t.file))?;
                cache.push((t.file.to_string(), lex::lex(&src)));
                cache.len() - 1
            }
        };
        let lexed = &cache[idx].1;
        findings.extend(check_target(t.file, lexed, &t));
    }
    for (file, lexed) in &cache {
        findings.extend(check_allow_comments(file, lexed));
    }
    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    findings.dedup();
    Ok(findings)
}

/// Evaluate one rule over one lexed file (public so tests can run a rule
/// against fixture snippets with a synthetic scope).
pub fn check_target(file: &str, lexed: &LexedFile, target: &Target) -> Vec<Finding> {
    let test_spans = lex::test_mod_spans(lexed);
    let in_tests = |line: usize| test_spans.iter().any(|&(s, e)| line >= s && line <= e);
    let included: Vec<bool> = match &target.scope {
        Scope::WholeFile => (0..lexed.lines()).map(|l| !in_tests(l)).collect(),
        Scope::Funcs(names) => {
            let spans = lex::fn_spans(lexed);
            let mut inc = vec![false; lexed.lines()];
            let mut missing = Vec::new();
            for name in *names {
                let mut found = false;
                for s in spans.iter().filter(|s| &s.name == name) {
                    if in_tests(s.start) {
                        continue;
                    }
                    found = true;
                    for v in inc.iter_mut().take(s.end + 1).skip(s.start) {
                        *v = true;
                    }
                }
                if !found {
                    missing.push(*name);
                }
            }
            if !missing.is_empty() {
                // Scope rot: the function the rule should guard is gone.
                return missing
                    .iter()
                    .map(|name| Finding {
                        file: file.to_string(),
                        line: 1,
                        rule: target.rule.to_string(),
                        excerpt: format!(
                            "lint scope names function `{name}` which no longer exists in this file — update xtask::lints::targets()"
                        ),
                    })
                    .collect();
            }
            inc
        }
    };

    let mut findings = Vec::new();
    for line in 0..lexed.lines() {
        if !included[line] {
            continue;
        }
        let code = &lexed.code[line];
        for needle in rule_needles(target.rule) {
            let hit = match needle {
                Needle::Sub(s) => code.contains(s).then(|| s.to_string()),
                Needle::Ident(w) => lex::contains_word(code, w).then(|| w.to_string()),
                Needle::DebugAssertLen => debug_assert_len_hit(lexed, line),
            };
            if let Some(what) = hit {
                if allowed(lexed, line, target.rule) {
                    continue;
                }
                findings.push(Finding {
                    file: file.to_string(),
                    line: line + 1,
                    rule: target.rule.to_string(),
                    excerpt: format!("`{what}` in: {}", lexed.code[line].trim()),
                });
            }
        }
    }
    findings
}

/// Does line `line` start a `debug_assert!` whose argument (possibly
/// spanning lines) mentions a length? Returns the matched macro name.
fn debug_assert_len_hit(lexed: &LexedFile, line: usize) -> Option<String> {
    let code = &lexed.code[line];
    let pos = lex::find_word_from(code, "debug_assert", 0)
        .or_else(|| lex::find_word_from(code, "debug_assert_eq", 0))?;
    // Capture the macro argument text up to the matching close paren.
    let mut depth = 0i32;
    let mut arg = String::new();
    let mut started = false;
    'outer: for l in line..lexed.lines() {
        let text = &lexed.code[l];
        let begin = if l == line { pos } else { 0 };
        for c in text[begin.min(text.len())..].chars() {
            match c {
                '(' => {
                    depth += 1;
                    started = true;
                }
                ')' => {
                    depth -= 1;
                    if started && depth == 0 {
                        break 'outer;
                    }
                }
                _ => {}
            }
            if started {
                arg.push(c);
            }
        }
        arg.push(' ');
    }
    (arg.contains(".len()") || arg.contains("len_bits()") || arg.contains(".len_codes("))
        .then(|| "debug_assert! on a length".to_string())
}

/// Is `rule` suppressed at `line` by an `xtask-allow` comment on the same
/// line or the line directly above (with a non-empty reason)?
fn allowed(lexed: &LexedFile, line: usize, rule: &str) -> bool {
    let check = |l: usize| {
        parse_allows(&lexed.comments[l])
            .iter()
            .any(|(r, reason)| r == rule && !reason.is_empty())
    };
    check(line) || (line > 0 && check(line - 1))
}

/// Extract every `xtask-allow(rule): reason` from one comment string.
fn parse_allows(comment: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find("xtask-allow(") {
        rest = &rest[pos + "xtask-allow(".len()..];
        let Some(close) = rest.find(')') else { break };
        let rule = rest[..close].trim().to_string();
        rest = &rest[close + 1..];
        let reason = match rest.strip_prefix(':') {
            Some(r) => {
                let end = r.find("xtask-allow(").unwrap_or(r.len());
                r[..end].trim().to_string()
            }
            None => String::new(),
        };
        out.push((rule, reason));
    }
    out
}

/// Validate every allow comment in a file: the rule must exist and the
/// reason must be non-empty, so suppressions cannot rot silently.
pub fn check_allow_comments(file: &str, lexed: &LexedFile) -> Vec<Finding> {
    let mut findings = Vec::new();
    for line in 0..lexed.lines() {
        for (rule, reason) in parse_allows(&lexed.comments[line]) {
            if !RULE_NAMES.contains(&rule.as_str()) {
                findings.push(Finding {
                    file: file.to_string(),
                    line: line + 1,
                    rule: "xtask-allow".to_string(),
                    excerpt: format!("unknown rule `{rule}` in xtask-allow"),
                });
            } else if reason.is_empty() {
                findings.push(Finding {
                    file: file.to_string(),
                    line: line + 1,
                    rule: "xtask-allow".to_string(),
                    excerpt: format!("xtask-allow({rule}) without a reason — write `xtask-allow({rule}): why`"),
                });
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lex::lex;

    fn fixture(name: &str) -> LexedFile {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("fixtures")
            .join(name);
        lex(&std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("{name}: {e}")))
    }

    fn repo_root() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .ancestors()
            .nth(2)
            .unwrap()
            .to_path_buf()
    }

    fn rules_hit(file: &str, lexed: &LexedFile, target: &Target) -> Vec<String> {
        check_target(file, lexed, target)
            .into_iter()
            .map(|f| f.rule)
            .collect()
    }

    #[test]
    fn alloc_lint_fires_on_fixture() {
        let lx = fixture("bad_alloc_in_hot_path.rs");
        let t = Target {
            rule: "no-alloc-in-hot-path",
            file: "fixture",
            scope: Scope::Funcs(&["decode_tile"]),
        };
        let hits = check_target("fixture", &lx, &t);
        assert!(
            hits.iter().any(|f| f.excerpt.contains("collect")),
            "expected a collect() finding, got {hits:?}"
        );
        assert!(hits.iter().any(|f| f.excerpt.contains("Vec::new")));
        // The allocation in the helper OUTSIDE the scoped function is fine.
        assert!(!hits.iter().any(|f| f.excerpt.contains("grow_scratch")));
    }

    #[test]
    fn panic_lint_fires_on_fixture_but_not_in_tests() {
        let lx = fixture("bad_panic_in_serving.rs");
        let t = Target {
            rule: "no-panic-in-serving",
            file: "fixture",
            scope: Scope::WholeFile,
        };
        let hits = check_target("fixture", &lx, &t);
        assert!(hits.iter().any(|f| f.excerpt.contains(".unwrap()")));
        assert!(hits.iter().any(|f| f.excerpt.contains("panic!(")));
        // the unwrap inside #[cfg(test)] mod and the one inside a string
        // literal must NOT fire
        assert!(
            !hits.iter().any(|f| f.excerpt.contains("in_test_mod")),
            "{hits:?}"
        );
        assert_eq!(hits.len(), 3, "{hits:?}"); // unwrap, expect, panic!
    }

    #[test]
    fn nondeterminism_lint_fires_on_fixture() {
        let lx = fixture("bad_nondeterminism.rs");
        let t = Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "fixture",
            scope: Scope::WholeFile,
        };
        let hits = check_target("fixture", &lx, &t);
        assert!(hits.iter().any(|f| f.excerpt.contains("HashMap")));
        assert!(hits.iter().any(|f| f.excerpt.contains("Instant")));
        assert!(hits.iter().any(|f| f.excerpt.contains(".mul_add(")));
    }

    #[test]
    fn debug_bounds_lint_fires_on_fixture() {
        let lx = fixture("bad_debug_bounds.rs");
        let t = Target {
            rule: "release-checked-bounds",
            file: "fixture",
            scope: Scope::WholeFile,
        };
        let hits = check_target("fixture", &lx, &t);
        assert_eq!(hits.len(), 2, "{hits:?}"); // single-line + multi-line
        // a debug_assert NOT about lengths stays legal
        assert!(!hits.iter().any(|f| f.line == 1));
    }

    #[test]
    fn allow_comment_suppresses_with_reason_only() {
        let lx = fixture("allowed_suppressions.rs");
        let t = Target {
            rule: "no-panic-in-serving",
            file: "fixture",
            scope: Scope::WholeFile,
        };
        // Both unwraps carry allows, but only one has a reason: exactly
        // the reasonless one still fires, plus the malformed-allow finding.
        let hits = check_target("fixture", &lx, &t);
        assert_eq!(hits.len(), 1, "{hits:?}");
        let allows = check_allow_comments("fixture", &lx);
        assert!(allows.iter().any(|f| f.excerpt.contains("without a reason")));
        assert!(allows.iter().any(|f| f.excerpt.contains("unknown rule")));
    }

    #[test]
    fn obs_recording_alloc_lint_fires_on_fixture() {
        let lx = fixture("bad_obs_recording.rs");
        let t = Target {
            rule: "no-alloc-in-hot-path",
            file: "fixture",
            scope: Scope::Funcs(&["push", "record", "record_span"]),
        };
        let hits = check_target("fixture", &lx, &t);
        assert!(
            hits.iter().any(|f| f.excerpt.contains(".collect()")),
            "expected the per-event collect() finding, got {hits:?}"
        );
        assert!(hits.iter().any(|f| f.excerpt.contains(".to_string()")));
        // snapshot() is an exporter outside the recording scope: its
        // to_vec() must NOT fire.
        assert!(!hits.iter().any(|f| f.excerpt.contains(".to_vec()")), "{hits:?}");
    }

    #[test]
    fn obs_recording_clock_lint_fires_on_fixture() {
        let lx = fixture("bad_obs_recording.rs");
        let t = Target {
            rule: "no-nondeterminism-in-identity-paths",
            file: "fixture",
            scope: Scope::Funcs(&["push", "record", "record_span"]),
        };
        let hits = check_target("fixture", &lx, &t);
        assert_eq!(hits.len(), 1, "{hits:?}"); // only record()'s Instant
        assert!(hits[0].excerpt.contains("Instant"));
    }

    #[test]
    fn funcs_scope_reports_missing_function() {
        let lx = lex("fn present() {}\n");
        let t = Target {
            rule: "no-alloc-in-hot-path",
            file: "fixture",
            scope: Scope::Funcs(&["present", "vanished"]),
        };
        let hits = check_target("fixture", &lx, &t);
        assert_eq!(hits.len(), 1);
        assert!(hits[0].excerpt.contains("vanished"));
    }

    /// The gate the whole PR hinges on: the lint surface is clean on the
    /// tree it lands in. Equivalent to `cargo xtask lint` exiting 0.
    #[test]
    fn current_tree_is_clean() {
        let findings = run(&repo_root()).unwrap();
        assert!(
            findings.is_empty(),
            "lints must pass on the landed tree:\n{}",
            findings
                .iter()
                .map(|f| f.to_string())
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn needle_edges_do_not_overmatch() {
        // unwrap_or / unwrap_or_else are fine; HashMapLike is not HashMap.
        let lx = lex("fn f() { let x = o.unwrap_or(3); let h: HashMapLike = g(); }\n");
        let hits = rules_hit(
            "f",
            &lx,
            &Target { rule: "no-panic-in-serving", file: "f", scope: Scope::WholeFile },
        );
        assert!(hits.is_empty(), "{hits:?}");
        let hits = rules_hit(
            "f",
            &lx,
            &Target {
                rule: "no-nondeterminism-in-identity-paths",
                file: "f",
                scope: Scope::WholeFile,
            },
        );
        assert!(hits.is_empty(), "{hits:?}");
    }
}
