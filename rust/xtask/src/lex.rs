//! A minimal Rust source lexer for the lint pass.
//!
//! We cannot vendor `syn` offline, and the lint rules only need to know
//! three things about a file: which bytes are *code* (not string/char
//! literals or comments), what each line's comments say (for the
//! `xtask-allow` escape hatch), and where function bodies and
//! `#[cfg(test)]` modules begin and end. A small state machine over the
//! raw characters covers all of that; it understands line/block (nested)
//! comments, string/byte-string/raw-string literals, char literals, and
//! lifetimes.
//!
//! The output preserves line structure: `code[i]` is line `i` with every
//! non-code region collapsed to a single space (so adjacent tokens never
//! fuse), and `comments[i]` is the concatenated comment text on line `i`.

/// Per-line split of a source file into code text and comment text.
pub struct LexedFile {
    /// Line text with literals and comments blanked out.
    pub code: Vec<String>,
    /// Comment text per line (without the `//` / `/*` markers).
    pub comments: Vec<String>,
}

impl LexedFile {
    /// Number of lines in the file.
    pub fn lines(&self) -> usize {
        self.code.len()
    }
}

/// True for characters that can continue an identifier.
pub fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into per-line code/comment text.
pub fn lex(src: &str) -> LexedFile {
    let cs: Vec<char> = src.chars().collect();
    let mut code = Vec::new();
    let mut comments = Vec::new();
    let mut cur_code = String::new();
    let mut cur_comment = String::new();
    let mut i = 0usize;

    macro_rules! newline {
        () => {{
            code.push(std::mem::take(&mut cur_code));
            comments.push(std::mem::take(&mut cur_comment));
        }};
    }

    while i < cs.len() {
        let c = cs[i];
        let next = cs.get(i + 1).copied();
        match c {
            '\n' => {
                newline!();
                i += 1;
            }
            '/' if next == Some('/') => {
                // Line comment: record its text, stop before the newline.
                i += 2;
                while i < cs.len() && cs[i] != '\n' {
                    cur_comment.push(cs[i]);
                    i += 1;
                }
                cur_code.push(' ');
            }
            '/' if next == Some('*') => {
                // Block comment, possibly nested; text still recorded per line.
                i += 2;
                let mut depth = 1u32;
                while i < cs.len() && depth > 0 {
                    if cs[i] == '/' && cs.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if cs[i] == '*' && cs.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else if cs[i] == '\n' {
                        newline!();
                        i += 1;
                    } else {
                        cur_comment.push(cs[i]);
                        i += 1;
                    }
                }
                cur_code.push(' ');
            }
            '"' => {
                i = skip_string(&cs, i + 1, &mut code, &mut comments, &mut cur_code, &mut cur_comment);
                cur_code.push(' ');
            }
            'r' | 'b' if raw_string_start(&cs, i).is_some() => {
                let (body_start, hashes) = raw_string_start(&cs, i).unwrap();
                i = skip_raw_string(&cs, body_start, hashes, &mut code, &mut comments, &mut cur_code, &mut cur_comment);
                cur_code.push(' ');
            }
            'b' if next == Some('"') => {
                i = skip_string(&cs, i + 2, &mut code, &mut comments, &mut cur_code, &mut cur_comment);
                cur_code.push(' ');
            }
            '\'' => {
                // Lifetime (`'a`, `'static`) vs char literal (`'a'`, `'\n'`).
                let after = cs.get(i + 2).copied();
                if next.map(|n| n.is_alphabetic() || n == '_') == Some(true) && after != Some('\'') {
                    // Lifetime: the tick is dropped, the name lexes as code.
                    cur_code.push(' ');
                    i += 1;
                } else {
                    i = skip_char_literal(&cs, i + 1);
                    cur_code.push(' ');
                }
            }
            _ => {
                cur_code.push(c);
                i += 1;
            }
        }
    }
    code.push(cur_code);
    comments.push(cur_comment);
    LexedFile { code, comments }
}

/// If `cs[i..]` starts a raw (byte) string like `r"`, `r#"`, `br##"`,
/// return `(index past the opening quote, hash count)`.
fn raw_string_start(cs: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
    }
    if cs.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if cs.get(j) == Some(&'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

fn skip_string(
    cs: &[char],
    mut i: usize,
    code: &mut Vec<String>,
    comments: &mut Vec<String>,
    cur_code: &mut String,
    cur_comment: &mut String,
) -> usize {
    while i < cs.len() {
        match cs[i] {
            '\\' => i += 2,
            '"' => return i + 1,
            '\n' => {
                code.push(std::mem::take(cur_code));
                comments.push(std::mem::take(cur_comment));
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

fn skip_raw_string(
    cs: &[char],
    mut i: usize,
    hashes: usize,
    code: &mut Vec<String>,
    comments: &mut Vec<String>,
    cur_code: &mut String,
    cur_comment: &mut String,
) -> usize {
    while i < cs.len() {
        if cs[i] == '"' && (1..=hashes).all(|k| cs.get(i + k) == Some(&'#')) {
            return i + 1 + hashes;
        }
        if cs[i] == '\n' {
            code.push(std::mem::take(cur_code));
            comments.push(std::mem::take(cur_comment));
        }
        i += 1;
    }
    i
}

/// Skip a char literal body starting just past the opening tick.
fn skip_char_literal(cs: &[char], mut i: usize) -> usize {
    if cs.get(i) == Some(&'\\') {
        i += 2; // escape marker plus the escaped char
        if cs.get(i.wrapping_sub(1)) == Some(&'{') || cs.get(i) == Some(&'{') {
            // `'\u{...}'`: consume through the closing brace.
            while i < cs.len() && cs[i] != '}' {
                i += 1;
            }
            i += 1;
        }
    } else if i < cs.len() {
        i += 1;
    }
    if cs.get(i) == Some(&'\'') {
        i + 1
    } else {
        i // malformed or actually a stray tick; resume lexing as code
    }
}

/// Line spans `[start, end]` (inclusive, 0-based) of `#[cfg(test)] mod`
/// blocks, so lint rules skip test code.
pub fn test_mod_spans(lexed: &LexedFile) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    for line in 0..lexed.lines() {
        if !lexed.code[line].contains("#[cfg(test)]") {
            continue;
        }
        // Scan forward over further attributes to the item; only `mod`
        // blocks are treated as spans (cfg(test) functions are rare and
        // would be caught as regular code otherwise).
        let mut j = line;
        let mut is_mod = false;
        while j < lexed.lines() {
            let t = lexed.code[j].trim();
            if contains_word(t, "mod") {
                is_mod = true;
                break;
            }
            if !t.is_empty() && !t.starts_with("#[") && j != line {
                break;
            }
            j += 1;
        }
        if !is_mod {
            continue;
        }
        if let Some((open_line, open_col)) = find_char_from(lexed, j, 0, '{') {
            if let Some(end) = match_brace(lexed, open_line, open_col) {
                spans.push((line, end));
            }
        }
    }
    spans
}

/// A function's name and body line span.
pub struct FnSpan {
    pub name: String,
    /// Inclusive line span covering the signature through the closing brace.
    pub start: usize,
    pub end: usize,
}

/// Locate every `fn name(...) { ... }` in the lexed file (including those
/// nested in impl blocks). Trait-declaration signatures ending in `;` are
/// skipped.
pub fn fn_spans(lexed: &LexedFile) -> Vec<FnSpan> {
    let mut spans = Vec::new();
    for line in 0..lexed.lines() {
        let text = &lexed.code[line];
        let mut from = 0usize;
        while let Some(pos) = find_word_from(text, "fn", from) {
            from = pos + 2;
            let rest: &str = &text[pos + 2..];
            let name: String = rest
                .trim_start()
                .chars()
                .take_while(|&c| is_ident_char(c))
                .collect();
            if name.is_empty() {
                continue;
            }
            // Find the body's opening brace, skipping over a `;` (trait
            // method declaration) if one comes first at depth zero.
            if let Some((open_line, open_col)) = find_body_open(lexed, line, pos + 2) {
                if let Some(end) = match_brace(lexed, open_line, open_col) {
                    spans.push(FnSpan { name, start: line, end });
                }
            }
        }
    }
    spans
}

/// Find a word with identifier boundaries.
pub fn contains_word(line: &str, word: &str) -> bool {
    find_word_from(line, word, 0).is_some()
}

/// Byte offset of `word` in `line` at identifier boundaries, from `from`.
pub fn find_word_from(line: &str, word: &str, from: usize) -> Option<usize> {
    let mut start = from.min(line.len());
    while let Some(rel) = line[start..].find(word) {
        let pos = start + rel;
        let before_ok = line[..pos].chars().next_back().map(is_ident_char) != Some(true);
        let after_ok = line[pos + word.len()..].chars().next().map(is_ident_char) != Some(true);
        if before_ok && after_ok {
            return Some(pos);
        }
        start = pos + word.len();
    }
    None
}

/// First occurrence of `ch` at or after `(line, col)`; returns (line, col).
fn find_char_from(lexed: &LexedFile, mut line: usize, mut col: usize, ch: char) -> Option<(usize, usize)> {
    while line < lexed.lines() {
        let text = &lexed.code[line];
        if let Some(rel) = text[col.min(text.len())..].find(ch) {
            return Some((line, col.min(text.len()) + rel));
        }
        line += 1;
        col = 0;
    }
    None
}

/// Find the opening brace of a fn body declared at `(line, col)`; stops at
/// a top-level `;` (no body). Parens in the signature are balanced so a
/// `{` inside a default-argument-like context cannot confuse it (closures
/// in signatures do not occur in this codebase).
fn find_body_open(lexed: &LexedFile, mut line: usize, mut col: usize) -> Option<(usize, usize)> {
    let mut paren = 0i32;
    let mut angle = 0i32;
    while line < lexed.lines() {
        let text: Vec<char> = lexed.code[line].chars().collect();
        // Work in char space; `col` below is a char index for this scan.
        let mut ci = lexed.code[line][..col.min(lexed.code[line].len())].chars().count();
        while ci < text.len() {
            match text[ci] {
                '(' => paren += 1,
                ')' => paren -= 1,
                '<' => angle += 1,
                '>' => angle = (angle - 1).max(0),
                ';' if paren == 0 => return None,
                '{' if paren == 0 && angle <= 0 => {
                    // Translate back to a byte column.
                    let byte_col = lexed.code[line]
                        .char_indices()
                        .nth(ci)
                        .map(|(b, _)| b)
                        .unwrap_or(0);
                    return Some((line, byte_col));
                }
                _ => {}
            }
            ci += 1;
        }
        line += 1;
        col = 0;
    }
    None
}

/// Match the brace opened at `(line, col)`; returns the closing line.
fn match_brace(lexed: &LexedFile, mut line: usize, col: usize) -> Option<usize> {
    let mut depth = 0i32;
    let mut first = true;
    let mut start_col = col;
    while line < lexed.lines() {
        for (b, c) in lexed.code[line].char_indices() {
            if first && b < start_col {
                continue;
            }
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(line);
                    }
                }
                _ => {}
            }
        }
        first = false;
        start_col = 0;
        line += 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let x = \"unwrap() inside\"; // comment .unwrap()\nlet y = 1;\n";
        let lx = lex(src);
        assert!(!lx.code[0].contains("unwrap"));
        assert!(lx.comments[0].contains(".unwrap()"));
        assert_eq!(lx.code[1].trim(), "let y = 1;");
    }

    #[test]
    fn raw_strings_and_chars_are_blanked() {
        let src = "let r = r#\"panic!(\"x\")\"#;\nlet c = '\"';\nlet l: &'static str = \"ok\";\n";
        let lx = lex(src);
        assert!(!lx.code[0].contains("panic"));
        assert!(!lx.code[1].contains('"'));
        assert!(lx.code[2].contains("static"));
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let src = "a /* one /* two */ still */ b\nc\n";
        let lx = lex(src);
        assert!(lx.code[0].contains('a') && lx.code[0].contains('b'));
        assert!(!lx.code[0].contains("one"));
        assert!(lx.comments[0].contains("two"));
    }

    #[test]
    fn fn_spans_cover_bodies() {
        let src = "fn foo() {\n    bar();\n}\n\nimpl T {\n    pub fn baz(&self) -> u8 {\n        1\n    }\n}\n";
        let lx = lex(src);
        let spans = fn_spans(&lx);
        let foo = spans.iter().find(|s| s.name == "foo").unwrap();
        assert_eq!((foo.start, foo.end), (0, 2));
        let baz = spans.iter().find(|s| s.name == "baz").unwrap();
        assert_eq!((baz.start, baz.end), (5, 7));
    }

    #[test]
    fn test_mod_spans_found() {
        let src = "fn live() {}\n\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let lx = lex(src);
        let spans = test_mod_spans(&lx);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].0, 2);
        assert_eq!(spans[0].1, 5);
    }

    #[test]
    fn generic_fn_signature_open_brace() {
        let src = "pub fn gen<T: Ord>(xs: &[T]) -> Option<&T> {\n    xs.first()\n}\n";
        let lx = lex(src);
        let spans = fn_spans(&lx);
        assert_eq!(spans.len(), 1);
        assert_eq!((spans[0].start, spans[0].end), (0, 2));
    }

    #[test]
    fn trait_decl_without_body_is_skipped() {
        let src = "trait T {\n    fn sig(&self) -> u8;\n    fn with_default(&self) -> u8 {\n        0\n    }\n}\n";
        let lx = lex(src);
        let spans = fn_spans(&lx);
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].name, "with_default");
    }
}
