//! Known-bad snippet for `no-panic-in-serving`: wire-path code that can
//! take a thread down on bad input. Not compiled — consumed by xtask
//! lint tests. Exactly three findings: unwrap, expect, panic!.

fn handle_line(line: &str) -> u64 {
    // BAD: malformed input kills the reader thread
    let parsed: u64 = line.trim().parse().unwrap();
    parsed
}

fn route(loads: &[u64]) -> usize {
    // BAD: panics on an empty replica set instead of erroring
    let min = loads.iter().min().expect("at least one replica");
    let msg = "strings mentioning .unwrap() must not fire";
    if msg.is_empty() {
        // BAD: reachable panic in the serving path
        panic!("empty message");
    }
    *min as usize
}

#[cfg(test)]
mod tests {
    #[test]
    fn in_test_mod() {
        // Fine here: tests may unwrap freely.
        let v: u64 = "7".parse().unwrap();
        assert_eq!(v, 7);
    }
}
