//! Known-bad snippet for `no-nondeterminism-in-identity-paths`: hash
//! iteration order, wall-clock time, and fused float ops feeding a
//! checksum. Not compiled — consumed by xtask lint tests.

use std::collections::HashMap;
use std::time::Instant;

fn content_checksum(pages: &HashMap<u64, Vec<u8>>) -> u64 {
    let mut h = 0u64;
    // BAD: HashMap iteration order differs run to run
    for (k, v) in pages {
        h = h.wrapping_mul(31).wrapping_add(k + v.len() as u64);
    }
    // BAD: wall-clock in an identity path
    let _t = Instant::now();
    // BAD: fma contracts differently across targets than mul-then-add
    let fused = (h as f32).mul_add(2.0, 1.0);
    h ^ fused as u64
}
