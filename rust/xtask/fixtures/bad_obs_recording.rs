//! Known-bad snippet for the obs-recording lint scopes: a trace-ring
//! `push` that allocates per event, and a `record` that reads a clock
//! type by name instead of going through the recorder epoch. Not
//! compiled — consumed by xtask lint tests.

fn push(&mut self, ev: TraceEvent) {
    // BAD: per-event allocation on the recording hot path
    let copy: Vec<TraceEvent> = self.buf.iter().copied().collect();
    self.buf = copy;
    // BAD: formatting allocates a String per event
    self.labels.push(ev.request_id.to_string());
}

fn record(&mut self, kind: EventKind, request_id: u64) {
    // BAD: naming the clock type here lets wall-clock state leak past
    // the recorder epoch into identity-adjacent code
    let t0 = Instant::now();
    self.ring_write(kind, request_id, t0);
}

fn record_span(&mut self, kind: EventKind) {
    // Clean: timestamps come from the epoch-relative helper, and the
    // write is an indexed store into the preallocated ring.
    let at_us = self.now_us();
    self.buf[self.head] = (kind, at_us);
}

fn snapshot(&self) -> Vec<TraceEvent> {
    // Fine here: exporters run off the hot path, OUTSIDE the scoped
    // recording functions, so the function-scoped rules must not flag it.
    self.buf.to_vec()
}
