// Known-bad snippet for `release-checked-bounds`. Line 1 carries a
// debug_assert that is NOT about lengths (legal); the two below vanish in
// release exactly where a truncated bitstream would read stale words.
fn kernel_entry(out: &mut [f32], codes: &[u16], width: u32) {
    debug_assert!(width <= 16);
    // BAD: bounds precondition only checked in debug builds
    debug_assert!(out.len() >= codes.len());
    // BAD: multi-line form, same problem
    debug_assert!(
        codes.len() * width as usize <= out.len() * 16,
        "stream truncated"
    );
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32;
    }
}
