//! Known-bad snippet for `no-alloc-in-hot-path`: a decode-stage function
//! that allocates per call. Not compiled — consumed by xtask lint tests.

fn decode_tile(codes: &[u16]) -> Vec<f32> {
    // BAD: fresh buffer every tile tick
    let mut out = Vec::new();
    out.extend(codes.iter().map(|&c| c as f32));
    // BAD: iterator collect in the hot body
    let doubled: Vec<f32> = out.iter().map(|v| v * 2.0).collect();
    doubled
}

fn grow_scratch(scratch: &mut Vec<f32>, elems: usize) {
    // Fine here: this helper is the grow-once scratch path, OUTSIDE the
    // scoped hot function, so the function-scoped rule must not flag it.
    if scratch.len() < elems {
        scratch.resize(elems, 0.0);
    }
    let _tmp: Vec<u8> = Vec::new();
}
