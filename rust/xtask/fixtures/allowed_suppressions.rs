//! Escape-hatch fixture: allows with and without reasons, plus a typo'd
//! rule name. Not compiled — consumed by xtask lint tests.

fn checked_invariant(slots: &[Option<u64>], i: usize) -> u64 {
    // xtask-allow(no-panic-in-serving): slot occupancy was established by the caller's scan one line up
    let a = slots[i].unwrap();
    // xtask-allow(no-panic-in-serving)
    let b = slots[i].unwrap();
    // xtask-allow(no-such-rule): typo'd rule names must be reported
    a + b
}
