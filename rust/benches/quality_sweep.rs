//! Quality sweep: the paper's full sensitivity loop, artifact-free — the
//! tentpole behind `BENCH_quality_sweep.json`.
//!
//! Three phases, all on the deterministic sim backend (no PJRT artifacts
//! anywhere):
//!
//! 1. **Sweep** — run the §4.4 layer-group sensitivity sweep on an 8-layer
//!    sim harness (groups of 2 → 4 groups) and time one full sweep.
//! 2. **Pick** — boost the most-sensitive half of the groups (lowest
//!    single-boost ΔPPL). Boosting 4 of 8 layers to (256,128) puts Eq. 1
//!    angle bits at 3.25 + 0.5·0.5 = 3.5 — inside the abstract's
//!    3.28–3.67 b/elem range, which the bench asserts.
//! 3. **Serve** — run the chosen schedule through a full `Engine` pass and
//!    compare the ACHIEVED bits-per-element from `MemoryStats` (exact
//!    packed bits over stored elements, sampled at peak cache occupancy)
//!    against `QuantConfig::bits_per_element()`: they must agree within 1%
//!    (exactly, for power-of-two codebooks).
//!
//! Quant flags are the shared [`QuantSpec`] set (`--nk`, `--boost-layers`,
//! `--norms`, …): the served schedule defaults to the sweep's pick but any
//! flag overrides it, so `--boost-layers 0,1` serves exactly what
//! `turboangle serve --sim --boost-layers 0,1` would.
//!
//! JSON summary fields are documented in docs/BENCH_GLOSSARY.md.
//!
//!     cargo bench --bench quality_sweep [-- --smoke]

use std::time::Duration;
use turboangle::coordinator::{Engine, EngineConfig, MemoryStats};
use turboangle::eval::{sensitivity, PplHarness};
use turboangle::quant::{QuantConfig, QuantSpec};
use turboangle::runtime::SimExecutor;
use turboangle::util::bench::{bench, black_box, JsonReport};
use turboangle::util::cli::Args;
use turboangle::workload::{self, WorkloadSpec};

const OUT_JSON: &str = "BENCH_quality_sweep.json";
const SIM_LAYERS: usize = 8;
const GROUP_SIZE: usize = 2;
const D_HEAD: usize = 8;

/// The one sim "model" every phase shares (seed 1, 8 layers — deep enough
/// that boost schedules differ layer to layer).
fn sim_exec() -> SimExecutor {
    SimExecutor::with_dims(1, SIM_LAYERS, 2, D_HEAD, 4, 32, 64)
}

fn wspec(n_requests: usize) -> WorkloadSpec {
    WorkloadSpec {
        n_requests,
        prompt_min: 8,
        prompt_max: 24,
        gen_min: 4,
        gen_max: 8,
        seed: 11,
        ..Default::default()
    }
}

/// Submit + drain one workload pass, tracking the peak-occupancy memory
/// snapshot (stats at completion are empty — sequences free on finish).
fn serve_pass(
    engine: &mut Engine<SimExecutor>,
    n_requests: usize,
    pass: u64,
    peak: &mut MemoryStats,
) -> usize {
    for mut req in workload::generate(&wspec(n_requests)) {
        req.id += pass * 1_000_000;
        engine.submit(req);
    }
    while engine.has_work() {
        engine.tick().expect("engine tick");
        let st = engine.memory_stats();
        if st.stored_elements > peak.stored_elements {
            *peak = st;
        }
    }
    engine.take_finished().len()
}

fn main() {
    let args = Args::parse(std::env::args().skip(1)).expect("bench flags");
    let smoke = args.get_bool("smoke");
    let mut spec = QuantSpec::from_args(&args, "k8v4log").expect("quant flags");
    let budget = if smoke {
        Duration::from_millis(60)
    } else {
        Duration::from_millis(600)
    };
    let n_requests = if smoke { 8 } else { 24 };
    println!(
        "== quality sweep: {SIM_LAYERS}-layer sim, groups of {GROUP_SIZE}, \
         {n_requests} requests/pass =="
    );

    // -- phase 1: the sensitivity sweep (artifact-free) ------------------
    let h = PplHarness::sim(sim_exec()).expect("sim harness");
    let report = sensitivity::layer_group_sweep(&h, GROUP_SIZE).expect("sweep");
    let sweep_evals = *h.evals_run.borrow();
    for row in &report.singles {
        println!(
            "  {}: layers {}..={}  dPPL {:+.4}",
            row.group, row.layers.0, row.layers.1, row.delta_ppl
        );
    }
    let r_sweep = bench("layer-group sensitivity sweep (sim)", budget, || {
        let h = PplHarness::sim(sim_exec()).expect("sim harness");
        let rep = sensitivity::layer_group_sweep(&h, GROUP_SIZE).expect("sweep");
        black_box(rep.singles.len());
    });
    println!("{}", r_sweep.line(Some((sweep_evals as f64, "eval"))));

    // -- phase 2: pick the boosted set (most-sensitive half) -------------
    let mut ranked: Vec<_> = report.singles.iter().collect();
    ranked.sort_by(|a, b| a.delta_ppl.total_cmp(&b.delta_ppl));
    let picked = &ranked[..report.singles.len() / 2];
    let mut layers: Vec<usize> = picked
        .iter()
        .flat_map(|r| r.layers.0..=r.layers.1)
        .collect();
    layers.sort_unstable();
    let best_groups: Vec<&str> = picked.iter().map(|r| r.group.as_str()).collect();
    let boosted_delta = h
        .delta_ppl(&QuantConfig::selective_boost(SIM_LAYERS, &layers, 256, 128))
        .expect("boosted eval");
    println!(
        "picked {} -> boost layers {layers:?}  dPPL {boosted_delta:+.4} \
         (uniform {:+.4})",
        best_groups.join("+"),
        report.uniform_delta
    );
    assert!(
        boosted_delta < report.uniform_delta,
        "sweep-picked boost must beat uniform: {boosted_delta} vs {}",
        report.uniform_delta
    );

    // the served schedule: sweep pick unless the user passed a boost flag
    let used_default_schedule = spec.boost_layers.is_none() && spec.n_early == 0;
    if used_default_schedule {
        spec.boost_layers = Some(layers.clone());
    }
    let cfg = spec.build(SIM_LAYERS).expect("boost schedule");
    let uniform_cfg = {
        let mut s = spec.clone();
        s.boost_layers = None;
        s.n_early = 0;
        s.build(SIM_LAYERS).expect("uniform schedule")
    };
    let eq1 = cfg.angle_bits_per_element();
    let eq3 = cfg.bits_per_element(D_HEAD);
    println!("serving {} ({eq1:.3} angle, {eq3:.3} total b/elem)", cfg.tag());
    if used_default_schedule {
        // the abstract's operating range for boosted angle schedules
        assert!(
            (3.28..=3.67).contains(&eq1),
            "default schedule angle bits {eq1} outside the paper's 3.28-3.67"
        );
    }

    // -- phase 3: serve the schedule, verify the achieved rate -----------
    let mut boosted_engine = Engine::new(sim_exec(), EngineConfig::new(cfg.clone()));
    let mut uniform_engine = Engine::new(sim_exec(), EngineConfig::new(uniform_cfg));
    let mut peak = MemoryStats::default();
    let mut pass = 0u64;
    let r_boost = bench("serve pass (sweep-boosted schedule)", budget, || {
        let done = serve_pass(&mut boosted_engine, n_requests, pass, &mut peak);
        pass += 1;
        black_box(done);
    });
    println!("{}", r_boost.line(Some((n_requests as f64, "req"))));
    let mut upeak = MemoryStats::default();
    let mut upass = 0u64;
    let r_uniform = bench("serve pass (uniform base schedule)", budget, || {
        let done = serve_pass(&mut uniform_engine, n_requests, upass, &mut upeak);
        upass += 1;
        black_box(done);
    });
    println!("{}", r_uniform.line(Some((n_requests as f64, "req"))));

    assert!(peak.stored_elements > 0, "serve pass stored nothing");
    let achieved = peak.total_bits_per_element();
    let rel_err = (achieved - eq3).abs() / eq3;
    println!(
        "achieved rate: {achieved:.4} b/elem ({:.4} angle + {:.4} norm) vs \
         Eq.3 {eq3:.4} — rel err {:.2e}",
        peak.angle_bits_per_element(),
        peak.norm_bits_per_element(),
        rel_err
    );
    let pow2 = cfg
        .layers
        .iter()
        .all(|b| b.n_k.is_power_of_two() && b.n_v.is_power_of_two());
    if pow2 {
        // acceptance criterion: stored bits match the paper accounting
        assert!(
            rel_err <= 0.01,
            "achieved {achieved} vs Eq.3 {eq3}: rel err {rel_err} > 1%"
        );
    } else {
        println!("(non-power-of-two codebooks: packed width exceeds log2(n); 1% gate skipped)");
    }

    // -- report ----------------------------------------------------------
    let mut rep = JsonReport::new();
    rep.summary("smoke", if smoke { 1.0 } else { 0.0 });
    rep.summary("sim_layers", SIM_LAYERS);
    rep.summary("group_size", GROUP_SIZE);
    rep.summary("d_head", D_HEAD);
    rep.summary("requests_per_pass", n_requests);
    rep.summary("sweep_evals", sweep_evals);
    rep.summary("uniform_delta_ppl", report.uniform_delta);
    rep.summary("boosted_delta_ppl", boosted_delta);
    rep.summary("best_groups", best_groups.join("+").as_str());
    rep.summary(
        "boosted_layers",
        layers
            .iter()
            .map(|l| l.to_string())
            .collect::<Vec<_>>()
            .join(",")
            .as_str(),
    );
    rep.summary("served_tag", cfg.tag().as_str());
    rep.summary("eq1_angle_bits", eq1);
    rep.summary("eq3_total_bits", eq3);
    rep.summary("achieved_angle_bits", peak.angle_bits_per_element());
    rep.summary("achieved_norm_bits", peak.norm_bits_per_element());
    rep.summary("achieved_total_bits", achieved);
    rep.summary("rate_rel_err", rel_err);
    rep.summary("compression_ratio", peak.compression_ratio());
    let boost_tput = r_boost.throughput(n_requests as f64);
    let uniform_tput = r_uniform.throughput(n_requests as f64);
    rep.summary("serve_req_per_s_boosted", boost_tput);
    rep.summary("serve_req_per_s_uniform", uniform_tput);
    rep.summary("boost_serve_overhead", uniform_tput / boost_tput);
    rep.push(
        &r_sweep,
        sweep_evals as f64,
        "eval",
        &[("op", "sensitivity_sweep".into()), ("mode", "sim".into())],
    );
    rep.push(
        &r_boost,
        n_requests as f64,
        "req",
        &[("op", "serve_pass".into()), ("mode", "boosted".into())],
    );
    rep.push(
        &r_uniform,
        n_requests as f64,
        "req",
        &[("op", "serve_pass".into()), ("mode", "uniform".into())],
    );
    rep.write(OUT_JSON).expect("write bench json");
    println!("wrote {OUT_JSON}");
}
