//! Regenerates paper Table 5 (norm quantization: fp32 vs norm8 vs
//! K8V4-log on top of each model's best per-layer config) and the §3.3
//! K-vs-V norm-sensitivity claim (K4 is catastrophic, V4-log is fine).
//!
//!     cargo bench --bench table5_norm_quant   (TA_MODELS=a,b to restrict)

use turboangle::eval::{sweep, PplHarness};
use turboangle::quant::NormMode;
use turboangle::report;
use turboangle::runtime::{Entry, Manifest, ModelExecutor, Runtime};

const ALL: [&str; 7] = [
    "tinyllama-sim",
    "mistral-sim",
    "smollm2-sim",
    "phi15-sim",
    "stablelm2-sim",
    "starcoder2-sim",
    "olmo-sim",
];

fn main() -> anyhow::Result<()> {
    let models: Vec<String> = std::env::var("TA_MODELS")
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect())
        .unwrap_or_else(|_| ALL.iter().map(|s| s.to_string()).collect());
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    let mut rows = Vec::new();
    let t0 = std::time::Instant::now();
    for model in &models {
        let exec = ModelExecutor::load(&rt, &manifest, model, Entry::Eval)?;
        let h = PplHarness::new(&manifest, exec)?;
        let best = sweep::early_boost_sweep(&h, model)?.best_cfg;
        rows.push(sweep::table5(&h, model, &best)?);
        eprintln!("{model} done ({} evals)", h.evals_run.borrow());
        // §3.3 asymmetry probe on one representative model
        if model == "mistral-sim" {
            let k4 = best
                .clone()
                .with_norms(NormMode { bits: 4, log_space: false }, NormMode::LOG4);
            let k4_delta = h.delta_ppl(&k4)?;
            let k8v4 = h.delta_ppl(&best.clone().with_k8v4_log())?;
            println!(
                "K-norm sensitivity ({model}): K4V4-log dPPL {k4_delta:+.4} vs K8V4-log {k8v4:+.4} ({}x worse)",
                if k8v4.abs() > 1e-9 { format!("{:.0}", k4_delta / k8v4) } else { "inf".into() }
            );
        }
    }
    println!("{}", report::table5(&rows));
    println!("total wall {:?}", t0.elapsed());
    Ok(())
}
