//! Ablation (DESIGN.md §6): the paper's Alg.-1 reconstruction uses the bin
//! LEFT edge (`r·cos(2πk/n)`), which carries a systematic half-bin bias.
//! This bench compares left-edge vs centered `(k+0.5)` reconstruction at
//! matched bit rates — both as raw MSE and as end-to-end ΔPPL.
//!
//!     cargo bench --bench ablation_centered

use turboangle::eval::PplHarness;
use turboangle::quant::{angle, fwht, Mode, QuantConfig};
use turboangle::runtime::{Entry, Manifest, ModelExecutor, Runtime};
use turboangle::util::prop::Gen;

fn main() -> anyhow::Result<()> {
    // 1) raw reconstruction error
    println!("== raw MSE, 4096 gaussian rows ==");
    for d in [64usize, 128] {
        let sign = fwht::test_sign_diag(d, 3);
        let mut g = Gen::new(5);
        for n in [32u32, 64, 128] {
            let (mut mse_l, mut mse_c) = (0.0f64, 0.0f64);
            let rows = 4096;
            for _ in 0..rows {
                let x = g.f32_vec(d, -3.0, 3.0);
                let xl = angle::quant_dequant(&x, &sign, n, false);
                let xc = angle::quant_dequant(&x, &sign, n, true);
                for i in 0..d {
                    mse_l += ((x[i] - xl[i]) as f64).powi(2);
                    mse_c += ((x[i] - xc[i]) as f64).powi(2);
                }
            }
            mse_l /= (rows * d) as f64;
            mse_c /= (rows * d) as f64;
            println!(
                "d={d} n={n:3}: left {mse_l:.6}  centered {mse_c:.6}  (left/centered {:.2}x)",
                mse_l / mse_c
            );
        }
    }

    // 2) end-to-end ΔPPL at the uniform baseline
    println!("\n== end-to-end dPPL (uniform K128V64) ==");
    let manifest = Manifest::discover()?;
    let rt = Runtime::cpu()?;
    for model in ["mistral-sim", "tinyllama-sim"] {
        let exec = ModelExecutor::load(&rt, &manifest, model, Entry::Eval)?;
        let h = PplHarness::new(&manifest, exec)?;
        let l = h.n_layers();
        let left = h.delta_ppl(&QuantConfig::paper_uniform(l))?;
        let mut cfg = QuantConfig::paper_uniform(l);
        cfg.mode = Mode::AngleCentered;
        let centered = h.delta_ppl(&cfg)?;
        println!("{model:16} left {left:+.4}  centered {centered:+.4}");
    }
    println!("\n(theory: centered halves the worst-case angular error; the paper's\n left-edge choice costs ~4x in MSE at matched bits)");
    Ok(())
}
